"""End-to-end loop: federated training feeding a personalized serving
engine with round-boundary hot-swaps.

    PYTHONPATH=src python examples/personalized_serving.py [--small]

4 clients train a scaled-down gemma on topic-skewed token streams with
FedaGrac (flat layout).  After the first training leg the simulation
publishes a versioned snapshot — the `(P,)` flat master plus the `(M, P)`
ν⁽ⁱ⁾ calibration rows — to disk (checkpoint/serialize.py).  A
``PersonalizedServeEngine`` serves a mixed-client request stream against
it: every ``Request.client_id`` resolves to base + ν-derived delta at
admission, so all four clients' personalized views batch into the same
decode ticks.  Training then continues; the second snapshot hot-swaps in
MID-STREAM while a long request is still decoding — that request drains
under the old version (its pinned row and KV cache predate the swap),
new admissions see the new weights, and each completion records the
version that served it.

This is the loop the ROADMAP calls the north star's serving half:
training output consumed, not just measured.
"""
import argparse
import dataclasses
import functools
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import FedConfig, reduced
from repro.configs.registry import get_arch
from repro.data import LMFederatedBatcher, lm_sequences
from repro.fed import FederatedSimulation
from repro.models import model as M
from repro.serving import (LoadGen, PersonalizedServeEngine, latency_stats,
                           load_snapshot, replay)

MCLIENTS = 4


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="1-layer reduced model (CI budget)")
    ap.add_argument("--rounds", type=int, default=4,
                    help="rounds per training leg (two legs total)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--personalizer", default="nu",
                    choices=("none", "nu", "lowrank"))
    args = ap.parse_args()

    cfg = reduced(get_arch("gemma-2b"),
                  n_layers=1 if args.small else 2,
                  d_model=32 if args.small else 128)
    cfg = dataclasses.replace(cfg, vocab=128 if args.small else 256)
    seq = 16 if args.small else 32

    key = jax.random.PRNGKey(0)
    streams = [lm_sequences(jax.random.fold_in(key, i), 64, seq,
                            cfg.vocab, skew_topic=i)
               for i in range(MCLIENTS)]
    fed = FedConfig(algorithm="fedagrac", n_clients=MCLIENTS, k_mean=2,
                    k_var=0.0, lr=0.1, calibration_rate=0.5,
                    param_layout="flat")
    sim = FederatedSimulation(
        functools.partial(M.lm_loss, cfg=cfg),
        M.init_params(key, cfg), fed,
        LMFederatedBatcher(streams, batch_size=4))

    print(f"model: gemma-family {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab}; P = {sim.flat_spec.p}")

    with tempfile.TemporaryDirectory() as tmp:
        # ---- leg 1: train, publish v_r to disk --------------------------
        t0 = time.time()
        sim.run(args.rounds, eval_every=args.rounds)
        p1 = os.path.join(tmp, "snap1.msgpack")
        sim.save_snapshot(p1)
        print(f"leg 1: {args.rounds} rounds in {time.time() - t0:.1f}s → "
              f"published v{args.rounds} ({os.path.getsize(p1)} bytes)")

        # ---- serve a mixed-client stream against it ---------------------
        eng = PersonalizedServeEngine(
            cfg, sim.flat_spec, load_snapshot(p1),
            personalizer=args.personalizer, slots=4, max_len=64,
            prefill_buckets=(8, 16))
        gen = LoadGen(population=MCLIENTS, rate=0.8, prompt_len=(3, 8),
                      max_new=(3, 6), vocab=cfg.vocab, seed=1)
        stats = replay(eng, gen.generate(args.requests))
        lat = latency_stats(stats["tick_wall"])
        print(f"served {stats['n_requests']} requests from "
              f"{MCLIENTS} clients: {stats['requests_per_s']:.1f} req/s, "
              f"tick p50 {lat['p50'] * 1e3:.1f} ms / "
              f"p99 {lat['p99'] * 1e3:.1f} ms, "
              f"utilization {stats['mean_utilization']:.2f}")

        # ---- leg 2: train more, hot-swap MID-STREAM ---------------------
        sim.run(args.rounds, eval_every=args.rounds)
        p2 = os.path.join(tmp, "snap2.msgpack")
        sim.save_snapshot(p2)
        v1, v2 = args.rounds, 2 * args.rounds
        print(f"leg 2: published v{v2}; swapping mid-stream…")

        rng = np.random.default_rng(7)
        from repro.serving import Request
        long_req = Request(uid=10_000,
                           prompt=rng.integers(1, cfg.vocab, 6).astype(
                               np.int32),
                           max_new_tokens=12, client_id=0)
        eng.submit(long_req)
        for _ in range(3):
            eng.step()                       # long_req is mid-decode
        eng.swap(load_snapshot(p2))          # between ticks
        stats2 = replay(eng, gen.generate(args.requests // 2))
        by_ver = {}
        for c in stats2["completions"]:
            by_ver.setdefault(c.version, 0)
            by_ver[c.version] += 1
        print(f"post-swap drain: completions per version {by_ver}")

        versions = set(by_ver)
        assert versions == {v1, v2}, (
            f"expected in-flight v{v1} + fresh v{v2}, got {versions}")
        pre = next(c for c in stats2["completions"] if c.uid == 10_000)
        assert pre.version == v1, "in-flight request must keep its version"
        assert len(pre.tokens) == 12
        print(f"OK — in-flight request drained under v{v1} while new "
              f"admissions served v{v2}")


if __name__ == "__main__":
    main()
