"""Quickstart: FedaGrac vs FedAvg/FedNova under step asynchronism.

    PYTHONPATH=src python examples/quickstart.py

10 clients on the FedProx synthetic(1,1) non-IID task; 9 clients run K=2
local steps per round, one (the "GPU client") runs K=200 — the paper's
bimodal step-asynchronism regime.  FedaGrac converts the fast client's
extra work into convergence speed; FedAvg and FedNova cannot.
"""
import jax.numpy as jnp
import numpy as np

import jax
from repro.configs.base import FedConfig
from repro.data import FederatedBatcher, fedprox_synthetic
from repro.fed import FederatedSimulation
from repro.models.simple import lr_accuracy, lr_loss

M, T = 10, 40


def main() -> None:
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    eval_fn = lambda p: float(lr_accuracy(p, {"x": data.x, "y": data.y}))
    ks = np.full((1, M), 2, np.int32)
    ks[0, -1] = 200                       # one fast client

    print(f"{'algorithm':12s} {'rounds→77%':>11s} {'final acc':>10s}")
    for algo in ("fedavg", "fednova", "fedagrac"):
        batcher = FederatedBatcher(data, parts, batch_size=20)
        fed = FedConfig(algorithm=algo, n_clients=M, lr=0.02,
                        calibration_rate=1.0, weights="data")
        params = {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}
        sim = FederatedSimulation(lr_loss, params, fed, batcher,
                                  eval_fn=eval_fn, k_schedule=ks)
        hist = sim.run(T)
        r = hist.rounds_to_target(0.77)
        print(f"{algo:12s} {str(r) if r else f'>{T}':>11s} "
              f"{hist.metric[-1]:>10.4f}")
    print("\nFedaGrac exploits the fast client's 100× local work; "
          "FedAvg drifts and FedNova normalizes it away (paper Table 2).")


if __name__ == "__main__":
    main()
