"""Continuous-batching serving: ragged requests through one cache pool.

    PYTHONPATH=src python examples/continuous_batching.py

Eight requests with different prompt/generation lengths stream through a
3-slot engine: prompts prefill into free slots (bucketed), every tick
decodes one token for all live slots in a single batched call, finished
requests free their slot immediately.  Output tokens are bit-identical to
per-request greedy decoding (tests/test_serving_engine.py).
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import get_arch
from repro.models import model as M
from repro.serving import Request, ServeEngine


def main() -> None:
    cfg = reduced(get_arch("llama3-8b"), n_layers=2, d_model=128)
    cfg = dataclasses.replace(cfg, vocab=1024)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab, size=int(n)).astype(
                        np.int32),
                    max_new_tokens=int(m))
            for i, (n, m) in enumerate(
                [(5, 12), (30, 4), (12, 20), (8, 6),
                 (28, 10), (3, 16), (17, 8), (22, 5)])]

    eng = ServeEngine(cfg, params, slots=3, max_len=128,
                      prefill_buckets=(8, 16, 32))
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total = sum(len(c.tokens) for c in done)
    print(f"{'uid':>4} {'prompt':>7} {'new':>4} {'ticks':>6}   first tokens")
    for c in sorted(done, key=lambda c: c.uid):
        print(f"{c.uid:>4} {c.prompt_len:>7} {len(c.tokens):>4} "
              f"{c.ticks:>6}   {c.tokens[:6]}")
    print(f"\n{len(done)} requests, {total} tokens, {eng.ticks} engine ticks "
          f"({total / max(eng.ticks, 1):.2f} tokens/tick vs 1.0 sequential) "
          f"in {dt:.1f}s")
    assert len(done) == len(reqs)
    assert total / max(eng.ticks, 1) > 1.2, "batching should beat sequential"


if __name__ == "__main__":
    main()
