"""Theorem 1 in pictures (ASCII): the FedAvg round map walks to a fixed
point that is NOT the optimum; FedaGrac walks to the optimum.

    PYTHONPATH=src python examples/objective_inconsistency.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import rounds, theory
from repro.core.fedopt import get_algorithm
from repro.data.synthetic import quadratic_clients
from repro.models.simple import quad_loss

M, D, LR = 8, 12, 0.02
K = np.array([1, 1, 2, 2, 4, 4, 8, 20], np.int32)
W = np.full(M, 1.0 / M, np.float32)


def trajectory(algo_name, lam, As, bs, t=200):
    fed = FedConfig(algorithm=algo_name, n_clients=M, lr=LR,
                    calibration_rate=lam)
    algo = get_algorithm(algo_name, fed)
    k_max = int(K.max())
    state = rounds.init_state({"x": jnp.zeros((D,))}, M, algo)
    fn = jax.jit(rounds.make_round(quad_loss, algo, lr=LR, k_max=k_max))
    batches = {
        "A": jnp.broadcast_to(jnp.asarray(As)[:, None], (M, k_max, D, D)),
        "b": jnp.broadcast_to(jnp.asarray(bs)[:, None], (M, k_max, D)),
        "c0": jnp.zeros((M, k_max)),
    }
    xs = []
    for _ in range(t):
        state, _ = fn(state, batches, jnp.asarray(K), jnp.asarray(W))
        xs.append(np.asarray(state["params"]["x"]))
    return xs


def main() -> None:
    As, bs = quadratic_clients(jax.random.PRNGKey(0), M, D, hetero=1.5)
    x_star = theory.global_optimum(As, bs, W)
    fp = theory.fedavg_fixed_point(As, bs, W, K, LR)
    print(f"Theorem-1 RHS (inconsistency bound): "
          f"{theory.objective_inconsistency_rhs(As, bs, W, K, x_star):.3f}")
    print(f"closed-form FedAvg fixed point is "
          f"{np.linalg.norm(fp - x_star):.3f} away from x*\n")
    print(f"{'round':>6} {'FedAvg → x*':>14} {'FedaGrac → x*':>14}")
    tr_avg = trajectory("fedavg", 0.0, As, bs)
    tr_grac = trajectory("fedagrac", 1.0, As, bs)
    for t in (0, 4, 9, 24, 49, 99, 199):
        da = np.linalg.norm(tr_avg[t] - x_star)
        dg = np.linalg.norm(tr_grac[t] - x_star)
        bar_a = "#" * int(20 * da / max(np.linalg.norm(tr_avg[0] - x_star),
                                       1e-9))
        print(f"{t + 1:>6} {da:>14.6f} {dg:>14.6f}   {bar_a}")
    print(f"\nFedAvg stalled at its fixed point "
          f"(dist {np.linalg.norm(tr_avg[-1] - fp):.2e} from closed form); "
          f"FedaGrac reached x*.")


if __name__ == "__main__":
    main()
