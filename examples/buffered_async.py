"""Buffered semi-asynchronous FedaGrac: one config switch away from sync.

    PYTHONPATH=src python examples/buffered_async.py

The same 10-client non-IID task as quickstart.py, but on a heterogeneous
*hardware* fleet (lognormal step rates): the synchronous engine pays the
straggler every round, while the buffered engine (FedConfig.buffer_size)
updates on the first M' reports and discounts stale ones (FedConfig.
staleness).  Both engines run the identical client-update / orientation
stages (core/stages.py) — with buffer_size = M and equal speeds the async
engine IS the synchronous one, reproduced below to machine precision.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.data import FederatedBatcher, fedprox_synthetic
from repro.fed import BufferedAsyncSimulation, FederatedSimulation
from repro.fed.clock import make_clock
from repro.models.simple import lr_accuracy, lr_loss

M, T = 10, 25


def main() -> None:
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    eval_fn = lambda p: float(lr_accuracy(p, {"x": data.x, "y": data.y}))
    params = {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}
    ks = np.full((T * M + 1, M), 40, np.int32)
    fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.02,
                    calibration_rate=1.0, weights="data")

    def batcher():
        return FederatedBatcher(data, parts, batch_size=20)

    # -- 1. buffer = M + equal speeds reproduces the synchronous engine -----
    sync = FederatedSimulation(lr_loss, params, fed, batcher(),
                               eval_fn=eval_fn, k_schedule=ks)
    h_sync = sync.run(T)
    full = BufferedAsyncSimulation(
        lr_loss, params,
        dataclasses.replace(fed, buffer_size=M, speed_dist="fixed"),
        batcher(), eval_fn=eval_fn, k_schedule=ks)
    h_full = full.run(T)
    drift = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(sync.params), jax.tree.leaves(full.params)))
    print(f"buffer=M vs synchronous: max |Δparam| = {drift:.2e}  "
          f"acc {h_sync.metric[-1]:.4f} vs {h_full.metric[-1]:.4f}")

    # -- 2. heterogeneous fleet: straggler-bound sync vs buffered async -----
    clock = make_clock(M, dist="lognormal", sigma=1.0, seed=7)
    sync_s = clock.round_time(ks[0]) * T            # straggler every round
    # λ halved under staleness: full-strength calibration against a stale ν
    # misorients clients (EXPERIMENTS.md, sync-vs-async table)
    buf = BufferedAsyncSimulation(
        lr_loss, params,
        dataclasses.replace(fed, buffer_size=4 * M // 5, staleness="hinge",
                            staleness_a=0.5, staleness_b=2,
                            calibration_rate=0.5),
        batcher(), eval_fn=eval_fn, k_schedule=ks, clock=clock)
    h_buf = buf.run(3 * T)          # straggler idle time buys extra updates
    print(f"\n{'engine':24s} {'server upd':>10s} {'sim seconds':>12s} "
          f"{'final acc':>10s} {'mean stale':>10s}")
    print(f"{'synchronous':24s} {T:>10d} {sync_s:>12.1f} "
          f"{h_sync.metric[-1]:>10.4f} {0.0:>10.1f}")
    print(f"{'buffered (0.8M, hinge)':24s} {len(h_buf.loss):>10d} "
          f"{h_buf.sim_time[-1]:>12.1f} {h_buf.metric[-1]:>10.4f} "
          f"{np.mean(h_buf.staleness):>10.1f}")
    print("\nThe buffered engine never waits for the straggler: within the "
          "synchronous run's wall-clock it fits 3x the server updates and "
          "ends higher (benchmarks/table_async.py for the full comparison).")


if __name__ == "__main__":
    main()
