"""Failure scenarios: mid-round dropout with partial-work recovery
(DESIGN.md §12) — a dropout-rate sweep over FedAvg, FedNova, and FedaGrac.

    PYTHONPATH=src python examples/failure_scenarios.py

The quickstart task under faults: M = 16 clients on the FedProx
synthetic(1,1) non-IID mixture, heterogeneous local steps K_i ~ N(8, 3²),
and the ``dropout`` scenario aborting each (round, client) independently
with probability p.  An aborted client is NOT discarded: it delivers the
k′-step prefix it completed before dying, the client-update mask computes
exactly that prefix, and FedNova-style normalization aggregates it at its
k′ step count — so losing part of the work loses mass, never direction.
The sweep shows graceful degradation: even at p = 0.6 (over half of all
client rounds aborted mid-flight) accuracy moves only marginally — no
cliff — and FedaGrac's calibration (computed from the delivered prefixes)
keeps its advantage at every dropout rate.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.data import DeviceBatcher, fedprox_synthetic
from repro.fed import FederatedSimulation
from repro.models.simple import lr_accuracy, lr_loss

M, T_ROUNDS = 16, 10
RATES = (0.0, 0.3, 0.6)
ALGORITHMS = ("fedavg", "fednova", "fedagrac")


def main() -> None:
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0,
                                    n_per_client=50)
    eval_fn = lambda p: float(lr_accuracy(p, {"x": data.x, "y": data.y}))

    print(f"{'algorithm':10s} " + " ".join(
        f"{'p=' + format(p, '.1f'):>10s}" for p in RATES)
        + f" {'dropped':>8s}")
    dropped = {}
    for algorithm in ALGORITHMS:
        accs = []
        for rate in RATES:
            fed = FedConfig(algorithm=algorithm, n_clients=M, lr=0.05,
                            calibration_rate=0.5, weights="data",
                            k_mean=8, k_var=3.0, k_mode="random",
                            scenario="baseline" if rate == 0 else "dropout",
                            dropout_rate=rate)
            params = {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}
            sim = FederatedSimulation(lr_loss, params, fed,
                                      DeviceBatcher(data, parts,
                                                    batch_size=20),
                                      eval_fn=eval_fn)
            hist = sim.run(T_ROUNDS, eval_every=T_ROUNDS)
            accs.append(hist.metric[-1])
            dropped[rate] = (float(np.mean(hist.dropped))
                             if hist.dropped else 0.0)
        print(f"{algorithm:10s} " + " ".join(f"{a:>10.4f}" for a in accs)
              + f" {dropped[RATES[-1]]:>8.3f}")

    print("\nDrop rates are per-(round, client) draws, pure in "
          "(seed, round, client): re-running any round — alone, resumed, "
          "or in a different chunk split — aborts the same clients at the "
          "same step counts (fed/scenarios.py).  Partial-work recovery "
          "keeps the sweep flat instead of cliffing: a server that "
          "discarded aborted clients would lose over half its updates at "
          "p = 0.6, while the delivered k′-step prefixes still aggregate "
          "at their true step counts and the calibrated runs stay "
          "oriented because ν̄ is recovered from what was actually "
          "computed.")


if __name__ == "__main__":
    main()
