"""Partial participation: FedaGrac with a sampled cohort of C = 8 out of
M = 256 clients vs full participation (DESIGN.md §10).

    PYTHONPATH=src python examples/partial_participation.py

The quickstart task at population scale: 256 clients on the FedProx
synthetic(1,1) non-IID mixture.  Full participation runs every client every
round; a cohort round runs 8 — 32× less client work — with
Horvitz–Thompson renormalized weights keeping the aggregated direction an
unbiased estimate of the population update, and the server's calibration
state (ν, ν⁽ⁱ⁾) maintained for the full population across cohorts.  The
comparison is at EQUAL CLIENT WORK (40 full rounds vs 1280 cohort rounds =
10240 client·rounds each): partial participation trades rounds for
per-round cost at no accuracy loss.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.data import DeviceBatcher, fedprox_synthetic
from repro.fed import FederatedSimulation
from repro.models.simple import lr_accuracy, lr_loss

M, C, WORK, TARGET = 256, 8, 40 * 256, 0.40


def main() -> None:
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0,
                                    n_per_client=50)
    eval_fn = lambda p: float(lr_accuracy(p, {"x": data.x, "y": data.y}))
    ks = np.full((1, M), 4, np.int32)

    runs = (("full  C=256", dict()),
            ("uniform C=8", dict(cohort_size=C, cohort_sampler="uniform")),
            ("roundrb C=8", dict(cohort_size=C,
                                 cohort_sampler="round_robin")))
    print(f"{'participation':14s} {'rounds':>7s} {'final acc':>10s} "
          f"{'client-work→{:.0%}'.format(TARGET):>16s}")
    for label, cohort_kw in runs:
        c = cohort_kw.get("cohort_size", M)
        t_rounds = WORK // c
        fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.1,
                        calibration_rate=0.5, weights="data", **cohort_kw)
        params = {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}
        sim = FederatedSimulation(lr_loss, params, fed,
                                  DeviceBatcher(data, parts, batch_size=20),
                                  eval_fn=eval_fn, k_schedule=ks)
        ev_every = t_rounds // 8
        hist = sim.run(t_rounds, eval_every=ev_every)
        r = hist.rounds_to_target(TARGET)
        work = f"{r * ev_every * c}" if r else f">{WORK}"
        print(f"{label:14s} {t_rounds:>7d} {hist.metric[-1]:>10.4f} "
              f"{work:>16s}")
    print("\nAt equal client work a cohort of 8 matches (here: beats) full "
          "participation — each round costs 32× less, and the calibration "
          "state spans the full population across cohorts "
          "(fed/population.py).")


if __name__ == "__main__":
    main()
