"""End-to-end driver: federated training of a ~100M-param LM with FedaGrac.

    PYTHONPATH=src python examples/fed_lm_train.py [--rounds 50] [--small]

4 clients hold topic-skewed Zipf token streams (non-IID at the unigram
level) and run K_i ~ N(4, 2²) local steps per round.  Default model: an
8-layer d=512 llama-family transformer (~100M params with the 32k vocab);
--small shrinks it to a 2-layer d=128 model for CI (≈30 s for 12 rounds).
Checkpoints every 10 rounds via repro.checkpoint.
"""
import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.configs.base import FedConfig, reduced
from repro.configs.registry import get_arch
from repro.data import LMFederatedBatcher, lm_sequences
from repro.fed import FederatedSimulation
from repro.models import model as M

MCLIENTS = 4


def build_config(small: bool):
    base = get_arch("llama3-8b")
    if small:
        cfg = reduced(base, n_layers=2, d_model=128)
        return dataclasses.replace(cfg, vocab=512)
    cfg = reduced(base, n_layers=8, d_model=512, vocab=32_000)
    return dataclasses.replace(cfg, n_heads=8, n_kv_heads=4, head_dim=64,
                               d_ff=2048, vocab=32_000)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--small", action="store_true",
                    help="2-layer reduced model (CI budget)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--algo", default="fedagrac")
    ap.add_argument("--ckpt", default="/tmp/fed_lm_{round}.msgpack")
    args = ap.parse_args()

    cfg = build_config(args.small)
    print(f"model: llama-family {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab}  params ≈ {cfg.param_count() / 1e6:.1f}M")

    key = jax.random.PRNGKey(0)
    streams = [lm_sequences(jax.random.fold_in(key, i), 128, args.seq,
                            cfg.vocab, skew_topic=i) for i in range(MCLIENTS)]
    batcher = LMFederatedBatcher(streams, batch_size=args.batch)
    fed = FedConfig(algorithm=args.algo, n_clients=MCLIENTS, k_mean=4,
                    k_var=4.0, lr=0.3, calibration_rate=0.5)

    params = M.init_params(key, cfg)
    loss_fn = functools.partial(M.lm_loss, cfg=cfg)
    held_out = lm_sequences(jax.random.fold_in(key, 999), 8, args.seq,
                            cfg.vocab, skew_topic=1)
    eval_jit = jax.jit(loss_fn)

    def eval_ppl(p):
        return float(jnp.exp(eval_jit(p, held_out)))

    sim = FederatedSimulation(lambda p, b: loss_fn(p, b), params, fed,
                              batcher, eval_fn=eval_ppl,
                              t_max=max(args.rounds, 1))
    ckpt_cb = checkpoint.save_every(args.ckpt, every=10)
    t0 = time.time()
    for t in range(args.rounds):
        hist = sim.run(1)
        ckpt_cb(t + 1, sim.params)
        if t % 5 == 0 or t == args.rounds - 1:
            print(f"round {t + 1:3d}  train loss {hist.loss[-1]:.4f}  "
                  f"held-out ppl {hist.metric[-1]:.1f}  "
                  f"({time.time() - t0:.0f}s)", flush=True)
    final = eval_ppl(sim.params)
    print(f"\nfinal held-out perplexity: {final:.1f} "
          f"(uniform baseline {cfg.vocab})")
    assert final < 0.8 * cfg.vocab, "model failed to learn"


if __name__ == "__main__":
    main()
