"""End-to-end driver: federated LM training on the FLAT single-buffer
engine (DESIGN.md §11/§13).

    PYTHONPATH=src python examples/fed_lm_train.py [--rounds 30] [--small]

4 clients hold topic-skewed Zipf token streams (non-IID at the unigram
level) and run K_i ~ N(4, 2²) local steps per round of a scaled-down
gemma-2b (MQA, GeGLU, tied embeddings, `jax.checkpoint` remat) through
``FederatedSimulation`` with ``param_layout="flat"``: the whole round —
k-step client scans included — runs on one lane-padded ``(P,)``/``(M, P)``
buffer, the model reading view-table slices of it at the loss boundary
(``core.flat.flat_value_and_grad``; flash-attention forward dispatches to
the Pallas kernel on TPU).  ``--bf16`` switches to the mixed-precision
production configuration: bf16 params/compute under an f32 master buffer
(``FedConfig.master_dtype``).  Batches are drawn on device inside the
scanned round chunks (``DeviceLMBatcher``); ``--sampler host`` keeps the
numpy host batcher.  Checkpoints at every eval boundary.

--small shrinks to a 2-layer d=64 model for CI (~40 s for 8 rounds).
"""
import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.configs.base import FedConfig, reduced
from repro.configs.registry import get_arch
from repro.data import DeviceLMBatcher, LMFederatedBatcher, lm_sequences
from repro.fed import FederatedSimulation
from repro.models import model as M

MCLIENTS = 4


def build_config(small: bool):
    base = get_arch("gemma-2b")
    if small:
        return reduced(base, n_layers=2, d_model=64, vocab=256)
    return reduced(base, n_layers=6, d_model=512, vocab=8192)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--small", action="store_true",
                    help="2-layer reduced model (CI budget)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--algo", default="fedagrac")
    ap.add_argument("--layout", choices=("flat", "tree"), default="flat")
    ap.add_argument("--bf16", action="store_true",
                    help="bf16 params/compute + f32 flat master buffer")
    ap.add_argument("--sampler", choices=("device", "host"),
                    default="device")
    ap.add_argument("--eval-every", type=int, default=5,
                    help="eval/checkpoint cadence = round-chunk length")
    ap.add_argument("--ckpt", default="/tmp/fed_lm_{round}.msgpack")
    args = ap.parse_args()

    cfg = build_config(args.small)
    if args.bf16:
        if args.layout != "flat":
            raise SystemExit("--bf16 requires --layout flat (the f32 "
                             "master IS the flat buffer)")
        cfg = dataclasses.replace(cfg, dtype="bfloat16")
    seq = min(args.seq, 32) if args.small else args.seq
    print(f"model: gemma-family {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab} dtype={cfg.dtype}  "
          f"params ≈ {cfg.param_count() / 1e6:.1f}M  layout={args.layout}"
          + (" (f32 master)" if args.bf16 else ""))

    key = jax.random.PRNGKey(0)
    streams = [lm_sequences(jax.random.fold_in(key, i), 128, seq,
                            cfg.vocab, skew_topic=i)
               for i in range(MCLIENTS)]
    make_batcher = (DeviceLMBatcher if args.sampler == "device"
                    else LMFederatedBatcher)
    batcher = make_batcher(streams, batch_size=args.batch)
    fed = FedConfig(algorithm=args.algo, n_clients=MCLIENTS, k_mean=4,
                    k_var=4.0, lr=0.3, calibration_rate=0.5,
                    param_layout=args.layout,
                    master_dtype="float32" if args.bf16 else "")

    params = M.init_params(key, cfg)
    loss_fn = functools.partial(M.lm_loss, cfg=cfg)
    held_out = lm_sequences(jax.random.fold_in(key, 999), 8, seq,
                            cfg.vocab, skew_topic=1)
    eval_jit = jax.jit(loss_fn)

    def eval_ppl(p):
        return float(jnp.exp(eval_jit(p, held_out)))

    sim = FederatedSimulation(lambda p, b: loss_fn(p, b), params, fed,
                              batcher, eval_fn=eval_ppl,
                              t_max=max(args.rounds, 1))
    ckpt_cb = checkpoint.save_every(args.ckpt, every=args.eval_every)
    t0 = time.time()
    done = 0
    while done < args.rounds:
        r = min(args.eval_every, args.rounds - done)
        # r rounds = ONE scanned, donated device chunk (core/engine.py);
        # the host syncs only here, at the eval/checkpoint boundary
        hist = sim.run(r, eval_every=r)
        done += r
        ckpt_cb(done, sim.params)
        print(f"round {done:3d}  train loss {hist.loss[-1]:.4f}  "
              f"held-out ppl {hist.metric[-1]:.1f}  "
              f"({time.time() - t0:.0f}s)", flush=True)
    final = eval_ppl(sim.params)
    print(f"\nfinal held-out perplexity: {final:.1f} "
          f"(uniform baseline {cfg.vocab})")
    assert final < 0.8 * cfg.vocab, "model failed to learn"


if __name__ == "__main__":
    main()
