"""Batched serving: prefill a prompt batch, then decode with the KV cache.

    PYTHONPATH=src python examples/serve_batched.py [--arch llama3-8b]

Uses the REDUCED variant of the chosen architecture (CPU budget), the same
serve_prefill / serve_decode entry points the pod-scale dry-run lowers.
Demonstrates: ragged prompt batch → prefill → greedy decode loop →
per-request detokenized ids.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import ARCHS, get_arch
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    if cfg.frontend != "none":
        raise SystemExit(f"{args.arch} needs a modality frontend — use a "
                         f"text arch for this example")
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B, S0, T = args.batch, args.prompt_len, args.new_tokens

    prompts = jax.random.randint(key, (B, S0), 0, cfg.vocab)
    caches = M.init_caches(cfg, B, max_len=S0 + T, dtype=jnp.float32)

    prefill = jax.jit(lambda p, b, c: M.serve_prefill(p, b, cfg, caches=c))
    decode = jax.jit(lambda p, b, c, off: M.serve_decode(p, b, c, off, cfg))

    t0 = time.time()
    logits, caches = prefill(params, {"tokens": prompts}, caches)
    tok = jnp.argmax(logits[:, -1], axis=-1)
    out = [tok]
    for s in range(T - 1):
        logits, caches = decode(params, {"tokens": tok[:, None]}, caches,
                                S0 + s)
        tok = jnp.argmax(logits[:, 0], axis=-1)
        out.append(tok)
    dt = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    for i in range(B):
        print(f"req {i}: prompt={np.asarray(prompts[i])[:8]}... "
              f"generated={gen[i][:12]}...")
    print(f"\n{B} requests × {T} tokens in {dt:.2f}s "
          f"({B * T / dt:.1f} tok/s on CPU, reduced {args.arch})")
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab)


if __name__ == "__main__":
    main()
