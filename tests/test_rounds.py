"""Round-engine invariants (core/rounds.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import rounds
from repro.core.fedopt import ALGORITHMS, get_algorithm
from repro.models.simple import quad_loss

M, D = 4, 6
W = jnp.full((M,), 1.0 / M, jnp.float32)


def _quad_batches(k_max, key=0):
    rng = np.random.default_rng(key)
    As = rng.normal(size=(M, D, D)).astype(np.float32)
    bs = rng.normal(size=(M, D)).astype(np.float32)
    return {
        "A": jnp.broadcast_to(jnp.asarray(As)[:, None], (M, k_max, D, D)),
        "b": jnp.broadcast_to(jnp.asarray(bs)[:, None], (M, k_max, D)),
        "c0": jnp.zeros((M, k_max)),
    }


def _round_fn(algo_name, k_max, lam=0.5, lr=0.01, **kw):
    fed = FedConfig(algorithm=algo_name, n_clients=M, lr=lr,
                    calibration_rate=lam)
    algo = get_algorithm(algo_name, fed)
    return algo, rounds.make_round(quad_loss, algo, lr=lr, k_max=k_max, **kw)


def _init(algo):
    return rounds.init_state({"x": jnp.zeros((D,), jnp.float32)}, M, algo)


@pytest.mark.parametrize("name", ALGORITHMS)
def test_every_algorithm_round_runs(name):
    algo, fn = _round_fn(name, k_max=4)
    state = _init(algo)
    ks = jnp.array([1, 2, 3, 4], jnp.int32)
    state, metrics = jax.jit(fn)(state, _quad_batches(4), ks, W)
    assert np.isfinite(float(metrics["loss"]))
    assert np.all(np.isfinite(np.asarray(state["params"]["x"])))
    assert float(metrics["kbar"]) == pytest.approx(2.5)


def test_masking_matches_smaller_scan():
    """K_i < k_max via masking == running a k_max=K_i scan directly."""
    algo, fn_big = _round_fn("fedavg", k_max=8)
    _, fn_small = _round_fn("fedavg", k_max=3)
    state = _init(algo)
    ks = jnp.full((M,), 3, jnp.int32)
    batches8 = _quad_batches(8)
    batches3 = jax.tree.map(lambda a: a[:, :3], batches8)
    out_big, _ = jax.jit(fn_big)(dict(state), batches8, ks, W)
    out_small, _ = jax.jit(fn_small)(dict(state), batches3, ks, W)
    np.testing.assert_allclose(np.asarray(out_big["params"]["x"]),
                               np.asarray(out_small["params"]["x"]),
                               rtol=1e-5, atol=1e-6)


def test_delta_recovery_equals_explicit_nu():
    """ν̄⁽ⁱ⁾ recovered from the parameter delta == explicitly accumulated."""
    ks = jnp.array([2, 3, 5, 8], jnp.int32)
    for track in ("delta", "explicit"):
        algo, fn = _round_fn("fedagrac", k_max=8, lam=0.7)
        state = _init(algo)
        out, _ = jax.jit(rounds.make_round(
            quad_loss, algo, lr=0.01, k_max=8, track_nu=track))(
                state, _quad_batches(8), ks, W)
        if track == "delta":
            nu_delta = np.asarray(out["nu"]["x"])
            nui_delta = np.asarray(out["nu_i"]["x"])
        else:
            nu_exp = np.asarray(out["nu"]["x"])
            nui_exp = np.asarray(out["nu_i"]["x"])
    np.testing.assert_allclose(nui_delta, nui_exp, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(nu_delta, nu_exp, rtol=1e-4, atol=1e-5)


def test_lambda_zero_equals_fedavg():
    ks = jnp.array([1, 2, 4, 8], jnp.int32)
    algo_a, fn_a = _round_fn("fedavg", k_max=8)
    algo_g, fn_g = _round_fn("fedagrac", k_max=8, lam=0.0)
    sa, sg = _init(algo_a), _init(algo_g)
    b = _quad_batches(8)
    for _ in range(3):
        sa, _ = jax.jit(fn_a)(sa, b, ks, W)
        sg, _ = jax.jit(fn_g)(sg, b, ks, W)
    np.testing.assert_allclose(np.asarray(sa["params"]["x"]),
                               np.asarray(sg["params"]["x"]),
                               rtol=1e-5, atol=1e-6)


def test_aggregation_is_weighted_average():
    """One local step: x₊ = Σ ω_i (x₀ − η ∇F_i(x₀)) exactly."""
    lr = 0.05
    algo, fn = _round_fn("fedavg", k_max=1, lr=lr)
    state = _init(algo)
    b = _quad_batches(1)
    ks = jnp.ones((M,), jnp.int32)
    w = jnp.array([0.1, 0.2, 0.3, 0.4], jnp.float32)
    out, _ = jax.jit(fn)(state, b, ks, w)
    A = np.asarray(b["A"][:, 0])
    bb = np.asarray(b["b"][:, 0])
    x0 = np.zeros(D, np.float32)
    grads = np.stack([A[i].T @ (A[i] @ x0 - bb[i]) for i in range(M)])
    want = sum(float(w[i]) * (x0 - lr * grads[i]) for i in range(M))
    np.testing.assert_allclose(np.asarray(out["params"]["x"]), want,
                               rtol=1e-5, atol=1e-6)


def test_fednova_normalized_aggregation():
    """FedNova: x₊ = x₀ + K̄ Σ ω_i (x_i − x₀)/K_i."""
    lr = 0.01
    algo, fn = _round_fn("fednova", k_max=4, lr=lr)
    _, fn_avg = _round_fn("fedavg", k_max=4, lr=lr)
    state = _init(algo)
    b = _quad_batches(4)
    ks = jnp.array([1, 2, 3, 4], jnp.int32)
    out_nova, _ = jax.jit(fn)(dict(state), b, ks, W)
    out_avg, _ = jax.jit(fn_avg)(dict(state), b, ks, W)
    # with heterogeneous K the two aggregations must differ
    assert not np.allclose(np.asarray(out_nova["params"]["x"]),
                           np.asarray(out_avg["params"]["x"]))
    # with homogeneous K FedNova reduces to FedAvg
    ks_eq = jnp.full((M,), 4, jnp.int32)
    out_nova_eq, _ = jax.jit(fn)(dict(state), b, ks_eq, W)
    out_avg_eq, _ = jax.jit(fn_avg)(dict(state), b, ks_eq, W)
    np.testing.assert_allclose(np.asarray(out_nova_eq["params"]["x"]),
                               np.asarray(out_avg_eq["params"]["x"]),
                               rtol=1e-5, atol=1e-6)


def test_orientation_strategies_differ_only_for_fast_nodes():
    """fedagrac vs scaffold(avg): ν⁽ⁱ⁾ (line 11) identical; transmitted ν
    differs whenever some K_i > K̄."""
    ks = jnp.array([1, 1, 1, 9], jnp.int32)          # K̄ = 3, client 3 fast
    b = _quad_batches(9)
    algo_g, fn_g = _round_fn("fedagrac", k_max=9, lam=0.5)
    algo_a, fn_a = _round_fn("fedagrac_avg", k_max=9, lam=0.5)
    out_g, _ = jax.jit(fn_g)(_init(algo_g), b, ks, W)
    out_a, _ = jax.jit(fn_a)(_init(algo_a), b, ks, W)
    np.testing.assert_allclose(np.asarray(out_g["nu_i"]["x"]),
                               np.asarray(out_a["nu_i"]["x"]),
                               rtol=1e-5, atol=1e-6)
    assert not np.allclose(np.asarray(out_g["nu"]["x"]),
                           np.asarray(out_a["nu"]["x"]))


def test_prox_pulls_towards_start():
    algo_p, fn_p = _round_fn("fedprox", k_max=6, lr=0.05)
    algo_a, fn_a = _round_fn("fedavg", k_max=6, lr=0.05)
    ks = jnp.full((M,), 6, jnp.int32)
    b = _quad_batches(6)
    out_p, _ = jax.jit(fn_p)(_init(algo_p), b, ks, W)
    out_a, _ = jax.jit(fn_a)(_init(algo_a), b, ks, W)
    # prox-regularized update moves strictly less from x0 = 0
    assert (np.linalg.norm(np.asarray(out_p["params"]["x"]))
            < np.linalg.norm(np.asarray(out_a["params"]["x"])))


def test_round_counter_increments():
    algo, fn = _round_fn("fedavg", k_max=2)
    state = _init(algo)
    b = _quad_batches(2)
    ks = jnp.full((M,), 2, jnp.int32)
    state, _ = jax.jit(fn)(state, b, ks, W)
    state, _ = jax.jit(fn)(state, b, ks, W)
    assert int(state["round"]) == 2


def test_server_sgd_lr1_is_plain_averaging():
    import dataclasses as dc
    algo, fn = _round_fn("fedavg", k_max=3)
    algo2 = dc.replace(algo, server_opt="sgd", server_lr=1.0)
    fn2 = rounds.make_round(quad_loss, algo2, lr=0.01, k_max=3)
    b = _quad_batches(3)
    ks = jnp.full((M,), 3, jnp.int32)
    s1, _ = jax.jit(fn)(_init(algo), b, ks, W)
    s2, _ = jax.jit(fn2)(rounds.init_state(
        {"x": jnp.zeros((D,), jnp.float32)}, M, algo2), b, ks, W)
    np.testing.assert_allclose(np.asarray(s1["params"]["x"]),
                               np.asarray(s2["params"]["x"]), rtol=1e-6)


def test_server_momentum_accumulates_pseudo_gradient():
    import dataclasses as dc
    fed = FedConfig(algorithm="fedavg", n_clients=M, lr=0.01)
    algo = dc.replace(get_algorithm("fedavg", fed),
                      server_opt="momentum", server_lr=1.0,
                      server_beta1=0.9)
    fn = jax.jit(rounds.make_round(quad_loss, algo, lr=0.01, k_max=2))
    state = rounds.init_state({"x": jnp.zeros((D,), jnp.float32)}, M, algo)
    b = _quad_batches(2)
    ks = jnp.full((M,), 2, jnp.int32)
    s1, _ = fn(state, b, ks, W)
    assert "server_m" in s1
    # second round: update = delta2 + 0.9 * m1 (momentum carries over)
    s2, _ = fn(s1, b, ks, W)
    m1 = np.asarray(s1["server_m"]["x"])
    step2 = np.asarray(s2["params"]["x"]) - np.asarray(s1["params"]["x"])
    # step2 = m2 = 0.9*m1 + delta2; with the same batches the raw deltas
    # shrink towards the optimum, but the momentum term must be present:
    assert np.linalg.norm(step2 - 0.9 * m1) < np.linalg.norm(step2)


def test_server_adam_converges_on_quadratic():
    import dataclasses as dc
    fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.01,
                    calibration_rate=1.0)
    algo = dc.replace(get_algorithm("fedagrac", fed),
                      server_opt="adam", server_lr=0.1)
    fn = jax.jit(rounds.make_round(quad_loss, algo, lr=0.01, k_max=4))
    state = rounds.init_state({"x": jnp.zeros((D,), jnp.float32)}, M, algo)
    b = _quad_batches(4)
    ks = jnp.array([1, 2, 3, 4], jnp.int32)
    losses = []
    for _ in range(30):
        state, m = fn(state, b, ks, W)
        losses.append(float(m["loss"]))
    # converges toward the (non-zero) heterogeneous optimum F(x*)
    assert losses[-1] < 0.65 * losses[0]
    assert np.isfinite(losses[-1])
    assert "server_v" in state
