"""Byzantine-robust aggregation (core/robust.py, DESIGN.md §16): defense
transforms on padded row blocks, weight-mass preservation, the
defense="none" bit-identity matrix over algorithms × engines × layouts,
payload-corruption purity across chunk splits and resumes, quarantine
semantics, and defended-vs-undefended survival."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import serialize
from repro.configs.base import FedConfig
from repro.core import flat as flat_mod
from repro.core.fedopt import ALGORITHMS
from repro.core.robust import (DEFENSES, HEALTH_WARMUP, ROBUST_STATE_KEYS,
                               RobustConfig, build_round_robust)
from repro.data import DeviceBatcher, fedprox_synthetic
from repro.fed import (BufferedAsyncSimulation, FederatedSimulation,
                       SCENARIOS, garbage_scenario, make_scenario,
                       nan_inject_scenario, scale_attack_scenario,
                       sign_flip_scenario)
from repro.models.simple import lr_loss

M = 8
ATTACKS = ["nan_inject", "inf_inject", "scale_attack", "sign_flip",
           "garbage"]


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    return DeviceBatcher(data, parts, batch_size=8, seed=0)


def _fed(**kw):
    kw.setdefault("algorithm", "fedagrac")
    kw.setdefault("k_mean", 5)
    kw.setdefault("k_var", 2.0)
    kw.setdefault("k_mode", "random")
    return FedConfig(n_clients=M, lr=0.05, calibration_rate=0.5, **kw)


def _params():
    return {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}


def _leaves_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _eval(params):
    return float(jnp.sum(jnp.abs(params["w"])) + jnp.sum(params["b"]))


# ---------------------------------------------------------------------------
# config validation (satellite: fail at construction, not in jit)
# ---------------------------------------------------------------------------

def test_unknown_defense_lists_valid_options():
    with pytest.raises(ValueError) as e:
        FedConfig(defense="majority")
    msg = str(e.value)
    assert "defense" in msg and "'majority'" in msg and "krum" in msg


@pytest.mark.parametrize("kw", [
    {"trim_frac": -0.1}, {"trim_frac": 0.5}, {"trim_frac": 1.0},
    {"defense_clip": -1.0}, {"krum_f": -1},
    {"quarantine_window": -1}, {"quarantine_nonfinite": 0},
    {"quarantine_z": 0.0}, {"quarantine_z": -2.0},
])
def test_robust_field_validation(kw):
    with pytest.raises(ValueError):
        FedConfig(**kw)


def test_robust_fields_construct():
    FedConfig(defense="trimmed_mean", trim_frac=0.25, defense_clip=2.0,
              krum_f=2, quarantine_window=5, quarantine_z=3.0,
              quarantine_nonfinite=2, nu_defense=False)


def test_from_fed_gates_on_none():
    assert RobustConfig.from_fed(FedConfig()) is None
    assert RobustConfig.from_fed(FedConfig(defense="none")) is None
    # quarantine alone activates the robust layer (defense stays identity)
    cfg = RobustConfig.from_fed(FedConfig(quarantine_window=3))
    assert cfg is not None and not cfg.defends and cfg.quarantines
    cfg = RobustConfig.from_fed(FedConfig(defense="median"))
    assert cfg is not None and cfg.defends and not cfg.quarantines


# ---------------------------------------------------------------------------
# defense transforms: unit behavior on (B, P) row blocks
# ---------------------------------------------------------------------------

def _rows(b=6, p=32, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, p),
                             jnp.float32) * scale


def test_clip_bounds_survivor_norms():
    cfg = RobustConfig(defense="clip", clip_norm=2.0)
    fn = DEFENSES["clip"](cfg, 32)
    rows = _rows().at[0].mul(100.0)
    out, mask = fn(rows, jnp.ones(6, bool))
    norms = np.sqrt((np.asarray(out) ** 2).sum(-1))
    assert norms.max() <= 2.0 + 1e-5
    assert bool(mask.all())                  # clip never excludes


def test_adaptive_clip_uses_median_of_survivors():
    cfg = RobustConfig(defense="clip", clip_norm=0.0)
    fn = DEFENSES["clip"](cfg, 32)
    rows = _rows().at[0].mul(1e6)
    mask = jnp.ones(6, bool).at[1].set(False)
    out, _ = fn(rows, mask)
    norms_in = np.sqrt((np.asarray(rows) ** 2).sum(-1))
    tau = np.median(np.delete(norms_in, 1))   # dead row excluded
    norms = np.sqrt((np.asarray(out) ** 2).sum(-1))
    assert norms[0] <= tau * (1 + 1e-5)       # outlier pulled to the median


def test_median_broadcasts_columnwise_median_of_survivors():
    cfg = RobustConfig(defense="median")
    fn = DEFENSES["median"](cfg, 32)
    rows = _rows(b=5)
    mask = jnp.ones(5, bool).at[4].set(False)
    out, _ = fn(rows, mask)
    want = np.median(np.asarray(rows)[:4], axis=0)
    for i in range(4):
        np.testing.assert_allclose(np.asarray(out)[i], want, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out)[4], 0.0)   # dead stays 0


def test_trimmed_mean_resists_one_outlier():
    cfg = RobustConfig(defense="trimmed_mean", trim_frac=0.2)
    fn = DEFENSES["trimmed_mean"](cfg, 32)
    rows = _rows(b=6)
    honest_mean = np.asarray(rows).mean(0)
    poisoned = rows.at[3].set(1e6)
    out, _ = fn(poisoned, jnp.ones(6, bool))
    # every surviving row carries the trimmed center; the outlier's mass
    # cannot shift it by more than the trim band
    np.testing.assert_allclose(np.asarray(out)[0], np.asarray(out)[1])
    assert np.abs(np.asarray(out)[0] - honest_mean).max() < 1.0


def test_krum_excludes_planted_outlier():
    cfg = RobustConfig(defense="krum", krum_f=1)
    fn = DEFENSES["krum"](cfg, 32)
    rows = _rows(b=6, scale=0.1).at[2].add(50.0)
    out, mask = fn(rows, jnp.ones(6, bool))
    assert not bool(mask[2])                  # the far row is deselected
    np.testing.assert_array_equal(np.asarray(out)[2], 0.0)
    assert int(np.asarray(mask).sum()) == 5   # keeps B - f rows


def test_defense_factories_cover_registry():
    assert set(DEFENSES) == {"none", "clip", "median", "trimmed_mean",
                             "krum"}


# ---------------------------------------------------------------------------
# attack scenarios: pure in (seed, round, client), persistent corrupt set
# ---------------------------------------------------------------------------

def test_attack_registry_and_corrupts_payload_flag():
    assert set(ATTACKS) <= set(SCENARIOS)
    for name in ATTACKS:
        sc = make_scenario(_fed(scenario=name, scenario_rate=0.3))
        assert sc is not None and sc.corrupts_payload
        assert not sc.perturbs_k      # payload-only: timelines untouched
    assert not make_scenario(_fed(scenario="dropout")).corrupts_payload


def test_corrupt_set_persistent_and_rate_bounded():
    sc = scale_attack_scenario(M, rate=0.5, magnitude=4.0, seed=3)
    rows = jnp.ones((M, 16))
    a = np.asarray(sc.corrupt_delta(0, rows, 16))
    for t in range(1, 6):
        b = np.asarray(sc.corrupt_delta(t, rows, 16))
        np.testing.assert_array_equal((a == 4.0), (b == 4.0))  # same set
    frac = float((a[:, 0] == 4.0).mean())
    assert 0.0 < frac < 1.0


def test_corrupt_rows_pure_across_rebuilds_and_id_subsets():
    a = garbage_scenario(M, rate=0.5, magnitude=3.0, seed=5)
    b = garbage_scenario(M, rate=0.5, magnitude=3.0, seed=5)
    rows = _rows(b=M, p=16, seed=9)
    np.testing.assert_array_equal(np.asarray(a.corrupt_delta(4, rows, 16)),
                                  np.asarray(b.corrupt_delta(4, rows, 16)))
    # a cohort subset sees exactly its rows of the full draw
    ids = jnp.asarray([1, 4, 6], jnp.int32)
    full = np.asarray(a.corrupt_delta(4, rows, 16))
    sub = np.asarray(a.corrupt_delta(4, rows[ids], 16, ids=ids))
    np.testing.assert_array_equal(sub, full[np.asarray(ids)])


def test_corruption_masks_padding_columns():
    sc = nan_inject_scenario(M, rate=1.0, seed=0)
    rows = jnp.zeros((M, 32))
    out = np.asarray(sc.corrupt_delta(0, rows, 20))
    assert np.isnan(out[:, :20]).all()
    np.testing.assert_array_equal(out[:, 20:], 0.0)   # pads stay clean


def test_delta_and_nu_streams_differ():
    sc = garbage_scenario(M, rate=1.0, magnitude=2.0, seed=0)
    rows = _rows(b=M, p=16, seed=2)
    d = np.asarray(sc.corrupt_delta(3, rows, 16))
    n = np.asarray(sc.corrupt_nu(3, rows, 16))
    assert not np.array_equal(d, n)


def test_attack_rate_validation():
    with pytest.raises(ValueError):
        nan_inject_scenario(M, rate=1.5)
    with pytest.raises(ValueError):
        scale_attack_scenario(M, magnitude=0.0)


# ---------------------------------------------------------------------------
# golden pins: defense="none" is trace-time gated to the identical round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_none_bit_identical_sync(task, algorithm, layout):
    fed_kw = {"algorithm": algorithm, "param_layout": layout}
    ref = FederatedSimulation(lr_loss, _params(), _fed(**fed_kw), task)
    ref.run(2, eval_every=2)
    none = FederatedSimulation(lr_loss, _params(),
                               _fed(**fed_kw, defense="none"), task)
    none.run(2, eval_every=2)
    _leaves_equal(ref.state, none.state)


@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_none_bit_identical_cohort(task, algorithm, layout):
    fed_kw = {"algorithm": algorithm, "param_layout": layout,
              "cohort_size": 4}
    ref = FederatedSimulation(lr_loss, _params(), _fed(**fed_kw), task)
    ref.run(2, eval_every=2)
    none = FederatedSimulation(lr_loss, _params(),
                               _fed(**fed_kw, defense="none"), task)
    none.run(2, eval_every=2)
    _leaves_equal(ref.state, none.state)


@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_none_bit_identical_async(task, algorithm, layout):
    fed_kw = {"algorithm": algorithm, "param_layout": layout,
              "buffer_size": 4, "staleness": "poly"}
    ref = BufferedAsyncSimulation(lr_loss, _params(), _fed(**fed_kw), task)
    ref.run(3)
    none = BufferedAsyncSimulation(lr_loss, _params(),
                                   _fed(**fed_kw, defense="none"), task)
    none.run(3)
    _leaves_equal(ref.state, none.state)


# ---------------------------------------------------------------------------
# corruption determinism: chunk splits, resumes, tree-vs-flat
# ---------------------------------------------------------------------------

def _attacked(**kw):
    kw.setdefault("scenario", "scale_attack")
    kw.setdefault("scenario_rate", 0.3)
    kw.setdefault("scenario_magnitude", 5.0)
    kw.setdefault("defense", "median")
    kw.setdefault("quarantine_window", 2)
    return _fed(**kw)


def test_attacked_run_bit_identical_across_chunk_splits(task):
    a = FederatedSimulation(lr_loss, _params(), _attacked(), task)
    a.run(6, eval_every=6)
    b = FederatedSimulation(lr_loss, _params(), _attacked(), task)
    b.run(6, eval_every=2)
    c = FederatedSimulation(lr_loss, _params(), _attacked(), task)
    c.run(6, eval_every=1)
    _leaves_equal(a.state, b.state)
    _leaves_equal(a.state, c.state)


def test_attacked_state_resumes_bit_exact_from_checkpoint(task, tmp_path):
    """Corruption is keyed off the round counter IN STATE, so a
    save/load/resume replays the identical injections: restoring mid-run
    state into a fresh engine leaves the next round bit-identical."""
    a = FederatedSimulation(lr_loss, _params(), _attacked(), task)
    a.run(2, eval_every=2)
    path = str(tmp_path / "mid.msgpack")
    serialize.save(path, a.state)
    b = FederatedSimulation(lr_loss, _params(), _attacked(), task)
    b.state = serialize.load(path, b.state)
    _leaves_equal(a.state, b.state)
    # one more identical-data round on both engines stays bit-equal
    ha = a.run(1, eval_every=1)
    hb = b.run(1, eval_every=1)
    _leaves_equal(a.state, b.state)
    assert ha.quarantined == hb.quarantined


@pytest.mark.parametrize("defense", ["clip", "median", "trimmed_mean",
                                     "krum"])
def test_tree_and_flat_agree_under_attack(task, defense):
    out = {}
    for layout in ("tree", "flat"):
        sim = FederatedSimulation(
            lr_loss, _params(),
            _attacked(defense=defense, param_layout=layout), task)
        sim.run(3, eval_every=3)
        out[layout] = jax.tree.leaves(sim.params)
    for lt, lf in zip(out["tree"], out["flat"]):
        np.testing.assert_allclose(np.asarray(lt), np.asarray(lf),
                                   rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# defense efficacy + the final non-finite guard
# ---------------------------------------------------------------------------

def test_undefended_nan_inject_raises_at_eval(task):
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(scenario="nan_inject",
                                   scenario_rate=0.25), task,
                              eval_fn=_eval)
    with pytest.raises(FloatingPointError, match="non-finite"):
        sim.run(4, eval_every=1)


@pytest.mark.parametrize("defense", ["median", "trimmed_mean", "krum"])
def test_defended_nan_inject_stays_finite(task, defense):
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(scenario="nan_inject",
                                   scenario_rate=0.25, defense=defense,
                                   quarantine_window=3), task,
                              eval_fn=_eval)
    hist = sim.run(4, eval_every=1)
    assert all(np.isfinite(hist.metric))
    for leaf in jax.tree.leaves(sim.state):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_defended_async_nan_inject_stays_finite(task):
    sim = BufferedAsyncSimulation(
        lr_loss, _params(),
        _fed(scenario="nan_inject", scenario_rate=0.25,
             defense="trimmed_mean", quarantine_window=3,
             buffer_size=4), task, eval_fn=_eval)
    hist = sim.run(6, eval_every=1)
    assert all(np.isfinite(hist.metric))
    for leaf in jax.tree.leaves(sim.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_guard_without_quarantine_keeps_nu_finite(task):
    """defense alone (no quarantine) must still never write NaN into the
    master or ν — the final guard, not the health layer, provides this."""
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(scenario="nan_inject",
                                   scenario_rate=0.25,
                                   defense="median"), task)
    sim.run(3, eval_every=3)
    for key in ("params", "nu", "nu_i"):
        for leaf in jax.tree.leaves(sim.state[key]):
            assert bool(jnp.all(jnp.isfinite(leaf))), key


# ---------------------------------------------------------------------------
# quarantine: health state, exclusion, History plumbing
# ---------------------------------------------------------------------------

def test_nonfinite_reporters_get_quarantined(task):
    fed = _fed(scenario="nan_inject", scenario_rate=0.25,
               defense="trimmed_mean", quarantine_window=4)
    sim = FederatedSimulation(lr_loss, _params(), fed, task)
    hist = sim.run(4, eval_every=1)
    hit = np.asarray(sim.state["hz_nonfinite"]) > 0
    assert hit.any()
    until = np.asarray(sim.state["hz_until"])
    np.testing.assert_array_equal(until > 0, hit)   # flagged ⇔ windowed
    # rounds after the first carry active exclusions
    assert len(hist.quarantined) == 4
    assert sum(hist.quarantined[1:]) > 0
    assert hist.quarantined[0] == 0.0      # nobody pre-flagged at round 0


def test_quarantine_state_keys_allocated_only_when_active(task):
    on = FederatedSimulation(lr_loss, _params(),
                             _fed(quarantine_window=2), task)
    for key in ROBUST_STATE_KEYS:
        assert key in on.state and on.state[key].shape == (M,)
    off = FederatedSimulation(lr_loss, _params(),
                              _fed(defense="median"), task)
    for key in ROBUST_STATE_KEYS:
        assert key not in off.state


def test_flatten_state_passes_health_keys_through(task):
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(scenario="nan_inject",
                                   scenario_rate=0.25,
                                   defense="median",
                                   quarantine_window=2), task)
    sim.run(1)
    spec = sim._spec
    flat_state = flat_mod.flatten_state(spec, sim.state)
    for key in ROBUST_STATE_KEYS:
        assert key in flat_state
        np.testing.assert_array_equal(np.asarray(flat_state[key]),
                                      np.asarray(sim.state[key]))
    round_trip = flat_mod.unflatten_state(spec, flat_state)
    for key in ROBUST_STATE_KEYS:
        np.testing.assert_array_equal(np.asarray(round_trip[key]),
                                      np.asarray(sim.state[key]))


def test_build_round_robust_requires_spec():
    cfg = RobustConfig(defense="median")
    with pytest.raises(ValueError, match="FlatSpec"):
        build_round_robust(cfg, None, True)
    assert build_round_robust(None, None, True) is None


# ---------------------------------------------------------------------------
# ν defense ablation: the knob actually changes the calibration stream
# ---------------------------------------------------------------------------

def test_nu_defense_knob_changes_nu_not_gated_runs(task):
    kw = dict(scenario="sign_flip", scenario_rate=0.3, defense="median")
    a = FederatedSimulation(lr_loss, _params(), _fed(**kw), task)
    a.run(3, eval_every=3)
    b = FederatedSimulation(lr_loss, _params(),
                            _fed(**kw, nu_defense=False), task)
    b.run(3, eval_every=3)
    na = np.concatenate([np.ravel(l) for l in jax.tree.leaves(
        a.state["nu"])])
    nb = np.concatenate([np.ravel(l) for l in jax.tree.leaves(
        b.state["nu"])])
    assert not np.array_equal(na, nb)     # ablation is live
    # with no defense at all the knob is inert (trace-time gated away)
    c = FederatedSimulation(lr_loss, _params(),
                            _fed(nu_defense=False), task)
    c.run(2, eval_every=2)
    d = FederatedSimulation(lr_loss, _params(), _fed(), task)
    d.run(2, eval_every=2)
    _leaves_equal(c.state, d.state)
