"""Golden equivalence: the layered engine (core/stages.py) must produce
BIT-IDENTICAL round outputs to the frozen pre-refactor engine
(tests/_seed_rounds.py) for every algorithm and engine option."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _seed_rounds as seed_rounds
from repro.configs.base import FedConfig
from repro.core import engine, rounds
from repro.core.fedopt import ALGORITHMS, get_algorithm
from repro.models.simple import quad_loss

M, D, K_MAX = 4, 6, 8
W = jnp.array([0.1, 0.2, 0.3, 0.4], jnp.float32)
KS = jnp.array([1, 3, 5, 8], jnp.int32)


def _batches(key=0):
    rng = np.random.default_rng(key)
    return {
        "A": jnp.asarray(rng.normal(size=(M, K_MAX, D, D)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(M, K_MAX, D)).astype(np.float32)),
        "c0": jnp.zeros((M, K_MAX)),
    }


def _algo(name, **replace):
    fed = FedConfig(algorithm=name, n_clients=M, lr=0.01,
                    calibration_rate=0.5)
    algo = get_algorithm(name, fed)
    return dataclasses.replace(algo, **replace) if replace else algo


def _run_both(algo, n_rounds=3, **make_kw):
    state_a = rounds.init_state({"x": jnp.zeros((D,), jnp.float32)}, M, algo)
    state_b = {k: v for k, v in state_a.items()}
    fn_seed = jax.jit(seed_rounds.make_round(quad_loss, algo, lr=0.01,
                                             k_max=K_MAX, **make_kw))
    fn_new = jax.jit(rounds.make_round(quad_loss, algo, lr=0.01,
                                       k_max=K_MAX, **make_kw))
    b = _batches()
    for _ in range(n_rounds):
        state_a, metrics_a = fn_seed(state_a, b, KS, W)
        state_b, metrics_b = fn_new(state_b, b, KS, W)
    return (state_a, metrics_a), (state_b, metrics_b)


def _assert_identical(out_a, out_b):
    (state_a, metrics_a), (state_b, metrics_b) = out_a, out_b
    assert set(state_a) == set(state_b)
    paths_a = jax.tree_util.tree_leaves_with_path(state_a)
    leaves_b = jax.tree.leaves(state_b)
    for (path, la), lb in zip(paths_a, leaves_b):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb),
            err_msg=f"state leaf {jax.tree_util.keystr(path)} diverged")
    for k in metrics_a:
        np.testing.assert_array_equal(np.asarray(metrics_a[k]),
                                      np.asarray(metrics_b[k]),
                                      err_msg=f"metric {k!r} diverged")


@pytest.mark.parametrize("name", ALGORITHMS)
def test_bit_identical_all_algorithms(name):
    """All 9 algorithms: 3 chained rounds, every state leaf + metric equal."""
    algo = _algo(name)
    _assert_identical(*_run_both(algo))


@pytest.mark.parametrize("name", ["fedavg", "fedagrac"])
@pytest.mark.parametrize("server_opt,server_lr", [("momentum", 0.7),
                                                  ("adam", 0.1)])
def test_bit_identical_server_optimizers(name, server_opt, server_lr):
    algo = _algo(name, server_opt=server_opt, server_lr=server_lr)
    _assert_identical(*_run_both(algo))


def test_bit_identical_explicit_nu():
    algo = _algo("fedagrac")
    _assert_identical(*_run_both(algo, track_nu="explicit"))


def test_bit_identical_quantized_transmit():
    algo = _algo("fedagrac")
    _assert_identical(*_run_both(algo, quantize_transmit=True))


def test_traced_lam_matches_baked_lam():
    """λ passed as a traced scalar (the no-recompile path) == λ baked into
    the trace as a compile-time constant."""
    algo = _algo("fedagrac")
    state = rounds.init_state({"x": jnp.zeros((D,), jnp.float32)}, M, algo)
    fn = jax.jit(rounds.make_round(quad_loss, algo, lr=0.01, k_max=K_MAX))
    b = _batches()
    baked, _ = fn(dict(state), b, KS, W)
    traced, _ = fn(dict(state), b, KS, W, jnp.float32(algo.lam))
    for la, lb in zip(jax.tree.leaves(baked), jax.tree.leaves(traced)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-6, atol=1e-7)


def test_traced_lam_preserves_bf16_state():
    """A traced λ is a STRONG f32 scalar; it must not promote a bf16 round
    state to f32 (the baked python-float λ is weak-typed and never did)."""
    algo = _algo("fedagrac")
    params = {"x": jnp.zeros((D,), jnp.bfloat16)}
    state = rounds.init_state(params, M, algo)
    b = jax.tree.map(lambda a: a.astype(jnp.bfloat16), _batches())
    fn = jax.jit(rounds.make_round(quad_loss, algo, lr=0.01, k_max=K_MAX))
    out, _ = fn(state, b, KS, W, jnp.float32(0.5))
    assert out["params"]["x"].dtype == jnp.bfloat16
    assert out["nu"]["x"].dtype == jnp.bfloat16


@pytest.mark.parametrize("name", ALGORITHMS)
def test_chunked_scan_bit_identical(name):
    """Device-resident chunking (core/engine.py): R rounds fused into one
    jitted lax.scan must equal R sequential jit(round_fn) calls BIT-FOR-BIT
    — the scan body is the unmodified layered round."""
    algo = _algo(name)
    r = 4
    state_a = rounds.init_state({"x": jnp.zeros((D,), jnp.float32)}, M, algo)
    state_b = dict(state_a)
    fn = jax.jit(rounds.make_round(quad_loss, algo, lr=0.01, k_max=K_MAX))
    b = _batches()
    lam = jnp.float32(algo.lam)
    metrics_a = None
    for _ in range(r):
        state_a, metrics_a = fn(state_a, b, KS, W, lam)
    chunk = engine.make_round_chunk(
        rounds.make_round(quad_loss, algo, lr=0.01, k_max=K_MAX), r,
        donate=False)
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (r,) + a.shape), b)
    state_b, metrics_b = chunk(state_b, stacked,
                               jnp.broadcast_to(KS, (r, M)),
                               jnp.broadcast_to(W, (r, M)),
                               jnp.full((r,), lam))
    metrics_last = {k: v[-1] for k, v in metrics_b.items()}
    _assert_identical((state_a, metrics_a), (state_b, metrics_last))
    for k, v in metrics_b.items():
        assert v.shape == (r,), f"metric {k!r} not stacked per round"


def test_chunked_simulation_matches_per_round_loop():
    """FederatedSimulation chunked at the eval cadence == the chunk_rounds=1
    compat loop, bit-for-bit (host sampler: identical batches by
    construction, identical rounds by the scan golden test)."""
    from repro.data import FederatedBatcher, fedprox_synthetic
    from repro.fed import FederatedSimulation
    from repro.models.simple import lr_accuracy, lr_loss

    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    params = {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}
    fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.05,
                    calibration_rate=0.5, weights="data")
    ks = np.full((20, M), 3, np.int32)
    ev = lambda p: float(lr_accuracy(p, {"x": data.x, "y": data.y}))

    def make():
        return FederatedSimulation(
            lr_loss, params, fed, FederatedBatcher(data, parts, 10),
            eval_fn=ev, k_schedule=ks,
            lam_schedule=lambda t: 0.25 * (t + 1))
    a, b = make(), make()
    ha = a.run(12, eval_every=4, chunk_rounds=1)
    hb = b.run(12, eval_every=4)               # auto-chunks at eval cadence
    assert ha.loss == hb.loss
    assert ha.kbar == hb.kbar
    assert ha.metric == hb.metric
    for la, lb in zip(jax.tree.leaves(a.state), jax.tree.leaves(b.state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_lam_schedule_does_not_retrace():
    """The simulation compiles ONE round for any λ-schedule (the old cache
    keyed on the float λ retraced every round)."""
    from repro.configs.base import FedConfig as FC
    from repro.data import FederatedBatcher, fedprox_synthetic
    from repro.fed import FederatedSimulation
    from repro.models.simple import lr_loss

    traces = []

    def counting_loss(params, batch):
        traces.append(1)
        return lr_loss(params, batch)

    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    fed = FC(algorithm="fedagrac", n_clients=M, lr=0.05)
    sim = FederatedSimulation(
        counting_loss, {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))},
        fed, FederatedBatcher(data, parts, batch_size=10),
        k_schedule=np.full((8, M), 3, np.int32),
        lam_schedule=lambda t: 0.1 * (t + 1))        # distinct λ every round
    sim.run(1)
    after_first = len(traces)
    assert after_first > 0
    sim.run(4)
    assert len(traces) == after_first, (
        f"λ-schedule retraced the round: {len(traces)} loss-fn traces "
        f"after 5 rounds vs {after_first} after 1")
