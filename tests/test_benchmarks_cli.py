"""benchmarks/run.py CLI: --only must fail fast on unknown names, listing
the valid modules, instead of silently running nothing."""
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.run import MODULES, parse_only  # noqa: E402


def test_default_selects_every_module():
    assert parse_only(None) == list(MODULES)


def test_subset_preserves_order_and_dedupes():
    assert parse_only("engine,thm1,engine") == ["engine", "thm1"]


def test_whitespace_tolerated():
    assert parse_only(" engine , population ") == ["engine", "population"]


def test_unknown_name_fails_fast_listing_valid():
    with pytest.raises(SystemExit) as e:
        parse_only("engine,typo_bench")
    msg = str(e.value)
    assert "typo_bench" in msg
    for name in MODULES:
        assert name in msg


def test_empty_selection_fails_fast():
    with pytest.raises(SystemExit) as e:
        parse_only(" , ,")
    assert "selects nothing" in str(e.value)


def test_population_bench_registered():
    assert "population" in MODULES
