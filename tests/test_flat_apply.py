"""Flat-native model execution (core/flat.py view table, DESIGN.md §13).

Three layers of pins:

* **View table** — for every model config in the registry (reduced), each
  leaf view round-trips through the flat buffer (offset/shape/dtype
  exact, lane padding stays zero), including non-lane-multiple leaves and
  the mixed-precision (bf16 leaves / f32 master) dtype rules.
* **Boundary** — ``flat_value_and_grad`` matches the tree
  ``value_and_grad`` at ulp tolerance and ``quantize_int8_flat`` matches
  the per-client-per-leaf tree quantizer exactly.
* **End-to-end** — real LM rounds (gemma-2b transformer + granite-moe
  MoE, reduced) golden-pinned flat vs tree across the sync, cohort and
  buffered-async engines at the flat-layout suite's ulp tolerance.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, reduced
from repro.configs.registry import ARCHS, get_arch
from repro.core import flat, stages
from repro.core.flat import LANES
from repro.data import DeviceLMBatcher, LMFederatedBatcher, lm_sequences
from repro.fed import BufferedAsyncSimulation, FederatedSimulation
from repro.models import model as M

RTOL, ATOL = 1e-6, 1e-7


def _tiny(name: str):
    return reduced(get_arch(name), n_layers=2, d_model=64, vocab=256)


def _abstract_params(cfg):
    return jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def _assert_tree_close(a, b, rtol=RTOL, atol=ATOL):
    for pa, pb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(pa, np.float64),
                                   np.asarray(pb, np.float64),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# view table: every registry config
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ARCHS))
def test_view_table_covers_every_registry_config(name):
    """Offsets/shapes/dtypes of the view table tile [0, n) exactly, for
    every architecture family's (reduced) parameter tree."""
    cfg = _tiny(name)
    spec = flat.make_flat_spec(_abstract_params(cfg))
    leaves = jax.tree.leaves(_abstract_params(cfg))
    assert len(spec.offsets) == len(leaves) > 0
    expect = 0
    for off, shape, size, dtype, lv in zip(spec.offsets, spec.shapes,
                                           spec.sizes, spec.dtypes, leaves):
        assert off == expect                    # contiguous, in tree order
        assert shape == tuple(lv.shape)
        assert dtype == lv.dtype
        assert size == int(np.prod(shape, dtype=np.int64))
        expect += size
    assert expect == spec.n <= spec.p
    assert spec.p % LANES == 0
    # padding is the tail only — no view overlaps it
    assert spec.offsets[-1] + spec.sizes[-1] == spec.n


@pytest.mark.parametrize("name", ["gemma-2b", "granite-moe-1b-a400m",
                                  "zamba2-2.7b", "xlstm-125m"])
def test_leaf_views_round_trip(name):
    """ravel → view_tree reproduces every leaf exactly; flat_cotangent of
    the views reproduces the buffer (pad tail exactly zero)."""
    cfg = _tiny(name)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    spec = flat.make_flat_spec(params)
    buf = flat.ravel(spec, params)
    views = flat.view_tree(spec, buf)
    for got, want in zip(jax.tree.leaves(views), jax.tree.leaves(params)):
        assert got.shape == want.shape and got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    back = flat.flat_cotangent(spec, views)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(buf))
    assert not np.any(np.asarray(buf)[spec.n:])         # pad stays zero


def test_view_table_non_lane_multiple_leaves():
    """Leaves whose sizes are nowhere near LANES multiples still tile the
    buffer contiguously; client-stacked (M, P) views round-trip too."""
    tree = {"a": jnp.arange(15, dtype=jnp.float32).reshape(3, 5),
            "w": {"b": jnp.ones((7,), jnp.float32),
                  "c": jnp.full((2, 2, 3), 2.0, jnp.float32)}}
    spec = flat.make_flat_spec(tree)
    assert spec.n == 34 and spec.p == LANES and spec.n % LANES != 0
    rows = jax.tree.map(lambda a: jnp.stack([a, 2 * a]), tree)
    mat = flat.ravel(spec, rows, client_dims=1)
    assert mat.shape == (2, LANES)
    views = flat.view_tree(spec, mat, client_dims=1)
    for got, want in zip(jax.tree.leaves(views), jax.tree.leaves(rows)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    back = flat.flat_cotangent(spec, views, client_dims=1)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(mat))
    assert not np.any(np.asarray(mat)[:, spec.n:])


def test_view_table_mixed_precision_dtypes():
    """bf16 leaves under an f32 master: the buffer holds f32, every view
    reads bf16 (exactly — bf16→f32→bf16 is lossless), the cotangent
    accumulates at f32, and the pad stays zero."""
    cfg = dataclasses.replace(_tiny("gemma-2b"), dtype="bfloat16")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    spec = flat.make_flat_spec(params, master_dtype=jnp.float32)
    assert spec.dtype == jnp.dtype(jnp.float32)
    assert all(d == jnp.dtype(jnp.bfloat16) for d in spec.dtypes)
    buf = flat.ravel(spec, params)
    assert buf.dtype == jnp.dtype(jnp.float32)
    views = flat.view_tree(spec, buf)
    for got, want in zip(jax.tree.leaves(views), jax.tree.leaves(params)):
        assert got.dtype == jnp.dtype(jnp.bfloat16)
        np.testing.assert_array_equal(
            np.asarray(got, np.float32), np.asarray(want, np.float32))
    back = flat.flat_cotangent(spec, views)
    assert back.dtype == jnp.dtype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(buf))
    assert not np.any(np.asarray(buf)[spec.n:])


# ---------------------------------------------------------------------------
# the flat-native loss boundary
# ---------------------------------------------------------------------------

def test_flat_value_and_grad_matches_tree():
    cfg = _tiny("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    spec = flat.make_flat_spec(params)
    loss_fn = functools.partial(M.lm_loss, cfg=cfg)
    batch = jax.tree.map(
        lambda a: jnp.stack([a[:2], a[2:4]]),
        lm_sequences(jax.random.PRNGKey(1), 4, 16, cfg.vocab))   # (2, 2, S)

    rows = jnp.stack([flat.ravel(spec, params)] * 2)
    loss_f, g_f = jax.jit(jax.vmap(flat.flat_value_and_grad(
        spec, loss_fn)))(rows, batch)

    def tree_grads(tr, b):
        return jax.vmap(jax.value_and_grad(loss_fn))(tr, b)
    trees = jax.tree.map(lambda a: jnp.stack([a] * 2), params)
    loss_t, g_t = jax.jit(tree_grads)(trees, batch)

    np.testing.assert_allclose(np.asarray(loss_f), np.asarray(loss_t),
                               rtol=RTOL)
    np.testing.assert_allclose(np.asarray(g_f, np.float64),
                               np.asarray(flat.ravel_rows(spec, g_t),
                                          np.float64),
                               rtol=RTOL, atol=ATOL)
    assert not np.any(np.asarray(g_f)[:, spec.n:])      # pad stays zero


def test_flat_apply_matches_tree_loss():
    cfg = _tiny("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    spec = flat.make_flat_spec(params)
    batch = lm_sequences(jax.random.PRNGKey(1), 2, 16, cfg.vocab)
    loss_fn = functools.partial(M.lm_loss, cfg=cfg)
    got = jax.jit(lambda b, x: flat.flat_apply(spec, loss_fn, x, b))(
        batch, flat.ravel(spec, params))
    want = jax.jit(loss_fn)(params, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL)


def test_quantize_int8_flat_matches_tree():
    """Segment-wise flat int8 == unravel → stages.quantize_int8 → ravel
    (the per-client-per-leaf scale semantics), bit-for-bit."""
    rng = np.random.default_rng(0)
    tree = {"a": jnp.asarray(rng.normal(size=(3, 4, 5)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(3, 7)).astype(np.float32) * 50)}
    spec = flat.make_flat_spec(jax.tree.map(lambda a: a[0], tree))
    mat = flat.ravel(spec, tree, client_dims=1)
    got = jax.jit(lambda x: flat.quantize_int8_flat(spec, x))(mat)
    want = flat.ravel_rows(spec, stages.quantize_int8(tree))
    # ulp tolerance: XLA fuses the round/scale chain differently per layout
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64),
                               rtol=RTOL, atol=ATOL)
    assert not np.any(np.asarray(got)[:, spec.n:])


# ---------------------------------------------------------------------------
# end-to-end LM golden pins: flat vs tree on every engine
# ---------------------------------------------------------------------------

M_CLIENTS, SEQ, BATCH = 3, 16, 2
FAMILIES = ["gemma-2b", "granite-moe-1b-a400m"]     # transformer + MoE


def _lm_setup(name, device=False):
    cfg = _tiny(name)
    key = jax.random.PRNGKey(0)
    streams = [lm_sequences(jax.random.fold_in(key, i), 16, SEQ, cfg.vocab,
                            skew_topic=i) for i in range(M_CLIENTS)]
    make = DeviceLMBatcher if device else LMFederatedBatcher
    batcher = make(streams, batch_size=BATCH)
    params = M.init_params(key, cfg)
    loss_fn = functools.partial(M.lm_loss, cfg=cfg)
    return (lambda p, b: loss_fn(p, b)), params, batcher


def _fed(layout, **kw):
    base = dict(algorithm="fedagrac", n_clients=M_CLIENTS, k_mean=2,
                lr=0.1, calibration_rate=0.5, param_layout=layout)
    base.update(kw)
    return FedConfig(**base)


@pytest.mark.parametrize("name", FAMILIES)
def test_lm_sync_flat_matches_tree(name):
    final = {}
    for layout in ("tree", "flat"):
        loss_fn, params, batcher = _lm_setup(name)
        sim = FederatedSimulation(loss_fn, params, _fed(layout), batcher,
                                  t_max=2)
        sim.run(2, eval_every=2)                 # one scanned chunk
        final[layout] = sim.params
    _assert_tree_close(final["flat"], final["tree"])


@pytest.mark.parametrize("name", FAMILIES)
def test_lm_cohort_flat_matches_tree(name):
    final = {}
    for layout in ("tree", "flat"):
        loss_fn, params, batcher = _lm_setup(name)
        fed = _fed(layout, cohort_size=2, cohort_sampler="uniform")
        sim = FederatedSimulation(loss_fn, params, fed, batcher, t_max=2)
        sim.run(2, eval_every=2)
        final[layout] = sim.params
    _assert_tree_close(final["flat"], final["tree"])


@pytest.mark.parametrize("name", FAMILIES)
def test_lm_async_flat_matches_tree(name):
    final = {}
    for layout in ("tree", "flat"):
        loss_fn, params, batcher = _lm_setup(name)
        fed = _fed(layout, buffer_size=2, staleness="poly",
                   speed_dist="lognormal", speed_sigma=0.5)
        sim = BufferedAsyncSimulation(loss_fn, params, fed, batcher)
        sim.run(3)
        final[layout] = sim.params
    _assert_tree_close(final["flat"], final["tree"])


def test_lm_device_sampler_flat_matches_tree():
    """DeviceLMBatcher draws inside the scanned chunk identically under
    both layouts — the real-LM device path pin."""
    final = {}
    for layout in ("tree", "flat"):
        loss_fn, params, batcher = _lm_setup("gemma-2b", device=True)
        sim = FederatedSimulation(loss_fn, params, _fed(layout), batcher,
                                  t_max=2)
        sim.run(2, eval_every=2)
        final[layout] = sim.params
    _assert_tree_close(final["flat"], final["tree"])


def test_device_lm_batcher_row_consistency():
    """sample / sample_cohort rows equal sample_row — the invariant that
    makes chunk splits, cohorts and async dispatches draw identically."""
    _, _, b = _lm_setup("gemma-2b", device=True)
    full = b.sample(jnp.int32(3), 2)
    cohort = b.sample_cohort(jnp.int32(3), jnp.asarray([2, 0]), 2)
    for i in range(M_CLIENTS):
        row = b.sample_row(jnp.int32(3), jnp.int32(i), 2)
        np.testing.assert_array_equal(np.asarray(full["tokens"][i]),
                                      np.asarray(row["tokens"]))
    np.testing.assert_array_equal(np.asarray(cohort["tokens"][1]),
                                  np.asarray(full["tokens"][0]))


def test_lm_bf16_master_round_trains():
    """Mixed precision end-to-end: bf16 params/compute, f32 master buffer
    — state stays f32, padding stays zero, the loss moves."""
    cfg = dataclasses.replace(_tiny("gemma-2b"), dtype="bfloat16")
    key = jax.random.PRNGKey(0)
    streams = [lm_sequences(jax.random.fold_in(key, i), 16, SEQ, cfg.vocab,
                            skew_topic=i) for i in range(M_CLIENTS)]
    batcher = LMFederatedBatcher(streams, batch_size=BATCH)
    params = M.init_params(key, cfg)
    loss_fn = functools.partial(M.lm_loss, cfg=cfg)
    fed = _fed("flat", master_dtype="float32", lr=0.3)
    sim = FederatedSimulation(lambda p, b: loss_fn(p, b), params, fed,
                              batcher, t_max=4)
    hist = sim.run(4, eval_every=2)
    assert sim.state["params"].dtype == jnp.dtype(jnp.float32)
    assert sim.state["nu"].dtype == jnp.dtype(jnp.float32)
    spec = sim._spec
    assert not np.any(np.asarray(sim.state["params"])[spec.n:])
    out = sim.params                              # unravels to bf16 leaves
    assert all(lv.dtype == jnp.dtype(jnp.bfloat16)
               for lv in jax.tree.leaves(out))
    assert np.isfinite(hist.loss).all() and hist.loss[-1] < hist.loss[0]


def test_master_dtype_requires_flat_layout():
    with pytest.raises(ValueError, match="master_dtype"):
        FedConfig(master_dtype="float32", param_layout="tree")
    with pytest.raises(ValueError, match="unknown master_dtype"):
        FedConfig(master_dtype="int8", param_layout="flat")
