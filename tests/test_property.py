"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import theory
from repro.data.partition import (dirichlet_partition, gaussian_k_schedule,
                                  iid_partition, shard_partition)
from repro.kernels.calibrated_update import ref as cu_ref
from repro.kernels.calibrated_update.kernel import calibrated_update_2d
from repro.kernels.calibrated_update.ops import (flatten_to_2d,
                                                 unflatten_from_2d)
from repro.roofline import hlo

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# kernel ≡ oracle over random shapes / scalars
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(rows=st.integers(1, 200), kcols=st.integers(1, 3),
       eta=st.floats(0.0, 1.0), lam=st.floats(0.0, 2.0),
       seed=st.integers(0, 2**31 - 1))
def test_calibrated_update_matches_oracle(rows, kcols, eta, lam, seed):
    cols = 128 * kcols
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    x, g, c = (jax.random.normal(k, (rows, cols), jnp.float32) for k in ks)
    got = calibrated_update_2d(x, g, c, eta, lam, interpret=True)
    want = cu_ref.calibrated_update(x, g, c, eta, lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(shapes=st.lists(
    st.tuples(st.integers(1, 20), st.integers(1, 20)), min_size=1,
    max_size=5), seed=st.integers(0, 2**31 - 1))
def test_flatten_roundtrip(shapes, seed):
    key = jax.random.PRNGKey(seed)
    tree = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), s)
            for i, s in enumerate(shapes)}
    mat, metas, treedef, n = flatten_to_2d(tree)
    assert n == sum(a * b for a, b in shapes)
    back = unflatten_from_2d(mat, metas, treedef, n)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree[k]),
                                      np.asarray(back[k]))


# ---------------------------------------------------------------------------
# partition invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n=st.integers(50, 400), m=st.integers(2, 10),
       alpha=st.floats(0.05, 5.0), seed=st.integers(0, 1000))
def test_dirichlet_partition_is_a_partition(n, m, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n)
    parts = dirichlet_partition(labels, m, alpha, seed)
    assert len(parts) == m
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) >= 0.95 * n     # near-total coverage
    for p in parts:
        assert len(p) > 0
        assert np.all(p >= 0) and np.all(p < n)


@settings(**SETTINGS)
@given(m=st.integers(2, 8), cpc=st.integers(1, 5), seed=st.integers(0, 100))
def test_shard_partition_class_limit(m, cpc, seed):
    rng = np.random.default_rng(seed)
    n, n_classes = 2000, 10
    labels = rng.integers(0, n_classes, n)
    parts = shard_partition(labels, m, cpc, seed)
    assert len(parts) == m
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= cpc + 1       # equal volume
    # a contiguous label-sorted shard of size s spans ≤ ceil(s/min_class)+1
    # labels — cpc shards per client multiply that bound
    n_shards = m * cpc
    shard_size = -(-n // n_shards)
    min_class = np.bincount(labels, minlength=n_classes).min()
    span = -(-shard_size // max(min_class, 1)) + 1
    for p in parts:
        assert len(np.unique(labels[p])) <= min(n_classes, cpc * span)
    # partition: disjoint and total
    allidx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(allidx, np.arange(n))


@settings(**SETTINGS)
@given(n=st.integers(10, 500), m=st.integers(1, 10))
def test_iid_partition_exact(n, m):
    parts = iid_partition(n, m)
    allidx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(allidx, np.arange(n))


@settings(**SETTINGS)
@given(m=st.integers(1, 16), mean=st.integers(1, 100),
       var=st.floats(0, 1e4), t=st.integers(1, 20),
       mode=st.sampled_from(["fixed", "random"]))
def test_k_schedule_bounds(m, mean, var, t, mode):
    ks = gaussian_k_schedule(m, mean, var, t, mode=mode, k_min=1)
    assert ks.shape == (t, m)
    assert ks.min() >= 1
    if mode == "fixed":
        assert np.all(ks == ks[0])


# ---------------------------------------------------------------------------
# theory invariants
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), m=st.integers(2, 6), d=st.integers(2, 8))
def test_global_opt_is_stationary(seed, m, d):
    rng = np.random.default_rng(seed)
    As = rng.normal(size=(m, d, d)).astype(np.float64) + 2 * np.eye(d)
    bs = rng.normal(size=(m, d)).astype(np.float64)
    w = rng.dirichlet(np.ones(m))
    x_star = theory.global_optimum(As, bs, w)
    grad = sum(wi * A.T @ (A @ x_star - b) for wi, A, b in zip(w, As, bs))
    np.testing.assert_allclose(grad, 0.0, atol=1e-8)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500))
def test_fixed_point_is_invariant_under_round_map(seed):
    """F(x̃_∞) = x̃_∞ under one exact-gradient FedAvg round."""
    rng = np.random.default_rng(seed)
    m, d, lr = 4, 5, 0.01
    As = rng.normal(size=(m, d, d)) + 2 * np.eye(d)
    bs = rng.normal(size=(m, d))
    w = np.full(m, 0.25)
    ks = rng.integers(1, 6, m)
    fp = theory.fedavg_fixed_point(As, bs, w, ks, lr)
    agg = np.zeros(d)
    for wi, A, b, k in zip(w, As, bs, ks):
        x = fp.copy()
        for _ in range(int(k)):
            x = x - lr * A.T @ (A @ x - b)
        agg += wi * x
    np.testing.assert_allclose(agg, fp, atol=1e-7)


# ---------------------------------------------------------------------------
# HLO cost-model invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(trip=st.integers(1, 64), dim=st.sampled_from([128, 256, 512]))
def test_hlo_trip_count_scales_collectives(trip, dim):
    text = f"""HloModule test

%body (p: (s32[], f32[{dim}])) -> (s32[], f32[{dim}]) {{
  %p = (s32[], f32[{dim}]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[{dim}] get-tuple-element(%p), index=1
  %ar = f32[{dim}] all-reduce(%x), replica_groups={{}}, to_apply=%add
  ROOT %t = (s32[], f32[{dim}]) tuple(%i, %ar)
}}

%cond (p.1: (s32[], f32[{dim}])) -> pred[] {{
  %p.1 = (s32[], f32[{dim}]) parameter(0)
  ROOT %lt = pred[] constant(true)
}}

ENTRY %main (a: f32[{dim}]) -> f32[{dim}] {{
  %a = f32[{dim}] parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[{dim}]) tuple(%zero, %a)
  %w = (s32[], f32[{dim}]) while(%tup), condition=%cond, body=%body, backend_config={{"known_trip_count":{{"n":"{trip}"}}}}
  ROOT %out = f32[{dim}] get-tuple-element(%w), index=1
}}
"""
    cost = hlo.analyze(text)
    assert cost.coll_bytes["all-reduce"] == trip * dim * 4
    assert cost.coll_count["all-reduce"] == trip


def test_hlo_dot_flops():
    text = """HloModule t

ENTRY %main (a: f32[8,16], b: f32[16,32]) -> f32[8,32] {
  %a = f32[8,16] parameter(0)
  %b = f32[16,32] parameter(1)
  ROOT %d = f32[8,32] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    cost = hlo.analyze(text)
    assert cost.flops == 2 * 8 * 32 * 16


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500))
def test_round_engine_permutation_invariant(seed):
    """Permuting (clients, weights, K_i, batches) together must not change
    the aggregated parameters — no client is privileged by position."""
    import jax
    from repro.configs.base import FedConfig
    from repro.core import rounds
    from repro.core.fedopt import get_algorithm
    from repro.models.simple import quad_loss

    m, d, k_max = 4, 5, 3
    rng = np.random.default_rng(seed)
    As = rng.normal(size=(m, k_max, d, d)).astype(np.float32)
    bs = rng.normal(size=(m, k_max, d)).astype(np.float32)
    w = rng.dirichlet(np.ones(m)).astype(np.float32)
    ks = rng.integers(1, k_max + 1, m).astype(np.int32)
    perm = rng.permutation(m)

    fed = FedConfig(algorithm="fedagrac", n_clients=m, lr=0.01,
                    calibration_rate=0.5)
    algo = get_algorithm("fedagrac", fed)
    fn = jax.jit(rounds.make_round(quad_loss, algo, lr=0.01, k_max=k_max))

    def run(order):
        state = rounds.init_state({"x": jnp.zeros((d,), jnp.float32)},
                                  m, algo)
        batches = {"A": jnp.asarray(As[order]), "b": jnp.asarray(bs[order]),
                   "c0": jnp.zeros((m, k_max))}
        out, _ = fn(state, batches, jnp.asarray(ks[order]),
                    jnp.asarray(w[order]))
        return np.asarray(out["params"]["x"]), np.asarray(out["nu"]["x"])

    p1, n1 = run(np.arange(m))
    p2, n2 = run(perm)
    np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(n1, n2, rtol=1e-5, atol=1e-6)
