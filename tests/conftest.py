"""Shared fixtures.  NOTE: no XLA_FLAGS here — unit/smoke tests must see the
default single CPU device.  SPMD tests spawn subprocesses with their own
device counts (test_dist_spmd.py)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
