"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.calibrated_update import ref as cu_ref
from repro.kernels.calibrated_update.kernel import (calibrated_update_2d,
                                                    calibrated_update_prox_2d)
from repro.kernels.calibrated_update.ops import (calibrated_update_prox_tree,
                                                 calibrated_update_tree,
                                                 flatten_to_2d,
                                                 unflatten_from_2d)
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.ops import flash_attention


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# calibrated update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,cols", [(8, 128), (100, 128), (512, 256),
                                       (1000, 384), (3, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_calibrated_update_2d(rows, cols, dtype):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    x, g, c = (_rand(k, (rows, cols), dtype) for k in keys)
    got = calibrated_update_2d(x, g, c, 0.03, 0.7, interpret=True)
    want = cu_ref.calibrated_update(x, g, c, 0.03, 0.7)
    # bf16: a 1-ulp f32 fusion difference (FMA contraction) can straddle a
    # bf16 rounding boundary ⇒ allow one bf16 ulp (2⁻⁸)
    tol = 1e-5 if dtype == jnp.float32 else 2 ** -8
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert got.dtype == x.dtype


@pytest.mark.parametrize("rows,cols", [(8, 128), (100, 128), (512, 256),
                                       (1000, 384), (3, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_calibrated_update_prox_2d(rows, cols, dtype):
    """The prox variant (FedProx baselines) against the jnp oracle — the
    same shape/dtype sweep the plain kernel gets, incl. row counts that
    are not a multiple of any block size and bf16 I/O."""
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    x, g, c, x0 = (_rand(k, (rows, cols), dtype) for k in keys)
    got = calibrated_update_prox_2d(x, g, c, x0, 0.05, 0.5, 0.1,
                                    interpret=True)
    want = cu_ref.calibrated_update_prox(x, g, c, x0, 0.05, 0.5, 0.1)
    tol = 1e-5 if dtype == jnp.float32 else 2 ** -8
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert got.dtype == x.dtype


def test_calibrated_update_prox_2d_traced_scalars_no_recompile():
    """η/λ/μ are SMEM operands — changing them must not retrace."""
    x = _rand(jax.random.PRNGKey(0), (64, 128), jnp.float32)
    f = jax.jit(lambda e, l, m: calibrated_update_prox_2d(
        x, x, x, 0.5 * x, e, l, m, interpret=True))
    a = f(jnp.float32(0.1), jnp.float32(0.0), jnp.float32(0.0))
    b = f(jnp.float32(0.2), jnp.float32(1.0), jnp.float32(0.3))
    assert not np.allclose(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("sizes", [((7, 13), (5,), (2, 3, 4)),   # 110 → pad 18
                                   ((128,),),                    # exact fit
                                   ((129,), (63,))])             # 192 → pad 64
def test_calibrated_update_prox_tree_padding_path(sizes):
    """Ragged trees through ``flatten_to_2d``: the lane-padding tail must
    not leak into any leaf of the prox update (non-multiple-of-LANES
    element counts ⇒ a partially-padded last row)."""
    def mk(key):
        ks = jax.random.split(key, len(sizes))
        return {f"l{i}": _rand(k, s, jnp.float32)
                for i, (k, s) in enumerate(zip(ks, sizes))}
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    x, g, c, x0 = (mk(k) for k in keys)
    got = calibrated_update_prox_tree(x, g, c, x0, 0.05, 0.5, 0.1,
                                      interpret=True)
    want = calibrated_update_prox_tree(x, g, c, x0, 0.05, 0.5, 0.1,
                                       use_pallas=False)
    for k in x:
        assert got[k].shape == x[k].shape
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-6)


def test_calibrated_update_prox_tree_bf16():
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    mk = lambda k: {"w": _rand(k, (33, 17), jnp.bfloat16),
                    "b": _rand(k, (9,), jnp.bfloat16)}
    x, g, c, x0 = (mk(k) for k in keys)
    got = calibrated_update_prox_tree(x, g, c, x0, 0.05, 0.5, 0.1,
                                      interpret=True)
    want = calibrated_update_prox_tree(x, g, c, x0, 0.05, 0.5, 0.1,
                                       use_pallas=False)
    for k in x:
        assert got[k].dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(got[k], np.float32),
                                   np.asarray(want[k], np.float32),
                                   rtol=2 ** -8, atol=2 ** -8)


def test_calibrated_update_traced_scalars_no_recompile():
    """η/λ are SMEM operands — changing them must not retrace."""
    x = _rand(jax.random.PRNGKey(0), (64, 128), jnp.float32)
    f = jax.jit(lambda e, l: calibrated_update_2d(x, x, x, e, l,
                                                  interpret=True))
    a = f(jnp.float32(0.1), jnp.float32(0.0))
    b = f(jnp.float32(0.2), jnp.float32(1.0))
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_flatten_roundtrip_ragged_tree():
    tree = {
        "a": _rand(jax.random.PRNGKey(0), (7, 13), jnp.float32),
        "b": {"c": _rand(jax.random.PRNGKey(1), (5,), jnp.bfloat16),
              "d": _rand(jax.random.PRNGKey(2), (2, 3, 4), jnp.float32)},
    }
    mat, metas, treedef, n = flatten_to_2d(tree)
    assert mat.shape[1] == 128
    back = unflatten_from_2d(mat, metas, treedef, n)
    for k1, k2 in [("a", None), ("b", "c"), ("b", "d")]:
        x = tree[k1] if k2 is None else tree[k1][k2]
        y = back[k1] if k2 is None else back[k1][k2]
        assert x.dtype == y.dtype
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), rtol=1e-2)


def test_calibrated_update_tree_matches_ref():
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    mk = lambda k: {"w": _rand(k, (33, 17), jnp.float32),
                    "b": _rand(k, (9,), jnp.float32)}
    x, g, c = mk(keys[0]), mk(keys[1]), mk(keys[2])
    got = calibrated_update_tree(x, g, c, 0.01, 0.3, interpret=True)
    want = calibrated_update_tree(x, g, c, 0.01, 0.3, use_pallas=False)
    for k in ("w", "b"):
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

CASES = [
    # B, S, H, Hkv, D, window
    (2, 128, 4, 4, 64, 0),        # MHA
    (1, 256, 8, 2, 64, 0),        # GQA 4:1
    (2, 128, 4, 1, 128, 0),       # MQA
    (1, 256, 4, 4, 64, 64),       # sliding window
    (1, 128, 2, 2, 80, 0),        # non-128 head dim (lane padding)
    (1, 512, 2, 1, 64, 128),      # GQA + window
]


@pytest.mark.parametrize("B,S,H,Hkv,D,window", CASES)
def test_flash_attention_vs_ref(B, S, H, Hkv, D, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, Hkv, D), jnp.float32)
    v = _rand(ks[2], (B, S, Hkv, D), jnp.float32)
    got = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                          interpret=True)
    want = fa_ref.attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 128, 4, 64), jnp.bfloat16)
    k = _rand(ks[1], (1, 128, 4, 64), jnp.bfloat16)
    v = _rand(ks[2], (1, 128, 4, 64), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = fa_ref.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)
    assert got.dtype == jnp.bfloat16


def test_flash_attention_block_shape_invariance():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (1, 256, 2, 64), jnp.float32)
    k = _rand(ks[1], (1, 256, 2, 64), jnp.float32)
    v = _rand(ks[2], (1, 256, 2, 64), jnp.float32)
    a = flash_attention(q, k, v, block_q=64, block_k=128, interpret=True)
    b = flash_attention(q, k, v, block_q=256, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)
