"""MoE dispatch correctness: the sort-based capacity dispatch must equal a
dense (every-expert-on-every-token) reference when capacity is unlimited,
and degrade only by dropping when capacity binds."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import init_moe, moe, route


def _cfg(E=4, top_k=2, cap=None) -> ModelConfig:
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=16, vocab=64,
        moe=MoEConfig(n_experts=E, top_k=top_k, d_ff=16,
                      capacity_factor=cap if cap is not None else float(E),
                      aux_loss_coef=0.0))


def dense_moe_ref(params, x, cfg):
    """Every token through every expert, combined by router weights."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    w, ids, _ = route(params["router"], xt, cfg.moe.top_k)
    h = jnp.einsum("td,edf->etf", xt, params["w_in"])
    g = jnp.einsum("td,edf->etf", xt, params["w_gate"])
    out_all = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * h,
                         params["w_out"])                      # (E,T,d)
    y = jnp.zeros_like(xt)
    for j in range(cfg.moe.top_k):
        y = y + w[:, j, None] * jnp.take_along_axis(
            out_all, ids[None, :, j, None], axis=0)[0]
    return y.reshape(B, S, d)


def test_moe_matches_dense_reference_no_drop():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    params = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    got, aux = moe(params, x, cfg)
    want = dense_moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    assert float(aux) == 0.0                                   # coef 0


def test_route_weights_normalized():
    cfg = _cfg(E=8, top_k=3)
    key = jax.random.PRNGKey(2)
    router = jax.random.normal(key, (32, 8))
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
    w, ids, aux = route(router, x, 3)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
    assert int(ids.max()) < 8 and int(ids.min()) >= 0
    # top-k ids distinct per token
    for row in np.asarray(ids):
        assert len(set(row.tolist())) == 3
    assert float(aux) >= 1.0 - 1e-3    # switch aux loss lower bound is 1


def test_capacity_drops_are_bounded():
    """With tight capacity the output differs from dense only on dropped
    tokens, and the shared expert still covers every token."""
    cfg = _cfg(E=4, top_k=2, cap=0.5)
    key = jax.random.PRNGKey(4)
    params = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, 32))
    got, _ = moe(params, x, cfg)
    assert np.all(np.isfinite(np.asarray(got)))
    # dropped-token rows are exactly zero (no shared expert here)
    dense = dense_moe_ref(params, x, cfg)
    diff = np.abs(np.asarray(got - dense)).max(axis=-1)[0]
    kept = diff < 1e-4
    assert kept.sum() >= 4          # capacity 0.5 keeps ≥ E*C/k tokens


def test_moe_gradients_flow_to_all_parts():
    cfg = _cfg()
    key = jax.random.PRNGKey(6)
    params = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 8, 32))

    def loss(p):
        y, aux = moe(p, x, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(params)
    for name in ("router", "w_in", "w_gate", "w_out"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0, name
