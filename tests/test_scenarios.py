"""Failure-scenario layer (fed/scenarios.py, DESIGN.md §12): pure
per-(seed, round, client) draws, partial-work recovery, abort/rejoin
timelines, trace-driven clocks, config validation, and the zero-fault
golden pins."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import stages
from repro.core.fedopt import ALGORITHMS, get_algorithm
from repro.data import DeviceBatcher, fedprox_synthetic
from repro.fed import (BufferedAsyncSimulation, ClientPopulation,
                       FederatedSimulation, SCENARIOS, Scenario,
                       diurnal_scenario, dropout_scenario, flaky_scenario,
                       make_clock, make_scenario, simulate_timeline,
                       spike_scenario, trace_scenario)
from repro.models.simple import lr_accuracy, lr_loss

M = 8


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    batcher = DeviceBatcher(data, parts, batch_size=8, seed=0)
    return batcher


def _fed(**kw):
    kw.setdefault("algorithm", "fedagrac")
    kw.setdefault("k_mean", 5)
    kw.setdefault("k_var", 2.0)
    kw.setdefault("k_mode", "random")
    return FedConfig(n_clients=M, lr=0.05, calibration_rate=0.5, **kw)


def _params():
    return {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}


def _leaves_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _scenarios_under_test():
    return [dropout_scenario(M, rate=0.5, seed=3),
            spike_scenario(M, rate=0.5, magnitude=4.0, seed=3),
            flaky_scenario(M, rate=0.4, magnitude=3.0, seed=3)]


# ---------------------------------------------------------------------------
# config validation (satellite: fail at construction, not in jit)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("field,bad,expect", [
    ("algorithm", "fedsgd", "fedagrac"),
    ("cohort_sampler", "random", "uniform"),
    ("param_layout", "dense", "flat"),
    ("server_opt", "lamb", "momentum"),
    ("scenario", "meteor", "dropout"),
    ("staleness", "exp", "poly"),
    ("speed_dist", "zipf", "trace"),
    ("weights", "mass", "uniform"),
    ("k_mode", "poisson", "random"),
])
def test_config_validation_lists_valid_options(field, bad, expect):
    """Unknown registry names raise ValueError at construction, naming the
    field, the bad value, and the valid options."""
    with pytest.raises(ValueError) as e:
        FedConfig(**{field: bad})
    msg = str(e.value)
    assert field in msg and repr(bad) in msg and expect in msg


def test_config_valid_everything_constructs():
    FedConfig(algorithm="fednova", cohort_sampler="availability",
              param_layout="flat", server_opt="adam", scenario="spike",
              staleness="poly", speed_dist="bimodal", weights="data",
              k_mode="random")


def test_trace_scenario_config_points_to_explicit_builder():
    with pytest.raises(ValueError, match="trace_scenario"):
        make_scenario(FedConfig(scenario="trace"))


# ---------------------------------------------------------------------------
# scenario draws: pure in (seed, round, client)
# ---------------------------------------------------------------------------

def test_registry_names_and_baseline_is_none():
    assert {"baseline", "dropout", "diurnal", "spike", "flaky",
            "trace"} <= set(SCENARIOS)
    assert make_scenario(FedConfig(n_clients=M)) is None
    assert make_scenario(FedConfig(n_clients=M,
                                   scenario="baseline")) is None
    assert make_scenario(FedConfig(n_clients=M,
                                   scenario="dropout")).perturbs_k


def test_dropout_draws_bounded_and_deterministic():
    scn = dropout_scenario(M, rate=0.6, seed=7)
    row = np.full(M, 6)
    dropped = 0
    for t in range(50):
        k1 = scn.host_k_eff(t, row)
        k2 = scn.host_k_eff(t, row)
        np.testing.assert_array_equal(k1, k2)       # pure in (seed, t, i)
        assert np.all(k1 >= 1) and np.all(k1 <= row)
        dropped += int((k1 < row).sum())
    frac = dropped / (50 * M)
    assert 0.4 < frac < 0.8                          # ≈ rate
    # K_i = 1 clients cannot abort mid-round: no deliverable prefix
    ones = np.ones(M, np.int64)
    for t in range(10):
        np.testing.assert_array_equal(scn.host_k_eff(t, ones), ones)


def test_distinct_rounds_and_seeds_give_distinct_draws():
    row = np.full(M, 9)
    a = dropout_scenario(M, rate=0.5, seed=0)
    b = dropout_scenario(M, rate=0.5, seed=1)
    tdiff = [not np.array_equal(a.host_k_eff(t, row),
                                a.host_k_eff(t + 1, row))
             for t in range(8)]
    sdiff = [not np.array_equal(a.host_k_eff(t, row),
                                b.host_k_eff(t, row)) for t in range(8)]
    assert any(tdiff) and any(sdiff)


def test_subset_eval_matches_full_row():
    """The O(C) cohort-form evaluation (ids given) must equal the full-row
    draw indexed at ids — the per-client keying contract that keeps host
    mirrors and in-scan hooks bit-identical."""
    row = np.arange(2, M + 2)
    ids = jnp.asarray([5, 1, 6], jnp.int32)
    for scn in _scenarios_under_test():
        for t in (0, 3, 11):
            full_k = scn.host_k_eff(t, row)
            sub_k = np.asarray(scn.k_eff(t, jnp.asarray(row[np.asarray(ids)],
                                                        jnp.int32), ids=ids))
            np.testing.assert_array_equal(full_k[np.asarray(ids)], sub_k)
            np.testing.assert_array_equal(
                scn.host_speed_factor(t)[np.asarray(ids)],
                np.asarray(scn.speed_factor(t, ids=ids), np.float64))
            np.testing.assert_array_equal(
                scn.host_latency_extra(t)[np.asarray(ids)],
                np.asarray(scn.latency_extra(t, ids=ids), np.float64))


def test_draws_identical_under_jit():
    """Eager and jitted evaluation agree bitwise (the host-mirror
    contract)."""
    scn = dropout_scenario(M, rate=0.5, seed=2)
    row = jnp.full((M,), 7, jnp.int32)
    eager = scn.k_eff(5, row)
    jitted = jax.jit(lambda t, k: scn.k_eff(t, k))(jnp.int32(5), row)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))


def test_spike_couples_keff_and_speed():
    """A spiked (round, client) is slowed AND step-capped by the SAME event
    draw; unspiked entries are untouched."""
    scn = spike_scenario(M, rate=1.0, magnitude=4.0, frac=0.5, seed=0)
    row = np.full(M, 8)
    hit_any = False
    for t in range(10):
        k = scn.host_k_eff(t, row)
        f = scn.host_speed_factor(t)
        hit = f < 1.0
        np.testing.assert_array_equal(k[hit], 2)     # ceil(8/4)
        np.testing.assert_array_equal(k[~hit], 8)
        np.testing.assert_allclose(f[hit], 0.25)
        hit_any = hit_any or hit.any()
    assert hit_any


def test_diurnal_hemispheres_in_antiphase():
    scn = diurnal_scenario(M, period=2.0, floor=0.0, seed=0)
    a0 = scn.host_avail(0)
    np.testing.assert_allclose(a0[: M // 2], 1.0, atol=1e-6)
    np.testing.assert_allclose(a0[M // 2:], 0.0, atol=1e-6)
    a1 = scn.host_avail(1)
    np.testing.assert_allclose(a1[: M // 2], 0.0, atol=1e-6)
    np.testing.assert_allclose(a1[M // 2:], 1.0, atol=1e-6)


def test_trace_scenario_tables_cycle_and_validate():
    tbl = np.linspace(0.5, 2.0, 3 * M).reshape(3, M)
    scn = trace_scenario(tbl, avail=np.full((3, M), 0.5))
    for t in range(7):
        np.testing.assert_allclose(scn.host_speed_factor(t), tbl[t % 3],
                                   rtol=1e-6)
        np.testing.assert_allclose(scn.host_avail(t), 0.5)
    with pytest.raises(ValueError, match="positive"):
        trace_scenario(np.zeros((2, M)))
    with pytest.raises(ValueError, match="shape"):
        trace_scenario(np.ones(M))
    with pytest.raises(ValueError, match="share shape"):
        trace_scenario(np.ones((2, M)), avail=np.ones((4, M)))


def test_delivered_weights_rule():
    w = jnp.asarray([0.5, 0.25, 0.25], jnp.float32)
    out = stages.delivered_weights(w, jnp.asarray([2, 4, 1]),
                                   jnp.asarray([4, 4, 1]))
    np.testing.assert_allclose(np.asarray(out), [0.25, 0.25, 0.25])


def test_scenario_m_mismatch_raises(task):
    scn = dropout_scenario(M + 1, rate=0.2)
    with pytest.raises(ValueError, match="does not match"):
        FederatedSimulation(lr_loss, _params(), _fed(), task, scenario=scn)
    with pytest.raises(ValueError, match="does not match"):
        BufferedAsyncSimulation(lr_loss, _params(),
                                _fed(buffer_size=4), task, scenario=scn)


# ---------------------------------------------------------------------------
# trace-driven clock (satellite)
# ---------------------------------------------------------------------------

def test_make_clock_trace_roundtrip():
    speeds = np.asarray([1.0, 2.0, 0.5, 4.0])
    lat = np.asarray([0.1, 0.0, 0.3, 0.2])
    clock = make_clock(4, dist="trace", speeds=speeds, latency=lat)
    np.testing.assert_array_equal(clock.speeds, speeds)
    np.testing.assert_array_equal(clock.latency, lat)
    assert clock.duration(2, 6) == pytest.approx(6 / 0.5 + 0.3)
    # round-trips through simulate_timeline: identical to a hand-built
    # ClientClock with the same arrays
    ks = np.full((10, 4), 3)
    tl = simulate_timeline(ks, clock, 2, 8)
    from repro.fed import ClientClock
    tl2 = simulate_timeline(ks, ClientClock(speeds=speeds, latency=lat),
                            2, 8)
    for f in ("ids", "versions", "waves", "k_steps", "arrival_t",
              "k_sched", "aborted"):
        np.testing.assert_array_equal(getattr(tl, f), getattr(tl2, f))


def test_make_clock_trace_validates():
    with pytest.raises(ValueError, match="needs an explicit speeds"):
        make_clock(4, dist="trace")
    with pytest.raises(ValueError, match="shape"):
        make_clock(4, dist="trace", speeds=np.ones(3))
    with pytest.raises(ValueError, match="positive"):
        make_clock(2, dist="trace", speeds=np.asarray([1.0, 0.0]))
    with pytest.raises(ValueError, match="only valid"):
        make_clock(4, dist="lognormal", speeds=np.ones(4))
    with pytest.raises(ValueError, match="valid options"):
        make_clock(4, dist="warp")


# ---------------------------------------------------------------------------
# scenario timelines: determinism, aborts, rejoin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["fixed", "lognormal"])
@pytest.mark.parametrize("idx", [0, 1, 2])
def test_timeline_deterministic_and_prefix_stable(dist, idx):
    """Property over scenarios × clocks: perturbed timelines are
    bit-identical across repeated simulation, and a T-update timeline is
    the prefix of the 2T one (the resumability contract)."""
    scn = _scenarios_under_test()[idx]
    clock = make_clock(M, dist=dist, seed=4)
    ks = np.full((40, M), 6)
    a = simulate_timeline(ks, clock, 3, 10, scenario=scn)
    b = simulate_timeline(ks, clock, 3, 10, scenario=scn)
    c = simulate_timeline(ks, clock, 3, 20, scenario=scn)
    for f in ("ids", "versions", "waves", "k_steps", "staleness",
              "arrival_t", "fresh", "dispatch_ids", "k_sched", "aborted"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
        np.testing.assert_array_equal(getattr(a, f), getattr(c, f)[:10])


def test_timeline_aborts_and_k_sched():
    scn = dropout_scenario(M, rate=1.0, seed=0)
    ks = np.full((20, M), 6)
    tl = simulate_timeline(ks, make_clock(M, dist="fixed"), M, 5,
                           scenario=scn)
    np.testing.assert_array_equal(tl.k_sched, 6)
    assert tl.aborted.all()                       # rate 1, K > 1
    assert np.all(tl.k_steps >= 1) and np.all(tl.k_steps < 6)
    # durations follow k′: a 2-step abort reports before a 5-step one
    base = simulate_timeline(ks, make_clock(M, dist="fixed"), M, 5)
    assert tl.arrival_t[-1, -1] < base.arrival_t[-1, -1]


def test_rejoin_delay_penalizes_aborted_clients():
    """A deterministic always-abort scenario (k′ independent of the round)
    isolates the rejoin penalty: same k′ stream, strictly later arrivals."""
    def _half(rejoin):
        return Scenario("halfwork", M, rejoin_delay=rejoin,
                        k_eff=lambda key, t, ids, k: jnp.maximum(k // 2, 1))
    ks = np.full((20, M), 6)
    clock = make_clock(M, dist="fixed")
    t0 = simulate_timeline(ks, clock, M, 6, scenario=_half(0.0))
    t5 = simulate_timeline(ks, clock, M, 6, scenario=_half(5.0))
    # same events, same k′ draws — only the downtime shifts later arrivals
    np.testing.assert_array_equal(t0.k_steps, t5.k_steps)
    assert t0.aborted.all() and t5.aborted.all()
    assert t5.arrival_t[-1, -1] >= t0.arrival_t[-1, -1] + 5.0
    # update 0 happens before any rejoin penalty can apply
    np.testing.assert_array_equal(t0.arrival_t[0], t5.arrival_t[0])


def test_flaky_timeline_shifts_arrivals_only():
    scn = flaky_scenario(M, rate=0.8, magnitude=4.0, seed=1)
    ks = np.full((20, M), 5)
    clock = make_clock(M, dist="lognormal", seed=2)
    tl = simulate_timeline(ks, clock, 3, 8, scenario=scn)
    base = simulate_timeline(ks, clock, 3, 8)
    np.testing.assert_array_equal(tl.k_sched, 5)
    assert not tl.aborted.any()
    assert tl.arrival_t[-1, -1] > base.arrival_t[-1, -1]


def test_diurnal_dispatch_profile_follows_phase():
    """The async dispatch profile tracks the availability hook: at phase 0
    hemisphere A is dispatchable, half a period later hemisphere B is."""
    pop = ClientPopulation(M, cohort_size=3, sampler="availability",
                           seed=0)
    pop.availability_fn = diurnal_scenario(M, period=2.0,
                                           floor=0.0).availability_fn
    p0 = pop._dispatch_profile(0)
    p1 = pop._dispatch_profile(1)
    assert p0[: M // 2].sum() > 0.99 and p0[M // 2:].sum() < 0.01
    assert p1[: M // 2].sum() < 0.01 and p1[M // 2:].sum() > 0.99


def test_diurnal_cohorts_follow_phase(task):
    """The availability sampler draws from the up hemisphere."""
    fed = _fed(scenario="diurnal", scenario_period=2.0, cohort_size=3,
               cohort_sampler="availability", availability=1.0)
    sim = FederatedSimulation(lr_loss, _params(), fed, task)
    assert sim.population.availability_fn is not None
    hemi_a, hemi_b = set(range(M // 2)), set(range(M // 2, M))
    a_hits = b_hits = 0
    for t in range(0, 20, 2):          # phase-0 rounds: hemisphere A up
        ids = set(np.asarray(sim.population.host_cohort(t)[0]).tolist())
        a_hits += len(ids & hemi_a)
        b_hits += len(ids & hemi_b)
    assert a_hits > 5 * max(b_hits, 1)


# ---------------------------------------------------------------------------
# zero-fault golden pin: baseline ≡ unperturbed engines
# ---------------------------------------------------------------------------

def _noop_scenario():
    """Identity hooks: the scenario plumbing engages on every path but
    perturbs nothing — multiplications by exactly 1.0 and additions of 0.0,
    which must leave every float bit untouched."""
    return Scenario("noop", M, seed=0,
                    k_eff=lambda key, t, ids, k: k,
                    speed=lambda key, t, ids: jnp.ones(ids.shape,
                                                       jnp.float32),
                    latency=lambda key, t, ids: jnp.zeros(ids.shape,
                                                          jnp.float32))


@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_noop_scenario_bit_identical_sync(task, algorithm):
    """Zero-fault pin, all 9 algorithms on the sync engine: a no-op
    scenario routes through every scenario branch yet reproduces the
    baseline state bit-for-bit."""
    ref = FederatedSimulation(lr_loss, _params(), _fed(algorithm=algorithm),
                              task)
    ref.run(3, eval_every=3)
    scn = FederatedSimulation(lr_loss, _params(), _fed(algorithm=algorithm),
                              task, scenario=_noop_scenario())
    scn.run(3, eval_every=3)
    _leaves_equal(ref.state, scn.state)


@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("algorithm", ["fedavg", "fednova", "fedagrac"])
def test_noop_scenario_bit_identical_cohort(task, algorithm, layout):
    fed = _fed(algorithm=algorithm, cohort_size=4, param_layout=layout)
    ref = FederatedSimulation(lr_loss, _params(), fed, task)
    ref.run(4, eval_every=2)
    scn = FederatedSimulation(lr_loss, _params(), fed, task,
                              scenario=_noop_scenario())
    scn.run(4, eval_every=2)
    _leaves_equal(ref.state, scn.state)


@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("algorithm", ["fedavg", "fednova", "fedagrac"])
def test_noop_scenario_bit_identical_async(task, algorithm, layout):
    fed = _fed(algorithm=algorithm, buffer_size=4, param_layout=layout,
               staleness="poly")
    ref = BufferedAsyncSimulation(lr_loss, _params(), fed, task)
    ref.run(4)
    scn = BufferedAsyncSimulation(lr_loss, _params(), fed, task,
                                  scenario=_noop_scenario())
    scn.run(4)
    _leaves_equal(ref.state, scn.state)


def test_baseline_config_resolves_to_none_path(task):
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(scenario="baseline"), task)
    asim = BufferedAsyncSimulation(lr_loss, _params(),
                                   _fed(scenario="baseline",
                                        buffer_size=4), task)
    assert sim.scenario is None and asim.scenario is None


# ---------------------------------------------------------------------------
# partial-work recovery: pinned against an explicit k′-step reference
# ---------------------------------------------------------------------------

def _kprime_reference_schedule(sim, t_rounds):
    """The realized k′ table, padded with one unused max-K row so the
    reference simulation compiles the same k_max scan (bit-identity needs
    identical scan lengths and batch draws)."""
    kp = np.stack([sim._k_row(t) for t in range(t_rounds)])
    pad = np.full((1, M), sim.k_max, kp.dtype)
    return np.concatenate([kp, pad])


@pytest.mark.parametrize("algorithm", ["fedavg", "fednova", "fedagrac"])
def test_dropout_equals_explicit_kprime_schedule(task, algorithm):
    """Sync full participation: the dropout scenario is bit-identical to
    literally running the realized k′ schedule — partial work IS the
    masked-K_i mechanism, fed with k′."""
    fed = _fed(algorithm=algorithm, scenario="dropout", dropout_rate=0.5)
    sim = FederatedSimulation(lr_loss, _params(), fed, task)
    hist = sim.run(5, eval_every=5)
    ref = FederatedSimulation(
        lr_loss, _params(), _fed(algorithm=algorithm), task,
        k_schedule=_kprime_reference_schedule(sim, 5))
    ref.run(5, eval_every=5)
    _leaves_equal(sim.state, ref.state)
    assert len(hist.dropped) == 5 and max(hist.dropped) > 0


def test_dropout_equals_explicit_kprime_schedule_flat(task):
    fed = _fed(scenario="dropout", dropout_rate=0.5, param_layout="flat")
    sim = FederatedSimulation(lr_loss, _params(), fed, task)
    sim.run(4, eval_every=4)
    ref = FederatedSimulation(
        lr_loss, _params(), _fed(param_layout="flat"), task,
        k_schedule=_kprime_reference_schedule(sim, 4))
    ref.run(4, eval_every=4)
    _leaves_equal(sim.state, ref.state)


def test_async_partial_work_reference(task):
    """Buffered-async, buffer = M, fixed clock: one server update under a
    deterministic half-work scenario equals the explicit stage-level
    reference computed with k′ and delivered-fraction weights."""
    # uniform k′ keeps durations equal, so the first buffer is exactly one
    # report per client on wave 0 (heterogeneous k′ would let fast clients
    # report twice before stragglers finish)
    half = Scenario("half", M,
                    k_eff=lambda key, t, ids, k: jnp.maximum(k // 2, 1))
    fed = _fed(algorithm="fedavg", k_var=0.0, k_mode="fixed",
               buffer_size=M, speed_dist="fixed")
    sim = BufferedAsyncSimulation(lr_loss, _params(), fed, task,
                                  scenario=half)
    sim.run(1)

    # reference: client_update at k′ + buffered mean with w̃·k′/K
    k_sched = np.full(M, fed.k_mean)
    k_eff = np.asarray(half.host_k_eff(0, k_sched))
    algo = get_algorithm("fedavg", fed)
    cu = stages.make_client_update(lr_loss, algo, lr=fed.lr,
                                   k_max=sim.k_max, per_client_anchor=True)
    params = _params()
    anchors = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (M,) + p.shape), params)
    batches = jax.vmap(
        lambda i: task.sample_row(jnp.int32(0), i, sim.k_max))(
            jnp.arange(M, dtype=jnp.int32))
    c_b = stages.zero_corrections(params, M)
    x_b, _, _, _ = cu(anchors, c_b, batches, jnp.asarray(k_eff, jnp.int32),
                      jnp.float32(algo.lam))
    w = np.full(M, 1.0 / M, np.float32)
    sw = np.asarray(stages.delivered_weights(
        jnp.asarray(w), jnp.asarray(k_eff), jnp.asarray(k_sched)))
    kf = jnp.asarray(k_eff, jnp.float32)
    kbar = jnp.dot(jnp.asarray(sw), kf) / np.sum(sw)
    expect = stages.buffered_mean(params, anchors, x_b, kf,
                                  jnp.asarray(sw), kbar)
    got = sim.state["params"]
    for le, lg in zip(jax.tree.leaves(expect), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(le), np.asarray(lg),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# perturbed runs: determinism across chunk splits, histories, engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario,knobs", [
    ("dropout", {"dropout_rate": 0.5}),
    ("spike", {"scenario_rate": 0.6, "scenario_magnitude": 3.0}),
])
def test_perturbed_run_bit_identical_across_chunk_splits(task, scenario,
                                                         knobs):
    fed = _fed(scenario=scenario, **knobs)
    a = FederatedSimulation(lr_loss, _params(), fed, task)
    a.run(6, eval_every=6)
    b = FederatedSimulation(lr_loss, _params(), fed, task)
    b.run(6, eval_every=2)
    c = FederatedSimulation(lr_loss, _params(), fed, task)
    c.run(6, eval_every=1)          # per-round compat path
    _leaves_equal(a.state, b.state)
    _leaves_equal(a.state, c.state)


def test_cohort_dropout_bit_identical_device_vs_host_paths(task):
    """Partial participation under dropout: the in-scan scenario hook
    (device chunk) and the host-precomputed per-round path agree
    bit-for-bit — the pure-draw contract end to end."""
    fed = _fed(scenario="dropout", dropout_rate=0.5, cohort_size=4)
    a = FederatedSimulation(lr_loss, _params(), fed, task)
    a.run(6, eval_every=3)          # device: in-scan hook
    b = FederatedSimulation(lr_loss, _params(), fed, task)
    b.run(6, eval_every=1)          # host: eager mirrors
    _leaves_equal(a.state, b.state)


def test_async_dropout_deterministic_and_weighted(task):
    fed = _fed(scenario="dropout", dropout_rate=0.6, buffer_size=4,
               rejoin_delay=1.0)
    a = BufferedAsyncSimulation(lr_loss, _params(), fed, task)
    ha = a.run(6)
    b = BufferedAsyncSimulation(lr_loss, _params(), fed, task)
    hb = b.run(6)
    _leaves_equal(a.state, b.state)
    assert ha.dropped == hb.dropped and len(ha.dropped) == 6
    assert max(ha.dropped) > 0
    # delivered-fraction weighting: dropped reports carry less mass
    base = BufferedAsyncSimulation(lr_loss, _params(),
                                   _fed(buffer_size=4), task)
    hbase = base.run(6)
    assert np.mean(ha.mass) < np.mean(hbase.mass)


def test_history_dropped_tracks_rate(task):
    fed = _fed(scenario="dropout", dropout_rate=0.4)
    sim = FederatedSimulation(lr_loss, _params(), fed, task)
    hist = sim.run(20, eval_every=20)
    assert len(hist.dropped) == 20
    assert all(0.0 <= d <= 1.0 for d in hist.dropped)
    assert 0.15 < float(np.mean(hist.dropped)) < 0.65
    # flaky perturbs only timing: sync dropped fraction is identically 0
    fsim = FederatedSimulation(lr_loss, _params(),
                               _fed(scenario="flaky"), task)
    fh = fsim.run(3, eval_every=3)
    assert fh.dropped == [0.0, 0.0, 0.0]


def test_flaky_sync_bit_identical_to_baseline(task):
    """Flaky networks delay reports, not work: the synchronous engine is
    bit-identical to baseline under the flaky scenario."""
    ref = FederatedSimulation(lr_loss, _params(), _fed(), task)
    ref.run(3, eval_every=3)
    scn = FederatedSimulation(lr_loss, _params(), _fed(scenario="flaky"),
                              task)
    scn.run(3, eval_every=3)
    _leaves_equal(ref.state, scn.state)


def test_scenario_round_time():
    scn = spike_scenario(M, rate=1.0, magnitude=2.0, frac=0.5, seed=0)
    clock = make_clock(M, dist="fixed", latency=0.5)
    row = np.full(M, 8)
    k = scn.host_k_eff(0, row).astype(np.float64)
    f = scn.host_speed_factor(0)
    expect = float(np.max(k / f + 0.5))
    assert scn.round_time(clock, 0, row) == pytest.approx(expect)
