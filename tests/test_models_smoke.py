"""Per-architecture smoke tests on REDUCED variants (per instructions):
2 layers, d_model ≤ 512, ≤ 4 experts — one forward/train step on CPU,
asserting output shapes + no NaNs; plus decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.models import model as M

ARCH_NAMES = sorted(ARCHS)


def make_batch(cfg, B=2, S=32, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.frontend == "audio":
        codes = jax.random.randint(key, (B, cfg.n_codebooks, S), 0, cfg.vocab)
        return {"codes": codes, "labels": codes}
    if cfg.frontend == "vision":
        embeds = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        t = jnp.broadcast_to(jnp.arange(S), (B, S))
        positions = jnp.stack([t, t % 4, t % 8], axis=1)
        labels = jax.random.randint(key, (B, S), 0, cfg.vocab)
        return {"embeds": embeds, "positions": positions, "labels": labels}
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


def slice_step(batch, s):
    """One-position slice of a prompt batch for incremental decode."""
    out = {}
    for k, v in batch.items():
        if k == "labels":
            continue
        if k == "codes":
            out[k] = v[:, :, s:s + 1]
        elif k == "positions":
            out[k] = v[:, :, s:s + 1]
        else:
            out[k] = v[:, s:s + 1]
    return out


@pytest.fixture(scope="module")
def params_cache():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(ARCHS[name])
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name, params_cache):
    cfg, params = params_cache(name)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, _, aux = M.forward(params, batch, cfg)
    if cfg.frontend == "audio":
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_no_nans(name, params_cache):
    cfg, params = params_cache(name)
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(M.lm_loss)(params, batch, cfg)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_forward(name, params_cache):
    """Prefill S₀ then decode token-by-token == full forward (cache
    correctness across every block kind).

    MoE archs use no-drop capacity here: finite-capacity token dropping is
    context-length dependent (a 4-token prefill and an 8-token forward drop
    different tokens), so exact equality only holds without drops."""
    import dataclasses

    cfg, params = params_cache(name)
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    B, S0, S = 2, 8, 12
    full = make_batch(cfg, B, S)
    logits_full, _, _ = M.forward(params, full, cfg)

    caches = M.init_caches(cfg, B, max_len=S, dtype=jnp.float32)
    prompt = {k: v for k, v in full.items() if k != "labels"}
    pre = jax.tree.map(
        lambda v: v[:, :, :S0] if v.ndim == 3 and v.shape[1] in (3, cfg.n_codebooks or -1) and v.shape[-1] == S else v[:, :S0],
        prompt)
    # build prefill slice per modality explicitly
    if cfg.frontend == "audio":
        pre = {"codes": prompt["codes"][:, :, :S0]}
    elif cfg.frontend == "vision":
        pre = {"embeds": prompt["embeds"][:, :S0],
               "positions": prompt["positions"][:, :, :S0]}
    else:
        pre = {"tokens": prompt["tokens"][:, :S0]}
    logits_pre, caches = M.serve_decode(params, pre, caches, 0, cfg)
    np.testing.assert_allclose(np.asarray(logits_pre[:, -1]),
                               np.asarray(logits_full[:, S0 - 1]),
                               rtol=2e-3, atol=2e-3)
    for s in range(S0, S):
        step = slice_step(prompt, s)
        logits_s, caches = M.serve_decode(params, step, caches, s, cfg)
        np.testing.assert_allclose(
            np.asarray(logits_s[:, 0]), np.asarray(logits_full[:, s]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{name}: decode mismatch at position {s}")


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_respects_limits(name):
    cfg = reduced(ARCHS[name])
    assert cfg.n_layers <= 2 or cfg.hybrid_attn_every
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    assert cfg.family == ARCHS[name].family


def test_param_count_close_to_exact():
    """Analytic param_count within 2% of the real init for every arch."""
    for name in ARCH_NAMES:
        cfg = reduced(ARCHS[name])
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(est - real) / real < 0.02, (name, est, real)
