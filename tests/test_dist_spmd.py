"""SPMD integration tests — each spawns a subprocess with its own host
device count (XLA locks the count at first init; the main pytest process
must stay single-device for the smoke tests)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_round_matches_single_device():
    """The FedaGrac LM round on a (4,2) mesh == the unsharded round."""
    run_py(r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import FedConfig, reduced
from repro.configs.registry import get_arch
from repro.configs.base import ShapeConfig
from repro.core import rounds
from repro.core.fedopt import get_algorithm
from repro.dist import set_mesh_rules, unset_mesh, use_mesh
from repro.launch.mesh import make_local_mesh
from repro.launch import train as train_lib, specs as specs_lib
from repro.models import model as M

cfg = reduced(get_arch("llama3-8b"), n_layers=2, d_model=128)
fed = FedConfig(algorithm="fedagrac", lr=0.05, calibration_rate=0.5)
algo = get_algorithm("fedagrac", fed)
k_max, m, b, s = 2, 4, 2, 16

key = jax.random.PRNGKey(0)
params = M.init_params(key, cfg)
toks = jax.random.randint(key, (m, k_max, b, s), 0, cfg.vocab)
batches = {"tokens": toks, "labels": toks}
ks = jnp.array([1, 2, 2, 1], jnp.int32)
w = jnp.full((m,), 0.25, jnp.float32)
loss = lambda p, bt: M.lm_loss(p, bt, cfg)

# --- single device ---------------------------------------------------------
unset_mesh()
state0 = rounds.init_state(params, m, algo)
fn = jax.jit(rounds.make_round(loss, algo, lr=fed.lr, k_max=k_max))
ref_state, ref_metrics = fn(state0, batches, ks, w)

# --- (data=4, model=2) mesh --------------------------------------------------
mesh = make_local_mesh(4, 2)
shape = ShapeConfig("t", seq_len=s, global_batch=m * b, kind="train")
with use_mesh(mesh):
    jitted, bundle = train_lib.build_train_round(cfg, shape, mesh, fed,
                                                 k_max=k_max)
    state0b = rounds.init_state(params, m, algo)
    sh = lambda t: specs_lib.to_shardings(t, mesh)
    ps = bundle["pspecs"]
    state0b = jax.device_put(state0b, sh(ps["state"]))
    batches_s = jax.device_put(batches, sh(ps["batches"]))
    spmd_state, spmd_metrics = jitted(state0b, batches_s,
                                      jax.device_put(ks, sh(ps["k_steps"])),
                                      jax.device_put(w, sh(ps["weights"])))

for pref, pspmd in zip(jax.tree.leaves(ref_state["params"]),
                       jax.tree.leaves(spmd_state["params"])):
    np.testing.assert_allclose(np.asarray(pref, np.float32),
                               np.asarray(pspmd, np.float32),
                               rtol=2e-4, atol=2e-5)
for nref, nspmd in zip(jax.tree.leaves(ref_state["nu"]),
                       jax.tree.leaves(spmd_state["nu"])):
    np.testing.assert_allclose(np.asarray(nref, np.float32),
                               np.asarray(nspmd, np.float32),
                               rtol=2e-4, atol=2e-5)
assert abs(float(ref_metrics["loss"]) - float(spmd_metrics["loss"])) < 1e-3
print("SPMD==single OK", float(ref_metrics["loss"]))
""")


def test_sharded_decode_matches_single_device():
    run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ShapeConfig, reduced
from repro.configs.registry import get_arch
from repro.dist import unset_mesh, use_mesh
from repro.launch.mesh import make_local_mesh
from repro.launch import serve as serve_lib
from repro.models import model as M

cfg = reduced(get_arch("llama3-8b"), n_layers=2, d_model=128)
B, S = 8, 32
key = jax.random.PRNGKey(0)
params = M.init_params(key, cfg)
toks = jax.random.randint(key, (B, 1), 0, cfg.vocab)
caches = M.init_caches(cfg, B, max_len=S, dtype=jnp.float32)

unset_mesh()
ref_logits, _ = M.serve_decode(params, {"tokens": toks}, caches, 0, cfg)

mesh = make_local_mesh(4, 2)
shape = ShapeConfig("d", seq_len=S, global_batch=B, kind="decode")
with use_mesh(mesh):
    jitted, bundle = serve_lib.build_decode(cfg, shape, mesh, kind="decode")
    spmd_logits, _ = jitted(params, {"tokens": toks}, caches,
                            jnp.zeros((), jnp.int32))
np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(spmd_logits),
                           rtol=2e-4, atol=2e-4)
print("decode SPMD OK")
""")


def test_dryrun_cli_small_mesh():
    """The dryrun module itself must import cleanly and its helpers work on
    a real (tiny) mesh inside a 512-device subprocess is too slow here; we
    check skip logic + one reduced lower/compile on 8 devices instead."""
    run_py(r"""
import jax, jax.numpy as jnp
from repro.configs.base import FedConfig, ShapeConfig, reduced
from repro.configs.registry import get_arch
from repro.launch.mesh import make_local_mesh
from repro.launch import train as train_lib
from repro.roofline import analysis as roofline

cfg = reduced(get_arch("granite-moe-1b-a400m"), n_layers=2, d_model=128)
mesh = make_local_mesh(4, 2)
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
lowered, bundle = train_lib.lower_train(cfg, shape, mesh,
                                        FedConfig(algorithm="fedagrac"),
                                        k_max=2)
compiled = lowered.compile()
rl = roofline.from_compiled(compiled, 8,
                            roofline.train_model_flops(cfg, 8 * 32 * 2))
d = rl.as_dict()
assert d["flops_per_chip"] > 0
assert d["t_memory_s"] > 0
print("dryrun-small OK", d["dominant"])
""")


def test_dryrun_skip_logic():
    """long_500k is skipped for pure full-attention archs and run for
    sub-quadratic ones (importing dryrun mutates XLA_FLAGS ⇒ subprocess)."""
    out = run_py(r"""
from repro.launch.dryrun import skip_reason
assert skip_reason("llama3-8b", "long_500k") is not None
assert skip_reason("qwen1.5-32b", "long_500k") is not None
assert skip_reason("zamba2-2.7b", "long_500k") is None
assert skip_reason("xlstm-125m", "long_500k") is None
assert skip_reason("gemma3-12b", "long_500k") is None
assert skip_reason("llama3-8b", "train_4k") is None
print("skip logic OK")
""", devices=1, timeout=300)
    assert "skip logic OK" in out


def test_host_client_slice_local_mesh():
    """Single-host: every client's slice is local ⇒ [0, n_clients)."""
    out = run_py(r"""
import jax
from repro.launch.mesh import make_local_mesh
from repro.launch.distributed import host_client_slice, bootstrap
bootstrap()                      # no-op without cluster env
mesh = make_local_mesh(4, 2)
lo, hi = host_client_slice(mesh)
assert (lo, hi) == (0, 4), (lo, hi)
print("host slice OK")
""", devices=8, timeout=600)
    assert "host slice OK" in out
