"""Launch-layer spec construction (pure shape logic — no devices)."""
import math

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS, get_arch
from repro.configs.shapes import SHAPES
from repro.core.fedopt import get_algorithm
from repro.launch import specs as specs_lib


class FakeMesh:
    def __init__(self, shape: dict):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})
MESH_2D = FakeMesh({"data": 16, "batch": 4, "model": 4})
ALGO = get_algorithm("fedagrac", FedConfig(algorithm="fedagrac"))


def test_train_specs_shapes_single_pod():
    cfg = specs_lib.bf16_config(get_arch("llama3-8b"))
    b = specs_lib.train_specs(cfg, SHAPES["train_4k"], MESH, ALGO, k_max=4)
    assert b["m"] == 16 and b["b_local"] == 16
    toks = b["specs"]["batches"]["tokens"]
    assert toks.shape == (16, 4, 16, 4096)
    assert b["pspecs"]["batches"]["tokens"][0] in ("data", ("data",))
    # state: nu_i carries the client axis on data
    nui_embed = b["pspecs"]["state"]["nu_i"]["embed"]
    assert nui_embed[0] in ("data", ("data",))
    assert "model" in nui_embed


def test_train_specs_multi_pod_doubles_clients():
    cfg = specs_lib.bf16_config(get_arch("llama3-8b"))
    b = specs_lib.train_specs(cfg, SHAPES["train_4k"], MESH_MP, ALGO,
                              k_max=4)
    assert b["m"] == 32 and b["b_local"] == 8
    assert b["pspecs"]["batches"]["tokens"][0] == ("pod", "data")


def test_train_specs_2d_shards_microbatch():
    cfg = specs_lib.bf16_config(get_arch("llama3-8b"))
    b = specs_lib.train_specs(cfg, SHAPES["train_4k"], MESH_2D, ALGO,
                              k_max=4)
    assert b["pspecs"]["batches"]["tokens"][2] == "batch"


@pytest.mark.parametrize("arch", ["musicgen-medium", "qwen2-vl-2b"])
def test_frontend_batch_specs(arch):
    cfg = specs_lib.bf16_config(get_arch(arch))
    b = specs_lib.train_specs(cfg, SHAPES["train_4k"], MESH, ALGO, k_max=2)
    keys = set(b["specs"]["batches"])
    if arch == "musicgen-medium":
        assert keys == {"codes", "labels"}
        assert b["specs"]["batches"]["codes"].shape[3] == cfg.n_codebooks
    else:
        assert keys == {"embeds", "positions", "labels"}
        assert b["specs"]["batches"]["positions"].shape[3] == 3


def test_serve_specs_decode_vs_long():
    cfg = specs_lib.bf16_config(get_arch("zamba2-2.7b"))
    dec = specs_lib.serve_specs(cfg, SHAPES["decode_32k"], MESH,
                                kind="decode")
    assert dec["batch"]["tokens"].shape == (128, 1)
    lng = specs_lib.serve_specs(cfg, SHAPES["long_500k"], MESH, kind="long")
    assert lng["batch"]["tokens"].shape == (1, 1)
    # long decode: some cache leaf is sequence-sharded over data

    def has_data_on_seq(ps_tree):
        found = []
        jax.tree_util.tree_map_with_path(
            lambda p, ps: found.append("data" in tuple(
                a for a in ps if a is not None and not isinstance(a, tuple))
                or any(isinstance(a, tuple) and "data" in a for a in ps)),
            ps_tree, is_leaf=lambda x: isinstance(x, P))
        return any(found)

    assert has_data_on_seq(lng["cache_ps"])


def test_population_train_specs_shapes():
    """Population cohort round (DESIGN.md §10): batches/cohort/k/cweights
    are cohort-sized (C = mesh clients) while nu_i keeps M_pop rows,
    row-sharded over the data axes."""
    cfg = specs_lib.bf16_config(get_arch("llama3-8b"))
    b = specs_lib.population_train_specs(cfg, SHAPES["train_4k"], MESH,
                                         ALGO, m_population=4096, k_max=4)
    assert b["m"] == 16 and b["m_population"] == 4096
    assert b["specs"]["batches"]["tokens"].shape == (16, 4, 16, 4096)
    assert b["specs"]["cohort"].shape == (16,)
    assert b["specs"]["cweights"].shape == (16,)
    # population-sized calibration state: M_pop rows, data-sharded
    nui_embed = b["specs"]["state"]["nu_i"]["embed"]
    assert nui_embed.shape[0] == 4096
    ps = b["pspecs"]["state"]["nu_i"]["embed"]
    assert ps[0] in ("data", ("data",))
    with pytest.raises(ValueError):
        specs_lib.population_train_specs(cfg, SHAPES["train_4k"], MESH,
                                         ALGO, m_population=8, k_max=4)


def test_abstract_params_no_allocation():
    cfg = specs_lib.bf16_config(get_arch("qwen1.5-32b"))
    params = specs_lib.abstract_params(cfg)
    leaves = jax.tree.leaves(params)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    total = sum(math.prod(l.shape) for l in leaves)
    assert abs(total - cfg.param_count()) / cfg.param_count() < 0.02


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_every_arch_every_shape_specs_build(arch):
    """Spec construction (the pre-lowering half of the dry-run) works for
    all 40 combos without touching devices."""
    cfg = specs_lib.bf16_config(get_arch(arch))
    for shape_name, kind in (("train_4k", "train"), ("prefill_32k",
                             "prefill"), ("decode_32k", "decode"),
                             ("long_500k", "long")):
        shape = SHAPES[shape_name]
        if kind == "train":
            specs_lib.train_specs(cfg, shape, MESH, ALGO, k_max=2)
        else:
            specs_lib.serve_specs(cfg, shape, MESH, kind=kind)


def test_flat_train_specs_shard_flat_axis():
    """Flat layout (core/flat.py, DESIGN.md §11): the round state collapses
    to (P,) vectors / (M, P) client matrices — the P axis (lane-padded to a
    multiple of 128) shards over the model axes with ONE rule, ν⁽ⁱ⁾ client
    rows over the data axes."""
    cfg = specs_lib.bf16_config(get_arch("llama3-8b"))
    b = specs_lib.flat_train_specs(cfg, SHAPES["train_4k"], MESH, ALGO,
                                   k_max=4)
    fs = b["flat_spec"]
    assert fs.p % 128 == 0 and fs.p >= fs.n
    st = b["specs"]["state"]
    assert st["params"].shape == (fs.p,)
    assert st["nu"].shape == (fs.p,)
    assert st["nu_i"].shape == (16, fs.p)
    ps = b["pspecs"]["state"]
    assert ps["params"] == P("model")
    assert ps["nu"] == P("model")
    assert ps["nu_i"][0] in ("data", ("data",)) and "model" in ps["nu_i"]
    # batches are layout-independent (the loss boundary still sees them)
    assert b["specs"]["batches"]["tokens"].shape == (16, 4, 16, 4096)


def test_flat_state_pspecs_replicates_when_indivisible():
    """A model size that does not divide the padded P leaves the flat axis
    replicated instead of producing an invalid spec."""
    mesh = FakeMesh({"data": 4, "model": 3})
    state = {"params": jax.ShapeDtypeStruct((256,), jnp.float32),
             "round": jax.ShapeDtypeStruct((), jnp.int32)}
    ps = specs_lib.flat_state_pspecs(state, mesh, 256)
    assert ps["params"] == P(None)
    assert ps["round"] == P()
