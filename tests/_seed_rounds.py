"""FROZEN copy of the pre-refactor round engine (seed commit 4f5b781).

Golden reference for tests/test_golden_equivalence.py ONLY — the live engine
is the layered composition in src/repro/core/stages.py.  Do not edit; do not
import outside the tests.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.fedopt import Algorithm

PyTree = Any


def tree_zeros(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_stack_zeros(tree: PyTree, m: int) -> PyTree:
    return jax.tree.map(lambda a: jnp.zeros((m,) + a.shape, a.dtype), tree)


def init_state(params: PyTree, n_clients: int, algo: Algorithm) -> dict:
    """Server + client state.  ν/ν⁽ⁱ⁾ start at zero: the first round then
    runs plain (uncalibrated) local SGD, matching the paper's init where
    ν⁽ⁱ⁾ = ∇f_i(x₁) is unknown before any gradient is computed."""
    state = {"params": params, "round": jnp.zeros((), jnp.int32)}
    if algo.uses_nu:
        state["nu"] = tree_zeros(params)
        state["nu_i"] = tree_stack_zeros(params, n_clients)
    if algo.server_opt == "momentum":
        state["server_m"] = tree_zeros(params)
    elif algo.server_opt == "adam":
        state["server_m"] = tree_zeros(params)
        state["server_v"] = tree_zeros(params)
    return state


def _server_update(algo: Algorithm, state: dict, params0: PyTree,
                   agg: PyTree, new_state: dict) -> PyTree:
    """FedOpt server step on the round pseudo-gradient Δ = agg − x̃_t
    (Reddi et al. 2021).  server_opt="sgd", server_lr=1 reproduces plain
    averaging exactly."""
    delta = jax.tree.map(
        lambda a, p: a.astype(jnp.float32) - p.astype(jnp.float32),
        agg, params0)
    lr, b1 = algo.server_lr, algo.server_beta1
    if algo.server_opt == "sgd":
        if lr == 1.0:
            return agg
        return jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + lr * d).astype(p.dtype),
            params0, delta)
    if algo.server_opt == "momentum":                   # FedAvgM
        m = jax.tree.map(lambda mm, d: b1 * mm.astype(jnp.float32) + d,
                         state["server_m"], delta)
        new_state["server_m"] = jax.tree.map(
            lambda mm, p: mm.astype(p.dtype), m, params0)
        return jax.tree.map(
            lambda p, mm: (p.astype(jnp.float32) + lr * mm).astype(p.dtype),
            params0, m)
    if algo.server_opt == "adam":                       # FedAdam
        b2, eps = 0.999, 1e-8
        t = state["round"].astype(jnp.float32) + 1.0
        m = jax.tree.map(
            lambda mm, d: b1 * mm.astype(jnp.float32) + (1 - b1) * d,
            state["server_m"], delta)
        v = jax.tree.map(
            lambda vv, d: b2 * vv.astype(jnp.float32) + (1 - b2) * d * d,
            state["server_v"], delta)
        new_state["server_m"] = jax.tree.map(
            lambda mm, p: mm.astype(p.dtype), m, params0)
        new_state["server_v"] = jax.tree.map(
            lambda vv, p: vv.astype(p.dtype), v, params0)
        bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t
        return jax.tree.map(
            lambda p, mm, vv: (p.astype(jnp.float32)
                               + lr * (mm / bc1)
                               / (jnp.sqrt(vv / bc2) + eps)).astype(p.dtype),
            params0, m, v)
    raise ValueError(algo.server_opt)


def quantize_int8(tree: PyTree) -> PyTree:
    """Per-client-per-leaf symmetric int8 fake-quantization of the
    transmitted orientation (beyond-paper comms ablation): scale =
    amax/127 over each client's tensor, round-to-nearest.  Halves the ν
    upload vs bf16; EXPERIMENTS.md reports the accuracy cost."""
    def q(a):
        red = tuple(range(1, a.ndim))
        scale = jnp.max(jnp.abs(a.astype(jnp.float32)), axis=red,
                        keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        return (jnp.round(a.astype(jnp.float32) / scale) * scale
                ).astype(a.dtype)
    return jax.tree.map(q, tree)


def make_round(loss_fn: Callable[[PyTree, PyTree], jax.Array],
               algo: Algorithm, *, lr: float, k_max: int,
               track_nu: str = "delta",
               spmd_axis_name=None,
               quantize_transmit: bool = False,
               param_constraint: Optional[Callable[[PyTree, int], PyTree]] = None):
    """Build ``round_fn(state, batches, k_steps, weights) -> (state, metrics)``.

    batches: pytree with leading dims (M, k_max, ...) — one microbatch per
    client per local step.  k_steps: (M,) int32.  weights: (M,) fp32 ω_i.
    ``param_constraint(tree, n_client_dims)`` optionally pins shardings at
    round boundaries.
    """
    needs_first = algo.strategy in ("fedagrac", "first", "reverse")
    grad_fn = jax.value_and_grad(loss_fn)

    def constrain(tree, client_dims):
        if param_constraint is None:
            return tree
        return param_constraint(tree, client_dims)

    def round_fn(state: dict, batches: PyTree, k_steps: jax.Array,
                 weights: jax.Array):
        params0 = state["params"]
        m = k_steps.shape[0]
        kbar = jnp.dot(weights, k_steps.astype(jnp.float32))

        if algo.uses_nu:
            c_all = jax.tree.map(lambda nu, nui: (nu[None] - nui) if nui.ndim
                                 else nu - nui, state["nu"], state["nu_i"])
        else:
            # zero-size placeholder keeps the vmap signature uniform
            c_all = jax.tree.map(
                lambda a: jnp.zeros((m,) + (0,) * a.ndim, a.dtype), params0)

        def client_run(c_i, batch_i, K_i):
            lam_c = (jax.tree.map(lambda c: algo.lam * c, c_i)
                     if algo.uses_nu else None)

            def step(carry, xs):
                k, batch_k = xs
                x, g0, nu_acc = carry
                loss, g = grad_fn(x, batch_k)
                if algo.prox_mu:
                    g = jax.tree.map(lambda gg, xx, x0: gg + algo.prox_mu * (xx - x0),
                                     g, x, params0)
                active = k < K_i
                if algo.uses_nu:
                    upd = jax.tree.map(lambda xx, gg, cc: xx - lr * (gg + cc),
                                       x, g, lam_c)
                else:
                    upd = jax.tree.map(lambda xx, gg: xx - lr * gg, x, g)
                x = jax.tree.map(lambda old, new: jnp.where(active, new, old),
                                 x, upd)
                if needs_first:
                    g0 = jax.tree.map(lambda a, gg: jnp.where(k == 0, gg, a),
                                      g0, g)
                if track_nu == "explicit" and algo.uses_nu:
                    w = jnp.where(active, 1.0 / K_i.astype(jnp.float32), 0.0)
                    nu_acc = jax.tree.map(lambda a, gg: a + w * gg, nu_acc, g)
                return (x, g0, nu_acc), loss

            g0_0 = tree_zeros(params0) if needs_first else jnp.zeros(())
            acc_0 = (tree_zeros(params0)
                     if (track_nu == "explicit" and algo.uses_nu)
                     else jnp.zeros(()))
            (x, g0, nu_acc), losses = jax.lax.scan(
                step, (params0, g0_0, acc_0),
                (jnp.arange(k_max), batch_i))
            return x, g0, nu_acc, losses[0]

        x_i, g0_i, acc_i, loss0 = jax.vmap(
            client_run, spmd_axis_name=spmd_axis_name)(c_all, batches, k_steps)
        x_i = constrain(x_i, 1)

        kf = k_steps.astype(jnp.float32)

        def wsum(tree):
            # accumulate the client average in f32, return in the state
            # dtype: f32 weights would otherwise promote the whole round
            # state to f32 — doubling every activation/grad collective and
            # breaking state-dtype stability across rounds (§Perf #3)
            return jax.tree.map(
                lambda a: jnp.einsum(
                    "m,m...->...", weights,
                    a.astype(jnp.float32)).astype(a.dtype), tree)

        # ---- aggregation --------------------------------------------------
        if algo.normalize:                                  # FedNova
            deltas = jax.tree.map(
                lambda xi, p0: (xi.astype(jnp.float32) - p0[None])
                / _expand(kf, xi), x_i, params0)
            new_params = jax.tree.map(
                lambda p0, d: (p0 + kbar * jnp.einsum("m,m...->...", weights,
                                                      d)).astype(p0.dtype),
                params0, deltas)
        else:
            new_params = wsum(x_i)

        new_state = dict(state)
        new_params = _server_update(algo, state, params0, new_params,
                                    new_state)
        new_params = constrain(new_params, 0)
        new_state["params"] = new_params
        new_state["round"] = state["round"] + 1

        # ---- orientation update (Alg. 1, lines 11/14/23) -------------------
        if algo.uses_nu:
            if track_nu == "explicit":
                avg_g = acc_i
            else:
                avg_g = jax.tree.map(
                    lambda x0, xi, ci: ((x0[None].astype(jnp.float32)
                                         - xi.astype(jnp.float32))
                                        / (lr * _expand(kf, xi))
                                        - algo.lam * ci.astype(jnp.float32)
                                        ).astype(x0.dtype),
                    params0, x_i, c_all)
            if algo.strategy == "avg":
                transmit = avg_g
            elif algo.strategy == "first":
                transmit = g0_i
            else:
                # K_i > K̄ with a tie tolerance: K_i are integers (spacing
                # 1) but K̄ is an f32 dot whose summation ORDER can leave
                # it 1 ulp under an exact tie — without the epsilon, a
                # client-permutation flips every tied client from "slow"
                # (send averaged) to "fast" (send first), found by the
                # permutation-invariance property test
                fast = kf > kbar + 1e-4 * jnp.maximum(kbar, 1.0)  # (M,)
                pick = (lambda f, a: jnp.where(_expand_b(fast, a), f, a)) \
                    if algo.strategy == "fedagrac" else \
                    (lambda f, a: jnp.where(_expand_b(fast, a), a, f))
                transmit = jax.tree.map(pick, g0_i, avg_g)
            if quantize_transmit:
                transmit = quantize_int8(transmit)
            new_state["nu"] = constrain(wsum(transmit), 0)
            # Line 11: the *local* reference ν⁽ⁱ⁾ is always the averaged grad
            new_state["nu_i"] = constrain(avg_g, 1)

        metrics = {"loss": jnp.dot(weights, loss0), "kbar": kbar}
        return new_state, metrics

    return round_fn


def _expand(v: jax.Array, like: jax.Array) -> jax.Array:
    """(M,) -> (M, 1, 1, ...) broadcastable against like (M, ...)."""
    return v.reshape((-1,) + (1,) * (like.ndim - 1))


def _expand_b(v: jax.Array, like: jax.Array) -> jax.Array:
    return v.reshape((-1,) + (1,) * (like.ndim - 1))
