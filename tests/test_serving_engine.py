"""Continuous-batching engine: exactness vs per-request greedy decoding,
slot reuse, ragged phases."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import get_arch
from repro.models import model as M
from repro.serving import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_arch("llama3-8b"), n_layers=2, d_model=128)
    cfg = dataclasses.replace(cfg, vocab=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def reference_generate(cfg, params, prompt: np.ndarray, n_new: int
                       ) -> list[int]:
    """Unpadded per-request greedy generation (ground truth)."""
    caches = M.init_caches(cfg, 1, max_len=256, dtype=jnp.float32)
    toks = jnp.asarray(prompt)[None]
    logits, caches = M.serve_prefill(params, {"tokens": toks}, cfg,
                                     caches=caches)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, caches = M.serve_decode(
            params, {"tokens": jnp.asarray([[out[-1]]])}, caches, pos, cfg)
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


def test_engine_matches_per_request_greedy(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab, size=n).astype(np.int32),
                    max_new_tokens=m)
            for i, (n, m) in enumerate([(5, 6), (16, 4), (9, 8), (12, 3),
                                        (3, 10), (16, 5)])]
    eng = ServeEngine(cfg, params, slots=3, max_len=256,
                      prefill_buckets=(8, 16))
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == len(reqs)
    by_uid = {c.uid: c for c in done}
    for r in reqs:
        want = reference_generate(cfg, params, r.prompt, r.max_new_tokens)
        got = by_uid[r.uid].tokens
        assert got == want, (r.uid, got, want)


def test_engine_slot_reuse_and_ragged_phases(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    # more requests than slots with very different lengths → slots recycle
    reqs = [Request(uid=100 + i,
                    prompt=rng.integers(1, cfg.vocab, 4 + i).astype(np.int32),
                    max_new_tokens=2 + (i % 5)) for i in range(7)]
    eng = ServeEngine(cfg, params, slots=2, max_len=128,
                      prefill_buckets=(16,))
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert sorted(c.uid for c in done) == sorted(r.uid for r in reqs)
    for c in done:
        assert len(c.tokens) == next(r.max_new_tokens for r in reqs
                                     if r.uid == c.uid)


def test_engine_eos_frees_slot(setup):
    cfg, params = setup
    prompt = np.asarray([5, 6, 7], np.int32)
    want = reference_generate(cfg, params, prompt, 8)
    eos = want[2]                       # force an early stop at token 3
    eng = ServeEngine(cfg, params, slots=1, max_len=64,
                      prefill_buckets=(8,))
    eng.submit(Request(uid=7, prompt=prompt, max_new_tokens=8, eos_id=eos))
    done = eng.run()
    assert len(done) == 1 and done[0].tokens == want[:3]


def test_engine_rejects_ssm(setup):
    cfg = reduced(get_arch("xlstm-125m"))
    with pytest.raises(AssertionError):
        ServeEngine(cfg, {}, slots=1)


def test_admission_bound_sheds_overflow(setup):
    """max_pending caps the queue: overflow submissions are shed (counted,
    not raised), the admitted ones complete normally, and the default
    stays unbounded."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    reqs = [Request(uid=200 + i,
                    prompt=rng.integers(1, cfg.vocab, 4).astype(np.int32),
                    max_new_tokens=2) for i in range(5)]
    eng = ServeEngine(cfg, params, slots=1, max_len=64,
                      prefill_buckets=(8,), max_pending=2)
    for r in reqs:
        eng.submit(r)
    assert len(eng.queue) == 2 and eng.dropped == 3
    done = eng.run()
    assert sorted(c.uid for c in done) == [200, 201]
    assert eng.dropped == 3                   # run() drops nothing more
    eng2 = ServeEngine(cfg, params, slots=1, max_len=64,
                       prefill_buckets=(8,))
    for r in reqs:
        eng2.submit(dataclasses.replace(r))
    assert len(eng2.queue) == 5 and eng2.dropped == 0


def test_replay_and_latency_stats_surface_dropped(setup):
    from repro.serving import LoadGen, latency_stats, replay
    cfg, params = setup
    gen = LoadGen(population=4, rate=3.0, prompt_len=(2, 4),
                  max_new=(2, 3), vocab=cfg.vocab, seed=0)
    trace = gen.generate(8)
    eng = ServeEngine(cfg, params, slots=1, max_len=64,
                      prefill_buckets=(8,), max_pending=1)
    stats = replay(eng, trace)
    assert stats["dropped"] == eng.dropped > 0
    # every trace request either completed or was shed — none lost
    assert stats["n_requests"] + stats["dropped"] == len(trace)
    lat = latency_stats(stats["tick_wall"], dropped=stats["dropped"])
    assert lat["dropped"] == float(stats["dropped"])
    assert latency_stats([], dropped=2)["dropped"] == 2.0


def test_sampling_independent_of_coscheduled_traffic(setup):
    """A request's sampled tokens depend only on (uid, step) — serving it
    alone and serving it among other traffic are bit-identical, for a
    key-USING sampler (determinism pin: keys are fold_in(PRNGKey(uid),
    step), never a function of tick count or batch composition)."""
    cfg, params = setup
    sampler = lambda logits, key: jax.random.categorical(key, logits)
    rng = np.random.default_rng(7)
    target = Request(uid=42,
                     prompt=rng.integers(1, cfg.vocab, 9).astype(np.int32),
                     max_new_tokens=6)
    noise = [Request(uid=i,
                     prompt=rng.integers(1, cfg.vocab, 4 + i).astype(
                         np.int32),
                     max_new_tokens=3 + i) for i in range(4)]

    def serve(reqs, slots):
        eng = ServeEngine(cfg, params, slots=slots, max_len=128,
                          prefill_buckets=(8, 16), sampler=sampler)
        for r in reqs:
            eng.submit(dataclasses.replace(r))
        return {c.uid: c.tokens for c in eng.run()}

    alone = serve([target], 1)[42]
    crowded = serve(noise[:2] + [target] + noise[2:], 3)[42]
    assert alone == crowded
    # and admission ORDER does not matter either
    reordered = serve([target] + noise, 2)[42]
    assert alone == reordered
