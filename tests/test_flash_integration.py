"""Flash-attention model integration: the kernel path (forced interpret)
must match the q-block-scan path on losses AND gradients for real archs."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os, jax, jax.numpy as jnp, numpy as np
from repro.configs.base import reduced
from repro.configs.registry import get_arch
from repro.models import model as M

def run(arch, S):
    cfg = reduced(get_arch(arch))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    os.environ["REPRO_FLASH_ATTENTION"] = "off"
    l0, g0 = jax.value_and_grad(M.lm_loss)(params, batch, cfg)
    l0 = float(l0)
    os.environ["REPRO_FLASH_ATTENTION"] = "interpret"
    l1, g1 = jax.value_and_grad(M.lm_loss)(params, batch, cfg)
    l1 = float(l1)
    assert abs(l0 - l1) < 2e-4 * max(abs(l0), 1), (arch, l0, l1)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-3)
    print(arch, "OK", l0)

run("llama3-8b", 128)           # GQA
run("gemma-2b", 128)            # MQA, head_dim pad (d_model/heads != 128)
run("deepseek-v2-lite-16b", 128)  # MLA prefill path
run("gemma3-12b", 128)          # sliding-window local layers
print("ALL OK")
"""


def test_flash_model_path_matches_scan_path():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("REPRO_FLASH_ATTENTION", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=2400)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL OK" in out.stdout
