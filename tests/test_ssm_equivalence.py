"""Deep equivalence tests for the recurrent blocks:

* Mamba2 chunked SSD scan == naive per-step recurrence (the chunked form
  is an exact algebraic refactoring, not an approximation);
* mLSTM stabilized parallel form == per-step recurrence (the stabilizer
  m_t = F_t + cummax(log ĩ_s − F_s) equals the recurrent running max).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.mamba2 import ssd_chunked
from repro.models.xlstm import _mlstm_parallel


def naive_ssd(x, dt, A, B, C):
    """Literal SSM recurrence: S_t = exp(dt_t A) S_{t-1} + dt_t x_t B_tᵀ."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xb = (x * dt[..., None]).astype(jnp.float32)
    decay = jnp.exp(dt * A[None, None, :])                    # (b,l,h)

    def step(S, t):
        S = (decay[:, t][..., None, None] * S
             + jnp.einsum("bhp,bhn->bhpn", xb[:, t], Bh[:, t]))
        y = jnp.einsum("bhn,bhpn->bhp", Ch[:, t], S)
        return S, y

    S0 = jnp.zeros((b, h, p, n), jnp.float32)
    S_last, ys = jax.lax.scan(step, S0, jnp.arange(l))
    return ys.transpose(1, 0, 2, 3), S_last


def test_ssd_chunked_equals_recurrence():
    key = jax.random.PRNGKey(0)
    b, l, h, p, g, n = 2, 64, 4, 16, 2, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, l, g, n))
    C = jax.random.normal(ks[4], (b, l, g, n))
    for chunk in (8, 16, 64):
        y_chunk, S_chunk = ssd_chunked(x, dt, A, B, C, chunk)
        y_naive, S_naive = naive_ssd(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"chunk={chunk}")
        np.testing.assert_allclose(np.asarray(S_chunk), np.asarray(S_naive),
                                   rtol=1e-4, atol=1e-4)


def naive_mlstm(q, k, v, log_i, log_f):
    """Literal stabilized mLSTM recurrence."""
    B, S, H, hd = q.shape
    scale = hd ** -0.5
    C = jnp.zeros((B, H, hd, hd), jnp.float32)
    n = jnp.zeros((B, H, hd), jnp.float32)
    m = jnp.full((B, H), -jnp.inf, jnp.float32)
    outs = []
    for t in range(S):
        li, lf = log_i[:, t], log_f[:, t]
        m_new = jnp.maximum(lf + m, li)
        i_s = jnp.exp(li - m_new)
        f_s = jnp.exp(lf + m - m_new)
        k0 = k[:, t].astype(jnp.float32) * scale
        v0 = v[:, t].astype(jnp.float32)
        q0 = q[:, t].astype(jnp.float32)
        C = (f_s[..., None, None] * C
             + i_s[..., None, None] * jnp.einsum("bhd,bhe->bhde", k0, v0))
        n = f_s[..., None] * n + i_s[..., None] * k0
        num = jnp.einsum("bhd,bhde->bhe", q0, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q0)),
                          jnp.exp(-m_new))
        outs.append(num / den[..., None])
        m = m_new
    return jnp.stack(outs, axis=1)


def test_mlstm_parallel_equals_recurrence():
    key = jax.random.PRNGKey(1)
    B, S, H, hd = 2, 48, 2, 16
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    log_i = jax.random.normal(ks[3], (B, S, H))
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    got = _mlstm_parallel(q, k, v, log_i, log_f, block_q=16)
    want = naive_mlstm(q, k, v, log_i, log_f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
