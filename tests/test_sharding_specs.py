"""Sharding-rule unit tests (pure functions — no devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.launch import specs as specs_lib


class _Key:
    def __init__(self, key):
        self.key = key


def _path(*names):
    return tuple(_Key(n) for n in names)


def test_name_rules_attention():
    # wq (d, H*hd): shard output dim
    assert specs_lib.param_pspec(_path("attn", "wq"), (512, 1024), 16) \
        == P(None, "model")
    # wo (H*hd, d): shard contract dim
    assert specs_lib.param_pspec(_path("attn", "wo"), (1024, 512), 16) \
        == P("model", None)


def test_moe_expert_axis_first():
    # (E, d, f) with E divisible -> expert parallel
    assert specs_lib.param_pspec(_path("moe", "w_in"), (32, 512, 128), 16) \
        == P("model", None, None)
    assert specs_lib.param_pspec(_path("moe", "w_out"), (32, 128, 512), 16) \
        == P("model", None, None)
    # E=4 not divisible by 16 -> falls through to mlp-style rule
    ps = specs_lib.param_pspec(_path("moe", "w_in"), (4, 512, 128), 16)
    assert ps == P(None, None, "model") or ps == P(None, "model", None)


def test_embed_vocab_sharding():
    assert specs_lib.param_pspec(_path("embed"), (128256, 512), 16) \
        == P("model", None)
    assert specs_lib.param_pspec(_path("head"), (512, 128256), 16) \
        == P(None, "model")
    # audio: stacked codebook embeddings (K, vocab, d)
    assert specs_lib.param_pspec(_path("embed"), (4, 2048, 512), 16) \
        == P(None, "model", None)


def test_segments_leading_stack_dims_never_sharded():
    # (n_groups, count, d, f) under "segments"
    ps = specs_lib.param_pspec(
        _path("segments", "0", "mlp", "w_in"), (32, 1, 512, 2048), 16)
    assert ps == P(None, None, None, "model")


def test_indivisible_replicates():
    assert specs_lib.param_pspec(_path("x", "norm"), (511,), 16) == P(None)
    assert specs_lib.param_pspec(_path("x", "scale"), (7,), 16) == P(None)


def test_generic_fallback_largest_dim():
    ps = specs_lib.param_pspec(_path("seg", "conv_w"), (4, 4096), 16)
    assert ps == P(None, "model")


def test_tree_pspecs_client_axes():
    tree = {"segments": [{"mlp": {"w_in": jnp.zeros((2, 1, 64, 256))}}],
            "embed": jnp.zeros((1024, 64))}
    # client-stacked (nu_i): leading M dim on data axes
    stacked = jax.tree.map(lambda a: jnp.zeros((8,) + a.shape), tree)
    ps = specs_lib.tree_pspecs(stacked, 16, client_axes=("data",))
    assert ps["embed"][0] == "data"
    assert ps["embed"][1] == "model"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_arch_pspecs_valid(arch):
    """Every full-size param leaf gets a spec whose sharded dims divide."""
    cfg = specs_lib.bf16_config(ARCHS[arch])
    params = specs_lib.abstract_params(cfg)
    pspecs = specs_lib.tree_pspecs(params, 16)

    def check(path, leaf, ps):
        for dim, ax in enumerate(ps):
            if ax is None:
                continue
            assert leaf.shape[dim] % 16 == 0, (path, leaf.shape, ps)

    jax.tree_util.tree_map_with_path(
        check, params, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_big_matrices_are_sharded():
    """No ≥16M-element full-size tensor may be fully replicated."""
    for arch in ("llama3-8b", "qwen1.5-32b", "deepseek-v2-lite-16b"):
        cfg = specs_lib.bf16_config(ARCHS[arch])
        params = specs_lib.abstract_params(cfg)
        pspecs = specs_lib.tree_pspecs(params, 16)

        def check(path, leaf, ps):
            n = int(np.prod(leaf.shape))
            if n >= 16_000_000:
                assert any(ax is not None for ax in ps), (arch, path,
                                                          leaf.shape)

        jax.tree_util.tree_map_with_path(
            check, params, pspecs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_cache_pspec_decode_batch_and_heads():
    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    # k cache (n_groups, count, B, S, Hkv, hd): B on data, Hkv on model
    ps = specs_lib.cache_pspec(_path("0", "k"), (32, 1, 128, 32768, 32, 128),
                               M(), kind="decode")
    assert ps == P(None, None, "data", None, "model", None)
    # Hkv=8 < 16: falls back to the sequence dim for model
    ps = specs_lib.cache_pspec(_path("0", "k"), (32, 1, 128, 32768, 8, 128),
                               M(), kind="decode")
    assert ps == P(None, None, "data", "model", None, None)


def test_cache_pspec_long_shards_sequence_on_data():
    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    ps = specs_lib.cache_pspec(_path("0", "k"), (8, 1, 1, 524288, 32, 128),
                               M(), kind="long")
    assert ps[3] == "data"
    # pos/idx always replicated
    assert specs_lib.cache_pspec(_path("0", "pos"), (8, 1, 524288), M(),
                                 kind="long") == P(None, None, None)
