"""data / optim / checkpoint / simulation substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load, save, save_every
from repro.configs.base import FedConfig
from repro.data import (Dataset, FederatedBatcher, LMFederatedBatcher,
                        dirichlet_partition, gaussian_classification,
                        image_classification, lm_sequences, token_stream)
from repro.fed import FederatedSimulation, compare_algorithms
from repro.models.simple import (cnn_loss, lr_accuracy, lr_loss, mlp_accuracy,
                                 mlp_init, mlp_loss)
from repro.optim import (adamw_init, adamw_update, apply_updates, constant,
                         cosine, lambda_increase, sgd_init, sgd_update,
                         step_decay)


# -- data ---------------------------------------------------------------------

def test_gaussian_classification_learnable(key):
    data = gaussian_classification(key, 1000, d=8, n_classes=3, sep=3.0)
    assert data.x.shape == (1000, 8)
    assert int(data.y.max()) <= 2


def test_image_classification_shapes(key):
    data = image_classification(key, 64)
    assert data.x.shape == (64, 28, 28, 1)
    loss = cnn_loss.__wrapped__ if hasattr(cnn_loss, "__wrapped__") else cnn_loss
    # cnn loss runs on it
    from repro.models.simple import cnn_init
    p = cnn_init(key)
    val = loss(p, {"x": data.x, "y": data.y})
    assert np.isfinite(float(val))


def test_token_stream_skew(key):
    a = token_stream(key, 20_000, 256, skew_topic=0)
    b = token_stream(key, 20_000, 256, skew_topic=4)
    ha = np.bincount(np.asarray(a), minlength=256) / 20_000
    hb = np.bincount(np.asarray(b), minlength=256) / 20_000
    assert np.abs(ha - hb).sum() > 0.1            # distributions differ


def test_lm_sequences_next_token(key):
    d = lm_sequences(key, 4, 16, 128)
    np.testing.assert_array_equal(np.asarray(d["tokens"][:, 1:]),
                                  np.asarray(d["labels"][:, :-1]))


def test_federated_batcher_shapes(key):
    data = gaussian_classification(key, 500, d=4, n_classes=2)
    parts = dirichlet_partition(np.asarray(data.y), 4, 0.5)
    b = FederatedBatcher(data, parts, batch_size=8)
    out = b.round_batches(0, k_max=3)
    assert out["x"].shape == (4, 3, 8, 4)
    assert out["y"].shape == (4, 3, 8)
    # deterministic per (seed, round)
    out2 = b.round_batches(0, k_max=3)
    np.testing.assert_array_equal(np.asarray(out["y"]), np.asarray(out2["y"]))
    assert float(jnp.sum(b.weights)) == pytest.approx(1.0)


def test_lm_federated_batcher(key):
    streams = [lm_sequences(jax.random.fold_in(key, i), 32, 16, 64)
               for i in range(3)]
    b = LMFederatedBatcher(streams, batch_size=4)
    out = b.round_batches(1, k_max=2)
    assert out["tokens"].shape == (3, 2, 4, 16)


# -- optim --------------------------------------------------------------------

def test_sgd_matches_manual():
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    st = sgd_init(p)
    upd, _ = sgd_update(g, st, p, lr=0.1)
    new = apply_updates(p, upd)
    np.testing.assert_allclose(np.asarray(new["w"]), [0.95, 2.1])


def test_sgd_momentum_accumulates():
    p = {"w": jnp.zeros(2)}
    g = {"w": jnp.ones(2)}
    st = sgd_init(p, momentum=0.9)
    upd1, st = sgd_update(g, st, p, lr=1.0, momentum=0.9)
    upd2, st = sgd_update(g, st, p, lr=1.0, momentum=0.9)
    np.testing.assert_allclose(np.asarray(upd1["w"]), -1.0)
    np.testing.assert_allclose(np.asarray(upd2["w"]), -1.9)


def test_adamw_first_step_is_lr_sized():
    p = {"w": jnp.array([0.0])}
    g = {"w": jnp.array([0.3])}
    st = adamw_init(p)
    upd, st = adamw_update(g, st, p, lr=0.01)
    np.testing.assert_allclose(np.asarray(upd["w"]), -0.01, rtol=1e-4)


def test_schedules():
    assert float(constant(0.1)(100)) == pytest.approx(0.1)
    cos = cosine(1.0, 100, warmup=10)
    assert float(cos(0)) == pytest.approx(0.0)
    assert float(cos(10)) == pytest.approx(1.0, abs=1e-3)
    assert float(cos(100)) == pytest.approx(0.0, abs=1e-6)
    sd = step_decay(1.0, (10, 20), (0.1, 0.01))
    assert float(sd(5)) == 1.0 and float(sd(15)) == pytest.approx(0.1)
    lam = lambda_increase((50, 150), (0.1, 0.5, 1.0))
    assert float(lam(0)) == pytest.approx(0.1)
    assert float(lam(75)) == pytest.approx(0.5)
    assert float(lam(200)) == pytest.approx(1.0)


# -- checkpoint ---------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.array(3, jnp.int32)}}
    path = str(tmp_path / "ck.msgpack")
    save(path, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    back = load(path, like)
    for want, got in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert want.dtype == got.dtype
        np.testing.assert_array_equal(np.asarray(want, np.float32),
                                      np.asarray(got, np.float32))


def test_checkpoint_shape_mismatch(tmp_path):
    save(str(tmp_path / "x.msgpack"), {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        load(str(tmp_path / "x.msgpack"), {"a": jnp.zeros((3,))})


def test_checkpoint_missing_leaf(tmp_path):
    save(str(tmp_path / "x.msgpack"), {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        load(str(tmp_path / "x.msgpack"),
             {"a": jnp.zeros((2,)), "b": jnp.zeros((1,))})


def test_save_every(tmp_path):
    cb = save_every(str(tmp_path / "r{round}.msgpack"), every=2)
    cb(1, {"a": jnp.zeros(1)})
    cb(2, {"a": jnp.zeros(1)})
    assert not os.path.exists(tmp_path / "r1.msgpack")
    assert os.path.exists(tmp_path / "r2.msgpack")


# -- simulation ---------------------------------------------------------------

def _make_sim(algo, key, k_var=16.0):
    data = gaussian_classification(key, 2000, d=16, n_classes=4, sep=2.5)
    parts = dirichlet_partition(np.asarray(data.y), 8, alpha=0.3)
    batcher = FederatedBatcher(data, parts, batch_size=16)
    fed = FedConfig(algorithm=algo, n_clients=8, k_mean=8, k_var=k_var,
                    lr=0.05, calibration_rate=0.5)
    params = {"w": jnp.zeros((16, 4)), "b": jnp.zeros((4,))}
    return FederatedSimulation(
        lr_loss, params, fed, batcher,
        eval_fn=lambda p: float(lr_accuracy(p, {"x": data.x, "y": data.y})))


def test_simulation_learns(key):
    hist = _make_sim("fedagrac", key).run(20)
    assert hist.metric[-1] > 0.85
    assert len(hist.loss) == 20
    assert hist.rounds_to_target(0.5) is not None


def test_compare_algorithms(key):
    out = compare_algorithms(["fedavg", "fednova"],
                             lambda n: _make_sim(n, key), t_rounds=5)
    assert set(out) == {"fedavg", "fednova"}
    assert all(len(h.loss) == 5 for h in out.values())


def test_lambda_schedule_applied(key):
    sim = _make_sim("fedagrac", key)
    sim.lam_schedule = lambda_increase((2,), (0.1, 1.0))
    sim.run(4)
    # λ is a traced argument of the round: ONE compiled round serves both
    # schedule values (the old cache compiled one round per distinct λ).
    # _cache_size is private jax API — the retrace behavior itself is pinned
    # version-independently by test_lambda_schedule_does_not_retrace.
    fn = sim._round_fn()
    if hasattr(fn, "_cache_size"):
        assert fn._cache_size() == 1
