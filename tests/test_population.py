"""Client population subsystem (fed/population.py, DESIGN.md §10):
samplers, weight renormalization, cohort execution on both engines, and the
golden full-participation reduction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core.fedopt import ALGORITHMS
from repro.data import DeviceBatcher, FederatedBatcher, fedprox_synthetic
from repro.fed import (BufferedAsyncSimulation, ClientPopulation,
                       FederatedSimulation, SAMPLERS, make_clock,
                       simulate_timeline)
from repro.fed.population import _permutation_points
from repro.models.simple import lr_accuracy, lr_loss

M, C = 12, 4


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    return data, parts


def _fed(algorithm="fedagrac", **kw):
    return FedConfig(algorithm=algorithm, n_clients=M, lr=0.05,
                     calibration_rate=0.5, weights="data", **kw)


def _params():
    return {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}


def _leaves_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# samplers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 2, 5, 17, 100, 1000])
def test_permutation_points_bijective(m):
    """The O(C) Feistel draw evaluates a true permutation of [0, M)."""
    pts = _permutation_points(jax.random.PRNGKey(3), m,
                              jnp.arange(m, dtype=jnp.uint32))
    assert sorted(np.asarray(pts).tolist()) == list(range(m))


@pytest.mark.parametrize("sampler", sorted(set(SAMPLERS) - {"all"}))
def test_cohorts_in_range_and_sized(sampler):
    pop = ClientPopulation(M, cohort_size=C, sampler=sampler, seed=1,
                           availability=0.6)
    for t in (0, 1, 9):
        ids = np.asarray(pop.cohort(t))
        assert ids.shape == (C,) and ids.dtype == np.int32
        assert np.all((0 <= ids) & (ids < M))
        if sampler != "weighted":          # with-replacement may repeat
            assert len(set(ids.tolist())) == C, (sampler, ids)


def test_uniform_cohorts_vary_and_cover():
    pop = ClientPopulation(M, cohort_size=C, sampler="uniform", seed=0)
    draws = [tuple(np.asarray(pop.cohort(t))) for t in range(40)]
    assert len(set(draws)) > 30                       # rounds differ
    seen = {i for d in draws for i in d}
    assert seen == set(range(M))                      # everyone sampled
    counts = np.zeros(M)
    for d in draws:
        np.add.at(counts, list(d), 1)
    exp = len(draws) * C / M
    assert abs(counts - exp).max() < 6 * np.sqrt(exp)


def test_round_robin_covers_exactly_once_per_cycle():
    pop = ClientPopulation(M, cohort_size=C, sampler="round_robin")
    cycle = np.concatenate([np.asarray(pop.cohort(t))
                            for t in range(M // C)])
    assert sorted(cycle.tolist()) == list(range(M))


def test_availability_prefers_up_clients():
    avail = np.zeros(M)
    avail[:C] = 1.0                      # only clients 0…C-1 ever up
    pop = ClientPopulation(M, cohort_size=C, sampler="availability",
                           availability=avail, seed=2)
    for t in range(6):
        assert set(np.asarray(pop.cohort(t)).tolist()) == set(range(C))


def test_all_sampler_requires_full_cohort():
    with pytest.raises(ValueError):
        ClientPopulation(M, cohort_size=C, sampler="all")
    with pytest.raises(ValueError):
        ClientPopulation(M, cohort_size=C, sampler="nope")


def test_cohort_determinism_host_vs_device():
    """Same (seed, round) → same cohort from the host draw, the eager
    device draw, and a draw traced inside a jitted scan (the three places
    cohorts are computed)."""
    pop = ClientPopulation(M, cohort_size=C, sampler="uniform", seed=5)
    pop2 = ClientPopulation(M, cohort_size=C, sampler="uniform", seed=5)

    def scan_draw(ts):
        return jax.lax.scan(lambda c, t: (c, pop2.cohort(t)), 0, ts)[1]

    scanned = np.asarray(jax.jit(scan_draw)(jnp.arange(8, dtype=jnp.int32)))
    for t in range(8):
        host_ids, host_w = pop.host_cohort(t)
        eager = np.asarray(pop2.cohort(t))
        np.testing.assert_array_equal(host_ids, eager)
        np.testing.assert_array_equal(host_ids, scanned[t])
        np.testing.assert_allclose(
            host_w, np.asarray(pop2.cohort_weights(jnp.asarray(host_ids))))


# ---------------------------------------------------------------------------
# weight renormalization (unbiasedness rules)
# ---------------------------------------------------------------------------

def test_cohort_weights_per_sampler():
    w = np.arange(1, M + 1, dtype=np.float64)
    pop_u = ClientPopulation(M, cohort_size=C, sampler="uniform", weights=w)
    ids = pop_u.cohort(0)
    omega = np.asarray(pop_u.weights)
    np.testing.assert_allclose(
        np.asarray(pop_u.cohort_weights(ids)),
        omega[np.asarray(ids)] * M / C, rtol=1e-6)
    pop_w = ClientPopulation(M, cohort_size=C, sampler="weighted", weights=w)
    np.testing.assert_allclose(
        np.asarray(pop_w.cohort_weights(pop_w.cohort(0))),
        np.full(C, 1.0 / C), rtol=1e-6)
    pop_a = ClientPopulation(M, cohort_size=C, sampler="availability",
                             weights=w, availability=0.5)
    cw = np.asarray(pop_a.cohort_weights(pop_a.cohort(0)))
    assert cw.sum() == pytest.approx(1.0, rel=1e-5)


def test_uniform_mass_is_unbiased():
    """E[Σ w̃] = 1 under uniform sampling (Horvitz–Thompson): the mean
    cohort mass over many rounds concentrates around 1."""
    rng = np.random.default_rng(0)
    w = rng.uniform(0.5, 2.0, M)
    pop = ClientPopulation(M, cohort_size=C, sampler="uniform", weights=w)
    masses = [float(np.sum(pop.host_cohort(t)[1])) for t in range(300)]
    assert np.mean(masses) == pytest.approx(1.0, abs=0.05)


# ---------------------------------------------------------------------------
# golden: sampler="all" with C = M is bit-identical to full participation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALGORITHMS)
def test_golden_full_participation_sync(name, task):
    """All 9 algorithms: a sync simulation carrying an explicit 'all'
    population equals the legacy full-participation engine bit-for-bit."""
    data, parts = task
    ks = np.full((4, M), 3, np.int32)
    fed = _fed(name)
    a = FederatedSimulation(lr_loss, _params(), fed,
                            FederatedBatcher(data, parts, 10),
                            k_schedule=ks)
    pop = ClientPopulation(M, cohort_size=M, sampler="all",
                           weights=np.asarray(a.weights))
    b = FederatedSimulation(lr_loss, _params(), fed,
                            FederatedBatcher(data, parts, 10),
                            k_schedule=ks, population=pop)
    assert not b._partial
    ha, hb = a.run(3), b.run(3)
    assert ha.loss == hb.loss and ha.kbar == hb.kbar
    _leaves_equal(a.state, b.state)


@pytest.mark.parametrize("name", ALGORITHMS)
def test_golden_full_participation_async(name, task):
    """All 9 algorithms, buffered-async: an explicit 'all' population runs
    the unified dispatch-sampled timeline, which must reproduce the legacy
    always-in-flight stream bit-for-bit (stale buffers included)."""
    data, parts = task
    ks = np.full((4, M), 3, np.int32)
    fed = _fed(name, buffer_size=5, staleness="hinge",
               speed_dist="lognormal", speed_sigma=1.0)
    a = BufferedAsyncSimulation(lr_loss, _params(), fed,
                                FederatedBatcher(data, parts, 10),
                                k_schedule=ks)
    pop = ClientPopulation(M, cohort_size=M, sampler="all",
                           weights=np.asarray(a.weights))
    b = BufferedAsyncSimulation(lr_loss, _params(), fed,
                                FederatedBatcher(data, parts, 10),
                                k_schedule=ks, population=pop)
    ha, hb = a.run(6), b.run(6)
    assert ha.loss == hb.loss and ha.staleness == hb.staleness
    assert ha.sim_time == hb.sim_time
    _leaves_equal(a.state, b.state)


def test_config_all_cohort_is_legacy_path(task):
    """cohort_size=M + sampler='all' through FedConfig stays on the legacy
    engine (population is None — the golden path by construction)."""
    data, parts = task
    fed = _fed(cohort_size=M, cohort_sampler="all")
    sim = FederatedSimulation(lr_loss, _params(), fed,
                              FederatedBatcher(data, parts, 10),
                              k_schedule=np.full((2, M), 3, np.int32))
    assert sim.population is None and not sim._partial


# ---------------------------------------------------------------------------
# cohort execution: synchronous engine
# ---------------------------------------------------------------------------

def test_cohort_chunked_matches_per_round(task):
    """Partial participation chunked at the eval cadence == the
    chunk_rounds=1 compat path, for host AND device batchers."""
    data, parts = task
    ks = np.full((10, M), 3, np.int32)
    for Batcher in (FederatedBatcher, DeviceBatcher):
        fed = _fed(cohort_size=C, cohort_sampler="uniform")

        def make():
            return FederatedSimulation(
                lr_loss, _params(), fed, Batcher(data, parts, 10),
                eval_fn=lambda p: float(lr_accuracy(
                    p, {"x": data.x, "y": data.y})), k_schedule=ks)
        a, b = make(), make()
        ha = a.run(8, eval_every=4, chunk_rounds=1)
        hb = b.run(8, eval_every=4)
        assert ha.loss == hb.loss and ha.metric == hb.metric
        assert ha.mass == hb.mass
        _leaves_equal(a.state, b.state)


def test_cohort_batches_are_o_of_c(task):
    """Only the cohort's batch rows are materialized — O(C), not O(M)."""
    data, parts = task
    host = FederatedBatcher(data, parts, batch_size=10)
    dev = DeviceBatcher(data, parts, batch_size=10)
    ids = np.array([3, 7, 1, 9])
    hb = host.cohort_batches(2, ids, 5)
    assert hb["x"].shape == (C, 5, 10, 60)
    db = dev.sample_cohort(jnp.int32(2), jnp.asarray(ids, jnp.int32), 5)
    assert db["x"].shape == (C, 5, 10, 60)
    # device cohort rows equal the standalone per-client draws
    for j, i in enumerate(ids):
        row = dev.sample_row(jnp.int32(2), jnp.int32(int(i)), 5)
        np.testing.assert_array_equal(np.asarray(db["x"][j]),
                                      np.asarray(row["x"]))


def test_cohort_batch_indices_disjoint_across_clients(task):
    """Under partial participation each client draws from its OWN disjoint
    partition: cohort batch indices never collide across clients, and a
    client's draw is independent of cohort membership."""
    data, parts = task
    host = FederatedBatcher(data, parts, batch_size=10)
    idx = host.cohort_indices(3, np.array([0, 4, 8, 11]), 5)
    flat = [set(a.ravel().tolist()) for a in idx]
    for j, i in enumerate([0, 4, 8, 11]):
        assert flat[j] <= set(parts[i].tolist())
    for a in range(C):
        for b in range(a + 1, C):
            assert not (flat[a] & flat[b])
    # same client, different cohort → identical indices
    np.testing.assert_array_equal(
        host.cohort_indices(3, np.array([4, 0]), 5)[0],
        idx[1])


def test_round_robin_full_cohort_approximates_full_participation(task):
    """C = M with the round-robin sampler routes through the cohort
    (pseudo-delta) round; it must agree with full participation to float
    tolerance (the renormalized weights reduce to ω exactly).  Device
    batcher on both sides: its per-(seed, t, i) draw makes the batch
    streams identical, isolating the aggregation-form difference."""
    data, parts = task
    ks = np.full((4, M), 3, np.int32)
    fed_full = _fed()
    fed_coh = _fed(cohort_size=M, cohort_sampler="round_robin")
    a = FederatedSimulation(lr_loss, _params(), fed_full,
                            DeviceBatcher(data, parts, 10),
                            k_schedule=ks)
    b = FederatedSimulation(lr_loss, _params(), fed_coh,
                            DeviceBatcher(data, parts, 10),
                            k_schedule=ks)
    assert b._partial
    a.run(3)
    b.run(3)
    for la, lb in zip(jax.tree.leaves(a.state), jax.tree.leaves(b.state)):
        np.testing.assert_allclose(np.asarray(la, np.float64),
                                   np.asarray(lb, np.float64),
                                   rtol=2e-4, atol=2e-6)


def test_stale_nu_decay_rule(task):
    """cohort_nu_decay=1: after a round, every NON-participant's ν⁽ⁱ⁾ row
    equals the new global ν (correction → 0), participants carry their
    fresh averaged gradient."""
    data, parts = task
    fed = _fed(cohort_size=C, cohort_sampler="round_robin",
               cohort_nu_decay=1.0)
    sim = FederatedSimulation(lr_loss, _params(), fed,
                              FederatedBatcher(data, parts, 10),
                              k_schedule=np.full((4, M), 3, np.int32))
    sim.run(1)                                   # cohort = clients 0…C-1
    nu = np.asarray(sim.state["nu"]["w"])
    nu_i = np.asarray(sim.state["nu_i"]["w"])
    for i in range(C, M):
        np.testing.assert_allclose(nu_i[i], nu, rtol=1e-6)
    for i in range(C):
        assert not np.allclose(nu_i[i], nu)


def test_cohort_sync_records_mass(task):
    data, parts = task
    fed = _fed(cohort_size=C, cohort_sampler="uniform")
    sim = FederatedSimulation(lr_loss, _params(), fed,
                              FederatedBatcher(data, parts, 10),
                              k_schedule=np.full((4, M), 3, np.int32))
    hist = sim.run(5)
    assert len(hist.mass) == 5
    assert np.mean(hist.mass) == pytest.approx(1.0, abs=0.5)


# ---------------------------------------------------------------------------
# cohort execution: buffered-async engine
# ---------------------------------------------------------------------------

def test_async_timeline_caps_concurrency():
    """With a population of concurrency C, exactly C tasks are ever in
    flight: replaying the timeline, every report consumes a previously
    dispatched task and every event re-fills the freed slot."""
    ks = np.full((6, M), 3, np.int32)
    clock = make_clock(M, dist="lognormal", sigma=1.0, seed=1)
    pop = ClientPopulation(M, cohort_size=C, sampler="uniform", seed=4)
    tl = simulate_timeline(ks, clock, 3, 10, population=pop)
    # the initial dispatch is reproducible: a fresh rng with the sim's seed
    inflight: dict[int, int] = {}
    init = ClientPopulation(M, cohort_size=C, sampler="uniform", seed=4
                            ).initial_dispatch(
        np.random.default_rng((pop.seed, 0x5eed)))
    for i in init:
        inflight[int(i)] = inflight.get(int(i), 0) + 1
    for u in range(tl.t_updates):
        for j in range(tl.buffer):
            rep, disp = int(tl.ids[u, j]), int(tl.dispatch_ids[u, j])
            assert inflight.get(rep, 0) > 0, (u, j, rep)     # was in flight
            inflight[rep] -= 1
            inflight[disp] = inflight.get(disp, 0) + 1
            assert sum(inflight.values()) == C
    # more than the initial C clients eventually participate
    assert len(set(tl.ids.ravel().tolist())) > C


def test_async_population_runs_and_learns(task):
    data, parts = task
    ks = np.full((6, M), 3, np.int32)
    fed = _fed(buffer_size=3, cohort_size=C, cohort_sampler="uniform",
               speed_dist="lognormal", staleness="hinge")
    sim = BufferedAsyncSimulation(
        lr_loss, _params(), fed, FederatedBatcher(data, parts, 10),
        eval_fn=lambda p: float(lr_accuracy(p, {"x": data.x,
                                                "y": data.y})),
        k_schedule=ks)
    assert sim.population is not None and not sim.population.full_participation
    hist = sim.run(12, eval_every=6)
    assert np.all(np.isfinite(hist.loss))
    assert len(hist.mass) == 12
    assert hist.metric[-1] > 0.3


def test_async_round_robin_rotates_through_population():
    """Dispatch-time sampling: with the round-robin population every client
    of M eventually reports even though only C are concurrent."""
    ks = np.full((4, M), 2, np.int32)
    clock = make_clock(M, dist="fixed")
    pop = ClientPopulation(M, cohort_size=C, sampler="round_robin")
    tl = simulate_timeline(ks, clock, 2, 3 * M, population=pop)
    assert set(tl.ids.ravel().tolist()) == set(range(M))


def test_cohort_size_alone_implies_uniform_sampler(task):
    """FedConfig(cohort_size=C) with the default sampler 'all' resolves to
    uniform partial participation (cohort_size alone is the opt-in)."""
    data, parts = task
    sim = FederatedSimulation(lr_loss, _params(), _fed(cohort_size=C),
                              FederatedBatcher(data, parts, 10),
                              k_schedule=np.full((2, M), 3, np.int32))
    assert sim._partial and sim.population.sampler == "uniform"
    assert sim.population.cohort_size == C


def test_async_stale_nu_decay(task):
    """cohort_nu_decay applies to the buffered-async engine too: with decay
    1 every non-reporting client's ν⁽ⁱ⁾ row tracks the global ν instead of
    staying frozen (the sync engine's state-scatter rule, DESIGN.md §10)."""
    data, parts = task
    ks = np.full((4, M), 2, np.int32)
    kw = dict(buffer_size=2, cohort_size=C, cohort_sampler="round_robin",
              speed_dist="fixed")
    frozen = BufferedAsyncSimulation(
        lr_loss, _params(), _fed(**kw),
        FederatedBatcher(data, parts, 10), k_schedule=ks)
    decayed = BufferedAsyncSimulation(
        lr_loss, _params(), _fed(cohort_nu_decay=1.0, **kw),
        FederatedBatcher(data, parts, 10), k_schedule=ks)
    frozen.run(2)
    decayed.run(2)
    nu = np.asarray(decayed.state["nu"]["w"])
    nu_i = np.asarray(decayed.state["nu_i"]["w"])
    reporters = set()
    # with buffer=2, 2 updates consumed 4 reports; find them via the frozen
    # run's rows that moved off zero
    fro = np.asarray(frozen.state["nu_i"]["w"])
    for i in range(M):
        if np.any(fro[i] != 0):
            reporters.add(i)
    stale = set(range(M)) - reporters
    assert stale, "need at least one non-reporting client"
    for i in stale:
        np.testing.assert_allclose(nu_i[i], nu, rtol=1e-5, atol=1e-7)
        assert np.any(fro[i] == 0)          # frozen run left it at init


def test_async_buffer_capped_at_concurrency(task):
    """Partial participation: an unset buffer defaults to C (not M — a
    B = M buffer would aggregate Σ w̃ ≈ M/C ≫ 1 and overshoot), and a
    buffer above the concurrency is rejected."""
    data, parts = task
    fed = _fed(cohort_size=C, cohort_sampler="uniform",
               speed_dist="lognormal")
    sim = BufferedAsyncSimulation(lr_loss, _params(), fed,
                                  FederatedBatcher(data, parts, 10),
                                  k_schedule=np.full((2, M), 2, np.int32))
    assert sim.buffer == C
    with pytest.raises(ValueError):
        BufferedAsyncSimulation(lr_loss, _params(),
                                _fed(buffer_size=C + 1, cohort_size=C,
                                     cohort_sampler="uniform"),
                                FederatedBatcher(data, parts, 10),
                                k_schedule=np.full((2, M), 2, np.int32))


def test_population_mismatch_raises(task):
    data, parts = task
    pop = ClientPopulation(M + 1, cohort_size=C, sampler="uniform")
    with pytest.raises(ValueError):
        FederatedSimulation(lr_loss, _params(), _fed(),
                            FederatedBatcher(data, parts, 10),
                            k_schedule=np.full((2, M), 3, np.int32),
                            population=pop)
    with pytest.raises(ValueError):
        BufferedAsyncSimulation(lr_loss, _params(),
                                _fed(buffer_size=3),
                                FederatedBatcher(data, parts, 10),
                                k_schedule=np.full((2, M), 3, np.int32),
                                population=pop)
