"""Device-resident sampling (data/pipeline.py DeviceBatcher) and the
chunked execution path it feeds (DESIGN.md §9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.data import DeviceBatcher, FederatedBatcher, fedprox_synthetic
from repro.fed import FederatedSimulation
from repro.models.simple import lr_accuracy, lr_loss

M = 6


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    return data, parts


def test_device_batcher_deterministic_across_instances(task):
    """Two instantiations with the same seed draw identical (seed, round)
    batches — the property the SPMD path and the async engine lean on."""
    data, parts = task
    a = DeviceBatcher(data, parts, batch_size=8, seed=3)
    b = DeviceBatcher(data, parts, batch_size=8, seed=3)
    for t in (0, 1, 7):
        wa = a.round_batches(t, 4)
        wb = b.round_batches(t, 4)
        for la, lb in zip(jax.tree.leaves(wa), jax.tree.leaves(wb)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_device_batcher_rounds_and_seeds_differ(task):
    data, parts = task
    a = DeviceBatcher(data, parts, batch_size=8, seed=3)
    b = DeviceBatcher(data, parts, batch_size=8, seed=4)
    i0 = np.asarray(a.row_indices(jnp.int32(0), jnp.int32(0), 4))
    i1 = np.asarray(a.row_indices(jnp.int32(1), jnp.int32(0), 4))
    j0 = np.asarray(b.row_indices(jnp.int32(0), jnp.int32(0), 4))
    assert not np.array_equal(i0, i1)
    assert not np.array_equal(i0, j0)


def test_device_batcher_respects_partitions(task):
    """Every drawn row belongs to the drawing client's own index set —
    including for unequal partition sizes (the padded-table edge)."""
    data, parts = task
    uneven = [p[:len(p) // (i + 1) + 1] for i, p in enumerate(parts)]
    db = DeviceBatcher(data, uneven, batch_size=16, seed=0)
    for i, part in enumerate(uneven):
        idx = np.asarray(db.row_indices(jnp.int32(5), jnp.int32(i), 6))
        assert np.isin(idx, part).all()


def test_device_batcher_wave_row_consistency(task):
    """Row i of the full wave == the standalone sample_row(t, i) — the
    sync engine's in-scan wave and the async engine's per-dispatch gather
    see the same data."""
    data, parts = task
    db = DeviceBatcher(data, parts, batch_size=8, seed=1)
    wave = db.sample(jnp.int32(9), 4)
    for i in range(M):
        row = db.sample_row(jnp.int32(9), jnp.int32(i), 4)
        for lw, lr_ in zip(jax.tree.leaves(wave), jax.tree.leaves(row)):
            np.testing.assert_array_equal(np.asarray(lw[i]),
                                          np.asarray(lr_))


def test_device_batcher_weights_match_host(task):
    data, parts = task
    host = FederatedBatcher(data, parts, batch_size=8)
    dev = DeviceBatcher(data, parts, batch_size=8)
    np.testing.assert_allclose(np.asarray(host.weights),
                               np.asarray(dev.weights), rtol=1e-6)


def test_device_sampled_simulation_learns_and_is_deterministic(task):
    """End-to-end: the fully device-resident path (DeviceBatcher inside the
    chunked scan) trains and reproduces itself exactly."""
    data, parts = task
    params = {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}
    fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.05,
                    calibration_rate=0.5, weights="data")
    ks = np.full((30, M), 4, np.int32)
    ev = lambda p: float(lr_accuracy(p, {"x": data.x, "y": data.y}))

    def run():
        sim = FederatedSimulation(
            lr_loss, params, fed, DeviceBatcher(data, parts, batch_size=10),
            eval_fn=ev, k_schedule=ks)
        return sim.run(16, eval_every=8)
    ha, hb = run(), run()
    assert ha.loss == hb.loss and ha.metric == hb.metric
    assert np.all(np.isfinite(ha.loss))
    assert ha.metric[-1] > 0.5
    assert len(ha.loss) == 16 and len(ha.metric) == 2
