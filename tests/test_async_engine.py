"""Buffered semi-asynchronous engine (fed/async_engine.py + fed/clock.py)."""
import dataclasses
import heapq

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import rounds, stages
from repro.core.fedopt import get_algorithm
from repro.data import DeviceBatcher, FederatedBatcher, fedprox_synthetic
from repro.fed import (BufferedAsyncSimulation, FederatedSimulation,
                       make_clock, simulate_timeline, staleness_weight)
from repro.models.simple import lr_loss, quad_loss

M = 8


# ---------------------------------------------------------------------------
# staleness weights
# ---------------------------------------------------------------------------

def test_staleness_constant_is_one():
    np.testing.assert_array_equal(
        staleness_weight(np.arange(10), "constant"), np.ones(10))


def test_staleness_zero_tau_is_one():
    for mode in ("constant", "hinge", "poly"):
        assert staleness_weight(0, mode, a=0.7, b=3) == pytest.approx(1.0)


def test_staleness_poly_values():
    np.testing.assert_allclose(
        staleness_weight(np.array([0, 1, 3]), "poly", a=0.5),
        [1.0, 2.0 ** -0.5, 4.0 ** -0.5])


def test_staleness_hinge_values():
    # free budget b=4: τ ≤ 4 undiscounted, then harmonic decay
    np.testing.assert_allclose(
        staleness_weight(np.array([0, 4, 5, 14]), "hinge", a=0.5, b=4),
        [1.0, 1.0, 1.0 / 1.5, 1.0 / 6.0])


@pytest.mark.parametrize("mode", ["hinge", "poly"])
def test_staleness_monotone_nonincreasing(mode):
    s = staleness_weight(np.arange(30), mode, a=0.5, b=4)
    assert np.all(np.diff(s) <= 0)
    assert np.all(s > 0)


def test_staleness_hinge_boundary():
    """τ exactly at the hinge budget b is still undiscounted; one past it
    starts the harmonic decay — the off-by-one FedAsync §5 gets wrong in
    half its reimplementations."""
    for b in (0, 1, 4):
        assert staleness_weight(b, "hinge", a=0.5, b=b) == 1.0
        assert staleness_weight(b + 1, "hinge", a=0.5, b=b) \
            == pytest.approx(1.0 / 1.5)


def test_staleness_large_tau_asymptotics():
    """Large τ: poly follows (1+τ)^-a exactly; hinge follows 1/(a(τ−b)+1);
    both stay strictly positive (a zero weight would delete the report
    instead of discounting it)."""
    tau = np.array([1e3, 1e6])
    np.testing.assert_allclose(staleness_weight(tau, "poly", a=0.5),
                               (1.0 + tau) ** -0.5, rtol=1e-12)
    np.testing.assert_allclose(
        staleness_weight(tau, "hinge", a=0.5, b=4),
        1.0 / (1.0 + 0.5 * (tau - 4)), rtol=1e-12)
    for mode in ("constant", "hinge", "poly"):
        assert np.all(staleness_weight(tau, mode, a=0.5, b=4) > 0)


def test_staleness_unknown_mode_raises():
    with pytest.raises(ValueError):
        staleness_weight(3, "exponential")


def test_history_mass_tracks_buffer_mass():
    """History.mass records Σ w̃ per server update: with buffer = M, equal
    speeds and no discount it is exactly the total weight mass 1 (the
    synchronous reduction); with a harsh poly discount and staleness it
    drops strictly below the undiscounted Σ ω of the same buffer."""
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    params = {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}
    ks = np.full((4, M), 2, np.int32)
    fed = FedConfig(algorithm="fedavg", n_clients=M, lr=0.05,
                    buffer_size=M, speed_dist="fixed")
    sim = BufferedAsyncSimulation(lr_loss, params, fed,
                                  FederatedBatcher(data, parts, 10),
                                  k_schedule=ks)
    hist = sim.run(3)
    assert len(hist.mass) == 3
    np.testing.assert_allclose(hist.mass, 1.0, rtol=1e-5)

    fed_d = FedConfig(algorithm="fedavg", n_clients=M, lr=0.05,
                      buffer_size=3, staleness="poly", staleness_a=2.0,
                      speed_dist="lognormal", speed_sigma=1.0)
    sim_d = BufferedAsyncSimulation(lr_loss, params, fed_d,
                                    FederatedBatcher(data, parts, 10),
                                    k_schedule=ks)
    hist_d = sim_d.run(8)
    assert len(hist_d.mass) == 8
    assert np.mean(hist_d.mass) < 3.0 / M       # discounted below Σω ≈ 3/M


# ---------------------------------------------------------------------------
# client wall-clock model
# ---------------------------------------------------------------------------

def test_clock_duration_scales_with_steps():
    clock = make_clock(4, dist="fixed", latency=0.5)
    assert clock.duration(0, 10) == pytest.approx(10.5)
    assert clock.duration(0, 20) == pytest.approx(20.5)


def test_clock_bimodal_has_one_fast_client():
    clock = make_clock(5, dist="bimodal")
    assert clock.speeds[-1] == pytest.approx(10.0)
    np.testing.assert_allclose(clock.speeds[:-1], 1.0)
    # sync round time is set by the stragglers, not the fast client
    assert clock.round_time(np.full(5, 10)) == pytest.approx(10.0)


def test_clock_seeded_reproducible():
    a = make_clock(16, dist="lognormal", sigma=0.8, seed=3)
    b = make_clock(16, dist="lognormal", sigma=0.8, seed=3)
    np.testing.assert_array_equal(a.speeds, b.speeds)


# ---------------------------------------------------------------------------
# buffered aggregation stages
# ---------------------------------------------------------------------------

def test_buffered_mean_reduces_to_weighted_average():
    """Identical anchors + weights summing to 1 ⇒ plain weighted average."""
    rng = np.random.default_rng(0)
    p0 = {"x": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}
    x_i = {"x": jnp.asarray(rng.normal(size=(4, 5)).astype(np.float32))}
    anchor = {"x": jnp.broadcast_to(p0["x"], (4, 5))}
    w = jnp.array([0.1, 0.2, 0.3, 0.4], jnp.float32)
    kf = jnp.full((4,), 3.0)
    out = stages.buffered_mean(p0, anchor, x_i, kf, w, jnp.float32(3.0))
    want = stages.aggregate_mean(p0, x_i, kf, w, jnp.float32(3.0))
    np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(want["x"]),
                               rtol=1e-6, atol=1e-7)


def test_buffered_fednova_normalizes_per_client_steps():
    rng = np.random.default_rng(1)
    p0 = {"x": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}
    deltas = rng.normal(size=(2, 5)).astype(np.float32)
    anchor = {"x": jnp.broadcast_to(p0["x"], (2, 5))}
    x_i = {"x": anchor["x"] + deltas}
    w = jnp.array([0.5, 0.5], jnp.float32)
    kf = jnp.array([2.0, 8.0])
    kbar = jnp.dot(w, kf)
    out = stages.buffered_fednova(p0, anchor, x_i, kf, w, kbar)
    want = np.asarray(p0["x"]) + 5.0 * (0.5 * deltas[0] / 2 + 0.5 * deltas[1] / 8)
    np.testing.assert_allclose(np.asarray(out["x"]), want, rtol=1e-5)


def test_stale_anchor_aggregates_the_delta_not_the_params():
    """A stale client's contribution is its OWN progress δ = x − anchor, not
    its absolute parameters — the buffered form must not drag the server
    back toward an old model version."""
    p_now = {"x": jnp.full((3,), 10.0)}
    stale_anchor = {"x": jnp.zeros((1, 3))}          # model 10 versions ago
    x_i = {"x": jnp.ones((1, 3))}                    # client moved by +1
    w = jnp.array([0.5], jnp.float32)
    out = stages.buffered_mean(p_now, stale_anchor, x_i,
                               jnp.ones((1,)), w, jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(out["x"]), np.full(3, 10.5))


# ---------------------------------------------------------------------------
# per-client anchors in the client-update stage
# ---------------------------------------------------------------------------

def test_per_client_anchor_matches_broadcast_anchor():
    fed = FedConfig(algorithm="fedagrac", n_clients=4, lr=0.01,
                    calibration_rate=0.5)
    algo = get_algorithm("fedagrac", fed)
    rng = np.random.default_rng(0)
    params = {"x": jnp.asarray(rng.normal(size=(6,)).astype(np.float32))}
    b = {"A": jnp.asarray(rng.normal(size=(4, 3, 6, 6)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=(4, 3, 6)).astype(np.float32)),
         "c0": jnp.zeros((4, 3))}
    ks = jnp.array([1, 2, 3, 3], jnp.int32)
    c = jax.tree.map(lambda a: jnp.zeros((4,) + params["x"].shape), params)
    shared = stages.make_client_update(quad_loss, algo, lr=0.01, k_max=3)
    stacked = stages.make_client_update(quad_loss, algo, lr=0.01, k_max=3,
                                        per_client_anchor=True)
    anchor_i = jax.tree.map(lambda a: jnp.broadcast_to(a, (4,) + a.shape),
                            params)
    out_a = shared(params, c, b, ks, jnp.float32(0.5))
    out_b = stacked(anchor_i, c, b, ks, jnp.float32(0.5))
    for la, lb in zip(jax.tree.leaves(out_a), jax.tree.leaves(out_b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# end-to-end: buffer = M reduces to the synchronous round
# ---------------------------------------------------------------------------

def _task(seed=0):
    key = jax.random.PRNGKey(seed)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    params = {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}
    return data, parts, params


@pytest.mark.parametrize("algo", ["fedavg", "fedagrac", "fednova",
                                  "scaffold"])
def test_full_buffer_equals_synchronous_round(algo):
    """buffer = M + identical speeds: every server update aggregates exactly
    one aligned wave with zero staleness ⇒ the synchronous engine."""
    data, parts, params = _task()
    ks = np.full((50, M), 4, np.int32)
    t = 5
    fed_sync = FedConfig(algorithm=algo, n_clients=M, lr=0.05,
                         calibration_rate=0.5, weights="data")
    sync = FederatedSimulation(lr_loss, params, fed_sync,
                               FederatedBatcher(data, parts, batch_size=10),
                               k_schedule=ks)
    h_sync = sync.run(t)
    fed_async = dataclasses.replace(fed_sync, buffer_size=M,
                                    speed_dist="fixed")
    async_ = BufferedAsyncSimulation(
        lr_loss, params, fed_async,
        FederatedBatcher(data, parts, batch_size=10), k_schedule=ks)
    h_async = async_.run(t)
    for a, b in zip(jax.tree.leaves(sync.state),
                    jax.tree.leaves(async_.state)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(h_sync.loss, h_async.loss, rtol=1e-5)
    assert h_async.staleness == [0.0] * t


def test_buffered_async_runs_and_tracks_staleness():
    data, parts, params = _task()
    fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.05,
                    calibration_rate=0.5, buffer_size=3, staleness="hinge",
                    speed_dist="lognormal", speed_sigma=1.0)
    sim = BufferedAsyncSimulation(
        lr_loss, params, fed, FederatedBatcher(data, parts, batch_size=10),
        k_schedule=np.full((50, M), 4, np.int32))
    h = sim.run(12)
    assert len(h.loss) == 12 and np.all(np.isfinite(h.loss))
    # simulated time advances monotonically; heterogeneous speeds + partial
    # buffers must produce some genuinely stale aggregations
    assert h.sim_time == sorted(h.sim_time)
    assert max(h.staleness) > 0


def test_anchor_buffer_bounds_memory():
    data, parts, params = _task()
    fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.05,
                    buffer_size=2, speed_dist="lognormal", speed_sigma=1.5)
    sim = BufferedAsyncSimulation(
        lr_loss, params, fed, FederatedBatcher(data, parts, batch_size=10),
        k_schedule=np.full((50, M), 4, np.int32))
    sim.run(20)
    # the device-resident anchor buffer holds exactly M + 1 model versions
    # (one dispatch-time row per client + the duplicate-write scratch row)
    # regardless of how far a fast client races ahead of a straggler, and
    # the host wave cache is consumed down by its precomputed counts
    for leaf in jax.tree.leaves(sim._anchors):
        assert leaf.shape[0] == M + 1
    for leaf in jax.tree.leaves(sim._nu_anchors):
        assert leaf.shape[0] == M + 1
    assert len(sim._wave_cache) <= M + 1


def test_staleness_discount_shrinks_the_update():
    """Same trajectory, hinge vs constant: discounted stale updates move the
    server strictly less far from init."""
    data, parts, params = _task()
    out = {}
    for mode in ("constant", "hinge"):
        fed = FedConfig(algorithm="fedavg", n_clients=M, lr=0.05,
                        buffer_size=1, staleness=mode, staleness_a=2.0,
                        staleness_b=0, speed_dist="lognormal",
                        speed_sigma=1.5)
        sim = BufferedAsyncSimulation(
            lr_loss, params, fed,
            FederatedBatcher(data, parts, batch_size=10),
            k_schedule=np.full((50, M), 4, np.int32))
        h = sim.run(16)
        assert max(h.staleness) > 0          # buffer=1 ⇒ staleness exists
        out[mode] = float(sum(np.linalg.norm(np.asarray(v))
                              for v in jax.tree.leaves(sim.params)))
    assert out["hinge"] < out["constant"]


def test_duplicate_reporter_keeps_nu_mixing_convex():
    """A high-data-weight fast client reporting twice into one buffer pushes
    Σ w̃ past 1; the ν mix must stay convex (no sign-flipped decay) and the
    run bounded."""
    from repro.fed.clock import ClientClock
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, 3, alpha=1.0, beta=1.0)
    # make client 0 own most of the data (ω₀ ≈ 0.58) AND be 50× faster
    cut = 3 * len(parts[1]) // 4
    parts = [np.concatenate([parts[0], parts[1][:cut]]),
             parts[1][cut:], parts[2]]
    clock = ClientClock(speeds=np.array([50.0, 1.0, 1.0]),
                        latency=np.zeros(3))
    fed = FedConfig(algorithm="fedagrac", n_clients=3, lr=0.05,
                    calibration_rate=0.5, weights="data", buffer_size=2)
    sim = BufferedAsyncSimulation(
        lr_loss, {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}, fed,
        FederatedBatcher(data, parts, batch_size=10),
        k_schedule=np.full((300, 3), 3, np.int32), clock=clock)
    h = sim.run(40)
    assert max(h.mass) > 1.0, h.mass        # the Σw̃ > 1 regime really occurs
    assert all(np.isfinite(h.loss))
    nu_norm = max(float(jnp.max(jnp.abs(v)))
                  for v in jax.tree.leaves(sim.state["nu"]))
    assert nu_norm < 1e3, nu_norm


def test_buffer_size_validation():
    data, parts, params = _task()
    fed = FedConfig(algorithm="fedavg", n_clients=M, buffer_size=M + 1)
    with pytest.raises(ValueError):
        BufferedAsyncSimulation(lr_loss, params, fed,
                                FederatedBatcher(data, parts, batch_size=10))


# ---------------------------------------------------------------------------
# precomputed timeline == the heapq event loop (golden, DESIGN.md §9)
# ---------------------------------------------------------------------------

def _reference_event_loop(k_schedule, clock, buffer, t_updates):
    """Frozen copy of the pre-refactor BufferedAsyncSimulation.run event
    bookkeeping (heap fill, immediate re-dispatch, tie upgrade) — the
    golden reference ``fed/clock.py::simulate_timeline`` must reproduce."""
    m = clock.m
    heap, inflight, seq = [], {}, 0
    waves = np.zeros(m, np.int64)
    version = 0
    out = []

    def dispatch(i, t_now, ver):
        nonlocal seq
        d = int(waves[i])
        k = int(k_schedule[d % len(k_schedule), i])
        inflight[i] = (ver, k, d, t_now)
        waves[i] += 1
        heapq.heappush(heap, (t_now + clock.duration(i, k), seq, i))
        seq += 1

    for i in range(m):
        dispatch(i, 0.0, 0)
    for _ in range(t_updates):
        pending = []
        while len(pending) < buffer:
            t_arr, _, i = heapq.heappop(heap)
            pending.append((t_arr, i, inflight.pop(i)))
            dispatch(i, t_arr, version)
        now = pending[-1][0]
        ids = [p[1] for p in pending]
        vs, ks, ds, _ = zip(*(p[2] for p in pending))
        tau = version - np.asarray(vs)
        pre_version = version
        version += 1
        for t_arr, i, _ in pending:
            if t_arr == now and i in inflight:
                ver, k, d, t_disp = inflight[i]
                if ver == pre_version and t_disp == t_arr:
                    inflight[i] = (version, k, d, t_disp)
        out.append((ids, vs, ds, ks, tau,
                    [p[0] for p in pending], now))
    return out


@pytest.mark.parametrize("dist,buffer", [("fixed", M), ("fixed", 3),
                                         ("lognormal", 3), ("lognormal", 1),
                                         ("bimodal", 2), ("bimodal", 5)])
def test_timeline_matches_heapq_event_loop(dist, buffer):
    """simulate_timeline reproduces the event loop exactly — same reporter
    ids, dispatch versions (tie-upgrade rule included), waves, K_i,
    staleness and arrival times per update — for all clock shapes."""
    clock = make_clock(M, dist=dist, sigma=1.0, seed=7)
    ks = np.arange(1, 1 + 60 * M).reshape(60, M) % 7 + 1
    t = 37
    tl = simulate_timeline(ks, clock, buffer, t)
    ref = _reference_event_loop(ks, clock, buffer, t)
    for u, (ids, vs, ds, kk, tau, t_arr, now) in enumerate(ref):
        np.testing.assert_array_equal(tl.ids[u], ids, err_msg=f"u={u}")
        np.testing.assert_array_equal(tl.versions[u], vs, err_msg=f"u={u}")
        np.testing.assert_array_equal(tl.waves[u], ds, err_msg=f"u={u}")
        np.testing.assert_array_equal(tl.k_steps[u], kk, err_msg=f"u={u}")
        np.testing.assert_array_equal(tl.staleness[u], tau,
                                      err_msg=f"u={u}")
        np.testing.assert_array_equal(tl.arrival_t[u], t_arr,
                                      err_msg=f"u={u}")
        assert tl.arrival_t[u, -1] == now


def test_timeline_full_buffer_is_synchronous():
    """buffer = M + fixed speeds: every update is one aligned wave — zero
    staleness, all clients once, and the tie-upgrade rule fires for all."""
    clock = make_clock(M, dist="fixed")
    tl = simulate_timeline(np.full((10, M), 4, np.int64), clock, M, 6)
    assert np.all(tl.staleness == 0)
    assert np.all(tl.fresh)
    for u in range(6):
        assert sorted(tl.ids[u]) == list(range(M))
        assert np.all(tl.waves[u] == u)


def test_timeline_fresh_matches_next_dispatch_version():
    """fresh[u, j] is exactly 'the reporter's next report carries version
    u + 1' — checked against each client's next appearance."""
    clock = make_clock(M, dist="lognormal", sigma=1.0, seed=3)
    ks = np.full((40, M), 4, np.int64)
    tl = simulate_timeline(ks, clock, 3, 30)
    for u in range(30):
        for j in range(3):
            i = tl.ids[u, j]
            later = [(u2, j2) for u2 in range(u + 1, 30)
                     for j2 in range(3)
                     if tl.ids[u2, j2] == i and tl.waves[u2, j2] > tl.waves[u, j]]
            if later:
                u2, j2 = later[0]
                assert tl.fresh[u, j] == (tl.versions[u2, j2] == u + 1)


# ---------------------------------------------------------------------------
# chunked execution and the device sampler
# ---------------------------------------------------------------------------

def test_chunked_async_matches_per_update():
    """Scanned chunks sync to host only at boundaries; the trajectory must
    match the per-update (chunk_updates=1) execution bit-for-bit — it is the
    same scan body either way."""
    data, parts, params = _task()
    fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.05,
                    calibration_rate=0.5, buffer_size=3, staleness="hinge",
                    speed_dist="lognormal", speed_sigma=1.0)
    ks = np.full((50, M), 4, np.int32)
    a = BufferedAsyncSimulation(
        lr_loss, params, fed, FederatedBatcher(data, parts, batch_size=10),
        k_schedule=ks)
    ha = a.run(12, chunk_updates=1)
    b = BufferedAsyncSimulation(
        lr_loss, params, fed, FederatedBatcher(data, parts, batch_size=10),
        k_schedule=ks)
    hb = b.run(12, chunk_updates=6)
    assert ha.loss == hb.loss
    assert ha.sim_time == hb.sim_time
    assert ha.staleness == hb.staleness
    for la, lb in zip(jax.tree.leaves(a.state), jax.tree.leaves(b.state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_async_device_sampler_full_buffer_reduces_to_sync():
    """DeviceBatcher + buffer = M + fixed speeds: the async engine samples
    row i of wave d inside the scan — identical draws to the synchronous
    device-sampled engine, so the trajectories coincide."""
    data, parts, params = _task()
    ks = np.full((50, M), 4, np.int32)
    fed_sync = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.05,
                         calibration_rate=0.5, weights="data")
    sync = FederatedSimulation(
        lr_loss, params, fed_sync, DeviceBatcher(data, parts, batch_size=10),
        k_schedule=ks)
    h_sync = sync.run(5)
    fed_async = dataclasses.replace(fed_sync, buffer_size=M,
                                    speed_dist="fixed")
    async_ = BufferedAsyncSimulation(
        lr_loss, params, fed_async, DeviceBatcher(data, parts, batch_size=10),
        k_schedule=ks)
    h_async = async_.run(5)
    np.testing.assert_allclose(h_sync.loss, h_async.loss, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(sync.state),
                    jax.tree.leaves(async_.state)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-5, atol=1e-6)
