"""Buffered semi-asynchronous engine (fed/async_engine.py + fed/clock.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import rounds, stages
from repro.core.fedopt import get_algorithm
from repro.data import FederatedBatcher, fedprox_synthetic
from repro.fed import (BufferedAsyncSimulation, FederatedSimulation,
                       make_clock, staleness_weight)
from repro.models.simple import lr_loss, quad_loss

M = 8


# ---------------------------------------------------------------------------
# staleness weights
# ---------------------------------------------------------------------------

def test_staleness_constant_is_one():
    np.testing.assert_array_equal(
        staleness_weight(np.arange(10), "constant"), np.ones(10))


def test_staleness_zero_tau_is_one():
    for mode in ("constant", "hinge", "poly"):
        assert staleness_weight(0, mode, a=0.7, b=3) == pytest.approx(1.0)


def test_staleness_poly_values():
    np.testing.assert_allclose(
        staleness_weight(np.array([0, 1, 3]), "poly", a=0.5),
        [1.0, 2.0 ** -0.5, 4.0 ** -0.5])


def test_staleness_hinge_values():
    # free budget b=4: τ ≤ 4 undiscounted, then harmonic decay
    np.testing.assert_allclose(
        staleness_weight(np.array([0, 4, 5, 14]), "hinge", a=0.5, b=4),
        [1.0, 1.0, 1.0 / 1.5, 1.0 / 6.0])


@pytest.mark.parametrize("mode", ["hinge", "poly"])
def test_staleness_monotone_nonincreasing(mode):
    s = staleness_weight(np.arange(30), mode, a=0.5, b=4)
    assert np.all(np.diff(s) <= 0)
    assert np.all(s > 0)


# ---------------------------------------------------------------------------
# client wall-clock model
# ---------------------------------------------------------------------------

def test_clock_duration_scales_with_steps():
    clock = make_clock(4, dist="fixed", latency=0.5)
    assert clock.duration(0, 10) == pytest.approx(10.5)
    assert clock.duration(0, 20) == pytest.approx(20.5)


def test_clock_bimodal_has_one_fast_client():
    clock = make_clock(5, dist="bimodal")
    assert clock.speeds[-1] == pytest.approx(10.0)
    np.testing.assert_allclose(clock.speeds[:-1], 1.0)
    # sync round time is set by the stragglers, not the fast client
    assert clock.round_time(np.full(5, 10)) == pytest.approx(10.0)


def test_clock_seeded_reproducible():
    a = make_clock(16, dist="lognormal", sigma=0.8, seed=3)
    b = make_clock(16, dist="lognormal", sigma=0.8, seed=3)
    np.testing.assert_array_equal(a.speeds, b.speeds)


# ---------------------------------------------------------------------------
# buffered aggregation stages
# ---------------------------------------------------------------------------

def test_buffered_mean_reduces_to_weighted_average():
    """Identical anchors + weights summing to 1 ⇒ plain weighted average."""
    rng = np.random.default_rng(0)
    p0 = {"x": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}
    x_i = {"x": jnp.asarray(rng.normal(size=(4, 5)).astype(np.float32))}
    anchor = {"x": jnp.broadcast_to(p0["x"], (4, 5))}
    w = jnp.array([0.1, 0.2, 0.3, 0.4], jnp.float32)
    kf = jnp.full((4,), 3.0)
    out = stages.buffered_mean(p0, anchor, x_i, kf, w, jnp.float32(3.0))
    want = stages.aggregate_mean(p0, x_i, kf, w, jnp.float32(3.0))
    np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(want["x"]),
                               rtol=1e-6, atol=1e-7)


def test_buffered_fednova_normalizes_per_client_steps():
    rng = np.random.default_rng(1)
    p0 = {"x": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}
    deltas = rng.normal(size=(2, 5)).astype(np.float32)
    anchor = {"x": jnp.broadcast_to(p0["x"], (2, 5))}
    x_i = {"x": anchor["x"] + deltas}
    w = jnp.array([0.5, 0.5], jnp.float32)
    kf = jnp.array([2.0, 8.0])
    kbar = jnp.dot(w, kf)
    out = stages.buffered_fednova(p0, anchor, x_i, kf, w, kbar)
    want = np.asarray(p0["x"]) + 5.0 * (0.5 * deltas[0] / 2 + 0.5 * deltas[1] / 8)
    np.testing.assert_allclose(np.asarray(out["x"]), want, rtol=1e-5)


def test_stale_anchor_aggregates_the_delta_not_the_params():
    """A stale client's contribution is its OWN progress δ = x − anchor, not
    its absolute parameters — the buffered form must not drag the server
    back toward an old model version."""
    p_now = {"x": jnp.full((3,), 10.0)}
    stale_anchor = {"x": jnp.zeros((1, 3))}          # model 10 versions ago
    x_i = {"x": jnp.ones((1, 3))}                    # client moved by +1
    w = jnp.array([0.5], jnp.float32)
    out = stages.buffered_mean(p_now, stale_anchor, x_i,
                               jnp.ones((1,)), w, jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(out["x"]), np.full(3, 10.5))


# ---------------------------------------------------------------------------
# per-client anchors in the client-update stage
# ---------------------------------------------------------------------------

def test_per_client_anchor_matches_broadcast_anchor():
    fed = FedConfig(algorithm="fedagrac", n_clients=4, lr=0.01,
                    calibration_rate=0.5)
    algo = get_algorithm("fedagrac", fed)
    rng = np.random.default_rng(0)
    params = {"x": jnp.asarray(rng.normal(size=(6,)).astype(np.float32))}
    b = {"A": jnp.asarray(rng.normal(size=(4, 3, 6, 6)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=(4, 3, 6)).astype(np.float32)),
         "c0": jnp.zeros((4, 3))}
    ks = jnp.array([1, 2, 3, 3], jnp.int32)
    c = jax.tree.map(lambda a: jnp.zeros((4,) + params["x"].shape), params)
    shared = stages.make_client_update(quad_loss, algo, lr=0.01, k_max=3)
    stacked = stages.make_client_update(quad_loss, algo, lr=0.01, k_max=3,
                                        per_client_anchor=True)
    anchor_i = jax.tree.map(lambda a: jnp.broadcast_to(a, (4,) + a.shape),
                            params)
    out_a = shared(params, c, b, ks, jnp.float32(0.5))
    out_b = stacked(anchor_i, c, b, ks, jnp.float32(0.5))
    for la, lb in zip(jax.tree.leaves(out_a), jax.tree.leaves(out_b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# end-to-end: buffer = M reduces to the synchronous round
# ---------------------------------------------------------------------------

def _task(seed=0):
    key = jax.random.PRNGKey(seed)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    params = {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}
    return data, parts, params


@pytest.mark.parametrize("algo", ["fedavg", "fedagrac", "fednova",
                                  "scaffold"])
def test_full_buffer_equals_synchronous_round(algo):
    """buffer = M + identical speeds: every server update aggregates exactly
    one aligned wave with zero staleness ⇒ the synchronous engine."""
    data, parts, params = _task()
    ks = np.full((50, M), 4, np.int32)
    t = 5
    fed_sync = FedConfig(algorithm=algo, n_clients=M, lr=0.05,
                         calibration_rate=0.5, weights="data")
    sync = FederatedSimulation(lr_loss, params, fed_sync,
                               FederatedBatcher(data, parts, batch_size=10),
                               k_schedule=ks)
    h_sync = sync.run(t)
    fed_async = dataclasses.replace(fed_sync, buffer_size=M,
                                    speed_dist="fixed")
    async_ = BufferedAsyncSimulation(
        lr_loss, params, fed_async,
        FederatedBatcher(data, parts, batch_size=10), k_schedule=ks)
    h_async = async_.run(t)
    for a, b in zip(jax.tree.leaves(sync.state),
                    jax.tree.leaves(async_.state)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(h_sync.loss, h_async.loss, rtol=1e-5)
    assert h_async.staleness == [0.0] * t


def test_buffered_async_runs_and_tracks_staleness():
    data, parts, params = _task()
    fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.05,
                    calibration_rate=0.5, buffer_size=3, staleness="hinge",
                    speed_dist="lognormal", speed_sigma=1.0)
    sim = BufferedAsyncSimulation(
        lr_loss, params, fed, FederatedBatcher(data, parts, batch_size=10),
        k_schedule=np.full((50, M), 4, np.int32))
    h = sim.run(12)
    assert len(h.loss) == 12 and np.all(np.isfinite(h.loss))
    # simulated time advances monotonically; heterogeneous speeds + partial
    # buffers must produce some genuinely stale aggregations
    assert h.sim_time == sorted(h.sim_time)
    assert max(h.staleness) > 0


def test_history_pruning_bounds_memory():
    data, parts, params = _task()
    fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.05,
                    buffer_size=2, speed_dist="lognormal", speed_sigma=1.5)
    sim = BufferedAsyncSimulation(
        lr_loss, params, fed, FederatedBatcher(data, parts, batch_size=10),
        k_schedule=np.full((50, M), 4, np.int32))
    sim.run(20)
    # version history holds only versions still referenced by in-flight
    # tasks (≤ M distinct) — never all 20
    assert len(sim._hist) <= M + 1
    assert len(sim._batch_cache) <= M + 1


def test_staleness_discount_shrinks_the_update():
    """Same trajectory, hinge vs constant: discounted stale updates move the
    server strictly less far from init."""
    data, parts, params = _task()
    out = {}
    for mode in ("constant", "hinge"):
        fed = FedConfig(algorithm="fedavg", n_clients=M, lr=0.05,
                        buffer_size=1, staleness=mode, staleness_a=2.0,
                        staleness_b=0, speed_dist="lognormal",
                        speed_sigma=1.5)
        sim = BufferedAsyncSimulation(
            lr_loss, params, fed,
            FederatedBatcher(data, parts, batch_size=10),
            k_schedule=np.full((50, M), 4, np.int32))
        h = sim.run(16)
        assert max(h.staleness) > 0          # buffer=1 ⇒ staleness exists
        out[mode] = float(sum(np.linalg.norm(np.asarray(v))
                              for v in jax.tree.leaves(sim.params)))
    assert out["hinge"] < out["constant"]


def test_duplicate_reporter_keeps_nu_mixing_convex():
    """A high-data-weight fast client reporting twice into one buffer pushes
    Σ w̃ past 1; the ν mix must stay convex (no sign-flipped decay) and the
    run bounded."""
    from repro.fed.clock import ClientClock
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, 3, alpha=1.0, beta=1.0)
    # make client 0 own most of the data (ω₀ ≈ 0.58) AND be 50× faster
    cut = 3 * len(parts[1]) // 4
    parts = [np.concatenate([parts[0], parts[1][:cut]]),
             parts[1][cut:], parts[2]]
    clock = ClientClock(speeds=np.array([50.0, 1.0, 1.0]),
                        latency=np.zeros(3))
    fed = FedConfig(algorithm="fedagrac", n_clients=3, lr=0.05,
                    calibration_rate=0.5, weights="data", buffer_size=2)
    sim = BufferedAsyncSimulation(
        lr_loss, {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}, fed,
        FederatedBatcher(data, parts, batch_size=10),
        k_schedule=np.full((300, 3), 3, np.int32), clock=clock)
    masses, orig = [], sim._step

    def spy(*args):
        state, metrics = orig(*args)
        masses.append(float(metrics["mass"]))
        return state, metrics

    sim._step = spy
    h = sim.run(40)
    assert max(masses) > 1.0, masses        # the Σw̃ > 1 regime really occurs
    assert all(np.isfinite(h.loss))
    nu_norm = max(float(jnp.max(jnp.abs(v)))
                  for v in jax.tree.leaves(sim.state["nu"]))
    assert nu_norm < 1e3, nu_norm


def test_buffer_size_validation():
    data, parts, params = _task()
    fed = FedConfig(algorithm="fedavg", n_clients=M, buffer_size=M + 1)
    with pytest.raises(ValueError):
        BufferedAsyncSimulation(lr_loss, params, fed,
                                FederatedBatcher(data, parts, batch_size=10))
