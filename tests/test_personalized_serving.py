"""Personalized serving: golden pin vs the plain engine, per-client view
resolution, hot-swap invariants, load generation, launch lowering."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, reduced
from repro.configs.registry import get_arch
from repro.core import flat
from repro.models import model as M
from repro.serving import (LoadGen, PersonalizedServeEngine, Request,
                           ServeEngine, lowrank_factors, make_personalizer,
                           make_snapshot, replay)
from tests.test_serving_engine import reference_generate


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_arch("llama3-8b"), n_layers=2, d_model=128)
    cfg = dataclasses.replace(cfg, vocab=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    spec = flat.make_flat_spec(params)
    base = flat.ravel(spec, params)
    return cfg, params, spec, base


def _requests(vocab, shapes, seed=0, clients=None):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(1, vocab, size=n).astype(np.int32),
                    max_new_tokens=m,
                    client_id=clients[i] if clients else i % 3)
            for i, (n, m) in enumerate(shapes)]


def _serve(eng, reqs):
    for r in reqs:
        eng.submit(dataclasses.replace(r))
    return {c.uid: c for c in eng.run()}


SHAPES = [(5, 6), (16, 4), (9, 8), (12, 3)]


def _nu_snapshot(spec, base, m=3, version=0, seed=1):
    nu = 1e-3 * jax.random.normal(jax.random.PRNGKey(seed), (spec.p,))
    nu_i = nu[None] + 1e-2 * jax.random.normal(
        jax.random.PRNGKey(seed + 1), (m, spec.p))
    return make_snapshot(version, base, nu=nu, nu_i=nu_i)


# -- golden pin ---------------------------------------------------------------


def test_none_matches_plain_engine_greedy(setup):
    """personalizer="none" serves bit-identical completions to ServeEngine
    on the same stream — the shared path runs the identical jaxpr on the
    materialized flat view (acceptance criterion)."""
    cfg, params, spec, base = setup
    reqs = _requests(cfg.vocab, SHAPES)
    done0 = _serve(ServeEngine(cfg, params, slots=2, max_len=128,
                               prefill_buckets=(8, 16)), reqs)
    eng = PersonalizedServeEngine(cfg, spec, make_snapshot(0, base),
                                  personalizer="none", slots=2,
                                  max_len=128, prefill_buckets=(8, 16))
    done1 = _serve(eng, reqs)
    assert {u: c.tokens for u, c in done0.items()} \
        == {u: c.tokens for u, c in done1.items()}


def test_none_matches_plain_engine_sampled(setup):
    """Same pin under a key-USING sampler: the per-(uid, step) keys flow
    identically through both engines."""
    cfg, params, spec, base = setup
    sampler = lambda logits, key: jax.random.categorical(key, logits)
    reqs = _requests(cfg.vocab, SHAPES, seed=3)
    done0 = _serve(ServeEngine(cfg, params, slots=2, max_len=128,
                               prefill_buckets=(8, 16), sampler=sampler),
                   reqs)
    done1 = _serve(PersonalizedServeEngine(
        cfg, spec, make_snapshot(0, base), personalizer="none", slots=2,
        max_len=128, prefill_buckets=(8, 16), sampler=sampler), reqs)
    assert {u: c.tokens for u, c in done0.items()} \
        == {u: c.tokens for u, c in done1.items()}


# -- view resolution ----------------------------------------------------------


def test_nu_rows_match_shifted_params_reference(setup):
    """Every completion under the "nu" personalizer equals per-request
    greedy decoding under params = unravel(base + scale·(ν⁽ⁱ⁾ − ν))."""
    cfg, params, spec, base = setup
    snap = _nu_snapshot(spec, base)
    reqs = _requests(cfg.vocab, SHAPES)
    done = _serve(PersonalizedServeEngine(
        cfg, spec, snap, personalizer="nu", scale=0.7, slots=2,
        max_len=128, prefill_buckets=(8, 16)), reqs)
    for r in reqs:
        shift = 0.7 * (snap["nu_i"][r.client_id] - snap["nu"])
        want = reference_generate(cfg, flat.unravel(spec, base + shift),
                                  r.prompt, r.max_new_tokens)
        assert done[r.uid].tokens == want, r.uid


def test_lowrank_exact_at_full_rank(setup):
    """lowrank_factors at r ≥ rank reconstructs the ν deltas exactly, so
    the lowrank engine serves the same tokens as the nu engine."""
    cfg, params, spec, base = setup
    snap = _nu_snapshot(spec, base)
    coeff, basis = lowrank_factors(snap["nu_i"], snap["nu"], r=3)
    assert coeff.shape == (3, 3) and basis.shape == (3, spec.p)
    np.testing.assert_allclose(
        np.asarray(coeff @ basis),
        np.asarray(snap["nu_i"] - snap["nu"][None]), atol=1e-4)
    lr = make_snapshot(0, base, coeff=coeff, basis=basis)
    reqs = _requests(cfg.vocab, SHAPES)
    done_nu = _serve(PersonalizedServeEngine(
        cfg, spec, snap, personalizer="nu", slots=2, max_len=128,
        prefill_buckets=(8, 16)), reqs)
    done_lr = _serve(PersonalizedServeEngine(
        cfg, spec, lr, personalizer="lowrank", slots=2, max_len=128,
        prefill_buckets=(8, 16)), reqs)
    assert {u: c.tokens for u, c in done_nu.items()} \
        == {u: c.tokens for u, c in done_lr.items()}


def test_cold_start_client_serves_base(setup):
    """A client_id outside the stored population resolves to the shared
    base — identical tokens to the plain engine."""
    cfg, params, spec, base = setup
    snap = _nu_snapshot(spec, base, m=3)
    req = _requests(cfg.vocab, [(7, 5)], clients=[999])[0]
    eng = PersonalizedServeEngine(cfg, spec, snap, personalizer="nu",
                                  slots=2, max_len=128,
                                  prefill_buckets=(8, 16))
    assert eng.resolve(999) is None
    done = _serve(eng, [req])
    want = reference_generate(cfg, params, req.prompt, req.max_new_tokens)
    assert done[req.uid].tokens == want


def test_mixed_clients_batch_together(setup):
    """Personalized and cold-start requests share the pool: each still
    matches its own single-request reference (row independence)."""
    cfg, params, spec, base = setup
    snap = _nu_snapshot(spec, base, m=2)
    reqs = _requests(cfg.vocab, SHAPES, clients=[0, 999, 1, 999])
    done = _serve(PersonalizedServeEngine(
        cfg, spec, snap, personalizer="nu", slots=4, max_len=128,
        prefill_buckets=(8, 16)), reqs)
    for r in reqs:
        if r.client_id < 2:
            shift = snap["nu_i"][r.client_id] - snap["nu"]
            p = flat.unravel(spec, base + shift)
        else:
            p = params
        assert done[r.uid].tokens == reference_generate(
            cfg, p, r.prompt, r.max_new_tokens), r.uid


# -- hot-swap -----------------------------------------------------------------


@pytest.mark.parametrize("kind", ["none", "nu"])
def test_hot_swap_preserves_in_flight(setup, kind):
    """A swap between ticks never changes tokens of requests admitted
    before it (acceptance criterion), on both the shared and row decode
    paths; completions record the version they were admitted under."""
    cfg, params, spec, base = setup
    base2 = base + 1e-2 * jax.random.normal(jax.random.PRNGKey(9),
                                            (spec.p,))
    mk = (lambda v, b: make_snapshot(v, b)) if kind == "none" \
        else (lambda v, b: _nu_snapshot(spec, b, version=v))
    pre = _requests(cfg.vocab, [(6, 12)], seed=1)[0]
    post = dataclasses.replace(_requests(cfg.vocab, [(6, 6)], seed=2)[0],
                               uid=1)

    def serve(swap):
        eng = PersonalizedServeEngine(cfg, spec, mk(3, base),
                                      personalizer=kind, slots=2,
                                      max_len=128, prefill_buckets=(8,))
        eng.submit(dataclasses.replace(pre))
        for _ in range(4):
            eng.step()
        if swap:
            eng.swap(mk(7, base2))
        eng.submit(dataclasses.replace(post))
        return {c.uid: c for c in eng.run()}

    plain, swapped = serve(False), serve(True)
    assert swapped[0].tokens == plain[0].tokens        # pre-swap invariant
    assert swapped[0].version == 3 and swapped[1].version == 7
    assert plain[1].version == 3
    # the post-swap request really sees the new base
    eng2 = PersonalizedServeEngine(cfg, spec, mk(7, base2),
                                   personalizer=kind, slots=2,
                                   max_len=128, prefill_buckets=(8,))
    eng2.submit(dataclasses.replace(post))
    assert swapped[1].tokens == eng2.run()[0].tokens


def test_swap_gc_drops_dead_versions(setup):
    cfg, params, spec, base = setup
    eng = PersonalizedServeEngine(cfg, spec, make_snapshot(1, base),
                                  personalizer="none", slots=2,
                                  max_len=128, prefill_buckets=(8,))
    done = _serve(eng, _requests(cfg.vocab, [(5, 3)]))
    assert done[0].version == 1
    eng.swap(make_snapshot(2, base))
    eng.swap(make_snapshot(5, base))
    assert sorted(eng._versions) == [5]


def test_registry_rejects_unknown_kind(setup):
    cfg, params, spec, base = setup
    with pytest.raises(ValueError, match="lowrank"):
        make_personalizer("bogus", make_snapshot(0, base))
    with pytest.raises(ValueError, match="nu_i"):
        make_personalizer("nu", make_snapshot(0, base))
    with pytest.raises(ValueError, match="coeff"):
        make_personalizer("lowrank", make_snapshot(0, base))


def test_lowrank_resolution_flat_in_population(setup):
    """The 100k-client representation: O(M·r + r·P) storage, O(r·P)
    resolve — structurally independent of M."""
    cfg, params, spec, base = setup
    m = 100_000
    coeff = 1e-3 * jax.random.normal(jax.random.PRNGKey(0), (m, 4))
    basis = jax.random.normal(jax.random.PRNGKey(1), (4, spec.p))
    fn = make_personalizer("lowrank",
                           make_snapshot(0, base, coeff=coeff, basis=basis))
    d = fn(m - 1)
    assert d.shape == (spec.p,)
    np.testing.assert_allclose(np.asarray(d),
                               np.asarray(coeff[m - 1] @ basis), rtol=1e-6)
    assert fn(m) is None and fn(-1) is None


# -- load generation ----------------------------------------------------------


def test_loadgen_deterministic_and_bounded():
    gen = LoadGen(population=50, rate=0.8, prompt_len=(3, 8),
                  max_new=(2, 6), vocab=99, seed=4, skew=2.0)
    a, b = gen.generate(40), gen.generate(40)
    assert len(a) == 40
    assert [t for t, _ in a] == [t for t, _ in b]
    assert all(t1 <= t2 for (t1, _), (t2, _) in zip(a, a[1:]))
    for (ta, ra), (tb, rb) in zip(a, b):
        assert ra.uid == rb.uid and ra.client_id == rb.client_id
        assert np.array_equal(ra.prompt, rb.prompt)
        assert 0 <= ra.client_id < 50
        assert 3 <= len(ra.prompt) <= 8 and 2 <= ra.max_new_tokens <= 6
        assert ra.prompt.min() >= 1 and ra.prompt.max() < 99
    # a different seed reshuffles the stream
    c = LoadGen(population=50, rate=0.8, prompt_len=(3, 8), max_new=(2, 6),
                vocab=99, seed=5, skew=2.0).generate(40)
    assert any(not np.array_equal(ra.prompt, rc.prompt)
               for (_, ra), (_, rc) in zip(a, c))


def test_replay_drains_trace_and_reports(setup):
    cfg, params, spec, base = setup
    eng = PersonalizedServeEngine(cfg, spec, make_snapshot(0, base),
                                  personalizer="none", slots=2,
                                  max_len=128, prefill_buckets=(8, 16))
    trace = LoadGen(population=8, rate=0.7, prompt_len=(3, 8),
                    max_new=(2, 5), vocab=cfg.vocab, seed=0).generate(10)
    stats = replay(eng, trace)
    assert stats["n_requests"] == 10
    assert len(stats["tick_wall"]) == len(stats["utilization"])
    assert stats["ticks"] > 0 and stats["requests_per_s"] > 0
    assert {c.uid for c in stats["completions"]} == set(range(10))


def test_replay_swaps_mid_stream(setup):
    cfg, params, spec, base = setup
    eng = PersonalizedServeEngine(cfg, spec, make_snapshot(0, base),
                                  personalizer="none", slots=2,
                                  max_len=128, prefill_buckets=(8, 16))
    trace = LoadGen(population=8, rate=0.5, prompt_len=(3, 8),
                    max_new=(4, 8), vocab=cfg.vocab, seed=2).generate(12)
    stats = replay(eng, trace, swap_at=4, snapshot=make_snapshot(1, base))
    vs = {c.version for c in stats["completions"]}
    assert vs == {0, 1}, vs


# -- launch specs -------------------------------------------------------------


def test_personalized_lowering_single_device(setup):
    """The sharded decode path lowers on a 1×1 local mesh and its bundle
    carries the flat base/delta shapes."""
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import lower_personalized_serve
    cfg, params, spec, base = setup
    mesh = make_local_mesh(1, 1)
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="decode")
    lowered, bundle = lower_personalized_serve(cfg, shape, mesh, spec)
    assert bundle["base"].shape == (spec.p,)
    assert bundle["deltas"].shape == (4, spec.p)
    assert lowered.compile() is not None


def test_personalized_decode_matches_engine_rows(setup):
    """The launch step (base + deltas → rows) computes the same logits the
    engine's row path does for one decode tick."""
    from repro.serving.personalized import personalized_decode
    cfg, params, spec, base = setup
    b = 2
    caches = M.init_caches(cfg, b, 64, jnp.dtype(cfg.dtype))
    toks = jnp.asarray([[5], [9]], jnp.int32)
    offs = jnp.zeros((b,), jnp.int32)
    deltas = 1e-3 * jax.random.normal(jax.random.PRNGKey(3), (b, spec.p))
    rows = base[None] + deltas
    logits, _ = personalized_decode(spec, cfg, rows, toks, caches, offs)
    assert logits.shape == (b, cfg.vocab)
    for i in range(b):
        ref, _ = M.serve_decode(
            flat.unravel(spec, rows[i]), {"tokens": toks[i][None]},
            M.init_caches(cfg, 1, 64, jnp.dtype(cfg.dtype)), 0, cfg)
        np.testing.assert_allclose(np.asarray(logits[i]),
                                   np.asarray(ref[0, 0]), atol=1e-5)
