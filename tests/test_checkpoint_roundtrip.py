"""Checkpoint round-trips for the serving hot-swap feed: full engine state
(flat master + ν rows + error-feedback residuals) bit-exactly through
checkpoint/serialize.py, snapshot publication from a live simulation, and
a mid-run swap-from-file while requests are in flight."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import serialize
from repro.configs.base import FedConfig, reduced
from repro.configs.registry import get_arch
from repro.core import flat
from repro.data import DeviceBatcher, fedprox_synthetic
from repro.fed import FederatedSimulation
from repro.models import model as M_model
from repro.models.simple import lr_loss
from repro.serving import (PersonalizedServeEngine, Request, load_snapshot,
                           make_snapshot, save_snapshot)

M = 8


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    return DeviceBatcher(data, parts, batch_size=8, seed=0)


def _fed(**kw):
    kw.setdefault("algorithm", "fedagrac")
    kw.setdefault("k_mean", 5)
    kw.setdefault("k_var", 2.0)
    kw.setdefault("k_mode", "random")
    return FedConfig(n_clients=M, lr=0.05, calibration_rate=0.5, **kw)


def _params():
    return {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}


def _leaves_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# -- full engine state (the hot-swap source) ---------------------------------


@pytest.mark.parametrize("layout", ["tree", "flat"])
def test_full_state_roundtrips_bit_exact(task, tmp_path, layout):
    """Everything hot-swap consumes — params/master, ν, ν⁽ⁱ⁾ rows — plus
    the PR-8 error-feedback residuals survives save/load bit-for-bit."""
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(param_layout=layout, compressor="int8"),
                              task)
    sim.run(3, eval_every=3)
    path = str(tmp_path / "state.msgpack")
    serialize.save(path, sim.state)
    restored = serialize.load(path, sim.state)
    for key in ("params", "nu", "nu_i", "ef_up", "ef_nu"):
        assert key in restored
    _leaves_equal(sim.state, restored)


def test_load_raw_matches_structured_load(task, tmp_path):
    """``load_raw`` recovers the identical bytes with no ``like`` tree —
    the schema-free path serving snapshots restore through."""
    sim = FederatedSimulation(lr_loss, _params(), _fed(param_layout="flat"),
                              task)
    sim.run(2, eval_every=2)
    path = str(tmp_path / "state.msgpack")
    serialize.save(path, sim.state)
    raw = serialize.load_raw(path)
    structured = serialize.load(path, sim.state)
    assert sorted(raw) == sorted(structured)
    for k in raw:
        np.testing.assert_array_equal(raw[k], np.asarray(structured[k]))
        assert raw[k].dtype == np.asarray(structured[k]).dtype


# -- snapshot publication -----------------------------------------------------


@pytest.mark.parametrize("layout", ["tree", "flat"])
def test_publish_snapshot_carries_training_state(task, layout):
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(param_layout=layout), task)
    sim.run(2, eval_every=2)
    snap = sim.publish_snapshot()
    spec = sim.flat_spec
    assert int(snap["version"]) == 2
    assert snap["flat_master"].shape == (spec.p,)
    assert snap["nu"].shape == (spec.p,)
    assert snap["nu_i"].shape == (M, spec.p)
    # the master IS the current model, whatever the layout
    _leaves_equal(flat.unravel(spec, snap["flat_master"]), sim.params)


def test_snapshot_file_roundtrip(task, tmp_path):
    sim = FederatedSimulation(lr_loss, _params(), _fed(param_layout="flat"),
                              task)
    sim.run(2, eval_every=2)
    path = str(tmp_path / "snap.msgpack")
    saved = sim.save_snapshot(path)
    loaded = load_snapshot(path)
    assert sorted(loaded) == sorted(saved)
    assert int(loaded["version"]) == int(saved["version"])
    _leaves_equal({k: v for k, v in saved.items() if k != "version"},
                  {k: v for k, v in loaded.items() if k != "version"})


def test_publish_hook_fires_on_round_boundaries(task):
    seen = []
    sim = FederatedSimulation(lr_loss, _params(), _fed(param_layout="flat"),
                              task)
    sim.run(6, eval_every=6, publish_fn=lambda s: seen.append(s),
            publish_every=2)
    assert [int(s["version"]) for s in seen] == [2, 4, 6]
    # each publication is the exact state at its round, so consecutive
    # masters differ (training moved) but shapes/schema are stable
    assert all(s["flat_master"].shape == seen[0]["flat_master"].shape
               for s in seen)
    assert not np.array_equal(np.asarray(seen[0]["flat_master"]),
                              np.asarray(seen[-1]["flat_master"]))


# -- health/quarantine state (core/robust.py, DESIGN.md §16) ------------------


_HEALTH_KEYS = ("hz_nonfinite", "hz_mean", "hz_var", "hz_count", "hz_until")


@pytest.mark.parametrize("layout", ["tree", "flat"])
def test_health_state_roundtrips_bit_exact(task, tmp_path, layout):
    """The per-client health vectors ride the same checkpoint as the
    model/ν/EF state, bit-for-bit, on both layouts."""
    fed = _fed(param_layout=layout, scenario="nan_inject",
               scenario_rate=0.25, defense="trimmed_mean",
               quarantine_window=3)
    sim = FederatedSimulation(lr_loss, _params(), fed, task)
    sim.run(3, eval_every=3)
    assert np.asarray(sim.state["hz_nonfinite"]).sum() > 0
    path = str(tmp_path / "robust.msgpack")
    serialize.save(path, sim.state)
    restored = serialize.load(path, sim.state)
    assert sorted(restored) == sorted(sim.state)
    for key in _HEALTH_KEYS:
        assert key in restored
    _leaves_equal(sim.state, restored)


def test_cohort_absentee_health_rows_untouched(task):
    """A client outside the sampled cohort reports nothing: its health
    rows must stay bit-identical (no decay, no accidental scatter)."""
    fed = _fed(cohort_size=3, scenario="nan_inject", scenario_rate=0.25,
               defense="median", quarantine_window=4)
    sim = FederatedSimulation(lr_loss, _params(), fed, task)
    before = {k: np.asarray(sim.state[k]).copy() for k in _HEALTH_KEYS}
    sim.run(1)
    ids = set(int(i) for i in sim.population.host_cohort(0)[0])
    after = {k: np.asarray(sim.state[k]) for k in _HEALTH_KEYS}
    for i in range(M):
        if i not in ids:
            for k in _HEALTH_KEYS:
                assert before[k][i] == after[k][i], (k, i)


def test_quarantine_survives_resume(task, tmp_path):
    """A quarantine window in force at save time is still in force after
    load: the restored engine keeps excluding the flagged clients."""
    fed = _fed(scenario="nan_inject", scenario_rate=0.25,
               defense="trimmed_mean", quarantine_window=8)
    sim = FederatedSimulation(lr_loss, _params(), fed, task)
    sim.run(2, eval_every=2)
    assert np.asarray(sim.state["hz_until"]).max() > 0
    path = str(tmp_path / "quar.msgpack")
    serialize.save(path, sim.state)
    sim2 = FederatedSimulation(lr_loss, _params(), fed, task)
    sim2.state = serialize.load(path, sim2.state)
    _leaves_equal(sim.state, sim2.state)
    hist = sim2.run(1, eval_every=1)
    assert hist.quarantined and hist.quarantined[0] > 0


# -- mid-run swap from file with requests in flight ---------------------------


def test_lm_train_publish_swap_while_in_flight(tmp_path):
    """The full loop: train a tiny LM federated sim, publish to disk,
    serve; train more rounds, publish again, hot-swap FROM FILE while a
    request is mid-decode — the in-flight request's tokens are unchanged
    and versions are recorded per completion."""
    from repro.data import LMFederatedBatcher, lm_sequences

    cfg = reduced(get_arch("gemma-2b"), n_layers=1, d_model=32)
    cfg = dataclasses.replace(cfg, vocab=128)
    params = M_model.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    streams = [lm_sequences(jax.random.fold_in(key, i), 64, 16, cfg.vocab,
                            skew_topic=i) for i in range(4)]
    batcher = LMFederatedBatcher(streams, batch_size=4)
    fed = FedConfig(algorithm="fedagrac", n_clients=4, k_mean=2,
                    k_var=0.0, lr=0.1, calibration_rate=0.5,
                    param_layout="flat")
    sim = FederatedSimulation(
        functools.partial(M_model.lm_loss, cfg=cfg), params, fed, batcher)
    sim.run(2, eval_every=2)
    p1 = str(tmp_path / "v2.msgpack")
    sim.save_snapshot(p1)
    sim.run(2, eval_every=2)
    p2 = str(tmp_path / "v4.msgpack")
    sim.save_snapshot(p2)

    spec = sim.flat_spec
    rng = np.random.default_rng(0)
    pre = Request(uid=0, prompt=rng.integers(1, cfg.vocab, 5).astype(
        np.int32), max_new_tokens=10, client_id=1)
    post = Request(uid=1, prompt=rng.integers(1, cfg.vocab, 5).astype(
        np.int32), max_new_tokens=4, client_id=2)

    def serve(swap):
        eng = PersonalizedServeEngine(cfg, spec, load_snapshot(p1),
                                      personalizer="nu", slots=2,
                                      max_len=64, prefill_buckets=(8,))
        eng.submit(dataclasses.replace(pre))
        for _ in range(3):
            eng.step()                 # pre is mid-decode
        if swap:
            eng.swap(load_snapshot(p2))
        eng.submit(dataclasses.replace(post))
        return {c.uid: c for c in eng.run()}

    plain, swapped = serve(False), serve(True)
    assert swapped[0].tokens == plain[0].tokens
    assert swapped[0].version == 2 and swapped[1].version == 4
    assert plain[1].version == 2
    # post-swap admission equals serving v4 outright
    eng4 = PersonalizedServeEngine(cfg, spec, load_snapshot(p2),
                                   personalizer="nu", slots=2,
                                   max_len=64, prefill_buckets=(8,))
    eng4.submit(dataclasses.replace(post))
    assert swapped[1].tokens == eng4.run()[0].tokens
