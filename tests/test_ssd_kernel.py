"""SSD (Mamba2) Pallas kernel vs the chunked-scan oracle (which
tests/test_ssm_equivalence.py proves equal to the naive recurrence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.ops import ssd_scan
from repro.models.mamba2 import ssd_chunked

CASES = [
    # b, l, h, p, g, n, chunk
    (2, 64, 4, 16, 2, 8, 16),      # grouped B/C (zamba2-style)
    (1, 128, 2, 32, 1, 16, 32),    # single group
    (2, 256, 4, 64, 4, 64, 128),   # production-ish dims (P=64, N=64, L=128)
    (1, 64, 2, 16, 2, 8, 64),      # single chunk (no inter-chunk term)
]


def _inputs(b, l, h, p, g, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, l, g, n))
    C = jax.random.normal(ks[4], (b, l, g, n))
    return x, dt, A, B, C


@pytest.mark.parametrize("b,l,h,p,g,n,chunk", CASES)
def test_ssd_kernel_vs_oracle(b, l, h, p, g, n, chunk):
    x, dt, A, B, C = _inputs(b, l, h, p, g, n)
    y_k, s_k = ssd_scan(x, dt, A, B, C, chunk, interpret=True)
    y_r, s_r = ssd_chunked(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_state_chains_across_calls():
    """Final state of a 2-chunk scan == state after scanning twice the
    half-length sequences would require carrying state — verify the single
    call's state equals the naive recurrence end state (already covered)
    AND that chunk size does not change results."""
    x, dt, A, B, C = _inputs(1, 128, 2, 16, 1, 8)
    y16, s16 = ssd_scan(x, dt, A, B, C, 16, interpret=True)
    y64, s64 = ssd_scan(x, dt, A, B, C, 64, interpret=True)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s64),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_use_pallas_false_is_oracle():
    x, dt, A, B, C = _inputs(1, 64, 2, 16, 1, 8)
    y1, s1 = ssd_scan(x, dt, A, B, C, 16, use_pallas=False)
    y2, s2 = ssd_chunked(x, dt, A, B, C, 16)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
