"""Golden layout equivalence: ``param_layout="flat"`` (core/flat.py) must
reproduce the tree-layout round for every algorithm on both engines.

Tolerance, not bit-equality, and deliberately so: the flat round performs
the same elementwise arithmetic in the same order, but XLA:CPU contracts
``x − η·g`` into an FMA (one rounding) in one program layout and not the
other — an LLVM fusion-context decision (verified: the tree path matches
the fused-multiply-add reference exactly, the flat path the two-rounding
reference; the same asymmetry test_calibrated_update_2d documents).  f32
trajectories therefore agree to ~1 ulp per local step; tests pin a few
chained rounds at rtol 1e-6.  bf16 additionally rounds once per fused
kernel instead of once per op — pinned at bf16-ulp scale.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import flat, rounds, stages
from repro.core.fedopt import ALGORITHMS, get_algorithm
from repro.data import DeviceBatcher, FederatedBatcher, fedprox_synthetic
from repro.fed import BufferedAsyncSimulation, FederatedSimulation
from repro.models.simple import lr_accuracy, lr_loss, quad_loss

M, D, K_MAX = 4, 6, 8
W = jnp.array([0.1, 0.2, 0.3, 0.4], jnp.float32)
KS = jnp.array([1, 3, 5, 8], jnp.int32)
PARAMS = {"x": jnp.zeros((D,), jnp.float32)}
SPEC = flat.make_flat_spec(PARAMS)
RTOL, ATOL = 1e-6, 1e-7


def _batches(m=M, key=0):
    rng = np.random.default_rng(key)
    return {
        "A": jnp.asarray(rng.normal(size=(m, K_MAX, D, D)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(m, K_MAX, D)).astype(np.float32)),
        "c0": jnp.zeros((m, K_MAX)),
    }


def _algo(name):
    fed = FedConfig(algorithm=name, n_clients=M, lr=0.01,
                    calibration_rate=0.5)
    return get_algorithm(name, fed)


def _assert_close(tree_out, flat_out, rtol=RTOL, atol=ATOL):
    (state_t, metrics_t), (state_f, metrics_f) = tree_out, flat_out
    assert set(state_t) == set(state_f)
    for (path, lt), lf in zip(
            jax.tree_util.tree_leaves_with_path(state_t),
            jax.tree.leaves(state_f)):
        np.testing.assert_allclose(
            np.asarray(lt, np.float32), np.asarray(lf, np.float32),
            rtol=rtol, atol=atol,
            err_msg=f"state leaf {jax.tree_util.keystr(path)} diverged")
    for k in metrics_t:
        np.testing.assert_allclose(
            np.asarray(metrics_t[k]), np.asarray(metrics_f[k]),
            rtol=rtol, atol=atol, err_msg=f"metric {k!r} diverged")


def _run_pair(algo, n_rounds=3, use_pallas=None, **make_kw):
    state_t = rounds.init_state(dict(PARAMS), M, algo)
    state_f = flat.flatten_state(SPEC, state_t)
    fn_t = jax.jit(rounds.make_round(quad_loss, algo, lr=0.01, k_max=K_MAX,
                                     **make_kw))
    fn_f = jax.jit(flat.make_flat_round(SPEC, quad_loss, algo, lr=0.01,
                                        k_max=K_MAX, use_pallas=use_pallas,
                                        **make_kw))
    b = _batches()
    for _ in range(n_rounds):
        state_t, metrics_t = fn_t(state_t, b, KS, W)
        state_f, metrics_f = fn_f(state_f, b, KS, W)
    return ((state_t, metrics_t),
            (flat.unflatten_state(SPEC, state_f), metrics_f))


# ---------------------------------------------------------------------------
# spec / ravel plumbing
# ---------------------------------------------------------------------------

def test_ravel_roundtrip_and_lane_padding():
    tree = {"a": jnp.arange(7, dtype=jnp.float32).reshape(1, 7),
            "b": {"c": jnp.ones((3, 2), jnp.float32)}}
    spec = flat.make_flat_spec(tree)
    assert spec.n == 13 and spec.p == 128 and spec.dtype == jnp.float32
    buf = flat.ravel(spec, tree)
    assert buf.shape == (128,)
    np.testing.assert_array_equal(np.asarray(buf[13:]), 0.0)
    back = flat.unravel(spec, buf)
    for (pa, la), lb in zip(jax.tree_util.tree_leaves_with_path(tree),
                            jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_ravel_client_stacked_rows():
    tree = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 2, 2),
            "b": jnp.ones((3, 5), jnp.float32)}
    spec = flat.make_flat_spec({"w": jnp.zeros((2, 2)),
                                "b": jnp.zeros((5,))})
    mat = flat.ravel(spec, tree, client_dims=1)
    assert mat.shape == (3, spec.p)
    back = flat.unravel(spec, mat, client_dims=1)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(back["b"]),
                                  np.asarray(tree["b"]))


def test_mixed_dtype_tree_flattens_to_f32():
    spec = flat.make_flat_spec({"a": jnp.zeros((4,), jnp.bfloat16),
                                "b": jnp.zeros((4,), jnp.float32)})
    assert spec.dtype == jnp.float32


def test_flat_round_keeps_padding_zero():
    """Every stage is padding-preserving: after several chained rounds the
    lane-padding tail of every flat state buffer is exactly zero (the
    invariant that makes the flat ↔ tree bijection stable)."""
    algo = _algo("fedagrac")
    state = flat.flatten_state(SPEC, rounds.init_state(dict(PARAMS), M,
                                                       algo))
    fn = jax.jit(flat.make_flat_round(SPEC, quad_loss, algo, lr=0.01,
                                      k_max=K_MAX))
    b = _batches()
    for _ in range(3):
        state, _ = fn(state, b, KS, W)
    for k in ("params", "nu"):
        np.testing.assert_array_equal(np.asarray(state[k][SPEC.n:]), 0.0)
    np.testing.assert_array_equal(np.asarray(state["nu_i"][:, SPEC.n:]),
                                  0.0)


# ---------------------------------------------------------------------------
# round-level golden equivalence (synchronous engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALGORITHMS)
def test_flat_round_matches_tree_all_algorithms(name):
    """All 9 algorithms: 3 chained rounds, every state leaf + metric within
    ulp-scale tolerance of the tree round."""
    _assert_close(*_run_pair(_algo(name)))


@pytest.mark.parametrize("server_opt,server_lr", [("momentum", 0.7),
                                                  ("adam", 0.1)])
def test_flat_round_matches_tree_server_optimizers(server_opt, server_lr):
    import dataclasses
    algo = dataclasses.replace(_algo("fedagrac"), server_opt=server_opt,
                               server_lr=server_lr)
    _assert_close(*_run_pair(algo))


def test_flat_round_matches_tree_explicit_nu():
    _assert_close(*_run_pair(_algo("fedagrac"), track_nu="explicit"))


@pytest.mark.parametrize("name", ["fedagrac", "fedprox", "fedavg"])
def test_flat_round_pallas_kernel_path(name):
    """The TPU client path — per-step ``calibrated_update_2d`` /
    ``_prox_2d`` launches (interpret mode here) — pinned against the tree
    round like the oracle path."""
    _assert_close(*_run_pair(_algo(name), use_pallas=True))


@pytest.mark.parametrize("use_pallas", [None, True])
def test_flat_round_prox_with_orientation(use_pallas):
    """prox + an orientation selector (no registered algorithm combines
    them, but the Algorithm dataclass permits it): the tree path adds the
    prox term into g BEFORE the g₀ select and ν recovery, so the flat
    path must augment g the same way instead of fusing prox into the
    update only."""
    import dataclasses
    algo = dataclasses.replace(_algo("fedagrac"), prox_mu=0.1)
    _assert_close(*_run_pair(algo, use_pallas=use_pallas))
    algo_first = dataclasses.replace(_algo("fedlin"), prox_mu=0.1)
    _assert_close(*_run_pair(algo_first, use_pallas=use_pallas))


def test_flat_round_matches_tree_quantized_transmit():
    """int8 fake-quantization keeps its per-client-per-LEAF scale semantics
    in flat mode (round-trips through the tree at the transmit)."""
    _assert_close(*_run_pair(_algo("fedagrac"), quantize_transmit=True))


def test_flat_round_bf16_ulp():
    """bf16 state: the fused kernel accumulates in f32 and rounds once
    where the tree path rounds per op — agreement to a few bf16 ulp."""
    algo = _algo("fedagrac")
    params = {"x": jnp.zeros((D,), jnp.bfloat16)}
    spec = flat.make_flat_spec(params)
    assert spec.dtype == jnp.bfloat16
    b = jax.tree.map(lambda a: a.astype(jnp.bfloat16), _batches())
    state_t = rounds.init_state(params, M, algo)
    state_f = flat.flatten_state(spec, state_t)
    fn_t = jax.jit(rounds.make_round(quad_loss, algo, lr=0.01, k_max=K_MAX))
    fn_f = jax.jit(flat.make_flat_round(spec, quad_loss, algo, lr=0.01,
                                        k_max=K_MAX))
    state_t, _ = fn_t(state_t, b, KS, W, jnp.float32(0.5))
    state_f, _ = fn_f(state_f, b, KS, W, jnp.float32(0.5))
    back = flat.unflatten_state(spec, state_f)
    assert back["params"]["x"].dtype == jnp.bfloat16
    for (path, lt), lf in zip(
            jax.tree_util.tree_leaves_with_path(state_t),
            jax.tree.leaves(back)):
        np.testing.assert_allclose(
            np.asarray(lt, np.float32), np.asarray(lf, np.float32),
            rtol=2 ** -6, atol=2 ** -6,
            err_msg=f"bf16 leaf {jax.tree_util.keystr(path)} diverged")


# ---------------------------------------------------------------------------
# cohort round (partial participation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALGORITHMS)
def test_flat_cohort_round_matches_tree(name):
    """The flat cohort round (row gather/scatter on the (M, P) ν⁽ⁱ⁾ store)
    against stages.make_cohort_round, Σw̃ ≠ 1 and ν-decay included."""
    algo = _algo(name)
    c = 3
    cohort = jnp.array([3, 0, 2], jnp.int32)
    ks = jnp.array([2, 5, 8], jnp.int32)
    cw = jnp.array([0.5, 0.7, 0.3], jnp.float32)
    b = _batches(m=c, key=1)
    state_t = rounds.init_state(dict(PARAMS), M, algo)
    state_f = flat.flatten_state(SPEC, state_t)
    fn_t = jax.jit(stages.make_cohort_round(quad_loss, algo, lr=0.01,
                                            k_max=K_MAX, nu_decay=0.1))
    fn_f = jax.jit(flat.make_flat_cohort_round(SPEC, quad_loss, algo,
                                               lr=0.01, k_max=K_MAX,
                                               nu_decay=0.1))
    for _ in range(3):
        state_t, metrics_t = fn_t(state_t, b, cohort, ks, cw)
        state_f, metrics_f = fn_f(state_f, b, cohort, ks, cw)
    _assert_close((state_t, metrics_t),
                  (flat.unflatten_state(SPEC, state_f), metrics_f))


# ---------------------------------------------------------------------------
# engine-level equivalence (the wired simulations)
# ---------------------------------------------------------------------------

def _lr_task():
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    params = {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}
    ev = lambda p: float(lr_accuracy(p, {"x": data.x, "y": data.y}))
    return data, parts, params, ev


@pytest.mark.parametrize("sampler", ["host", "device"])
def test_flat_simulation_matches_tree(sampler):
    """FederatedSimulation with param_layout="flat": same losses, metrics
    and final params as the tree layout, chunked AND per-round, λ-schedule
    included."""
    data, parts, params, ev = _lr_task()
    ks = np.full((20, M), 3, np.int32)
    B = {"host": FederatedBatcher, "device": DeviceBatcher}[sampler]

    def run(layout, chunk):
        fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.05,
                        calibration_rate=0.5, weights="data",
                        param_layout=layout)
        sim = FederatedSimulation(lr_loss, params, fed, B(data, parts, 10),
                                  eval_fn=ev, k_schedule=ks,
                                  lam_schedule=lambda t: 0.25 * (t + 1))
        hist = sim.run(8, eval_every=4, chunk_rounds=chunk)
        return sim, hist

    for chunk in (1, None):
        sim_t, h_t = run("tree", chunk)
        sim_f, h_f = run("flat", chunk)
        np.testing.assert_allclose(h_t.loss, h_f.loss, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(h_t.metric, h_f.metric, rtol=RTOL,
                                   atol=ATOL)
        for (path, lt), lf in zip(
                jax.tree_util.tree_leaves_with_path(sim_t.params),
                jax.tree.leaves(sim_f.params)):
            np.testing.assert_allclose(
                np.asarray(lt), np.asarray(lf), rtol=RTOL, atol=ATOL,
                err_msg=f"params leaf {jax.tree_util.keystr(path)}")


def test_flat_simulation_cohort_sampler_matches_tree():
    """Partial participation through the simulation: the flat cohort round
    under the uniform sampler reproduces the tree trajectories."""
    data, parts, params, ev = _lr_task()
    ks = np.full((20, M), 3, np.int32)

    def run(layout):
        fed = FedConfig(algorithm="fedagrac", n_clients=M, lr=0.05,
                        calibration_rate=0.5, weights="data", cohort_size=2,
                        cohort_sampler="uniform", cohort_nu_decay=0.1,
                        param_layout=layout)
        sim = FederatedSimulation(lr_loss, params, fed,
                                  FederatedBatcher(data, parts, 10),
                                  eval_fn=ev, k_schedule=ks)
        hist = sim.run(8, eval_every=4)
        return sim, hist

    sim_t, h_t = run("tree")
    sim_f, h_f = run("flat")
    np.testing.assert_allclose(h_t.loss, h_f.loss, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(h_t.mass, h_f.mass, rtol=RTOL, atol=ATOL)
    for lt, lf in zip(jax.tree.leaves(sim_t.params),
                      jax.tree.leaves(sim_f.params)):
        np.testing.assert_allclose(np.asarray(lt), np.asarray(lf),
                                   rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("name", ALGORITHMS)
def test_flat_async_engine_matches_tree(name):
    """BufferedAsyncSimulation: flat (M+1, P) anchor matrices + the
    per-client-anchor flat client scan reproduce the tree engine for all
    9 algorithms (stale anchors, duplicate reporters, staleness discounts
    all exercised by the lognormal clock)."""
    data, parts, params, ev = _lr_task()
    ks = np.full((8, M), 3, np.int32)

    def run(layout):
        fed = FedConfig(algorithm=name, n_clients=M, lr=0.05,
                        calibration_rate=0.5, weights="data", buffer_size=2,
                        staleness="hinge", speed_dist="lognormal",
                        speed_sigma=0.7, param_layout=layout)
        sim = BufferedAsyncSimulation(lr_loss, params, fed,
                                      FederatedBatcher(data, parts, 10),
                                      eval_fn=ev, k_schedule=ks)
        hist = sim.run(6, eval_every=3)
        return sim, hist

    sim_t, h_t = run("tree")
    sim_f, h_f = run("flat")
    np.testing.assert_allclose(h_t.loss, h_f.loss, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(h_t.metric, h_f.metric, rtol=RTOL, atol=ATOL)
    assert h_t.sim_time == h_f.sim_time          # timeline is layout-free
    for (path, lt), lf in zip(
            jax.tree_util.tree_leaves_with_path(sim_t.params),
            jax.tree.leaves(sim_f.params)):
        np.testing.assert_allclose(
            np.asarray(lt), np.asarray(lf), rtol=RTOL, atol=ATOL,
            err_msg=f"params leaf {jax.tree_util.keystr(path)}")


def test_unknown_layout_raises():
    data, parts, params, _ = _lr_task()
    # validation happens at config construction (FedConfig.__post_init__)
    with pytest.raises(ValueError, match="param_layout"):
        FedConfig(algorithm="fedavg", n_clients=M, param_layout="ring")
    # the engine guards are defense-in-depth for a layout smuggled past
    # the frozen dataclass
    fed = FedConfig(algorithm="fedavg", n_clients=M)
    object.__setattr__(fed, "param_layout", "ring")
    with pytest.raises(ValueError, match="param_layout"):
        FederatedSimulation(lr_loss, params, fed,
                            FederatedBatcher(data, parts, 10))
    with pytest.raises(ValueError, match="param_layout"):
        BufferedAsyncSimulation(lr_loss, params, fed,
                                FederatedBatcher(data, parts, 10))
