"""Wire compression stage (core/compress.py, DESIGN.md §14): quantize
kernel/oracle pins, padding-safety invariants, the compressor="none"
bit-identity matrix over algorithms × engines × layouts, error-feedback
semantics under partial participation, and checkpoint round-trips of the
(M, P) accumulators."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import serialize
from repro.configs.base import FedConfig
from repro.core.compress import (COMPRESSORS, CompressionConfig,
                                 make_codec, payload_bytes, wire_cost)
from repro.core.fedopt import ALGORITHMS
from repro.core import flat as flat_mod
from repro.data import DeviceBatcher, fedprox_synthetic
from repro.fed import BufferedAsyncSimulation, FederatedSimulation
from repro.kernels.quantize import kernel as qkernel
from repro.kernels.quantize import ops as qops
from repro.kernels.quantize import ref as qref
from repro.models.simple import lr_loss
from repro.roofline.analysis import bytes_on_the_wire

M = 8
NAMES = sorted(COMPRESSORS)


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    data, parts = fedprox_synthetic(key, M, alpha=1.0, beta=1.0)
    return DeviceBatcher(data, parts, batch_size=8, seed=0)


def _fed(**kw):
    kw.setdefault("algorithm", "fedagrac")
    kw.setdefault("k_mean", 5)
    kw.setdefault("k_var", 2.0)
    kw.setdefault("k_mode", "random")
    return FedConfig(n_clients=M, lr=0.05, calibration_rate=0.5, **kw)


def _params():
    return {"w": jnp.zeros((60, 10)), "b": jnp.zeros((10,))}


def _leaves_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# kernels: interpret-mode Pallas pinned bitwise to the jnp oracle
# ---------------------------------------------------------------------------

def _mat(rows=9, cols=256, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (rows, cols),
                             jnp.float32) * 3.0


@pytest.mark.parametrize("qmax", [127, 7])
def test_quantize_kernel_matches_oracle(qmax):
    x = _mat()
    scale = qops.row_scales(x, x.shape[1], qmax)
    k = qkernel.quantize_2d(x, scale, qmax=qmax, interpret=True)
    r = qref.quantize_2d(x, scale, qmax=qmax)
    assert k.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))
    assert int(np.abs(np.asarray(k)).max()) <= qmax


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_dequantize_kernel_matches_oracle(out_dtype):
    x = _mat(seed=1)
    scale = qops.row_scales(x, x.shape[1], 127)
    q = qref.quantize_2d(x, scale, qmax=127)
    k = qkernel.dequantize_2d(q, scale, out_dtype=out_dtype,
                              interpret=True)
    r = qref.dequantize_2d(q, scale, out_dtype=out_dtype)
    assert k.dtype == jnp.dtype(out_dtype)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


def test_topk_mask_kernel_matches_oracle():
    x = _mat(seed=2)
    th = qops.topk_thresholds(x, x.shape[1], 13)
    k = qkernel.topk_mask_2d(x, th, interpret=True)
    r = qref.topk_mask_2d(x, th)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))
    # at least k survivors per row (ties may keep more, wire charges k)
    assert (np.count_nonzero(np.asarray(k), axis=1) >= 13).all()


def test_dispatch_wrappers_route_to_oracle_off_tpu():
    x = _mat(seed=3)
    scale = qops.row_scales(x, x.shape[1], 127)
    np.testing.assert_array_equal(
        np.asarray(qops.quantize_2d(x, scale)),
        np.asarray(qref.quantize_2d(x, scale)))


# ---------------------------------------------------------------------------
# scalar selection: padding is structurally excluded
# ---------------------------------------------------------------------------

def test_masked_rowmax_excludes_poisoned_padding():
    n, p = 200, 256
    x = _mat(rows=4, cols=p, seed=4)
    poisoned = x.at[:, n:].set(1e9)
    np.testing.assert_array_equal(
        np.asarray(qops.masked_abs_rowmax(poisoned, n)),
        np.asarray(qops.masked_abs_rowmax(x, n)))
    amax = np.abs(np.asarray(x)[:, :n]).max(axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(qops.masked_abs_rowmax(x, n)),
                               amax, rtol=0)


def test_topk_thresholds_never_select_padding():
    n, p, k = 100, 256, 10
    x = jnp.zeros((3, p)).at[:, :n].set(
        _mat(rows=3, cols=n, seed=5)).at[:, n:].set(1e9)
    th = qops.topk_thresholds(x, n, k)
    # thresholds come from the true columns despite the enormous pad
    assert float(th.max()) < 1e9


def test_row_scales_eps_floor():
    z = jnp.zeros((2, 128))
    np.testing.assert_array_equal(np.asarray(qops.row_scales(z, 128, 127)),
                                  np.full((2, 1), 1e-12, np.float32))


# ---------------------------------------------------------------------------
# codecs: every compressor is padding-preserving and pad-scale-immune
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", NAMES)
def test_codec_padding_stays_zero_and_scale_excludes_pad(name):
    n, p = 300, 384
    clean = jnp.zeros((5, p)).at[:, :n].set(_mat(rows=5, cols=n, seed=6))
    poisoned = clean.at[:, n:].set(7e8)
    codec = make_codec(name, n, topk_frac=0.05)
    out_c, out_p = codec(clean), codec(poisoned)
    if name == "none":
        # identity codec: the pipeline never poisons padding upstream, so
        # "none" must stay a bit-exact pass-through (the golden-pin path)
        np.testing.assert_array_equal(np.asarray(out_c), np.asarray(clean))
        return
    # pad columns come out exactly zero, even from a poisoned pad
    np.testing.assert_array_equal(np.asarray(out_c)[:, n:], 0.0)
    np.testing.assert_array_equal(np.asarray(out_p)[:, n:], 0.0)
    # a poisoned pad cannot perturb the true columns (scale immunity)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_p))


def test_int8_codec_quantizes_to_levels():
    n = 256
    x = _mat(rows=2, cols=n, seed=7)
    out = make_codec("int8", n)(x)
    scale = np.asarray(qops.row_scales(x, n, 127))
    levels = np.round(np.asarray(out) / scale)
    np.testing.assert_allclose(np.asarray(out), levels * scale, atol=1e-6)
    assert np.abs(levels).max() <= 127


def test_unknown_compressor_raises_with_valid_names():
    with pytest.raises(KeyError, match="int4"):
        make_codec("gzip", 128)
    with pytest.raises(KeyError, match="topk"):
        payload_bytes("gzip", 128)


# ---------------------------------------------------------------------------
# wire model
# ---------------------------------------------------------------------------

def test_payload_bytes_formulas():
    n = 610
    assert payload_bytes("none", n) == 4 * n
    assert payload_bytes("int8", n) == n + 4
    assert payload_bytes("int4", n) == 305 + 4
    k = max(1, round(0.05 * n))
    assert payload_bytes("topk", n) == 8 * k
    assert payload_bytes("topk+int8", n) == 5 * k + 4


def test_wire_cost_doubles_for_nu_algorithms():
    comp = CompressionConfig(uplink="int8")
    one = wire_cost(100, False, comp)
    two = wire_cost(100, True, comp)
    assert two["uplink_per_client"] == 2 * one["uplink_per_client"]
    assert one["downlink_per_client"] == 4 * 100  # downlink uncompressed


def test_bytes_on_the_wire_reduction():
    out = bytes_on_the_wire(610, uses_nu=True, compressor="int4",
                            participants=10, rounds=5)
    assert out["uplink_reduction"] > 4.0
    assert out["uplink_total"] == 50 * out["uplink_per_client"]
    none = bytes_on_the_wire(610, uses_nu=True)
    assert none["uplink_reduction"] == 1.0


# ---------------------------------------------------------------------------
# config surface: validation + the deprecation shim
# ---------------------------------------------------------------------------

def test_config_rejects_unknown_compressors():
    with pytest.raises(ValueError, match="compressor"):
        _fed(compressor="gzip")
    with pytest.raises(ValueError, match="broadcast_compressor"):
        _fed(broadcast_compressor="lz4")
    with pytest.raises(ValueError, match="topk_frac"):
        _fed(topk_frac=0.0)


def test_quantize_transmit_deprecation_folds_into_compressor():
    with pytest.warns(DeprecationWarning, match="compressor"):
        fed = _fed(quantize_transmit=True)
    assert fed.compressor == "int8"
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert _fed().compressor == "none"


# ---------------------------------------------------------------------------
# compressor="none" bit-identity: algorithms × engines × layouts
# ---------------------------------------------------------------------------

def _none_kw():
    return {"compressor": "none", "broadcast_compressor": "none",
            "error_feedback": True}


@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_none_bit_identical_sync(task, algorithm, layout):
    fed_kw = {"algorithm": algorithm, "param_layout": layout}
    ref = FederatedSimulation(lr_loss, _params(), _fed(**fed_kw), task)
    ref.run(2, eval_every=2)
    none = FederatedSimulation(lr_loss, _params(),
                               _fed(**fed_kw, **_none_kw()), task)
    none.run(2, eval_every=2)
    _leaves_equal(ref.state, none.state)


@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_none_bit_identical_cohort(task, algorithm, layout):
    fed_kw = {"algorithm": algorithm, "param_layout": layout,
              "cohort_size": 4}
    ref = FederatedSimulation(lr_loss, _params(), _fed(**fed_kw), task)
    ref.run(2, eval_every=2)
    none = FederatedSimulation(lr_loss, _params(),
                               _fed(**fed_kw, **_none_kw()), task)
    none.run(2, eval_every=2)
    _leaves_equal(ref.state, none.state)


@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_none_bit_identical_async(task, algorithm, layout):
    fed_kw = {"algorithm": algorithm, "param_layout": layout,
              "buffer_size": 4, "staleness": "poly"}
    ref = BufferedAsyncSimulation(lr_loss, _params(), _fed(**fed_kw), task)
    ref.run(3)
    none = BufferedAsyncSimulation(lr_loss, _params(),
                                   _fed(**fed_kw, **_none_kw()), task)
    none.run(3)
    _leaves_equal(ref.state, none.state)


# ---------------------------------------------------------------------------
# compressed runs: layouts agree, error feedback engages
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp,bc", [("int8", "none"), ("int4", "int8"),
                                     ("topk", "none")])
def test_compressed_layouts_agree(task, comp, bc):
    """Tree (view-table bridged) and flat (native) compressed rounds run
    the same arithmetic on different memory layouts — ULP-scale agreement,
    the test_flat_layout convention."""
    out = {}
    for layout in ("tree", "flat"):
        sim = FederatedSimulation(
            lr_loss, _params(),
            _fed(compressor=comp, broadcast_compressor=bc,
                 param_layout=layout), task)
        sim.run(3, eval_every=3)
        out[layout] = jax.tree.leaves(sim.params)
    for a, b in zip(out["tree"], out["flat"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)


def test_error_feedback_state_allocated_and_nonzero(task):
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(compressor="int4",
                                   broadcast_compressor="int8"), task)
    sim.run(2, eval_every=2)
    assert sim.state["ef_up"].shape == (M, sim._spec.p)
    assert sim.state["ef_nu"].shape == (M, sim._spec.p)
    assert sim.state["ef_down"].shape == (sim._spec.p,)
    # quantization of real deltas leaves real residuals
    assert np.abs(np.asarray(sim.state["ef_up"])).max() > 0
    # the padding tail of every accumulator stays exactly zero
    for key in ("ef_up", "ef_nu"):
        np.testing.assert_array_equal(
            np.asarray(sim.state[key])[:, sim._spec.n:], 0.0)
    np.testing.assert_array_equal(
        np.asarray(sim.state["ef_down"])[sim._spec.n:], 0.0)


def test_error_feedback_off_keeps_state_clean(task):
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(compressor="int8",
                                   error_feedback=False), task)
    sim.run(2, eval_every=2)
    assert "ef_up" not in sim.state and "ef_nu" not in sim.state


# ---------------------------------------------------------------------------
# EF semantics under partial participation: absentees wait untouched
# ---------------------------------------------------------------------------

def test_cohort_absentee_accumulators_untouched(task):
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(compressor="int8", cohort_size=3), task)
    before = np.asarray(sim.state["ef_up"]).copy()
    sim.run(1)
    ids = set(int(i) for i in sim.population.host_cohort(0)[0])
    after = np.asarray(sim.state["ef_up"])
    for i in range(M):
        if i in ids:
            assert np.abs(after[i]).max() > 0, f"participant {i} row clean"
        else:
            np.testing.assert_array_equal(after[i], before[i],
                                          err_msg=f"absent client {i}")


def test_async_nonreporter_accumulators_untouched(task):
    sim = BufferedAsyncSimulation(lr_loss, _params(),
                                  _fed(compressor="int8", buffer_size=3,
                                       speed_dist="lognormal"), task)
    from repro.fed.clock import simulate_timeline
    tl = simulate_timeline(sim.k_schedule, sim.clock, sim.buffer, 2,
                           population=sim.population)
    sim.run(2)
    reporters = set(int(i) for i in tl.ids[:2].ravel())
    assert len(reporters) < M          # lognormal skew: someone is silent
    after = np.asarray(sim.state["ef_up"])
    for i in range(M):
        if i in reporters:
            assert np.abs(after[i]).max() > 0
        else:
            np.testing.assert_array_equal(after[i], 0.0,
                                          err_msg=f"silent client {i}")


def test_mid_round_dropout_keeps_nondelivered_residual(task):
    """A mid-round dropout (k′ < K) still REPORTS its partial delta — its
    accumulator updates like any reporter — but a client absent from the
    cohort entirely must keep its residual bit-for-bit (never zeroed,
    never renormalized)."""
    fed = _fed(compressor="int8", cohort_size=3, scenario="dropout",
               dropout_rate=0.5)
    sim = FederatedSimulation(lr_loss, _params(), fed, task)
    sim.run(2)
    before = np.asarray(sim.state["ef_up"]).copy()
    sim.run(1)  # run() restarts t at 0: this round draws host_cohort(0)
    ids = set(int(i) for i in sim.population.host_cohort(0)[0])
    after = np.asarray(sim.state["ef_up"])
    for i in range(M):
        if i not in ids:
            np.testing.assert_array_equal(after[i], before[i],
                                          err_msg=f"absent client {i}")


# ---------------------------------------------------------------------------
# checkpoint: (M, P) accumulators round-trip bit-exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["tree", "flat"])
def test_checkpoint_roundtrips_ef_state(task, tmp_path, layout):
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(compressor="int4",
                                   broadcast_compressor="int8",
                                   param_layout=layout), task)
    sim.run(2, eval_every=2)
    path = str(tmp_path / "state.msgpack")
    serialize.save(path, sim.state)
    restored = serialize.load(path, sim.state)
    assert sorted(restored) == sorted(sim.state)
    for key in ("ef_up", "ef_nu", "ef_down", "ef_down_nu"):
        assert key in restored
    _leaves_equal(sim.state, restored)


def test_async_checkpoint_roundtrips_broadcast_carry(task, tmp_path):
    sim = BufferedAsyncSimulation(lr_loss, _params(),
                                  _fed(compressor="int8",
                                       broadcast_compressor="int8",
                                       buffer_size=4), task)
    sim.run(2)
    assert "bc_params" in sim.state and "bc_nu" in sim.state
    path = str(tmp_path / "astate.msgpack")
    serialize.save(path, sim.state)
    restored = serialize.load(path, sim.state)
    _leaves_equal(sim.state, restored)


def test_flatten_state_passes_compression_keys_through(task):
    sim = FederatedSimulation(lr_loss, _params(),
                              _fed(compressor="int8"), task)
    sim.run(1)
    spec = sim._spec
    flat_state = flat_mod.flatten_state(spec, sim.state)
    assert flat_state["ef_up"] is sim.state["ef_up"]
    back = flat_mod.unflatten_state(spec, flat_state)
    assert back["ef_up"] is sim.state["ef_up"]
    _leaves_equal(sim.state["params"], back["params"])


# ---------------------------------------------------------------------------
# quantize_int8_flat: masked scale + padding pin (legacy transmit path)
# ---------------------------------------------------------------------------

def test_quantize_int8_flat_padding_and_scale():
    tree = {"w": jnp.arange(24, dtype=jnp.float32).reshape(4, 6) / 7.0,
            "b": jnp.array([0.5, -2.0], jnp.float32)}
    spec = flat_mod.make_flat_spec(tree)
    rows = flat_mod.ravel(spec, jax.tree.map(
        lambda x: jnp.stack([x, 2 * x]), tree), client_dims=1)
    out = flat_mod.quantize_int8_flat(spec, rows)
    # pad tail exactly zero
    np.testing.assert_array_equal(np.asarray(out)[:, spec.n:], 0.0)
    # per-leaf per-row scale semantics: each segment matches the explicit
    # tree-path fake-quant of its own leaf
    off = 0
    for lv, size in zip(jax.tree.leaves(jax.tree.map(
            lambda x: jnp.stack([x, 2 * x]), tree)), spec.sizes):
        seg = np.asarray(out)[:, off:off + size]
        a = np.asarray(lv).reshape(2, -1).astype(np.float32)
        scale = np.maximum(np.abs(a).max(axis=1, keepdims=True) / 127.0,
                           1e-12)
        np.testing.assert_allclose(seg, np.round(a / scale) * scale,
                                   rtol=1e-6, atol=1e-7)
        off += size
