"""Paper-faithfulness tests on closed-form quadratics.

Theorem 1: FedAvg + step asynchronism + data heterogeneity converges to a
point ≠ x* (objective inconsistency); homogeneous steps or IID data remove
the gap.  FedaGrac (λ=1) removes it under asynchronism (Theorem 3).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import rounds, theory
from repro.core.fedopt import get_algorithm
from repro.data.synthetic import quadratic_clients
from repro.models.simple import quad_loss

M, D = 8, 12
LR = 0.02
K_ASYNC = np.array([1, 1, 1, 2, 2, 4, 8, 20], dtype=np.int32)
K_EQUAL = np.full(M, 4, dtype=np.int32)
W = np.full(M, 1.0 / M, dtype=np.float32)


def _run(algo_name, lam, k_steps, As, bs, t_rounds=400, lr=LR):
    fed = FedConfig(algorithm=algo_name, n_clients=M, lr=lr,
                    calibration_rate=lam)
    algo = get_algorithm(algo_name, fed)
    k_max = int(k_steps.max())
    params = {"x": jnp.zeros((D,), jnp.float32)}
    state = rounds.init_state(params, M, algo)
    round_fn = jax.jit(rounds.make_round(quad_loss, algo, lr=lr, k_max=k_max))
    batches = {
        "A": jnp.broadcast_to(jnp.asarray(As)[:, None], (M, k_max, D, D)),
        "b": jnp.broadcast_to(jnp.asarray(bs)[:, None], (M, k_max, D)),
        "c0": jnp.zeros((M, k_max)),
    }
    ks, w = jnp.asarray(k_steps), jnp.asarray(W)
    for _ in range(t_rounds):
        state, _ = round_fn(state, batches, ks, w)
    return np.asarray(state["params"]["x"])


@pytest.fixture(scope="module")
def quads():
    As, bs = quadratic_clients(jax.random.PRNGKey(0), M, D, hetero=1.5)
    x_star = theory.global_optimum(As, bs, W)
    return As, bs, x_star


def test_fedavg_matches_thm1_fixed_point(quads):
    As, bs, x_star = quads
    fp = theory.fedavg_fixed_point(As, bs, W, K_ASYNC, LR)
    x = _run("fedavg", 0.0, K_ASYNC, As, bs)
    assert np.linalg.norm(x - fp) < 1e-3
    # ...and that point is FAR from the optimum (objective inconsistency)
    assert np.linalg.norm(x - x_star) > 0.5


def test_fedagrac_removes_inconsistency(quads):
    As, bs, x_star = quads
    x = _run("fedagrac", 1.0, K_ASYNC, As, bs)
    assert np.linalg.norm(x - x_star) < 1e-3


def test_fedagrac_beats_fednova(quads):
    As, bs, x_star = quads
    x_nova = _run("fednova", 0.0, K_ASYNC, As, bs)
    x_grac = _run("fedagrac", 1.0, K_ASYNC, As, bs)
    assert (np.linalg.norm(x_grac - x_star)
            < 0.1 * np.linalg.norm(x_nova - x_star))


def test_iid_data_no_inconsistency():
    """hetero=0 ⇒ identical local objectives ⇒ FedAvg reaches x* even with
    step asynchronism (the paper's remark after Theorem 1)."""
    As, bs = quadratic_clients(jax.random.PRNGKey(1), M, D, hetero=0.0)
    # identical b but A differs; make objectives literally identical:
    As = np.repeat(As[:1], M, axis=0)
    bs = np.repeat(bs[:1], M, axis=0)
    x_star = theory.global_optimum(As, bs, W)
    x = _run("fedavg", 0.0, K_ASYNC, As, bs)
    assert np.linalg.norm(x - x_star) < 1e-3


def test_inconsistency_rhs_zero_iff_homogeneous(quads):
    As, bs, x_star = quads
    rhs_async = theory.objective_inconsistency_rhs(As, bs, W, K_ASYNC, x_star)
    rhs_equal = theory.objective_inconsistency_rhs(As, bs, W, K_EQUAL, x_star)
    assert rhs_equal == 0.0
    assert rhs_async > 0.0


def test_fixed_point_approaches_opt_as_lr_shrinks(quads):
    """Equal-K FedAvg bias is O(η): the fixed point approaches x*."""
    As, bs, x_star = quads
    d_big = np.linalg.norm(
        theory.fedavg_fixed_point(As, bs, W, K_EQUAL, 0.02) - x_star)
    d_small = np.linalg.norm(
        theory.fedavg_fixed_point(As, bs, W, K_EQUAL, 0.002) - x_star)
    assert d_small < 0.2 * d_big


def test_scaffold_also_consistent_on_deterministic_quadratics(quads):
    """With exact gradients SCAFFOLD reaches x* too — the paper's critique
    is about stochastic drift of fast nodes, not the quadratic fixed point."""
    As, bs, x_star = quads
    x = _run("scaffold", 1.0, K_ASYNC, As, bs)
    assert np.linalg.norm(x - x_star) < 1e-2


def test_suboptimality_positive(quads):
    As, bs, x_star = quads
    x = _run("fedavg", 0.0, K_ASYNC, As, bs)
    assert theory.suboptimality(As, bs, W, x, x_star) > 0
    assert theory.suboptimality(As, bs, W, x_star, x_star) == pytest.approx(0)
