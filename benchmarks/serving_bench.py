"""Personalized serving: requests/s and tick latency vs population size.

Claim validated: per-request personalization cost is FLAT in the client
population M.  View resolution is a row gather + one `(P,)` add at
admission (``lowrank``: an `(r,)·(r, P)` matvec), never a scan over M —
so a 100 000-client deployment serves at the same per-tick cost as a
32-client one.  The benchmark replays deterministic seeded traces
(serving/loadgen.py) against ``PersonalizedServeEngine`` on a reduced
llama3 and reports:

  * **M sweep** — ``lowrank`` personalizer (the O(M·r + r·P) serving-scale
    representation) at M ∈ {32, 1 000, 100 000}: requests/s, p50/p99 tick
    wall, utilization.  The flatness check asserts requests/s at M=100k
    stays within a generous factor of M=32.
  * **personalizer kinds** at M=32 — "none" (shared-base fast path) vs
    "nu" ((M, P) training rows) vs "lowrank" (factored), same trace.
  * **hot-swap cost** — wall time of ``swap()`` (view materialization for
    the new version) and a mid-stream swap replay (in-flight requests keep
    their pinned version).

Writes ``BENCH_serving.json`` at the repo root; CI uploads it.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import reduced
from repro.configs.registry import get_arch
from repro.core import flat
from repro.models import model as model_lib
from repro.serving import (LoadGen, PersonalizedServeEngine, latency_stats,
                           lowrank_factors, make_snapshot, replay)

ROOT = pathlib.Path(__file__).resolve().parent.parent

RANK = 4
SLOTS = 4


def _setup():
    cfg = reduced(get_arch("llama3-8b"), n_layers=2, d_model=64)
    cfg = dataclasses.replace(cfg, vocab=256)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    spec = flat.make_flat_spec(params)
    base = flat.ravel(spec, params)
    return cfg, spec, base


def _snapshot(spec, base, kind: str, m: int, version: int = 0):
    """Synthetic per-client signal sized for ``kind``: full (M, P) ν rows
    for "nu" (training-state representation), factored (M, r) + (r, P) for
    "lowrank" — at M=100k the rows would be gigabytes, the factors ~1.6 MB,
    which is exactly the point of the factored form."""
    if kind == "none":
        return make_snapshot(version, base)
    key = jax.random.PRNGKey(42 + version)
    if kind == "nu":
        nu = 1e-3 * jax.random.normal(key, (spec.p,))
        nu_i = nu[None] + 1e-3 * jax.random.normal(
            jax.random.fold_in(key, 1), (m, spec.p))
        return make_snapshot(version, base, nu=nu, nu_i=nu_i)
    coeff = 1e-3 * jax.random.normal(key, (m, RANK))
    basis = jax.random.normal(jax.random.fold_in(key, 1), (RANK, spec.p))
    basis = basis / np.linalg.norm(np.asarray(basis), axis=1, keepdims=True)
    return make_snapshot(version, base, coeff=coeff, basis=basis)


def _run(cfg, spec, base, *, kind: str, m: int, n_requests: int,
         seed: int = 0) -> dict:
    snap = _snapshot(spec, base, kind, m)
    eng = PersonalizedServeEngine(cfg, spec, snap, personalizer=kind,
                                  slots=SLOTS, max_len=128,
                                  prefill_buckets=(8, 16))
    gen = LoadGen(population=m, rate=1.0, prompt_len=(4, 14),
                  max_new=(4, 10), vocab=cfg.vocab, seed=seed, skew=2.0)
    # warmup: compile every (bucket, path) the measured trace will hit
    replay(eng, gen.generate(max(SLOTS * 2, 8)))
    stats = replay(eng, [(t, r) for t, r in
                         LoadGen(population=m, rate=1.0,
                                 prompt_len=(4, 14), max_new=(4, 10),
                                 vocab=cfg.vocab, seed=seed + 1,
                                 skew=2.0).generate(n_requests)])
    lat = latency_stats(stats["tick_wall"])
    return {
        "personalizer": kind,
        "population": m,
        "n_requests": stats["n_requests"],
        "requests_per_s": stats["requests_per_s"],
        "tick_p50_ms": lat["p50"] * 1e3,
        "tick_p99_ms": lat["p99"] * 1e3,
        "mean_utilization": stats["mean_utilization"],
    }


def _swap_cost(cfg, spec, base, m: int, n_requests: int) -> dict:
    """Mid-stream hot-swap: replay with a version bump at the trace
    midpoint, plus the bare ``swap()`` wall cost."""
    eng = PersonalizedServeEngine(cfg, spec, _snapshot(spec, base,
                                                       "lowrank", m),
                                  personalizer="lowrank", slots=SLOTS,
                                  max_len=128, prefill_buckets=(8, 16))
    gen = LoadGen(population=m, rate=1.0, prompt_len=(4, 14),
                  max_new=(4, 10), vocab=cfg.vocab, seed=5, skew=2.0)
    replay(eng, gen.generate(SLOTS * 2))                      # warmup
    snap2 = _snapshot(spec, base + 1e-3, "lowrank", m, version=1)
    t0 = time.perf_counter()
    eng.swap(snap2)
    swap_s = time.perf_counter() - t0
    snap3 = _snapshot(spec, base + 2e-3, "lowrank", m, version=2)
    stats = replay(eng, gen.generate(n_requests), swap_at=eng.ticks + 4,
                   snapshot=snap3)
    versions = sorted({c.version for c in stats["completions"]})
    return {"swap_ms": swap_s * 1e3,
            "mid_stream_versions_served": versions,
            "requests_per_s_with_swap": stats["requests_per_s"]}


def main(quick: bool = False) -> None:
    cfg, spec, base = _setup()
    n_requests = 16 if quick else 48
    populations = (32, 1_000, 100_000)

    sweep = [_run(cfg, spec, base, kind="lowrank", m=m,
                  n_requests=n_requests) for m in populations]
    kinds = [_run(cfg, spec, base, kind=k, m=32, n_requests=n_requests)
             for k in ("none", "nu", "lowrank")]
    swap = _swap_cost(cfg, spec, base, 32, n_requests)

    rows = [(r["personalizer"], r["population"], r["n_requests"],
             f"{r['requests_per_s']:.2f}", f"{r['tick_p50_ms']:.2f}",
             f"{r['tick_p99_ms']:.2f}", f"{r['mean_utilization']:.2f}")
            for r in sweep + kinds]
    emit(rows, ("personalizer", "M", "requests", "req_per_s",
                "tick_p50_ms", "tick_p99_ms", "utilization"))

    # flatness: per-request cost must not scale with population size.
    # generous bound — CI wall clocks are noisy, the failure mode guarded
    # against (an O(M) scan in resolution) would be orders of magnitude off
    flat_ok = sweep[-1]["requests_per_s"] >= 0.3 * sweep[0]["requests_per_s"]
    report = {
        "population_sweep": sweep,
        "personalizer_kinds": kinds,
        "hot_swap": swap,
        "flat_in_population": bool(flat_ok),
        "meta": {
            "quick": quick,
            "model": "llama3-8b reduced (2 layers, d_model=64, vocab=256)",
            "flat_p": spec.p,
            "rank": RANK,
            "slots": SLOTS,
            "claim": "view resolution is a row gather — per-request cost "
                     "flat in M; hot-swap never blocks the pool",
        },
    }
    out = ROOT / "BENCH_serving.json"
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {out} — req/s flat in M: {'OK' if flat_ok else 'NO'} "
          f"({sweep[0]['requests_per_s']:.2f} @ 32 vs "
          f"{sweep[-1]['requests_per_s']:.2f} @ 100k); "
          f"swap {swap['swap_ms']:.1f} ms")
    if not flat_ok:
        raise SystemExit("per-request cost scales with population size")


if __name__ == "__main__":
    main()
