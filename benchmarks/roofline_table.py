"""§Roofline table: read the dry-run sweep JSONL and print the three-term
roofline per (arch × shape × mesh) with the dominant bottleneck."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")

FILES = ("dryrun_single_pod.jsonl", "dryrun_multi_pod.jsonl",
         "dryrun_2d_variant.jsonl", "dryrun_single_pod_baseline.jsonl")


def load(path: str) -> list[dict]:
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"])] = r   # keep latest per combo
    return list(recs.values())


def run(quick: bool = False) -> list[tuple]:
    rows = []
    for fname in FILES:
        path = os.path.join(RESULTS, fname)
        if not os.path.exists(path):
            continue
        tag = ("baseline" if "baseline" in fname else
               "2d" if "2d" in fname else "optimized")
        for r in load(path):
            if r["status"] == "skipped":
                rows.append(("roofline", tag, r["mesh"], r["arch"],
                             r["shape"], "skipped", "-", "-", "-", "-", "-"))
                continue
            if r["status"] != "ok":
                rows.append(("roofline", tag, r["mesh"], r["arch"],
                             r["shape"], "FAILED", "-", "-", "-", "-", "-"))
                continue
            rl = r["roofline"]
            rows.append((
                "roofline", tag, r["mesh"], r["arch"], r["shape"], "ok",
                f"{rl['t_compute_s']:.3e}", f"{rl['t_memory_s']:.3e}",
                f"{rl['t_collective_s']:.3e}", rl["dominant"],
                "-" if rl["useful_ratio"] is None
                else f"{rl['useful_ratio']:.3f}"))
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    hdr = ("bench", "source", "mesh", "arch", "shape", "status",
           "t_compute_s", "t_memory_s", "t_collective_s", "dominant",
           "useful_ratio")
    print(",".join(hdr))
    for row in rows:
        print(",".join(str(x) for x in row))
    if not rows:
        print("# no dry-run results found — run "
              "`python -m repro.launch.dryrun --all --out "
              "results/dryrun_single_pod.jsonl` first")


if __name__ == "__main__":
    main()
