"""§Roofline table: read the dry-run sweep JSONL and print the three-term
roofline per (arch × shape × mesh) with the dominant bottleneck.

The ``layout`` section compiles the benchmark-task round in BOTH parameter
layouts (tree vs flat single-buffer, DESIGN.md §11) on this host and
reports the flat round's memory/collective bytes and HLO op count next to
the tree round's — the layout win at the compiler level, deterministic
where wall-clock on this shared-core container is not."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")

FILES = ("dryrun_single_pod.jsonl", "dryrun_multi_pod.jsonl",
         "dryrun_2d_variant.jsonl", "dryrun_single_pod_baseline.jsonl")


def load(path: str) -> list[dict]:
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"])] = r   # keep latest per combo
    return list(recs.values())


def run(quick: bool = False) -> list[tuple]:
    rows = []
    for fname in FILES:
        path = os.path.join(RESULTS, fname)
        if not os.path.exists(path):
            continue
        tag = ("baseline" if "baseline" in fname else
               "2d" if "2d" in fname else "optimized")
        for r in load(path):
            if r["status"] == "skipped":
                rows.append(("roofline", tag, r["mesh"], r["arch"],
                             r["shape"], "skipped", "-", "-", "-", "-", "-"))
                continue
            if r["status"] != "ok":
                rows.append(("roofline", tag, r["mesh"], r["arch"],
                             r["shape"], "FAILED", "-", "-", "-", "-", "-"))
                continue
            rl = r["roofline"]
            rows.append((
                "roofline", tag, r["mesh"], r["arch"], r["shape"], "ok",
                f"{rl['t_compute_s']:.3e}", f"{rl['t_memory_s']:.3e}",
                f"{rl['t_collective_s']:.3e}", rl["dominant"],
                "-" if rl["useful_ratio"] is None
                else f"{rl['useful_ratio']:.3f}"))
    return rows


def conversion_bytes(spec, loss_fn, params, batches) -> float:
    """HLO bytes of the flat-native grad boundary MINUS the plain tree
    ``vmap(value_and_grad)`` at the same round shape (DESIGN.md §13): the
    view-table slices into the single buffer plus the flat cotangent
    accumulation out of it — the conversion traffic line item."""
    import jax
    import jax.numpy as jnp

    from repro.core import flat as flat_lib
    from repro.roofline import analysis

    m = jax.tree.leaves(batches)[0].shape[0]
    step = jax.tree.map(lambda a: a[:, 0], batches)      # one local step
    rows_ = jnp.stack([flat_lib.ravel(spec, params)] * m)
    trees = jax.tree.map(lambda a: jnp.stack([a] * m), params)

    flat_fn = jax.vmap(flat_lib.flat_value_and_grad(spec, loss_fn))
    tree_fn = jax.vmap(jax.value_and_grad(loss_fn))
    c_flat = jax.jit(flat_fn).lower(rows_, step).compile()
    c_tree = jax.jit(tree_fn).lower(trees, step).compile()
    b_flat = analysis.from_compiled(c_flat, chips=1).bytes_accessed
    b_tree = analysis.from_compiled(c_tree, chips=1).bytes_accessed
    return b_flat - b_tree


def layout_rows(quick: bool = False) -> list[tuple]:
    """Compile the lr/mlp round in both layouts, compare HLO bytes/ops."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import make_task
    from repro.configs.base import FedConfig
    from repro.core import flat as flat_lib, rounds
    from repro.core.fedopt import get_algorithm
    from repro.roofline import analysis

    rows = []
    for kind in ("lr",) if quick else ("lr", "mlp"):
        task = make_task(kind, noniid=True, seed=0)
        m = task.batcher.m
        fed = FedConfig(algorithm="fedagrac", n_clients=m, k_mean=4,
                        lr=task.lr, calibration_rate=0.5, weights="data")
        algo = get_algorithm("fedagrac", fed)
        spec = flat_lib.make_flat_spec(task.params)
        batches = task.batcher.round_batches(0, 4)
        ks = jnp.full((m,), 4, jnp.int32)
        ws = jnp.asarray(task.batcher.weights)
        lam = jnp.float32(0.5)
        rl, ops = {}, {}
        for layout in ("tree", "flat"):
            if layout == "flat":
                fn = flat_lib.make_flat_round(spec, task.loss_fn, algo,
                                              lr=task.lr, k_max=4)
                st = flat_lib.flatten_state(
                    spec, rounds.init_state(task.params, m, algo))
            else:
                fn = rounds.make_round(task.loss_fn, algo, lr=task.lr,
                                       k_max=4)
                st = rounds.init_state(task.params, m, algo)
            compiled = jax.jit(fn).lower(st, batches, ks, ws, lam).compile()
            hlo = compiled.as_text()
            rl[layout] = analysis.from_compiled(compiled, chips=1,
                                                hlo_text=hlo)
            ops[layout] = analysis.hlo_op_count(hlo)
        conv = conversion_bytes(spec, task.loss_fn, task.params, batches)
        cmp = analysis.layout_comparison(rl["tree"], rl["flat"],
                                         conversion_bytes=conv)
        for layout in ("tree", "flat"):
            rows.append((
                "roofline", "layout", "cpu", kind, layout,
                f"{rl[layout].bytes_accessed:.3e}",
                f"{sum(rl[layout].coll_bytes.values()):.3e}",
                ops[layout],
                "1.000" if layout == "tree"
                else f"{cmp['bytes_ratio']:.3f}",
                "1.000" if layout == "tree"
                else f"{ops['flat'] / ops['tree']:.3f}"))
        # the loss-boundary conversion line item (DESIGN.md §13): extra
        # grad-path bytes of the flat-native boundary over the tree one
        rows.append((
            "roofline", "layout", "cpu", kind, "conversion",
            f"{cmp['conversion_bytes']:.3e}", "-", "-",
            f"{cmp['conversion_fraction_of_flat']:+.4f}", "-"))
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    hdr = ("bench", "source", "mesh", "arch", "shape", "status",
           "t_compute_s", "t_memory_s", "t_collective_s", "dominant",
           "useful_ratio")
    print(",".join(hdr))
    for row in rows:
        print(",".join(str(x) for x in row))
    if not rows:
        print("# no dry-run results found — run "
              "`python -m repro.launch.dryrun --all --out "
              "results/dryrun_single_pod.jsonl` first")
    hdr2 = ("bench", "source", "backend", "task", "layout", "hlo_bytes",
            "collective_bytes", "hlo_ops", "bytes_vs_tree", "ops_vs_tree")
    print(",".join(hdr2))
    for row in layout_rows(quick):
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
