"""Benchmark aggregator: one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME[,NAME…]]

Each module prints CSV rows; headers carry the claim being validated in
the module docstring.
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (ablation_int8_nu, compression_bench, engine_bench,
                        fairness, fig2_lambda, fig3_orientation, fig4_grid,
                        fig5_curves, kernel_bench, lm_bench,
                        population_bench, robust_bench, roofline_table,
                        scenario_bench, server_opt, serving_bench,
                        table1_deterioration, table2_utilization,
                        table6_rounds, table_async, thm1_quadratic)

MODULES = {
    "thm1": thm1_quadratic,
    "table1": table1_deterioration,
    "table2": table2_utilization,
    "fig2": fig2_lambda,
    "fig3": fig3_orientation,
    "fig4": fig4_grid,
    "table6": table6_rounds,
    "table_async": table_async,
    "fig5": fig5_curves,
    "kernel": kernel_bench,
    "int8_nu": ablation_int8_nu,
    "compression": compression_bench,
    "fairness": fairness,
    "server_opt": server_opt,
    "roofline": roofline_table,
    "engine": engine_bench,
    "lm": lm_bench,
    "population": population_bench,
    "scenarios": scenario_bench,
    "robust": robust_bench,
    "serving": serving_bench,
}


def parse_only(only: str | None) -> list[str]:
    """Validate ``--only``: whitespace-tolerant, order-preserving dedup, and
    a fail-fast error naming every valid module for any unknown (or empty)
    selection — never a silent no-op run."""
    if only is None:
        return list(MODULES)
    names = [n.strip() for n in only.split(",") if n.strip()]
    names = list(dict.fromkeys(names))
    unknown = [n for n in names if n not in MODULES]
    if unknown or not names:
        what = (f"unknown module(s) {unknown}" if unknown
                else f"--only {only!r} selects nothing")
        raise SystemExit(f"error: {what}; choose from {sorted(MODULES)}")
    return names


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced rounds/grids (CI budget)")
    ap.add_argument("--only", default=None, metavar="NAME[,NAME…]",
                    help=f"comma-separated subset of {sorted(MODULES)}")
    args = ap.parse_args()

    names = parse_only(args.only)
    failures = []
    for name in names:
        mod = MODULES[name]
        print(f"\n# ===== {name}: {mod.__doc__.strip().splitlines()[0]}")
        t0 = time.time()
        try:
            mod.main(quick=args.quick)
            print(f"# {name} done in {time.time() - t0:.1f}s")
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
