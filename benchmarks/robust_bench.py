"""Attack × defense survival grid: Byzantine-robust aggregation
(core/robust.py, fed/scenarios.py, DESIGN.md §16).

Claim validated: FedaGrac is *more* exposed to corrupted payloads than
plain FedAvg — a poisoned report enters not just the model average but
the broadcast orientation ν, so one bad client deteriorates every
client's local direction next round — and the robust-aggregation layer
rehabilitates it: with a defense composed in front of the aggregator
(and the health quarantine absorbing repeat offenders), fedagrac reaches
the accuracy target under attacks where the undefended run diverges
outright (NaN injection poisons the master within one round; the eval
guard raises) or stalls below target (scale / sign-flip payloads).

The grid crosses payload-corruption scenario × defense on the
synchronous engine and reports final accuracy, rounds-to-target,
quarantined-client rounds, and whether the run survived (finite metric
to the end).  A second table ablates the ν defense: defending the model
average while leaving the ν stream undefended (``nu_defense=False``)
shows the calibration channel is an attack surface of its own.

Writes ``BENCH_robust.json`` at the repo root; CI uploads it as an
artifact alongside the scenario and compression reports.
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from benchmarks.common import M_CLIENTS, emit, make_task
from repro.configs.base import FedConfig
from repro.fed import FederatedSimulation

ROOT = pathlib.Path(__file__).resolve().parent.parent

TARGET = 0.70
K_MEAN = 40

# attack name -> FedConfig knobs (resolved by make_scenario)
ATTACK_KNOBS = {
    "clean": {},
    "nan_inject": {"scenario_rate": 0.3},
    "scale_attack": {"scenario_rate": 0.3, "scenario_magnitude": 25.0},
    "sign_flip": {"scenario_rate": 0.3},
    "garbage": {"scenario_rate": 0.3, "scenario_magnitude": 10.0},
}

DEFENSES = ("none", "clip", "median", "trimmed_mean", "krum")


def _one(attack: str, defense: str, rounds: int, *,
         nu_defense: bool = True, algorithm: str = "fedagrac") -> dict:
    m = M_CLIENTS
    task = make_task("lr", noniid=True)
    knobs = dict(ATTACK_KNOBS[attack])
    fed = FedConfig(algorithm=algorithm, n_clients=m, lr=task.lr,
                    k_mean=K_MEAN, k_var=0.3, k_mode="random",
                    calibration_rate=0.5, weights="data",
                    scenario=attack if attack != "clean" else "baseline",
                    defense=defense, nu_defense=nu_defense,
                    quarantine_window=4 if defense != "none" else 0,
                    **knobs)
    sim = FederatedSimulation(task.loss_fn, task.params, fed, task.batcher,
                              eval_fn=task.eval_fn)
    try:
        hist = sim.run(rounds, eval_every=1)
        # survived = the master is still finite (accuracy of NaN logits is
        # finite — argmax picks class 0 — so the metric alone can't tell)
        survived = all(bool(np.all(np.isfinite(np.asarray(leaf))))
                       for leaf in jax.tree.leaves(sim.params))
        # final = tail mean: the LR task oscillates round to round, a
        # single last eval is a coin flip around the plateau
        final = float(np.mean(hist.metric[-5:]))
        r = hist.rounds_to_target(TARGET)
        quar = float(np.sum(hist.quarantined)) if hist.quarantined else 0.0
    except FloatingPointError:
        # the eval guard fired: non-finite metric at the host readback
        survived, final, r, quar = False, None, None, 0.0
    return {
        "algorithm": algorithm,
        "attack": attack,
        "defense": defense,
        "nu_defense": nu_defense,
        "survived": survived,
        "final_acc": final,
        "rounds_to_target": r,
        "reached_target": final is not None and final >= TARGET,
        "quarantined_rounds": quar,
    }


def main(quick: bool = False) -> None:
    rounds = 40 if quick else 80
    attacks = (("clean", "nan_inject", "scale_attack", "sign_flip")
               if quick else tuple(ATTACK_KNOBS))
    defenses = (("none", "median", "trimmed_mean")
                if quick else DEFENSES)

    rows, table = [], []
    for attack in attacks:
        for defense in defenses:
            r = _one(attack, defense, rounds)
            table.append(r)
            rt = r["rounds_to_target"]
            rows.append((
                attack, defense,
                "yes" if r["survived"] else "DIVERGED",
                f"{r['final_acc']:.4f}" if r["final_acc"] is not None
                else "-",
                rt if rt is not None else f">{rounds}",
                f"{r['quarantined_rounds']:.0f}",
            ))
    emit(rows, ("attack", "defense", "survived", "final_acc",
                f"rounds_to_{int(TARGET * 100)}", "quarantined"))

    def cell(attack, defense):
        return next(r for r in table if r["attack"] == attack
                    and r["defense"] == defense)

    # ν-defense ablation: same attack + defense, model-only vs model+ν
    ablation = []
    for nu_def in (False, True):
        r = _one("sign_flip", "median", rounds, nu_defense=nu_def)
        ablation.append(r)
    abl = {
        "attack": "sign_flip",
        "defense": "median",
        "model_only_acc": ablation[0]["final_acc"],
        "model_and_nu_acc": ablation[1]["final_acc"],
        "nu_defense_helps": (
            ablation[0]["final_acc"] is None
            or (ablation[1]["final_acc"] is not None
                and ablation[1]["final_acc"]
                >= ablation[0]["final_acc"] - 0.01)),
    }

    def final(attack, defense):
        v = cell(attack, defense)["final_acc"]
        return -1.0 if v is None else v

    rescued = {
        a: {
            "undefended_final": final(a, "none"),
            "best_defended_final": max(final(a, d) for d in defenses
                                       if d != "none"),
            "undefended_reaches": cell(a, "none")["reached_target"],
            "best_defended_reaches": any(
                cell(a, d)["reached_target"] for d in defenses
                if d != "none"),
        }
        for a in attacks if a != "clean"
    }
    survival = {
        # the headline: ≥1 attack where a defense reaches the target
        # plateau and the undefended run does not
        "defense_rescues_some_attack": any(
            v["best_defended_reaches"] and not v["undefended_reaches"]
            for v in rescued.values()),
        # and under EVERY attack the best defense beats undefended by a
        # clear margin (NaN injection can't reach the clean plateau —
        # the quarantined clients' data is simply gone — but the defended
        # run is far above the poisoned one)
        "defended_gains_everywhere": all(
            v["best_defended_final"] >= v["undefended_final"] + 0.05
            for v in rescued.values()),
        "rescued": rescued,
        "undefended_nan_diverges": not cell("nan_inject", "none")[
            "survived"] if "nan_inject" in attacks else None,
        "nu_ablation": abl,
    }
    report = {
        "table": table,
        "ablation": ablation,
        "survival": survival,
        "meta": {
            "quick": quick,
            "target": TARGET,
            "rounds": rounds,
            "k_local_steps": K_MEAN,
            "attack_knobs": ATTACK_KNOBS,
            "claim": "robust aggregation + health quarantine let fedagrac "
                     "reach the target under payload corruption that "
                     "diverges or stalls the undefended run; defending "
                     "the ν stream matters on top of the model average",
        },
    }
    out = ROOT / "BENCH_robust.json"
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    n_rescued = sum(v["best_defended_reaches"]
                    and not v["undefended_reaches"]
                    for v in rescued.values())
    gains = survival["defended_gains_everywhere"]
    print(f"# wrote {out} — defense rescues {n_rescued}/{len(rescued)} "
          f"attacks to the {TARGET:.2f} plateau; defended gains "
          f"everywhere: {'OK' if gains else 'NO'}; ν-defense helps: "
          f"{'OK' if abl['nu_defense_helps'] else 'NO'}")


if __name__ == "__main__":
    main(quick=True)
