"""Paper Figure 3: global-orientation estimation strategies.

FedaGrac (fast→first, slow→avg) vs _avg (SCAFFOLD), _first, _reverse —
without asynchronism and in the high-noise bimodal regime (batch 5, one
client at K=500) where the strategies separate.  Claim validated: without
asynchronism the four coincide; with it the mixed rule is best and
all-first is worst (noisiest ν).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bimodal_schedule, emit, make_task, run_sim

T = 50
SEEDS = 3
VARIANTS = ("fedagrac", "fedagrac_avg", "fedagrac_first", "fedagrac_reverse")


def run(quick: bool = False) -> list[tuple]:
    t = 15 if quick else T
    seeds = 1 if quick else SEEDS
    rows = []
    for async_ in (False, True):
        ks = bimodal_schedule(k_fast=500) if async_ else None
        for algo in VARIANTS:
            finals = []
            for seed in range(seeds):
                task = make_task("lr", noniid=True, seed=0,
                                 batch=5 if async_ else 20,
                                 batcher_seed=seed)
                hist = run_sim(task, algo, t, k_mean=20, k_schedule=ks,
                               lam=1.0, lr=0.01, seed=seed)
                finals.append(hist.metric[-1])
            rows.append(("fig3", "async" if async_ else "const", algo,
                         round(float(np.mean(finals)), 4),
                         round(float(np.std(finals)), 4)))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "steps", "strategy", "final_acc", "std"))


if __name__ == "__main__":
    main()
