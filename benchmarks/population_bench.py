"""Population scaling: round cost must follow the cohort C, not the
population M (DESIGN.md §10).

Claim validated: with the client-population subsystem (fed/population.py),
a round over a sampled cohort of C clients does O(C) work and materializes
O(C) batch rows regardless of the population size — the in-scan cohort draw
(the O(C) Feistel permutation; an O(M) Gumbel draw alone would cost 3× the
whole round at M = 100k) and the O(C)-row state gather/scatter leave the
(R, M) K-schedule rows streamed per chunk as the only M-sized traffic.  The sweep holds C fixed and grows M two-and-a-half orders of
magnitude (32 → 100k on a laptop-class host); per-round time and the
materialized batch bytes stay flat while only the resident per-client
calibration state (``nu_i``, reported separately) grows with M.

Writes ``BENCH_population.json`` at the repo root; CI uploads it as an
artifact alongside ``BENCH_engine.json``.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import FedConfig
from repro.data import DeviceBatcher, gaussian_classification, iid_partition
from repro.fed import FederatedSimulation
from repro.models.simple import lr_loss

ROOT = pathlib.Path(__file__).resolve().parent.parent

C, K_MEAN, BATCH = 8, 4, 16
D, N_CLASSES = 60, 10
N_DATA = 4096                 # global dataset FIXED: only M grows
REPEATS = 3                   # best-of-N: the container CPU is noisy


def _tree_bytes(tree) -> int:
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(tree))


def _one_scale(m: int, algorithm: str, t_rounds: int, chunk: int,
               seed: int = 0) -> dict:
    # every client needs a non-empty partition, so beyond N_DATA/2 clients
    # the simulation's resident dataset grows at 2 samples/client — input
    # data, reported separately (dataset_bytes) so the flat-in-M claim is
    # about the per-round cohort working set, not the corpus
    data = gaussian_classification(jax.random.PRNGKey(seed),
                                   max(N_DATA, 2 * m), d=D,
                                   n_classes=N_CLASSES)
    parts = iid_partition(len(data), m, seed=seed)
    batcher = DeviceBatcher(data, parts, batch_size=BATCH, seed=seed)
    fed = FedConfig(algorithm=algorithm, n_clients=m, k_mean=K_MEAN,
                    lr=0.05, calibration_rate=0.5, seed=seed,
                    cohort_size=C, cohort_sampler="uniform")
    params = {"w": jnp.zeros((D, N_CLASSES)), "b": jnp.zeros((N_CLASSES,))}
    # explicit single-row schedule: the default builder would allocate a
    # (10k, M) table — population-scale runs pass their own
    ks = np.full((1, m), K_MEAN, np.int32)
    sim = FederatedSimulation(lr_loss, params, fed, batcher, k_schedule=ks)
    assert sim._partial, "population path not engaged"
    sim.run(min(chunk, t_rounds), chunk_rounds=chunk)    # compile + caches
    best = 0.0
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        sim.run(t_rounds, chunk_rounds=chunk)
        best = max(best, t_rounds / (time.perf_counter() - t0))
    stats = getattr(jax.local_devices()[0], "memory_stats", lambda: None)()
    return {
        "m": m,
        "algorithm": algorithm,
        "rounds_per_s": best,
        "ms_per_round": 1e3 / best,
        # O(C) materialized per round vs the O(M) a full wave would cost
        "cohort_batch_bytes": C * K_MEAN * BATCH * (D + 1) * 4,
        "full_wave_batch_bytes": m * K_MEAN * BATCH * (D + 1) * 4,
        # M-resident tensors: the round state (nu_i rows for calibrated
        # algorithms) and the simulation's device-resident dataset
        "state_bytes": _tree_bytes(sim.state),
        "dataset_bytes": len(data) * (D + 1) * 4,
        "device_peak_bytes": (stats or {}).get("peak_bytes_in_use"),
    }


def main(quick: bool = False) -> None:
    m_list = [32, 1024] if quick else [32, 1024, 100_000]
    t_rounds = 24 if quick else 48
    chunk = 12
    rows, sweep = [], []
    for algorithm in ("fedavg", "fedagrac"):
        for m in m_list:
            r = _one_scale(m, algorithm, t_rounds, chunk)
            sweep.append(r)
            rows.append((algorithm, m, C, f"{r['ms_per_round']:.2f}",
                         r["cohort_batch_bytes"], r["state_bytes"]))
    emit(rows, ("algorithm", "m_population", "cohort", "ms_per_round",
                "cohort_batch_bytes", "state_bytes"))

    def ratio(algorithm):
        ms = [r["ms_per_round"] for r in sweep
              if r["algorithm"] == algorithm]
        return ms[-1] / ms[0]

    report = {
        "sweep": sweep,
        "meta": {
            "quick": quick,
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "cohort_size": C,
            "sampler": "uniform",
            "k_local_steps": K_MEAN,
            "batch_size": BATCH,
            "t_rounds": t_rounds,
            "chunk_rounds": chunk,
            "claim": "per-round time and materialized batch bytes are flat "
                     "in M at fixed C; the M-resident tensors — per-client "
                     "state (nu_i rows) and the simulation's dataset "
                     "(2 samples/client beyond 2048) — are reported "
                     "separately as state_bytes / dataset_bytes",
        },
        # flatness: round time at the largest M over the smallest — the
        # stateless algorithm isolates the cohort compute path
        "time_ratio_largest_over_smallest": {
            a: ratio(a) for a in ("fedavg", "fedagrac")},
    }
    out = ROOT / "BENCH_population.json"
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    fa = report["time_ratio_largest_over_smallest"]["fedavg"]
    span = m_list[-1] // m_list[0]
    print(f"# wrote {out} — fedavg round time at M={m_list[-1]} is "
          f"{fa:.2f}x M={m_list[0]} ({span}x more clients): "
          f"{'FLAT OK' if fa < 2.0 else 'NOT FLAT'}")


if __name__ == "__main__":
    main(quick=True)
