"""Paper Figure 2: calibration-rate sensitivity (non-convex track).

λ sweep under constant and asynchronous local steps + the "Increase"
schedule (0.1 → 0.5 → 1.0).  Claim validated: small λ ≈ FedAvg, large λ
over-calibrates (accuracy collapses under asynchronism); the increasing
schedule matches the best constants.
"""
from __future__ import annotations

from benchmarks.common import emit, make_task, run_sim
from repro.optim import lambda_increase

T = 40
LAMBDAS = (0.0, 0.05, 0.1, 0.5, 1.0, 2.0)


def run(quick: bool = False) -> list[tuple]:
    t = 15 if quick else T
    lams = (0.0, 0.5, 2.0) if quick else LAMBDAS
    rows = []
    for async_ in (False, True):
        k_var = 400.0 if async_ else 0.0
        for lam in lams:
            task = make_task("mlp", noniid=True)
            hist = run_sim(task, "fedagrac", t, k_mean=40, k_var=k_var,
                           lam=lam)
            rows.append(("fig2", "async" if async_ else "const",
                         lam, round(hist.metric[-1], 4)))
        task = make_task("mlp", noniid=True)
        hist = run_sim(task, "fedagrac", t, k_mean=40, k_var=k_var, lam=0.1,
                       lam_schedule=lambda_increase(
                           (t // 4, t // 2), (0.1, 0.5, 1.0)))
        rows.append(("fig2", "async" if async_ else "const",
                     "increase", round(hist.metric[-1], 4)))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "steps", "lambda", "final_acc"))


if __name__ == "__main__":
    main()
