"""Beyond-paper: FedOpt server optimizers × client calibration.

Reddi et al. (2021) server optimizers applied to the round pseudo-gradient
compose freely with the client-side rules here.  Question examined: does a
server optimizer (FedAvgM / FedAdam) substitute for calibration under
step asynchronism, or do they address different failure modes?
"""
from __future__ import annotations

import dataclasses as dc

import jax
import jax.numpy as jnp

from benchmarks.common import bimodal_schedule, emit, make_task, rounds_to
from repro.configs.base import FedConfig
from repro.core import rounds
from repro.core.fedopt import get_algorithm
from repro.fed.simulation import FederatedSimulation

T = 40
COMBOS = (
    ("fedavg", "sgd", 1.0),
    ("fedavg", "momentum", 1.0),
    ("fedavg", "adam", 0.05),
    ("fedagrac", "sgd", 1.0),
    ("fedagrac", "adam", 0.05),
)


def run(quick: bool = False) -> list[tuple]:
    t = 15 if quick else T
    rows = []
    ks = bimodal_schedule()
    for client_algo, server, slr in COMBOS:
        task = make_task("lr", noniid=True)
        fed = FedConfig(algorithm=client_algo, n_clients=task.batcher.m,
                        lr=task.lr, calibration_rate=1.0, weights="data",
                        server_opt=server, server_lr=slr)
        sim = FederatedSimulation(task.loss_fn, task.params, fed,
                                  task.batcher, eval_fn=task.eval_fn,
                                  k_schedule=ks)
        hist = sim.run(t)
        rows.append(("server_opt", client_algo, server, slr,
                     rounds_to(hist, 0.77), round(hist.metric[-1], 4)))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "client", "server", "server_lr",
                      "rounds_to_077", "final_acc"))


if __name__ == "__main__":
    main()
