"""Paper Table 6: rounds-to-target across algorithms × K-variance × mode.

Five algorithms under Gaussian K_i ~ N(40, V), V ∈ {0, 100, 1600},
fixed/random modes, DP1 (Dirichlet-like model skew) and DP2 (label
shards).  Claim validated: calibrated methods (FedaGrac / SCAFFOLD) hold
their round count as variance grows; FedAvg/FedNova lose the most.
"""
from __future__ import annotations

from benchmarks.common import (emit, make_task, make_task_dp2, rounds_to,
                               run_sim)

T = 50
TARGET = {"dp1": 0.80, "dp2": 0.80}
ALGOS = ("fedagrac", "fedavg", "fednova", "scaffold", "fedprox")
LAM = {"fedagrac": 0.5}


def run(quick: bool = False) -> list[tuple]:
    t = 20 if quick else T
    variances = ((0.0, "fixed"), (1600.0, "fixed")) if quick else \
        ((0.0, "fixed"), (100.0, "fixed"), (100.0, "random"),
         (1600.0, "fixed"), (1600.0, "random"))
    rows = []
    for dp, mk in (("dp1", lambda: make_task("mlp", noniid=True)),
                   ("dp2", lambda: make_task_dp2("mlp"))):
        for var, mode in variances:
            for algo in ALGOS:
                hist = run_sim(mk(), algo, t, k_mean=40, k_var=var,
                               k_mode=mode, lam=LAM.get(algo, 1.0))
                rows.append(("table6", dp, f"V={var:g}", mode, algo,
                             rounds_to(hist, TARGET[dp]),
                             round(hist.metric[-1], 4)))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "partition", "variance", "mode", "algorithm",
                      "rounds_to_target", "final_acc"))


if __name__ == "__main__":
    main()
