"""Paper Table 1: FedAvg deterioration matrix.

Rounds to reach the target accuracy under {neither, step-async, non-IID,
both} — async is the paper's bimodal regime (9 slow clients K=2, one fast
K=200).  Claim validated: each factor alone is mild; combined they
deteriorate sharply, worst for the convex model (objective inconsistency).
"""
from __future__ import annotations

from benchmarks.common import bimodal_schedule, emit, make_task, rounds_to, \
    run_sim

T = 60
TARGET = {"lr": 0.78, "mlp": 0.78}


def run(quick: bool = False) -> list[tuple]:
    t = 25 if quick else T
    rows = []
    for kind in ("lr", "mlp"):
        for noniid in (False, True):
            for async_ in (False, True):
                task = make_task(kind, noniid=noniid)
                ks = bimodal_schedule() if async_ else None
                hist = run_sim(task, "fedavg", t, k_mean=20, k_var=0.0,
                               k_schedule=ks)
                label = {(False, False): "neither",
                         (False, True): "step_async",
                         (True, False): "non_iid",
                         (True, True): "both"}[(noniid, async_)]
                rows.append(("table1", kind, label,
                             rounds_to(hist, TARGET[kind]),
                             round(hist.metric[-1], 4)))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "model", "setting", "rounds_to_target",
                      "final_acc"))


if __name__ == "__main__":
    main()
