"""Beyond-paper ablation: int8-quantized ν transmission.

The paper cites gradient compression as orthogonal related work (§2); here
we quantify it on FedaGrac's orientation upload: per-client symmetric int8
fake-quantization of the transmitted gradient halves the ν payload vs
bf16 (4× vs fp32).  Claim examined: calibration quality survives 8-bit ν.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bimodal_schedule, emit, make_task
from repro.configs.base import FedConfig
from repro.core import rounds
from repro.core.fedopt import get_algorithm
from repro.fed.simulation import FederatedSimulation

T = 50


def run(quick: bool = False) -> list[tuple]:
    t = 15 if quick else T
    rows = []
    ks = bimodal_schedule()
    for quant in (False, True):
        task = make_task("lr", noniid=True)
        fed = FedConfig(algorithm="fedagrac", n_clients=task.batcher.m,
                        lr=task.lr, calibration_rate=1.0, weights="data")
        sim = FederatedSimulation(task.loss_fn, task.params, fed,
                                  task.batcher, eval_fn=task.eval_fn,
                                  k_schedule=ks)
        # rebuild the round with quantized transmission
        algo = get_algorithm("fedagrac", fed)
        sim._round = jax.jit(rounds.make_round(
            task.loss_fn, algo, lr=fed.lr, k_max=sim.k_max,
            quantize_transmit=quant))
        hist = sim.run(t)
        rows.append(("int8_nu", "int8" if quant else "fp32",
                     round(hist.metric[-1], 4),
                     hist.rounds_to_target(0.77) or f">{t}"))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "nu_dtype", "final_acc", "rounds_to_077"))


if __name__ == "__main__":
    main()
