"""Beyond-paper ablation: int8-quantized transmission (registry port).

The paper cites gradient compression as orthogonal related work (§2); here
we quantify it on FedaGrac's uploads via the first-class compression stage
(core/compress.py): ``FedConfig.compressor="int8"`` applies per-row
symmetric int8 fake-quantization with error feedback to BOTH wire
quantities — the parameter delta and the ν orientation — 4× fewer uplink
bytes than fp32.  Claim examined: calibration quality survives 8-bit
transmission.  (The pre-registry version fake-quantized only ν through the
deprecated ``quantize_transmit`` flag; the full sweep with bytes-to-target
lives in benchmarks/compression_bench.py.)
"""
from __future__ import annotations

from benchmarks.common import bimodal_schedule, emit, make_task
from repro.configs.base import FedConfig
from repro.fed.simulation import FederatedSimulation

T = 50


def run(quick: bool = False) -> list[tuple]:
    t = 15 if quick else T
    rows = []
    ks = bimodal_schedule()
    for comp in ("none", "int8"):
        task = make_task("lr", noniid=True)
        fed = FedConfig(algorithm="fedagrac", n_clients=task.batcher.m,
                        lr=task.lr, calibration_rate=1.0, weights="data",
                        compressor=comp)
        sim = FederatedSimulation(task.loss_fn, task.params, fed,
                                  task.batcher, eval_fn=task.eval_fn,
                                  k_schedule=ks)
        hist = sim.run(t)
        rows.append(("int8_nu", "int8" if comp == "int8" else "fp32",
                     round(hist.metric[-1], 4),
                     hist.rounds_to_target(0.77) or f">{t}"))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "nu_dtype", "final_acc", "rounds_to_077"))


if __name__ == "__main__":
    main()
