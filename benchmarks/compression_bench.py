"""Communication-efficient rounds: compressor sweep with bytes accounting.

Claim validated (DESIGN.md §14): with error feedback, the aggressive
compressors deliver a ≥4× uplink-bytes reduction at accuracy parity with
fp32 on the quickstart workload — bytes-to-target, not rounds-to-target,
is the cross-device cost model, and FedaGrac ships TWO quantities per
report (delta + ν), so the wire win applies twice per client.

Sweep: compressor × algorithm × {sync, async}.  Per row: final accuracy,
measured uplink bytes/round (``History.bytes_up``, pinned against the
analytic ``roofline.analysis.bytes_on_the_wire`` model), uplink reduction
vs fp32, rounds-to-target, bytes-to-target.  Also asserts that
``compressor="none"`` leaves the round BIT-IDENTICAL to a config without
compression (the CI quick-gate twin of tests/test_compression.py's
nine-algorithm pin).  ``BENCH_compression.json`` at the repo root is the
tracked artifact (CI uploads it).
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from benchmarks.common import bimodal_schedule, emit, make_task
from repro.configs.base import FedConfig
from repro.fed import BufferedAsyncSimulation, FederatedSimulation
from repro.fed.clock import make_clock
from repro.roofline.analysis import bytes_on_the_wire

ROOT = pathlib.Path(__file__).resolve().parent.parent

COMPRESSORS = ("none", "int8", "int4", "topk", "topk+int8")
TARGET = 0.70        # reached by every engine on this track (0.77 is not)
PARITY = 0.01        # |acc − fp32 acc| tolerance for the headline


def _fed(task, algorithm, compressor, **kw):
    return FedConfig(algorithm=algorithm, n_clients=task.batcher.m,
                     lr=task.lr, calibration_rate=1.0, weights="data",
                     compressor=compressor, **kw)


def _run_sync(algorithm, compressor, t):
    task = make_task("lr", noniid=True)
    sim = FederatedSimulation(task.loss_fn, task.params,
                              _fed(task, algorithm, compressor),
                              task.batcher, eval_fn=task.eval_fn,
                              k_schedule=bimodal_schedule())
    return sim, sim.run(t)


def _run_async(algorithm, compressor, t_updates):
    task = make_task("lr", noniid=True)
    m = task.batcher.m
    fed = _fed(task, algorithm, compressor, buffer_size=m // 2,
               staleness="hinge", staleness_a=0.5, staleness_b=2)
    clock = make_clock(m, dist="lognormal", sigma=1.0, seed=7)
    sim = BufferedAsyncSimulation(task.loss_fn, task.params, fed,
                                  task.batcher, eval_fn=task.eval_fn,
                                  clock=clock)
    return sim, sim.run(t_updates)


def _assert_none_is_golden(t: int) -> None:
    """compressor="none" must bake the literally unchanged round: state
    after t rounds is BIT-identical to a config with no compression
    fields touched (uplink + downlink, sync engine)."""
    states = []
    for kw in ({}, {"compressor": "none", "broadcast_compressor": "none"}):
        task = make_task("lr", noniid=True)
        fed = FedConfig(algorithm="fedagrac", n_clients=task.batcher.m,
                        lr=task.lr, calibration_rate=1.0, weights="data",
                        **kw)
        sim = FederatedSimulation(task.loss_fn, task.params, fed,
                                  task.batcher,
                                  k_schedule=bimodal_schedule())
        sim.run(t)
        states.append(sim.state)
    ref, got = states
    assert sorted(ref) == sorted(got), (sorted(ref), sorted(got))
    for k in ref:
        for a, b in zip(jax.tree.leaves(ref[k]), jax.tree.leaves(got[k])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=k)


def run(quick: bool = False) -> tuple[list[tuple], dict]:
    t_sync = 15 if quick else 50
    t_async = 30 if quick else 100
    algorithms = ("fedagrac",) if quick else ("fedagrac", "fedavg")

    _assert_none_is_golden(5 if quick else 10)
    print("# none-compression bit-identity: OK")

    rows, report_rows = [], []
    base_acc: dict[tuple, float] = {}
    for mode in ("sync", "async"):
        for algorithm in algorithms:
            for comp in COMPRESSORS:
                if mode == "sync":
                    sim, hist = _run_sync(algorithm, comp, t_sync)
                else:
                    sim, hist = _run_async(algorithm, comp, t_async)
                n = sim._spec.n if sim._spec is not None else sim._n_true
                model = bytes_on_the_wire(
                    n, uses_nu=sim.algo.uses_nu, compressor=comp,
                    topk_frac=sim.fed.topk_frac)
                # measured series must match the analytic model per client
                participants = hist.bytes_up[0] / model["uplink_per_client"]
                assert participants == round(participants), (
                    comp, hist.bytes_up[0], model["uplink_per_client"])
                acc = hist.metric[-1]
                if comp == "none":
                    base_acc[(mode, algorithm)] = acc
                r_t = hist.rounds_to_target(TARGET)
                b_t = hist.bytes_to_target(TARGET)
                rows.append((mode, algorithm, comp, round(acc, 4),
                             round(hist.bytes_up[0]),
                             round(model["uplink_reduction"], 2),
                             r_t or f">{len(hist.metric)}",
                             round(b_t) if b_t is not None else "-"))
                report_rows.append({
                    "mode": mode, "algorithm": algorithm,
                    "compressor": comp, "final_acc": float(acc),
                    "bytes_up_per_round": float(hist.bytes_up[0]),
                    "bytes_down_per_round": float(hist.bytes_down[0]),
                    "uplink_reduction_vs_fp32":
                        float(model["uplink_reduction"]),
                    "rounds_to_target": r_t,
                    "bytes_to_target": b_t,
                    "target": TARGET,
                })

    # headline: best uplink reduction among compressors at accuracy parity
    headline = None
    for r in report_rows:
        if r["compressor"] == "none":
            continue
        ref = base_acc[(r["mode"], r["algorithm"])]
        if r["final_acc"] >= ref - PARITY:
            if headline is None or (r["uplink_reduction_vs_fp32"]
                                    > headline["uplink_reduction_vs_fp32"]):
                headline = dict(r, fp32_acc=ref)
    assert headline is not None and \
        headline["uplink_reduction_vs_fp32"] >= 4.0, headline
    print(f"# headline: {headline['compressor']} "
          f"({headline['mode']}/{headline['algorithm']}) — "
          f"{headline['uplink_reduction_vs_fp32']:.1f}× uplink reduction, "
          f"acc {headline['final_acc']:.4f} vs fp32 "
          f"{headline['fp32_acc']:.4f}")

    report = {
        "rows": report_rows,
        "headline": headline,
        "meta": {"quick": quick, "backend": jax.default_backend(),
                 "jax": jax.__version__, "target": TARGET,
                 "parity_tol": PARITY},
    }
    return rows, report


def main(quick: bool = False) -> None:
    rows, report = run(quick)
    emit(rows, ("mode", "algorithm", "compressor", "final_acc",
                "bytes_up_per_round", "uplink_reduction",
                f"rounds_to_{int(TARGET * 100)}",
                f"bytes_to_{int(TARGET * 100)}"))
    out = ROOT / "BENCH_compression.json"
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()
