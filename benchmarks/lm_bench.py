"""§LM rounds: real language-model federated training on the flat buffer.

Claim validated (DESIGN.md §13): the flat-native loss boundary — the model
reading view-table slices of the single lane-padded buffer, gradients
accumulated straight back into one ``(P,)`` cotangent — runs real
transformer rounds (scaled-down gemma-2b: MQA, GeGLU, tied embeddings,
remat) end-to-end on the sync engine with NO per-round pytree
materialisation, at tree-round parity or better, and supports the
mixed-precision production configuration (bf16 params/compute under an
f32 master) that the tree layout cannot express.

Measured INTERLEAVED (tree, flat, bf16, tree, …, best-of-N each) for the
same reason as engine_bench: this container's shared cores swing single
measurements by ±50%.  The deterministic companion numbers are the HLO
layout comparison with the DESIGN.md §13 conversion-bytes line item (the
grad-boundary traffic the flat path adds over the plain tree
``value_and_grad``).

Writes ``BENCH_lm.json`` at the repo root (CI uploads it) and back-fills
``headline.lm_tokens_per_s`` into ``BENCH_engine.json`` when present.
"""
from __future__ import annotations

import functools
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs.base import FedConfig, reduced
from repro.configs.registry import get_arch
from repro.data import DeviceLMBatcher, lm_sequences
from repro.fed import FederatedSimulation
from repro.models import model as M

ROOT = pathlib.Path(__file__).resolve().parent.parent

M_CLIENTS = 4


def _build(quick: bool):
    base = get_arch("gemma-2b")
    if quick:
        cfg = reduced(base, n_layers=2, d_model=64, vocab=256)
        seq, batch = 16, 2
    else:
        cfg = reduced(base, n_layers=4, d_model=128, vocab=512)
        seq, batch = 32, 2
    return cfg, seq, batch


def _make_sim(cfg, seq, batch, layout, k_mean, bf16=False, seed=0):
    import dataclasses
    if bf16:
        cfg = dataclasses.replace(cfg, dtype="bfloat16")
    key = jax.random.PRNGKey(seed)
    streams = [lm_sequences(jax.random.fold_in(key, i), 32, seq, cfg.vocab,
                            skew_topic=i) for i in range(M_CLIENTS)]
    batcher = DeviceLMBatcher(streams, batch_size=batch, seed=seed)
    fed = FedConfig(algorithm="fedagrac", n_clients=M_CLIENTS,
                    k_mean=k_mean, lr=0.1, calibration_rate=0.5,
                    param_layout=layout,
                    master_dtype="float32" if bf16 else "")
    params = M.init_params(key, cfg)
    loss_fn = functools.partial(M.lm_loss, cfg=cfg)
    return FederatedSimulation(lambda p, b: loss_fn(p, b), params, fed,
                               batcher), loss_fn, params, batcher


def _round_rates(cfg, seq, batch, k_mean, chunk, t_rounds,
                 reps) -> dict[str, float]:
    """(variant → rounds/s), variants interleaved against ambient load."""
    sims = {}
    for variant in ("tree", "flat", "flat_bf16"):
        sim, *_ = _make_sim(cfg, seq, batch,
                            "tree" if variant == "tree" else "flat",
                            k_mean, bf16=variant == "flat_bf16")
        sim.run(min(chunk, t_rounds), chunk_rounds=chunk)   # compile
        sims[variant] = sim
    best = {v: 0.0 for v in sims}
    for _ in range(reps):
        for variant, sim in sims.items():
            t0 = time.perf_counter()
            sim.run(t_rounds, chunk_rounds=chunk)
            best[variant] = max(best[variant],
                                t_rounds / (time.perf_counter() - t0))
    return best


def _hlo_comparison(cfg, seq, batch, k_mean) -> dict:
    """Deterministic companion: compile the full LM round in both layouts
    and the bare grad boundary in both layouts — bytes ratio plus the
    conversion line item."""
    from benchmarks.roofline_table import conversion_bytes
    from repro.core import flat as flat_lib, rounds
    from repro.core.fedopt import get_algorithm
    from repro.roofline import analysis

    _, loss_fn, params, batcher = _make_sim(cfg, seq, batch, "tree", k_mean)
    fed = FedConfig(algorithm="fedagrac", n_clients=M_CLIENTS,
                    k_mean=k_mean, lr=0.1, calibration_rate=0.5)
    algo = get_algorithm("fedagrac", fed)
    spec = flat_lib.make_flat_spec(params)
    batches = batcher.round_batches(jnp.int32(0), k_mean)
    ks = jnp.full((M_CLIENTS,), k_mean, jnp.int32)
    ws = jnp.full((M_CLIENTS,), 1.0 / M_CLIENTS, jnp.float32)
    lam = jnp.float32(0.5)
    rl = {}
    for layout in ("tree", "flat"):
        if layout == "flat":
            fn = flat_lib.make_flat_round(spec, loss_fn, algo, lr=0.1,
                                          k_max=k_mean)
            st = flat_lib.flatten_state(
                spec, rounds.init_state(params, M_CLIENTS, algo))
        else:
            fn = rounds.make_round(loss_fn, algo, lr=0.1, k_max=k_mean)
            st = rounds.init_state(params, M_CLIENTS, algo)
        compiled = jax.jit(fn).lower(st, batches, ks, ws, lam).compile()
        rl[layout] = analysis.from_compiled(compiled, chips=1)
    conv = conversion_bytes(spec, loss_fn, params, batches)
    return analysis.layout_comparison(rl["tree"], rl["flat"],
                                      conversion_bytes=conv)


def main(quick: bool = False) -> None:
    cfg, seq, batch = _build(quick)
    k_mean = 2 if quick else 4
    chunk = 4
    t_rounds = 8 if quick else 16
    reps = 3 if quick else 5

    rates = _round_rates(cfg, seq, batch, k_mean, chunk, t_rounds, reps)
    tokens_per_round = M_CLIENTS * k_mean * batch * seq
    cmp = _hlo_comparison(cfg, seq, batch, k_mean)

    rows = []
    for variant, rps in rates.items():
        rows.append(("lm", "sync", variant, chunk, f"{rps:.2f}",
                     f"{rps * tokens_per_round:.0f}",
                     f"{rps / rates['tree']:.2f}"))
    rows.append(("lm", "hlo", "conversion_bytes", "-",
                 f"{cmp['conversion_bytes']:.3e}",
                 f"{cmp['conversion_fraction_of_flat']:+.4f}", "-"))
    emit(rows, ("task", "engine", "variant", "chunk", "rounds_per_s",
                "tokens_per_s", "speedup_vs_tree"))

    report = {
        "model": {
            "family": "gemma-2b (reduced)",
            "n_layers": cfg.n_layers, "d_model": cfg.d_model,
            "vocab": cfg.vocab, "seq": seq, "batch": batch,
            "params": cfg.param_count(),
        },
        "sync": {v: {
            "rounds_per_s": r,
            "tokens_per_s": r * tokens_per_round,
            "speedup_vs_tree": r / rates["tree"],
        } for v, r in rates.items()},
        "layout_hlo": cmp,
        "meta": {
            "quick": quick, "backend": jax.default_backend(),
            "jax": jax.__version__, "m_clients": M_CLIENTS,
            "k_local_steps": k_mean, "t_rounds": t_rounds, "chunk": chunk,
            "algorithm": "fedagrac",
            "unit": "rounds/s, tokens/s = rounds/s × M × K̄ × B × S",
        },
    }
    out = ROOT / "BENCH_lm.json"
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    sp = rates["flat"] / rates["tree"]
    print(f"# wrote {out} — flat/tree LM round ratio {sp:.2f}x, "
          f"flat {rates['flat'] * tokens_per_round:.0f} tok/s, "
          f"bf16+f32-master {rates['flat_bf16'] * tokens_per_round:.0f} "
          f"tok/s; conversion {cmp['conversion_fraction_of_flat']:+.2%} "
          f"of flat round bytes")

    # back-fill the headline into BENCH_engine.json when it exists
    eng = ROOT / "BENCH_engine.json"
    if eng.exists():
        data = json.loads(eng.read_text())
        data.setdefault("headline", {})["lm_tokens_per_s"] = (
            rates["flat"] * tokens_per_round)
        data["headline"]["lm_layout_speedup"] = sp
        eng.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")


if __name__ == "__main__":
    main(quick=True)
