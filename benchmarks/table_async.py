"""Sync-vs-async deterioration table (beyond-paper extension, DESIGN.md §5).

Claim validated: the FedaGrac calibration machinery survives the move from
synchronous rounds to buffered semi-asynchronous execution, and the buffered
engine converts straggler idle time into extra server updates.  On a
lognormal-speed fleet the synchronous round clock is set by the slowest
client; the buffered engine (event-accurate FedBuff semantics: the server
steps on every M'-th REPORT, fast clients report repeatedly) never waits.
Three checks:

1. **Sanity** — buffer = M with identical speeds reproduces the synchronous
   FedaGrac trajectory exactly (the `async_full` row; observed drift is 0).
2. **Deterioration** — staleness + fast-client participation bias cost
   statistical efficiency: buffered rows need several × more *server
   updates* to the target than synchronous FedaGrac, single-report FedAsync
   (buffer = 1) deteriorates furthest, and full-strength calibration (λ = 1)
   against a stale ν misorients clients — the λ = 1 buffered row trails the
   λ = 0.5 row.  Staleness demands gentler calibration: the async analogue
   of the paper's λ-vs-K̄ prescription.
3. **Rehabilitation** — at a MATCHED WALL-CLOCK horizon (the column
   `acc@budget`: accuracy once simulated time reaches the synchronous run's
   total budget) tempered buffered FedaGrac (λ = 0.5, buffer = 0.8 M,
   hinge) ends ABOVE the synchronous final accuracy: the extra updates the
   straggler's idle time buys outweigh the staleness they cost.

Columns: algorithm, mode, buffer, staleness, updates→target, simulated
seconds→target, accuracy at the sync wall-clock budget, mean staleness.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import M_CLIENTS, emit, make_task
from repro.configs.base import FedConfig
from repro.fed import BufferedAsyncSimulation, FederatedSimulation
from repro.fed.clock import make_clock

TARGET = 0.75


def _fed(task, algorithm, lam=1.0, **kw):
    return FedConfig(algorithm=algorithm, n_clients=task.batcher.m,
                     lr=task.lr, calibration_rate=lam, weights="data",
                     **kw)


def _to_target(hist, sim_times):
    r = hist.rounds_to_target(TARGET)
    if r is None:
        return f">{len(hist.metric)}", ""
    return r, f"{sim_times[r - 1]:.1f}"


def main(quick: bool = False) -> None:
    t_rounds = 20 if quick else 40
    m = M_CLIENTS
    ks = np.full((t_rounds * m + 1, m), 40, np.int32)  # fixed K: round-async only
    clock = make_clock(m, dist="lognormal", sigma=1.0, seed=7)
    sync_round_s = clock.round_time(ks[0])            # straggler-bound
    budget = t_rounds * sync_round_s                  # sync total wall-clock

    rows = []

    def run_sync(algorithm):
        task = make_task("lr", noniid=True)
        sim = FederatedSimulation(task.loss_fn, task.params,
                                  _fed(task, algorithm), task.batcher,
                                  eval_fn=task.eval_fn, k_schedule=ks)
        hist = sim.run(t_rounds)
        upd, secs = _to_target(
            hist, [sync_round_s * (t + 1) for t in range(t_rounds)])
        rows.append((algorithm, "sync", m, "-", upd, secs,
                     f"{hist.metric[-1]:.4f}", "0.0"))
        return hist

    def run_async(algorithm, label, buffer, staleness, *, lam=1.0,
                  fixed_speed=False):
        task = make_task("lr", noniid=True)
        fed = _fed(task, algorithm, lam=lam, buffer_size=buffer,
                   staleness=staleness, staleness_a=0.5, staleness_b=2)
        c = (make_clock(m, dist="fixed") if fixed_speed else clock)
        sim = BufferedAsyncSimulation(task.loss_fn, task.params, fed,
                                      task.batcher, eval_fn=task.eval_fn,
                                      k_schedule=ks, clock=c)
        if fixed_speed:
            hist = sim.run(t_rounds)                  # the sanity row
        else:
            # generous update budget, then judged at the wall-clock budget
            hist = sim.run(5 * t_rounds * m // max(buffer, 2))
        upd, secs = _to_target(hist, hist.sim_time)
        within = [a for a, t in zip(hist.metric, hist.sim_time)
                  if t <= budget] or [hist.metric[0]]
        rows.append((f"{algorithm}(λ={lam:g})"
                     if algorithm.startswith("fedagrac") else algorithm,
                     label, buffer, staleness, upd, secs,
                     f"{within[-1]:.4f}",
                     f"{np.mean(hist.staleness):.2f}"))
        return hist

    h_sync = run_sync("fedagrac")
    run_sync("fedavg")
    # 1: full buffer + equal speeds == the synchronous engine
    h_full = run_async("fedagrac", "async_full", m, "constant",
                       fixed_speed=True)
    # 2/3: partial buffers on the heterogeneous fleet
    run_async("fedagrac", "async_buf", 4 * m // 5, "hinge", lam=0.5)
    run_async("fedagrac", "async_buf", 4 * m // 5, "hinge", lam=1.0)
    run_async("fedavg", "async_buf", m // 2, "constant")   # FedBuff
    run_async("fedavg", "async_buf", m // 2, "hinge")      # FedBuff + discount
    run_async("fedagrac", "async_one", 1, "poly", lam=0.5)  # FedAsync + calib.

    emit(rows, ("algorithm", "mode", "buffer", "staleness",
                f"updates_to_{int(TARGET * 100)}",
                f"sim_s_to_{int(TARGET * 100)}", "acc_at_budget",
                "mean_stale"))
    drift = abs(h_sync.metric[-1] - h_full.metric[-1])
    print(f"# sync wall-clock budget: {budget:.0f} s "
          f"({t_rounds} straggler-bound rounds)")
    print(f"# buffer=M vs sync final-acc drift: {drift:.2e} "
          f"({'OK' if drift < 1e-3 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
