"""Engine throughput: host-driven per-round loop vs device-resident chunks.

Claim validated (DESIGN.md §9): moving the round loop onto the device —
R rounds fused into one jitted ``lax.scan`` (core/engine.py), batches drawn
in-scan by ``DeviceBatcher`` — removes the per-round dispatch, host sync,
dataset gather and transfer that made the paper-scale benchmarks
dispatch-bound.  Three sync modes per task (host loop / chunked scan with
host-stacked batches / chunked scan with on-device sampling) and the
analogous per-update vs chunked comparison for the buffered-async engine.

Writes ``BENCH_engine.json`` at the repo root — the start of the repo's
perf trajectory; CI uploads it as an artifact.  Rows are also printed as
CSV like every other benchmark module.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import M_CLIENTS, emit, make_task
from repro.configs.base import FedConfig
from repro.fed import BufferedAsyncSimulation, FederatedSimulation

ROOT = pathlib.Path(__file__).resolve().parent.parent


REPEATS = 3           # best-of-N: the container CPU is noisy


def _sync_rounds_per_s(kind: str, sampler: str, chunk_rounds: int,
                       t_rounds: int, k_mean: int, seed: int = 0) -> float:
    task = make_task(kind, noniid=True, seed=seed, sampler=sampler)
    fed = FedConfig(algorithm="fedagrac", n_clients=task.batcher.m,
                    k_mean=k_mean, lr=task.lr, calibration_rate=0.5,
                    weights="data", seed=seed)
    sim = FederatedSimulation(task.loss_fn, task.params, fed, task.batcher)
    sim.run(min(chunk_rounds, t_rounds),
            chunk_rounds=chunk_rounds)                  # compile + caches
    best = 0.0
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        sim.run(t_rounds, chunk_rounds=chunk_rounds)
        best = max(best, t_rounds / (time.perf_counter() - t0))
    return best


def _async_updates_per_s(kind: str, sampler: str, chunk_updates: int,
                         t_updates: int, k_mean: int,
                         seed: int = 0) -> float:
    task = make_task(kind, noniid=True, seed=seed, sampler=sampler)
    m = task.batcher.m
    fed = FedConfig(algorithm="fedagrac", n_clients=m, k_mean=k_mean,
                    lr=task.lr, calibration_rate=0.5, weights="data",
                    buffer_size=4 * m // 5, staleness="hinge",
                    speed_dist="lognormal", speed_sigma=1.0, seed=seed)
    sim = BufferedAsyncSimulation(task.loss_fn, task.params, fed,
                                  task.batcher)
    sim.run(min(chunk_updates, t_updates), chunk_updates=chunk_updates)
    best = 0.0
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        sim.run(t_updates, chunk_updates=chunk_updates)
        best = max(best, t_updates / (time.perf_counter() - t0))
    return best


def main(quick: bool = False) -> None:
    # K̄ = 4 is the FedConfig default round shape; the host loop is
    # dispatch/transfer-bound there — exactly the regime chunking targets
    t_rounds = 80 if quick else 160
    chunk = 40
    k_mean = 4 if quick else 8
    rows, report = [], {"sync": {}, "async": {}}

    for kind in (("lr",) if quick else ("lr", "mlp")):
        host_loop = _sync_rounds_per_s(kind, "host", 1, t_rounds, k_mean)
        chunked_host = _sync_rounds_per_s(kind, "host", chunk, t_rounds,
                                          k_mean)
        chunked_dev = _sync_rounds_per_s(kind, "device", chunk, t_rounds,
                                         k_mean)
        report["sync"][kind] = {
            "host_loop_rounds_per_s": host_loop,
            "chunked_host_rounds_per_s": chunked_host,
            "chunked_device_rounds_per_s": chunked_dev,
            "speedup_chunked_host": chunked_host / host_loop,
            "speedup_chunked_device": chunked_dev / host_loop,
        }
        rows += [(kind, "sync", "host_loop", 1, f"{host_loop:.1f}", "1.00"),
                 (kind, "sync", "chunked_host", chunk,
                  f"{chunked_host:.1f}", f"{chunked_host / host_loop:.2f}"),
                 (kind, "sync", "chunked_device", chunk,
                  f"{chunked_dev:.1f}", f"{chunked_dev / host_loop:.2f}")]

        per_update = _async_updates_per_s(kind, "host", 1, t_rounds, k_mean)
        chunked_a = _async_updates_per_s(kind, "host", chunk, t_rounds,
                                         k_mean)
        chunked_ad = _async_updates_per_s(kind, "device", chunk, t_rounds,
                                          k_mean)
        report["async"][kind] = {
            "per_update_updates_per_s": per_update,
            "chunked_host_updates_per_s": chunked_a,
            "chunked_device_updates_per_s": chunked_ad,
            "speedup_chunked_host": chunked_a / per_update,
            "speedup_chunked_device": chunked_ad / per_update,
        }
        rows += [(kind, "async", "per_update", 1, f"{per_update:.1f}",
                  "1.00"),
                 (kind, "async", "chunked_host", chunk, f"{chunked_a:.1f}",
                  f"{chunked_a / per_update:.2f}"),
                 (kind, "async", "chunked_device", chunk,
                  f"{chunked_ad:.1f}", f"{chunked_ad / per_update:.2f}")]

    emit(rows, ("task", "engine", "mode", "chunk", "throughput_per_s",
                "speedup"))

    report["meta"] = {
        "quick": quick,
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "m_clients": M_CLIENTS,
        "k_local_steps": k_mean,
        "t_rounds": t_rounds,
        "chunk": chunk,
        "algorithm": "fedagrac",
        "unit": "rounds/s (sync), server updates/s (async)",
    }
    out = ROOT / "BENCH_engine.json"
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    sp = report["sync"]["lr"]["speedup_chunked_device"]
    print(f"# wrote {out} — lr sync chunked-device speedup over host loop: "
          f"{sp:.2f}x ({'OK' if sp >= 3.0 else 'BELOW 3x TARGET'})")


if __name__ == "__main__":
    main(quick=True)
