"""Engine throughput: host-driven per-round loop vs device-resident chunks.

Claim validated (DESIGN.md §9): moving the round loop onto the device —
R rounds fused into one jitted ``lax.scan`` (core/engine.py), batches drawn
in-scan by ``DeviceBatcher`` — removes the per-round dispatch, host sync,
dataset gather and transfer that made the paper-scale benchmarks
dispatch-bound.  Three sync modes per task (host loop / chunked scan with
host-stacked batches / chunked scan with on-device sampling) and the
analogous per-update vs chunked comparison for the buffered-async engine.

Writes ``BENCH_engine.json`` at the repo root — the start of the repo's
perf trajectory; CI uploads it as an artifact.  Rows are also printed as
CSV like every other benchmark module.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import M_CLIENTS, emit, make_task
from repro.configs.base import FedConfig
from repro.fed import BufferedAsyncSimulation, FederatedSimulation

ROOT = pathlib.Path(__file__).resolve().parent.parent


REPEATS = 3           # best-of-N: the container CPU is noisy


def _sync_rounds_per_s(kind: str, sampler: str, chunk_rounds: int,
                       t_rounds: int, k_mean: int, seed: int = 0) -> float:
    task = make_task(kind, noniid=True, seed=seed, sampler=sampler)
    fed = FedConfig(algorithm="fedagrac", n_clients=task.batcher.m,
                    k_mean=k_mean, lr=task.lr, calibration_rate=0.5,
                    weights="data", seed=seed)
    sim = FederatedSimulation(task.loss_fn, task.params, fed, task.batcher)
    sim.run(min(chunk_rounds, t_rounds),
            chunk_rounds=chunk_rounds)                  # compile + caches
    best = 0.0
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        sim.run(t_rounds, chunk_rounds=chunk_rounds)
        best = max(best, t_rounds / (time.perf_counter() - t0))
    return best


def _layout_rounds_per_s(kind: str, chunk_rounds: int, t_rounds: int,
                         k_mean: int, seed: int = 0,
                         reps: int = 6) -> tuple[float, float]:
    """(tree, flat) rounds/s of the chunked device-sampled engine under
    the two parameter layouts (DESIGN.md §11).

    Measured INTERLEAVED (tree, flat, tree, flat, …, best-of-N each):
    this container's shared cores swing single measurements by ±50%, and
    a sequential tree-block/flat-block protocol hands whichever ran in
    the quieter window a spurious 1.5× — interleaving gives both layouts
    the same ambient load."""
    def build(layout):
        task = make_task(kind, noniid=True, seed=seed, sampler="device")
        fed = FedConfig(algorithm="fedagrac", n_clients=task.batcher.m,
                        k_mean=k_mean, lr=task.lr, calibration_rate=0.5,
                        weights="data", seed=seed, param_layout=layout)
        sim = FederatedSimulation(task.loss_fn, task.params, fed,
                                  task.batcher)
        sim.run(min(chunk_rounds, t_rounds), chunk_rounds=chunk_rounds)
        return sim
    sims = {"tree": build("tree"), "flat": build("flat")}
    best = {"tree": 0.0, "flat": 0.0}
    for _ in range(reps):
        for layout, sim in sims.items():
            t0 = time.perf_counter()
            sim.run(t_rounds, chunk_rounds=chunk_rounds)
            best[layout] = max(best[layout],
                               t_rounds / (time.perf_counter() - t0))
    return best["tree"], best["flat"]


def _zero_model_loss(params, batch):
    """Placeholder client objective of ~zero cost with a live gradient in
    every leaf (∇ = leaf): swaps the model compute out of the round while
    keeping every engine stage — k-step scan, K_i masking, aggregation,
    orientation recovery/selection, ν mass updates, server opt — real."""
    import jax as _jax
    return 0.5 * sum(jnp.vdot(lv, lv) for lv in _jax.tree.leaves(params))


def _layout_engine_rates(kind: str, k_mean: int, seed: int = 0,
                         chunk: int = 20, reps: int = 6
                         ) -> tuple[float, float, float]:
    """(tree, flat, grad_fraction) — rounds/s of the ROUND ENGINE alone:
    the same chunked round with the per-client loss/grad computation
    (layout-independent by construction — both layouts differentiate the
    identical per-leaf model) replaced by ``_zero_model_loss``, at ONE
    local step (the comm-bound shape where the per-round state algebra —
    aggregation, orientation, ν updates, server opt — IS the round).  The
    residual is exactly the machinery the flat layout rewrites.
    ``grad_fraction`` (measured at the bench's k_mean) estimates how much
    of the REAL tree round the model compute occupies — the Amdahl cap on
    any end-to-end layout speedup."""
    import jax as _jax
    from repro.core import engine as engine_lib, flat as flat_lib, rounds

    task = make_task(kind, noniid=True, seed=seed, sampler="host")
    m = task.batcher.m
    fed = FedConfig(algorithm="fedagrac", n_clients=m, k_mean=k_mean,
                    lr=task.lr, calibration_rate=0.5, weights="data",
                    seed=seed)
    from repro.core.fedopt import get_algorithm
    algo = get_algorithm("fedagrac", fed)
    spec = flat_lib.make_flat_spec(task.params)
    batches = task.batcher.round_batches(0, k_mean)
    stack = lambda tr: _jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (chunk,) + a.shape), tr)
    ws = jnp.broadcast_to(jnp.asarray(task.batcher.weights), (chunk, m))
    lams = jnp.full((chunk,), 0.5, jnp.float32)

    def build(loss_fn, layout, k_max):
        if layout == "flat":
            fn = flat_lib.make_flat_round(spec, loss_fn, algo, lr=task.lr,
                                          k_max=k_max)
            st = flat_lib.flatten_state(
                spec, rounds.init_state(task.params, m, algo))
        else:
            fn = rounds.make_round(loss_fn, algo, lr=task.lr, k_max=k_max)
            st = rounds.init_state(task.params, m, algo)
        ch = engine_lib.make_round_chunk(fn, chunk, donate=False)
        b = (batches if k_max == k_mean
             else _jax.tree.map(lambda a: a[:, :k_max], batches))
        kk = jnp.broadcast_to(jnp.full((m,), k_max, jnp.int32), (chunk, m))
        args = (st, stack(b), kk, ws, lams)
        _jax.block_until_ready(ch(*args))           # compile
        return ch, args

    builds = {("eng", "tree"): build(_zero_model_loss, "tree", 1),
              ("eng", "flat"): build(_zero_model_loss, "flat", 1),
              ("engk", "tree"): build(_zero_model_loss, "tree", k_mean),
              ("full", "tree"): build(task.loss_fn, "tree", k_mean)}
    best: dict = {k: 0.0 for k in builds}
    for _ in range(reps):
        for key, (ch, args) in builds.items():
            t0 = time.perf_counter()
            _jax.block_until_ready(ch(*args))
            best[key] = max(best[key],
                            chunk / (time.perf_counter() - t0))
    grad_frac = max(0.0, 1.0 - best[("full", "tree")] / best[("engk",
                                                              "tree")])
    return best[("eng", "tree")], best[("eng", "flat")], grad_frac


def _async_updates_per_s(kind: str, sampler: str, chunk_updates: int,
                         t_updates: int, k_mean: int,
                         seed: int = 0) -> float:
    task = make_task(kind, noniid=True, seed=seed, sampler=sampler)
    m = task.batcher.m
    fed = FedConfig(algorithm="fedagrac", n_clients=m, k_mean=k_mean,
                    lr=task.lr, calibration_rate=0.5, weights="data",
                    buffer_size=4 * m // 5, staleness="hinge",
                    speed_dist="lognormal", speed_sigma=1.0, seed=seed)
    sim = BufferedAsyncSimulation(task.loss_fn, task.params, fed,
                                  task.batcher)
    sim.run(min(chunk_updates, t_updates), chunk_updates=chunk_updates)
    best = 0.0
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        sim.run(t_updates, chunk_updates=chunk_updates)
        best = max(best, t_updates / (time.perf_counter() - t0))
    return best


def main(quick: bool = False) -> None:
    # K̄ = 4 is the FedConfig default round shape; the host loop is
    # dispatch/transfer-bound there — exactly the regime chunking targets
    t_rounds = 80 if quick else 160
    chunk = 40
    k_mean = 4 if quick else 8
    rows, report = [], {"sync": {}, "async": {}}

    for kind in (("lr",) if quick else ("lr", "mlp")):
        host_loop = _sync_rounds_per_s(kind, "host", 1, t_rounds, k_mean)
        chunked_host = _sync_rounds_per_s(kind, "host", chunk, t_rounds,
                                          k_mean)
        chunked_dev = _sync_rounds_per_s(kind, "device", chunk, t_rounds,
                                         k_mean)
        report["sync"][kind] = {
            "host_loop_rounds_per_s": host_loop,
            "chunked_host_rounds_per_s": chunked_host,
            "chunked_device_rounds_per_s": chunked_dev,
            "speedup_chunked_host": chunked_host / host_loop,
            "speedup_chunked_device": chunked_dev / host_loop,
        }
        rows += [(kind, "sync", "host_loop", 1, f"{host_loop:.1f}", "1.00"),
                 (kind, "sync", "chunked_host", chunk,
                  f"{chunked_host:.1f}", f"{chunked_host / host_loop:.2f}"),
                 (kind, "sync", "chunked_device", chunk,
                  f"{chunked_dev:.1f}", f"{chunked_dev / host_loop:.2f}")]

        per_update = _async_updates_per_s(kind, "host", 1, t_rounds, k_mean)
        chunked_a = _async_updates_per_s(kind, "host", chunk, t_rounds,
                                         k_mean)
        chunked_ad = _async_updates_per_s(kind, "device", chunk, t_rounds,
                                          k_mean)
        report["async"][kind] = {
            "per_update_updates_per_s": per_update,
            "chunked_host_updates_per_s": chunked_a,
            "chunked_device_updates_per_s": chunked_ad,
            "speedup_chunked_host": chunked_a / per_update,
            "speedup_chunked_device": chunked_ad / per_update,
        }
        rows += [(kind, "async", "per_update", 1, f"{per_update:.1f}",
                  "1.00"),
                 (kind, "async", "chunked_host", chunk, f"{chunked_a:.1f}",
                  f"{chunked_a / per_update:.2f}"),
                 (kind, "async", "chunked_device", chunk,
                  f"{chunked_ad:.1f}", f"{chunked_ad / per_update:.2f}")]

    # layout sweep (DESIGN.md §11): tree vs flat single-buffer rounds on
    # the chunked device engine — BOTH tasks even in quick mode.  The
    # end-to-end number is Amdahl-capped: the per-client grad waves are
    # layout-independent (~75% of the mlp round on CPU, see the
    # grad_fraction entries), so the layout effect concentrates in the
    # remaining state algebra — reported separately as engine_* (the
    # round with the loss/grad computation replaced by a placeholder of
    # fixed cost), where the single-buffer win is the whole measurement.
    report["layout"] = {}
    for kind in ("lr", "mlp"):
        tree_rps, flat_rps = _layout_rounds_per_s(kind, chunk, t_rounds,
                                                  k_mean)
        eng_tree, eng_flat, grad_frac = _layout_engine_rates(kind, k_mean)
        report["layout"][kind] = {
            "tree_rounds_per_s": tree_rps,
            "flat_rounds_per_s": flat_rps,
            "speedup_flat": flat_rps / tree_rps,
            "engine_tree_rounds_per_s": eng_tree,
            "engine_flat_rounds_per_s": eng_flat,
            "engine_speedup_flat": eng_flat / eng_tree,
            "grad_fraction_tree": grad_frac,
        }
        rows += [(kind, "layout", "tree", chunk, f"{tree_rps:.1f}", "1.00"),
                 (kind, "layout", "flat", chunk, f"{flat_rps:.1f}",
                  f"{flat_rps / tree_rps:.2f}"),
                 (kind, "layout_engine", "tree", 1, f"{eng_tree:.1f}",
                  "1.00"),
                 (kind, "layout_engine", "flat", 1, f"{eng_flat:.1f}",
                  f"{eng_flat / eng_tree:.2f}")]

    emit(rows, ("task", "engine", "mode", "chunk", "throughput_per_s",
                "speedup"))

    # headline metrics: the repo's perf trajectory at a glance.  The lm_*
    # entries are back-filled by benchmarks/lm_bench.py when it runs after
    # this module (benchmarks.run keeps that ordering).
    lay_mlp = report["layout"]["mlp"]
    report["headline"] = {
        "sync_rounds_per_s": report["sync"]["lr"][
            "chunked_device_rounds_per_s"],
        "async_updates_per_s": report["async"]["lr"][
            "chunked_device_updates_per_s"],
        "layout_speedup_end_to_end": lay_mlp["speedup_flat"],
        "layout_speedup_engine": lay_mlp["engine_speedup_flat"],
    }

    report["meta"] = {
        "quick": quick,
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "m_clients": M_CLIENTS,
        "k_local_steps": k_mean,
        "t_rounds": t_rounds,
        "chunk": chunk,
        "algorithm": "fedagrac",
        "unit": "rounds/s (sync), server updates/s (async)",
    }
    out = ROOT / "BENCH_engine.json"
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    sp = report["sync"]["lr"]["speedup_chunked_device"]
    print(f"# wrote {out} — lr sync chunked-device speedup over host loop: "
          f"{sp:.2f}x ({'OK' if sp >= 3.0 else 'BELOW 3x TARGET'})")
    lay = report["layout"]["mlp"]
    print(f"# flat-vs-tree layout (mlp sync): end-to-end "
          f"{lay['speedup_flat']:.2f}x (grad waves are "
          f"{lay['grad_fraction_tree']:.0%} of the tree round — Amdahl cap "
          f"{1/max(1e-9, 1-lay['grad_fraction_tree']):.2f}x), round-engine "
          f"{lay['engine_speedup_flat']:.2f}x "
          f"({'OK' if lay['engine_speedup_flat'] >= 1.5 else 'BELOW 1.5x TARGET'}"
          f" vs the 1.5x issue target; see EXPERIMENTS.md §layout)")


if __name__ == "__main__":
    main(quick=True)
