"""Paper Figure 5: accuracy-vs-round curves under different Gaussian means
(fixed relative variance).  Claim validated: FedaGrac reaches the target in
fewer rounds; the convex track exposes objective inconsistency —
FedAvg/FedNova/FedProx plateau below FedaGrac/SCAFFOLD.
"""
from __future__ import annotations

from benchmarks.common import emit, make_task, run_sim

T = 40
ALGOS = ("fedagrac", "fedavg", "fednova", "scaffold", "fedprox")
LAM = {"fedagrac": 0.5}


def run(quick: bool = False) -> list[tuple]:
    t = 15 if quick else T
    rows = []
    means = (40,) if quick else (10, 40)
    for kind in ("lr", "mlp"):
        for mean in means:
            for algo in ALGOS:
                task = make_task(kind, noniid=True)
                lam = 1.0 if kind == "lr" else LAM.get(algo, 1.0)
                hist = run_sim(task, algo, t, k_mean=mean,
                               k_var=float(mean ** 2) / 4, lam=lam)
                pts = hist.metric[:: max(t // 5, 1)] + [hist.metric[-1]]
                rows.append(("fig5", kind, mean, algo,
                             ";".join(f"{p:.3f}" for p in pts)))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "model", "k_mean", "algorithm", "acc_curve"))


if __name__ == "__main__":
    main()
