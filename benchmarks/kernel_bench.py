"""Kernel-level benchmarks.

On this CPU container Pallas runs in interpret mode (not representative),
so we benchmark the XLA-fused jnp oracle vs an intentionally UNFUSED
3-pass variant to quantify the fusion win the Pallas kernel locks in on
TPU, and report the analytic HBM-traffic model (bytes moved per element).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels.calibrated_update import ref as cu_ref
from repro.kernels.flash_attention import ref as fa_ref

N = 4_000_000


def _timeit(fn, *args, reps=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


@jax.jit
def _fused(x, g, c):
    return cu_ref.calibrated_update(x, g, c, 0.01, 0.5)


@jax.jit
def _unfused(x, g, c):
    # forced materialization of each stage via optimization barriers
    s1 = jax.lax.optimization_barrier(0.5 * c)
    s2 = jax.lax.optimization_barrier(g + s1)
    return x - 0.01 * s2


def run(quick: bool = False) -> list[tuple]:
    n = N // 8 if quick else N
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    x, g, c = (jax.random.normal(k, (n,), jnp.float32) for k in ks)
    t_fused = _timeit(_fused, x, g, c)
    t_unfused = _timeit(_unfused, x, g, c)
    rows = [
        ("kernel", "calibrated_update_fused_us", round(t_fused * 1e6, 1)),
        ("kernel", "calibrated_update_unfused_us",
         round(t_unfused * 1e6, 1)),
        ("kernel", "fusion_speedup", round(t_unfused / t_fused, 3)),
        # analytic HBM model (bytes/element): fused 3R+1W vs unfused 7R+3W
        ("kernel", "bytes_per_elem_fused", 16),
        ("kernel", "bytes_per_elem_unfused", 40),
    ]
    B, S, H, D = (1, 256, 4, 64) if quick else (2, 512, 8, 64)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    att = jax.jit(lambda a, b, c2: fa_ref.attention(a, b, c2))
    t_att = _timeit(att, q, k, v, reps=5)
    rows.append(("kernel", "ref_attention_us", round(t_att * 1e6, 1)))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "metric", "value"))


if __name__ == "__main__":
    main()
