"""Kernel-level benchmarks.

On this CPU container Pallas runs in interpret mode (not representative),
so we benchmark the XLA-fused jnp oracle vs an intentionally UNFUSED
3-pass variant to quantify the fusion win the Pallas kernel locks in on
TPU, and report the analytic HBM-traffic model (bytes moved per element).

The flat-path rows benchmark the calibrated-update ops exactly as the
flat training layout invokes them (core/flat.py, DESIGN.md §11): one
fused launch over a lane-padded (rows, 128·k) buffer — plain and prox
variants — reporting effective GB/s of the 3-read/1-write (4R/1W prox)
streaming pattern.  ``BENCH_kernels.json`` at the repo root is the
tracked artifact (CI uploads it).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels.calibrated_update import ref as cu_ref
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.quantize import ops as qops

ROOT = pathlib.Path(__file__).resolve().parent.parent

N = 4_000_000


def _timeit(fn, *args, reps=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


@jax.jit
def _fused(x, g, c):
    return cu_ref.calibrated_update(x, g, c, 0.01, 0.5)


@jax.jit
def _unfused(x, g, c):
    # forced materialization of each stage via optimization barriers
    s1 = jax.lax.optimization_barrier(0.5 * c)
    s2 = jax.lax.optimization_barrier(g + s1)
    return x - 0.01 * s2


@jax.jit
def _flat_2d(x, g, c):
    """The flat training hot path: one fused launch on (rows, 128)."""
    return cu_ref.calibrated_update(x, g, c, 0.01, 0.5)


@jax.jit
def _flat_prox_2d(x, g, c, x0):
    return cu_ref.calibrated_update_prox(x, g, c, x0, 0.01, 0.5, 0.1)


def run(quick: bool = False) -> tuple[list[tuple], dict]:
    n = N // 8 if quick else N
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x, g, c = (jax.random.normal(k, (n,), jnp.float32) for k in ks[:3])
    t_fused = _timeit(_fused, x, g, c)
    t_unfused = _timeit(_unfused, x, g, c)
    report = {
        "calibrated_update": {
            "n_elements": n,
            "fused_us": t_fused * 1e6,
            "unfused_us": t_unfused * 1e6,
            "fusion_speedup": t_unfused / t_fused,
            # analytic HBM model (bytes/element): fused 3R+1W, unfused 7R+3W
            "bytes_per_elem_fused": 16,
            "bytes_per_elem_unfused": 40,
        },
    }
    rows = [
        ("kernel", "calibrated_update_fused_us", round(t_fused * 1e6, 1)),
        ("kernel", "calibrated_update_unfused_us",
         round(t_unfused * 1e6, 1)),
        ("kernel", "fusion_speedup", round(t_unfused / t_fused, 3)),
        ("kernel", "bytes_per_elem_fused", 16),
        ("kernel", "bytes_per_elem_unfused", 40),
    ]

    # flat-path shape: the lane-padded (rows, 128) matrix core/flat.py
    # streams through one launch per local step
    rows2d = n // 128
    n2d = rows2d * 128
    xm, gm, cm, x0m = (v[:n2d].reshape(rows2d, 128) for v in
                       (x, g, c, jax.random.normal(ks[3], (n,),
                                                   jnp.float32)))
    t_flat = _timeit(_flat_2d, xm, gm, cm)
    t_prox = _timeit(_flat_prox_2d, xm, gm, cm, x0m)
    gbps = n2d * 16 / t_flat / 1e9
    gbps_prox = n2d * 20 / t_prox / 1e9
    report["flat_path"] = {
        "rows": rows2d, "lanes": 128,
        "calibrated_update_2d_us": t_flat * 1e6,
        "calibrated_update_2d_gbps": gbps,
        "calibrated_update_prox_2d_us": t_prox * 1e6,
        "calibrated_update_prox_2d_gbps": gbps_prox,
    }
    rows += [
        ("kernel", "flat_calibrated_update_2d_us", round(t_flat * 1e6, 1)),
        ("kernel", "flat_calibrated_update_2d_gbps", round(gbps, 2)),
        ("kernel", "flat_calibrated_update_prox_2d_us",
         round(t_prox * 1e6, 1)),
        ("kernel", "flat_calibrated_update_prox_2d_gbps",
         round(gbps_prox, 2)),
    ]

    # wire-compression kernels (kernels/quantize/, DESIGN.md §14) on the
    # same lane-padded layout the compression stage streams: quantize is
    # 4R+1W bytes/elem (f32 in, int8 codes out), dequantize 1R+4W, the
    # top-k mask 4R+4W; scale selection (row_scales) is timed separately —
    # it is a reduction, not part of the streaming transform
    scale = qops.row_scales(xm, 128, 127)
    q_fn = jax.jit(lambda a, s: qops.quantize_2d(a, s, use_pallas=False))
    dq_fn = jax.jit(lambda a, s: qops.dequantize_2d(a, s,
                                                    use_pallas=False))
    tk_fn = jax.jit(lambda a, th: qops.topk_mask_2d(a, th,
                                                    use_pallas=False))
    sc_fn = jax.jit(lambda a: qops.row_scales(a, 128, 127))
    th = qops.topk_thresholds(xm, 128, 7)         # ~5% of a 128-lane row
    qm = q_fn(xm, scale)
    t_q = _timeit(q_fn, xm, scale)
    t_dq = _timeit(dq_fn, qm, scale)
    t_tk = _timeit(tk_fn, xm, th)
    t_sc = _timeit(sc_fn, xm)
    report["quantize_path"] = {
        "rows": rows2d, "lanes": 128,
        "quantize_int8_2d_us": t_q * 1e6,
        "quantize_int8_2d_gbps": n2d * 5 / t_q / 1e9,
        "dequantize_int8_2d_us": t_dq * 1e6,
        "dequantize_int8_2d_gbps": n2d * 5 / t_dq / 1e9,
        "topk_mask_2d_us": t_tk * 1e6,
        "topk_mask_2d_gbps": n2d * 8 / t_tk / 1e9,
        "row_scales_us": t_sc * 1e6,
    }
    rows += [
        ("kernel", "quantize_int8_2d_gbps",
         round(n2d * 5 / t_q / 1e9, 2)),
        ("kernel", "dequantize_int8_2d_gbps",
         round(n2d * 5 / t_dq / 1e9, 2)),
        ("kernel", "topk_mask_2d_gbps",
         round(n2d * 8 / t_tk / 1e9, 2)),
        ("kernel", "row_scales_us", round(t_sc * 1e6, 1)),
    ]

    B, S, H, D = (1, 256, 4, 64) if quick else (2, 512, 8, 64)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    att = jax.jit(lambda a, b, c2: fa_ref.attention(a, b, c2))
    t_att = _timeit(att, q, k, v, reps=5)
    rows.append(("kernel", "ref_attention_us", round(t_att * 1e6, 1)))
    report["ref_attention_us"] = t_att * 1e6
    report["meta"] = {
        "quick": quick,
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "note": "CPU container: jnp-oracle timings; the Pallas kernels "
                "run interpret-mode here and real on TPU",
    }
    return rows, report


def main(quick: bool = False) -> None:
    rows, report = run(quick)
    emit(rows, ("bench", "metric", "value"))
    out = ROOT / "BENCH_kernels.json"
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()
