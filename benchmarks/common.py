"""Shared task builders for the paper-experiment benchmarks.

The paper's datasets (Fashion-MNIST / CIFAR-10 / a9a) are replaced by the
canonical FedProx ``synthetic(α, β)`` task — per-client softmax models and
feature shift, the standard benchmark where client drift measurably hurts
(no network access in this container — see DESIGN.md §7).  "lr" keeps the
paper's convex track, "mlp" the non-convex track.  Scales are reduced to
single-CPU budgets; each module's docstring states the paper claim it
validates.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.data import (DeviceBatcher, FederatedBatcher, fedprox_synthetic,
                        shard_partition)
from repro.fed import FederatedSimulation
from repro.models.simple import (lr_accuracy, lr_loss, mlp_accuracy,
                                 mlp_init, mlp_loss)

M_CLIENTS = 10
D, N_CLASSES = 60, 10
# calibrated on this task: FedAvg needs ~26-46 rounds to 80% under bimodal
# step asynchronism; calibrated methods need ~5-8 (see EXPERIMENTS.md)
LR_CONVEX = 0.02
LR_NONCONVEX = 0.03


@dataclasses.dataclass
class Task:
    name: str
    loss_fn: object
    params: object
    batcher: FederatedBatcher
    eval_fn: object
    lr: float


def make_task(kind: str, *, noniid: bool, seed: int = 0,
              m: int = M_CLIENTS, batch: int = 20,
              batcher_seed: int | None = None,
              sampler: str = "host") -> Task:
    """kind: "lr" (convex) or "mlp" (non-convex).

    The GLOBAL dataset is always the same synthetic(1,1) mixture;
    ``noniid`` only switches the PARTITION (client-generated shards vs an
    IID shuffle) — the correct Table-1 contrast.  ``sampler`` picks the
    batcher family (DESIGN.md §9): "host" (numpy per-round gather, the
    paper-pinned compat mode) or "device" (DeviceBatcher, drawn inside the
    jitted round chunk)."""
    key = jax.random.PRNGKey(seed)
    data, parts = fedprox_synthetic(key, m, alpha=1.0, beta=1.0,
                                    d=D, n_classes=N_CLASSES)
    if not noniid:
        from repro.data import iid_partition
        parts = iid_partition(len(data), m, seed=seed)
    batcher_cls = {"host": FederatedBatcher,
                   "device": DeviceBatcher}[sampler]
    batcher = batcher_cls(data, parts, batch_size=batch,
                          seed=seed if batcher_seed is None
                          else batcher_seed)
    if kind == "lr":
        params = {"w": jnp.zeros((D, N_CLASSES)), "b": jnp.zeros((N_CLASSES,))}
        return Task("lr", lr_loss, params, batcher,
                    lambda p: float(lr_accuracy(p, {"x": data.x,
                                                    "y": data.y})),
                    LR_CONVEX)
    params = mlp_init(key, D, 64, N_CLASSES)
    return Task("mlp", mlp_loss, params, batcher,
                lambda p: float(mlp_accuracy(p, {"x": data.x,
                                                 "y": data.y})),
                LR_NONCONVEX)


def make_task_dp2(kind: str, seed: int = 0, m: int = M_CLIENTS) -> Task:
    """DP2 variant: same synthetic features, clients re-partitioned by
    label shards (5 of 10 classes per client) — label skew on top of the
    model/feature skew."""
    key = jax.random.PRNGKey(seed)
    data, _ = fedprox_synthetic(key, m, alpha=1.0, beta=1.0, d=D,
                                n_classes=N_CLASSES)
    parts = shard_partition(np.asarray(data.y), m, classes_per_client=5,
                            seed=seed)
    batcher = FederatedBatcher(data, parts, batch_size=20, seed=seed)
    if kind == "lr":
        params = {"w": jnp.zeros((D, N_CLASSES)), "b": jnp.zeros((N_CLASSES,))}
        return Task("lr", lr_loss, params, batcher,
                    lambda p: float(lr_accuracy(p, {"x": data.x,
                                                    "y": data.y})),
                    LR_CONVEX)
    params = mlp_init(key, D, 64, N_CLASSES)
    return Task("mlp", mlp_loss, params, batcher,
                lambda p: float(mlp_accuracy(p, {"x": data.x,
                                                 "y": data.y})),
                LR_NONCONVEX)


def bimodal_schedule(m: int = M_CLIENTS, k_slow: int = 2,
                     k_fast: int = 200) -> np.ndarray:
    """The paper's Raspberry-Pi + GPU regime: m−1 slow clients, one fast."""
    ks = np.full((1, m), k_slow, np.int32)
    ks[0, -1] = k_fast
    return ks


def run_sim(task: Task, algorithm: str, t_rounds: int, *,
            k_mean: int = 40, k_var: float = 0.0, k_mode: str = "fixed",
            lam: float = 1.0, lr: float | None = None, seed: int = 0,
            k_schedule=None, lam_schedule=None, eval_every: int = 1,
            chunk_rounds=None):
    fed = FedConfig(algorithm=algorithm, n_clients=task.batcher.m,
                    k_mean=k_mean, k_var=k_var, k_mode=k_mode,
                    lr=lr if lr is not None else task.lr,
                    calibration_rate=lam, weights="data", seed=seed)
    sim = FederatedSimulation(task.loss_fn, task.params, fed, task.batcher,
                              eval_fn=task.eval_fn, k_schedule=k_schedule,
                              lam_schedule=lam_schedule)
    return sim.run(t_rounds, eval_every=eval_every,
                   chunk_rounds=chunk_rounds)


def rounds_to(hist, target: float):
    r = hist.rounds_to_target(target)
    return r if r is not None else f">{len(hist.metric)}"


def emit(rows: list[tuple], header: tuple) -> None:
    print(",".join(header))
    for row in rows:
        print(",".join(str(x) for x in row))
