"""Paper Table 2: fast-node utilization.

One powerful client (K_fast = scale × K_slow) + 9 slow clients, non-IID.
Claim validated: FedAvg/FedNova cannot convert the fast node's extra local
work into speed (rounds-to-target stays flat or worsens); FedaGrac
accelerates with it — i.e. full utilization of the powerful device.
"""
from __future__ import annotations

from benchmarks.common import bimodal_schedule, emit, make_task, rounds_to, \
    run_sim

T = 50
TARGET = 0.77
K_SLOW = 2


def run(quick: bool = False) -> list[tuple]:
    t = 25 if quick else T
    rows = []
    scales = (1, 100) if quick else (1, 10, 50, 100)
    for scale in scales:
        ks = bimodal_schedule(k_slow=K_SLOW, k_fast=K_SLOW * scale)
        for algo in ("fednova", "fedagrac", "fedavg"):
            task = make_task("lr", noniid=True)
            hist = run_sim(task, algo, t, k_schedule=ks, lam=1.0)
            rows.append(("table2", algo, f"fast_x{scale}",
                         rounds_to(hist, TARGET),
                         round(hist.metric[-1], 4)))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "algorithm", "fast_node_scale",
                      "rounds_to_target", "final_acc"))


if __name__ == "__main__":
    main()
