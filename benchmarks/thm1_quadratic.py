"""Theorem 1 / Theorem 3 closed-form validation on quadratics.

Reports, per heterogeneity level: the distance of the *simulated* FedAvg
round map's limit from (a) the closed-form fixed point (should be ≈0) and
(b) the global optimum (the objective-inconsistency gap), the Theorem-1
RHS bound, and FedaGrac's terminal distance (should be ≈0, Theorem 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import FedConfig
from repro.core import rounds, theory
from repro.core.fedopt import get_algorithm
from repro.data.synthetic import quadratic_clients
from repro.models.simple import quad_loss

M, D, LR = 8, 12, 0.02
K = np.array([1, 1, 2, 2, 4, 4, 8, 20], np.int32)
W = np.full(M, 1.0 / M, np.float32)


def _simulate(algo_name, lam, As, bs, t=400):
    fed = FedConfig(algorithm=algo_name, n_clients=M, lr=LR,
                    calibration_rate=lam)
    algo = get_algorithm(algo_name, fed)
    k_max = int(K.max())
    state = rounds.init_state({"x": jnp.zeros((D,))}, M, algo)
    fn = jax.jit(rounds.make_round(quad_loss, algo, lr=LR, k_max=k_max))
    batches = {
        "A": jnp.broadcast_to(jnp.asarray(As)[:, None], (M, k_max, D, D)),
        "b": jnp.broadcast_to(jnp.asarray(bs)[:, None], (M, k_max, D)),
        "c0": jnp.zeros((M, k_max)),
    }
    for _ in range(t):
        state, _ = fn(state, batches, jnp.asarray(K), jnp.asarray(W))
    return np.asarray(state["params"]["x"])


def run(quick: bool = False) -> list[tuple]:
    t = 150 if quick else 400
    rows = []
    for hetero in (0.5, 1.5, 3.0):
        As, bs = quadratic_clients(jax.random.PRNGKey(0), M, D,
                                   hetero=hetero)
        x_star = theory.global_optimum(As, bs, W)
        fp = theory.fedavg_fixed_point(As, bs, W, K, LR)
        x_avg = _simulate("fedavg", 0.0, As, bs, t)
        x_grac = _simulate("fedagrac", 1.0, As, bs, t)
        rhs = theory.objective_inconsistency_rhs(As, bs, W, K, x_star)
        rows.append(("thm1", hetero,
                     round(float(np.linalg.norm(x_avg - fp)), 6),
                     round(float(np.linalg.norm(x_avg - x_star)), 4),
                     round(float(theory.suboptimality(As, bs, W, x_avg,
                                                      x_star)), 4),
                     round(rhs, 4),
                     round(float(np.linalg.norm(x_grac - x_star)), 6)))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "hetero", "fedavg_to_fixed_point",
                      "fedavg_to_opt", "fedavg_subopt", "thm1_rhs",
                      "fedagrac_to_opt"))


if __name__ == "__main__":
    main()
