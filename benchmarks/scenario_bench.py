"""Survival table: algorithms under failure scenarios (DESIGN.md §12).

Claim validated: the failure-scenario engine (fed/scenarios.py) turns
device-model faults — mid-round dropout with partial-work recovery,
adversarial straggler spikes, flaky-network latency bursts, correlated
diurnal availability — into reproducible benchmark conditions, and the
partial-work recovery rule (client contributes its k′-step prefix at
delivered-fraction weight k′/K) keeps every algorithm convergent where a
discard-on-failure server would lose the work entirely.  The table crosses
algorithm × staleness-discount × scenario on the buffered-async engine
(lognormal fleet, buffer = M/2) and reports final accuracy, server updates
to the target, simulated seconds to the target, and the realized
abort/dropped fraction.  Two survival checks:

1. **Graceful degradation** — under every fault model each algorithm still
   reaches the target; dropout and spikes cost updates (lost step mass),
   flaky networks cost only simulated seconds (arrivals shift, work is
   intact — the sync engine is bit-identical to baseline under flaky).
2. **Calibration survives faults** — FedaGrac's final accuracy under each
   scenario stays within a small margin of its own baseline row and it
   reaches the target in fewer server updates than FedAvg under the same
   scenario: the ν̄ orientation is computed from the delivered k′-step
   prefixes, so partial work calibrates instead of corrupting.

Writes ``BENCH_scenarios.json`` at the repo root; CI uploads it as an
artifact alongside the engine and population reports.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from benchmarks.common import M_CLIENTS, emit, make_task
from repro.configs.base import FedConfig
from repro.fed import BufferedAsyncSimulation, make_clock

ROOT = pathlib.Path(__file__).resolve().parent.parent

TARGET = 0.70
K_MEAN = 40

# scenario name -> FedConfig knobs (all resolved by make_scenario)
SCENARIO_KNOBS = {
    "baseline": {},
    "dropout": {"dropout_rate": 0.3, "rejoin_delay": 2.0},
    "spike": {"scenario_rate": 0.2, "scenario_magnitude": 8.0},
    "flaky": {"scenario_rate": 0.3, "scenario_magnitude": 5.0},
    "diurnal": {"scenario_period": 16.0,
                "cohort_size": 8, "cohort_sampler": "availability"},
}


def _one(algorithm: str, staleness: str, scenario: str, t_updates: int,
         lam: float = 0.5) -> dict:
    m = M_CLIENTS
    task = make_task("lr", noniid=True)
    knobs = dict(SCENARIO_KNOBS[scenario])
    buffer = min(m // 2, knobs.get("cohort_size", m))
    fed = FedConfig(algorithm=algorithm, n_clients=m, lr=task.lr,
                    calibration_rate=lam, weights="data",
                    buffer_size=buffer, staleness=staleness,
                    staleness_a=0.5, staleness_b=2,
                    scenario=scenario, **knobs)
    ks = np.full((t_updates * m + 1, m), K_MEAN, np.int32)
    clock = make_clock(m, dist="lognormal", sigma=1.0, seed=7)
    sim = BufferedAsyncSimulation(task.loss_fn, task.params, fed,
                                  task.batcher, eval_fn=task.eval_fn,
                                  k_schedule=ks, clock=clock)
    hist = sim.run(t_updates)
    r = hist.rounds_to_target(TARGET)
    return {
        "algorithm": algorithm,
        "staleness": staleness,
        "scenario": scenario,
        "final_acc": float(hist.metric[-1]),
        "updates_to_target": r,
        "sim_s_to_target": (float(hist.sim_time[r - 1])
                            if r is not None else None),
        "sim_s_total": float(hist.sim_time[-1]),
        "dropped_frac": (float(np.mean(hist.dropped))
                         if hist.dropped else 0.0),
        "mean_mass": float(np.mean(hist.mass)),
    }


def main(quick: bool = False) -> None:
    algorithms = (("fedavg", "fedagrac") if quick
                  else ("fedavg", "fednova", "fedagrac"))
    staleness_modes = ("poly",) if quick else ("constant", "poly")
    t_updates = 80 if quick else 120

    rows, table = [], []
    for algorithm in algorithms:
        for staleness in staleness_modes:
            for scenario in SCENARIO_KNOBS:
                r = _one(algorithm, staleness, scenario, t_updates)
                table.append(r)
                rt = r["updates_to_target"]
                rows.append((
                    algorithm, staleness, scenario,
                    f"{r['final_acc']:.4f}",
                    rt if rt is not None else f">{t_updates}",
                    (f"{r['sim_s_to_target']:.1f}"
                     if r["sim_s_to_target"] is not None else "-"),
                    f"{r['dropped_frac']:.3f}",
                ))
    emit(rows, ("algorithm", "staleness", "scenario", "final_acc",
                f"updates_to_{int(TARGET * 100)}",
                f"sim_s_to_{int(TARGET * 100)}", "dropped_frac"))

    def acc(algorithm, scenario, staleness=staleness_modes[-1]):
        return next(r["final_acc"] for r in table
                    if r["algorithm"] == algorithm
                    and r["scenario"] == scenario
                    and r["staleness"] == staleness)

    survival = {
        # every (algorithm, scenario) cell reached the target
        "all_reach_target": all(r["updates_to_target"] is not None
                                for r in table),
        # calibration under faults: fedagrac ≥ fedavg per fault scenario
        "fedagrac_beats_fedavg": {
            s: acc("fedagrac", s) >= acc("fedavg", s)
            for s in SCENARIO_KNOBS if s != "baseline"},
        # worst per-algorithm accuracy drop vs own baseline row
        "max_acc_drop_vs_baseline": {
            a: max(acc(a, "baseline", st) - acc(a, s, st)
                   for s in SCENARIO_KNOBS for st in staleness_modes)
            for a in algorithms},
    }
    report = {
        "table": table,
        "survival": survival,
        "meta": {
            "quick": quick,
            "target": TARGET,
            "t_updates": t_updates,
            "k_local_steps": K_MEAN,
            "clock": "lognormal(sigma=1.0, seed=7)",
            "scenario_knobs": SCENARIO_KNOBS,
            "claim": "partial-work recovery keeps every algorithm "
                     "convergent under mid-round dropout, straggler "
                     "spikes, flaky networks, and diurnal availability; "
                     "FedaGrac's calibration survives every fault model",
        },
    }
    out = ROOT / "BENCH_scenarios.json"
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    ok = survival["all_reach_target"]
    beats = sum(survival["fedagrac_beats_fedavg"].values())
    print(f"# wrote {out} — all cells reach {TARGET:.2f}: "
          f"{'OK' if ok else 'NO'}; fedagrac >= fedavg on "
          f"{beats}/{len(survival['fedagrac_beats_fedavg'])} fault "
          f"scenarios")


if __name__ == "__main__":
    main(quick=True)
