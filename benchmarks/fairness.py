"""Beyond-paper: per-client fairness under step asynchronism.

FL fairness reporting (q-FFL convention): worst-client accuracy and the
across-client std of the final model.  Question examined: does FedaGrac's
calibration — which prevents the fast client from dragging the model
toward its local optimum — also improve the WORST client?
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import D, N_CLASSES, bimodal_schedule, emit, make_task
from repro.configs.base import FedConfig
from repro.fed.simulation import FederatedSimulation
from repro.models.simple import lr_accuracy, lr_loss

T = 40


def run(quick: bool = False) -> list[tuple]:
    t = 15 if quick else T
    rows = []
    ks = bimodal_schedule()
    for algo in ("fedavg", "fednova", "fedagrac"):
        task = make_task("lr", noniid=True)
        parts = task.batcher.parts
        data = task.batcher.data

        def per_client(p):
            return [float(lr_accuracy(p, {"x": data.x[idx],
                                          "y": data.y[idx]}))
                    for idx in parts]

        fed = FedConfig(algorithm=algo, n_clients=task.batcher.m,
                        lr=task.lr, calibration_rate=1.0, weights="data")
        sim = FederatedSimulation(task.loss_fn, task.params, fed,
                                  task.batcher, eval_fn=task.eval_fn,
                                  eval_per_client=per_client,
                                  k_schedule=ks)
        hist = sim.run(t, eval_every=t)          # evaluate final model only
        f = hist.fairness()
        rows.append(("fairness", algo, round(hist.metric[-1], 4),
                     round(f["worst"], 4), round(f["best"], 4),
                     round(f["std"], 4)))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "algorithm", "global_acc", "worst_client",
                      "best_client", "client_std"))


if __name__ == "__main__":
    main()
