"""Paper Figure 4: η × λ grid.

Claim validated: under convex objectives λ=1 is robust across learning
rates and pairs best with a SMALL η (the strongly-convex theory sets λ=1);
over-calibration shows as the large-η/large-λ corner collapsing.
"""
from __future__ import annotations

from benchmarks.common import emit, make_task, run_sim

ETAS = (0.005, 0.02, 0.05)
LAMBDAS = (0.05, 0.5, 1.0)
T = 40


def run(quick: bool = False) -> list[tuple]:
    t = 15 if quick else T
    etas = (0.02,) if quick else ETAS
    rows = []
    for kind in ("lr", "mlp"):
        for eta in etas:
            for lam in LAMBDAS:
                task = make_task(kind, noniid=True)
                hist = run_sim(task, "fedagrac", t, k_mean=40, k_var=400.0,
                               lam=lam, lr=eta)
                rows.append(("fig4", kind, eta, lam,
                             round(hist.metric[-1], 4)))
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick), ("bench", "model", "eta", "lambda", "final_acc"))


if __name__ == "__main__":
    main()
