"""Logical-axis sharding constraints (the model-side half of the mesh story).

``launch/mesh.py`` decides *which physical mesh axes* implement each logical
axis per step kind (``mesh_rules``); this module holds that decision in
process-global state so model code can annotate intermediates with logical
names only:

    constrain(h, "dp", None, "mp")     # (batch, seq, hidden)

Logical names: ``dp`` (batch/data parallel), ``mp`` (tensor/model parallel),
``sp`` (sequence parallel — long-decode KV caches).  Outside any mesh (unit
tests, CPU simulation) every call is a no-op, so the model zoo runs unchanged
on a single device.

Two deliberate behaviours (relied on by the model code):

* an axis whose physical size does not evenly divide the dimension is
  *dropped* (stays replicated) — e.g. KV heads on meshes wider than Hkv
  (attention.py), vocab on odd vocab sizes;
* rules may map a logical name to ``()`` (train mode maps ``dp`` to nothing
  because vmap already consumed the client axis) — also replicated.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# process-global current mesh + logical→physical rules; set by the launch
# layer (build_train_round / build_prefill / build_decode) before tracing.
_MESH = None
_RULES: dict[str, tuple[str, ...]] = {}


def set_mesh_rules(mesh, rules: dict[str, Sequence[str]]) -> None:
    """Install ``mesh`` and logical→physical ``rules`` for subsequent
    ``constrain`` calls (idempotent; last call wins)."""
    global _MESH, _RULES
    _MESH = mesh
    _RULES = {k: tuple(v) for k, v in rules.items()}


def unset_mesh() -> None:
    """Clear the mesh: every later ``constrain`` is a no-op (single-device)."""
    global _MESH, _RULES
    _MESH = None
    _RULES = {}


def current_mesh():
    return _MESH


def use_mesh(mesh):
    """Context manager making ``mesh`` the ambient jax mesh.

    ``jax.set_mesh`` first shipped after the toolchain baked into this
    container (0.4.37); there the ``Mesh`` object itself is the context
    manager with the same scoping semantics."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(name: str) -> int:
    """Total device count implementing logical axis ``name`` (1 if unmapped
    or no mesh is installed)."""
    if _MESH is None:
        return 1
    out = 1
    for ax in _RULES.get(name, ()):
        out *= _MESH.shape[ax]
    return out


def _physical(name: Optional[str], dim: int):
    """Physical axes for one tensor dimension, or None to replicate."""
    if name is None or _MESH is None:
        return None
    axes = _RULES.get(name, ())
    size = 1
    for ax in axes:
        size *= _MESH.shape[ax]
    if not axes or size <= 1:
        return None
    if dim % size != 0:              # non-dividing axis: keep replicated
        return None
    return axes if len(axes) > 1 else axes[0]


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names, one per dim.

    No-op when no mesh is installed.  Under ``vmap(spmd_axis_name=...)``
    (the round engine's client axis) ``x`` is the per-client view and
    ``names`` describe its per-client dims only.
    """
    if _MESH is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(
            f"constrain: {len(names)} axis names for rank-{x.ndim} value")
    spec = P(*(_physical(n, d) for n, d in zip(names, x.shape)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
