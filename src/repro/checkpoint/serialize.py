"""Msgpack pytree checkpointing with sharding-aware restore.

Format: msgpack map {"tree": <structure>, "leaves": [ {dtype, shape, data} ]}
where <structure> is the treedef serialized via jax.tree_util string repr —
we instead store key paths explicitly so restore does not depend on Python
class identity (works across refactors).
"""
from __future__ import annotations

import os
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(path: str, tree: PyTree) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = {}
    for kp, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        payload[_path_str(kp)] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load(path: str, like: PyTree,
         sharding_fn: Optional[Callable[[str, np.ndarray], Any]] = None
         ) -> PyTree:
    """Restore into the structure of ``like``.

    ``sharding_fn(path_str, array) -> Sharding | None`` lets the launcher
    device_put each leaf directly to its target sharding (no host-side
    full-model copy on multi-device restores)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, proto in flat:
        key = _path_str(kp)
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = payload[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        arr = arr.reshape(rec["shape"])
        proto_arr = jnp.asarray(proto)
        if tuple(arr.shape) != tuple(proto_arr.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {proto_arr.shape}")
        sh = sharding_fn(key, arr) if sharding_fn else None
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        treedef, [lv for lv in leaves])


def load_raw(path: str) -> dict[str, np.ndarray]:
    """Restore WITHOUT a ``like`` structure: the path-keyed flat dict of
    arrays exactly as saved.  For consumers that define the schema
    themselves (serving snapshots: serving/personalized.py) rather than
    restoring into a live pytree."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    out = {}
    for key, rec in payload.items():
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        out[key] = arr.reshape(rec["shape"])
    return out


def save_every(path_fmt: str, every: int):
    """Returns callback(round, tree) that saves every ``every`` rounds."""
    def cb(t: int, tree: PyTree) -> None:
        if every > 0 and t % every == 0:
            save(path_fmt.format(round=t), tree)
    return cb
