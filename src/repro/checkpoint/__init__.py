from repro.checkpoint.serialize import load, save, save_every

__all__ = ["load", "save", "save_every"]
