from repro.checkpoint.serialize import load, load_raw, save, save_every

__all__ = ["load", "load_raw", "save", "save_every"]
