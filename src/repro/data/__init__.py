from repro.data.partition import (dirichlet_partition, gaussian_k_schedule,
                                  iid_partition, shard_partition)
from repro.data.pipeline import (DeviceBatcher, DeviceLMBatcher,
                                 FederatedBatcher, LMFederatedBatcher,
                                 eval_metric)
from repro.data.synthetic import (Dataset, fedprox_synthetic,
                                  gaussian_classification,
                                  image_classification, lm_sequences,
                                  quadratic_clients, token_stream)

__all__ = [
    "Dataset", "DeviceBatcher", "DeviceLMBatcher", "FederatedBatcher",
    "LMFederatedBatcher",
    "dirichlet_partition", "fedprox_synthetic",
    "eval_metric", "gaussian_classification", "gaussian_k_schedule",
    "iid_partition", "image_classification", "lm_sequences",
    "quadratic_clients", "shard_partition", "token_stream",
]
