"""Non-IID client partitioning — the paper's DP1 (Dirichlet) and DP2
(label sharding) schemes, plus the Gaussian K_i schedule (§6.1)."""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, m: int, alpha: float = 0.3,
                        seed: int = 0) -> list[np.ndarray]:
    """DP1: split indices across ``m`` clients via per-class Dirichlet(α)
    proportions.  Smaller α ⇒ more heterogeneous."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    idx_by_client: list[list[int]] = [[] for _ in range(m)]
    for c in np.unique(labels):
        idx_c = np.flatnonzero(labels == c)
        rng.shuffle(idx_c)
        props = rng.dirichlet(np.full(m, alpha))
        cuts = (np.cumsum(props)[:-1] * len(idx_c)).astype(int)
        for i, part in enumerate(np.split(idx_c, cuts)):
            idx_by_client[i].extend(part.tolist())
    out = []
    for parts in idx_by_client:
        arr = np.array(sorted(parts), dtype=np.int64)
        if arr.size == 0:                       # degenerate draw: give 1 sample
            arr = np.array([int(rng.integers(len(labels)))], dtype=np.int64)
        out.append(arr)
    return out


def shard_partition(labels: np.ndarray, m: int, classes_per_client: int = 5,
                    seed: int = 0) -> list[np.ndarray]:
    """DP2: label-sorted sharding (McMahan-style).  Indices are sorted by
    label and split into ``m × classes_per_client`` contiguous shards; each
    client receives ``classes_per_client`` random shards — equal data volume,
    ≈``classes_per_client`` labels each (a shard spans extra classes only
    when shards are larger than classes)."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    n_shards = m * classes_per_client
    order = np.lexsort((rng.permutation(len(labels)), labels))
    shards = np.array_split(order, n_shards)
    perm = rng.permutation(n_shards)
    return [np.sort(np.concatenate(
        [shards[perm[i * classes_per_client + j]]
         for j in range(classes_per_client)])).astype(np.int64)
        for i in range(m)]


def iid_partition(n: int, m: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(p).astype(np.int64) for p in np.array_split(perm, m)]


def gaussian_k_schedule(m: int, mean: int, var: float, t_rounds: int,
                        mode: str = "fixed", k_min: int = 1,
                        seed: int = 0) -> np.ndarray:
    """K_i schedule (paper §6.1): Gaussian(mean, var), clipped at ``k_min``.

    Returns (t_rounds, m) int32.  ``fixed``: one draw reused every round;
    ``random``: re-drawn per round."""
    rng = np.random.default_rng(seed)
    if mode == "fixed":
        k = np.maximum(rng.normal(mean, np.sqrt(var), m).round(), k_min)
        ks = np.tile(k[None, :], (t_rounds, 1))
    elif mode == "random":
        ks = np.maximum(rng.normal(mean, np.sqrt(var), (t_rounds, m)).round(),
                        k_min)
    else:
        raise ValueError(mode)
    return ks.astype(np.int32)
