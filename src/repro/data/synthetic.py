"""Synthetic datasets preserving the paper's experimental structure.

No network access in this container, so Fashion-MNIST / CIFAR-10 / a9a are
replaced by synthetic tasks with the same convex/non-convex split:

* ``gaussian_classification`` — linearly-separable-ish Gaussian class blobs
  (stands in for a9a / Fashion-MNIST under LR and MLP objectives);
* ``image_classification`` — class-templated 28×28×1 "images" with noise
  (stands in for Fashion-MNIST under the 2-layer CNN);
* ``quadratic_clients`` — per-client strongly-convex quadratics with closed
  -form local/global optima (Theorem 1/3 validation);
* ``token_stream`` — Zipf-sampled LM token streams with per-client unigram
  skew (the non-IID LM task used by the framework-scale FedaGrac runs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Dataset:
    """In-memory supervised dataset (features x, int labels y)."""
    x: jnp.ndarray
    y: jnp.ndarray

    def __len__(self) -> int:
        return self.x.shape[0]


def gaussian_classification(key, n: int, d: int = 32, n_classes: int = 10,
                            sep: float = 2.0, noise: float = 1.0) -> Dataset:
    """Gaussian blobs: class c centred at sep·μ_c, unit covariance."""
    k_mu, k_y, k_x = jax.random.split(key, 3)
    mus = jax.random.normal(k_mu, (n_classes, d)) * sep
    y = jax.random.randint(k_y, (n,), 0, n_classes)
    x = mus[y] + jax.random.normal(k_x, (n, d)) * noise
    return Dataset(x=x, y=y)


def image_classification(key, n: int, n_classes: int = 10, side: int = 28,
                         noise: float = 0.35) -> Dataset:
    """Class-templated grey-scale images (B, side, side, 1)."""
    k_t, k_y, k_x = jax.random.split(key, 3)
    templates = jax.random.normal(k_t, (n_classes, side, side, 1))
    templates = jax.nn.sigmoid(2.0 * templates)                 # [0,1]-ish
    y = jax.random.randint(k_y, (n,), 0, n_classes)
    x = templates[y] + jax.random.normal(k_x, (n, side, side, 1)) * noise
    return Dataset(x=x, y=y)


def quadratic_clients(key, m: int, d: int = 16, hetero: float = 1.0,
                      cond: float = 4.0):
    """Per-client F_i(x) = ½‖A_i x − b_i‖².

    ``hetero`` scales the spread of the per-client optima x*_i (0 ⇒ IID:
    identical b_i); ``cond`` the condition-number spread of A_i.  Returns
    (As (m,d,d), bs (m,d)) as numpy for the closed-form theory module.
    """
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    As, bs = [], []
    b_common = rng.normal(size=d)
    for _ in range(m):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        eig = np.exp(rng.uniform(0.0, np.log(cond), size=d))
        A = q * np.sqrt(eig)                                  # A s.t. AᵀA = QΛQᵀ
        b = b_common + hetero * rng.normal(size=d)
        As.append(A.astype(np.float32))
        bs.append(b.astype(np.float32))
    return np.stack(As), np.stack(bs)


def token_stream(key, n_tokens: int, vocab: int, skew_topic=None,
                 zipf_a: float = 1.2) -> jnp.ndarray:
    """Zipf token stream; ``skew_topic`` (int) biases a vocab band so clients
    with different topics are non-IID at the unigram level."""
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    probs = ranks ** (-zipf_a)
    if skew_topic is not None:
        band = vocab // 8
        start = (skew_topic * band) % max(vocab - band, 1)
        boost = jnp.zeros((vocab,)).at[start:start + band].set(1.0)
        probs = probs * (1.0 + 7.0 * boost)
    probs = probs / jnp.sum(probs)
    return jax.random.choice(key, vocab, (n_tokens,), p=probs)


def lm_sequences(key, n_seq: int, seq_len: int, vocab: int,
                 skew_topic=None) -> dict:
    """(tokens, labels) next-token pairs of shape (n_seq, seq_len)."""
    stream = token_stream(key, n_seq * (seq_len + 1), vocab, skew_topic)
    chunks = stream.reshape(n_seq, seq_len + 1)
    return {"tokens": chunks[:, :-1], "labels": chunks[:, 1:]}


def fedprox_synthetic(key, m: int, alpha: float = 1.0, beta: float = 1.0,
                      d: int = 60, n_classes: int = 10,
                      n_per_client: int = 400, iid: bool = False):
    """Synthetic(α, β) from Li et al. (FedProx) — the canonical non-IID FL
    task.  Client i draws a local softmax model W_i ~ N(u_i, 1),
    u_i ~ N(0, α), and features x ~ N(v_i, Λ), v_i ~ N(B_i, 1),
    B_i ~ N(0, β), Λ_jj = j^{-1.2}.  α controls model conflict (no single
    global model fits all clients), β feature skew.

    Returns (Dataset over the union, list of per-client index arrays).
    """
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    lam = np.diag(np.arange(1, d + 1, dtype=np.float64) ** -1.2)
    xs, ys, parts = [], [], []
    offset = 0
    W_shared = rng.normal(0, 1.0, size=(d, n_classes))
    b_shared = rng.normal(0, 1.0, size=(n_classes,))
    for i in range(m):
        if iid:
            W, b, v = W_shared, b_shared, np.zeros(d)
        else:
            u = rng.normal(0, np.sqrt(alpha))
            W = rng.normal(u, 1.0, size=(d, n_classes))
            b = rng.normal(u, 1.0, size=(n_classes,))
            Bi = rng.normal(0, np.sqrt(beta))
            v = rng.normal(Bi, 1.0, size=(d,))
        x = rng.multivariate_normal(v, lam, size=n_per_client)
        logits = x @ W + b
        p = np.exp(logits - logits.max(axis=1, keepdims=True))
        p /= p.sum(axis=1, keepdims=True)
        y = np.array([rng.choice(n_classes, p=pi) for pi in p])
        xs.append(x.astype(np.float32))
        ys.append(y.astype(np.int32))
        parts.append(np.arange(offset, offset + n_per_client))
        offset += n_per_client
    data = Dataset(x=jnp.asarray(np.concatenate(xs)),
                   y=jnp.asarray(np.concatenate(ys)))
    return data, parts
