"""Federated round batching: assemble the (M, k_max, batch…) microbatch
tensors that the round engine (core/rounds.py) scans over.

Each client re-samples with replacement from its own partition — clients own
disjoint index sets, so the per-round tensor is fully determined by (round,
seed) and regenerable on any host (important for the SPMD path, where each
data slice materializes only its own clients' rows)."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import Dataset


class FederatedBatcher:
    """Per-round microbatch sampler over client partitions."""

    def __init__(self, data: Dataset, parts: list[np.ndarray],
                 batch_size: int, seed: int = 0):
        self.data = data
        self.parts = parts
        self.m = len(parts)
        self.batch_size = batch_size
        self.seed = seed
        n_total = sum(len(p) for p in parts)
        self.weights = jnp.array([len(p) / n_total for p in parts],
                                 jnp.float32)

    def round_batches(self, t: int, k_max: int) -> dict:
        """(M, k_max, B, …) feature/label tensors for round ``t``."""
        rng = np.random.default_rng((self.seed, t))
        idx = np.stack([
            part[rng.integers(0, len(part), (k_max, self.batch_size))]
            for part in self.parts])                       # (M, k_max, B)
        return {"x": jnp.asarray(np.asarray(self.data.x)[idx]),
                "y": jnp.asarray(np.asarray(self.data.y)[idx])}


class LMFederatedBatcher:
    """Token-stream version: each client owns a topic-skewed stream."""

    def __init__(self, streams: list[dict], batch_size: int, seed: int = 0):
        self.streams = streams                              # per-client dicts
        self.m = len(streams)
        self.batch_size = batch_size
        self.seed = seed
        n_total = sum(s["tokens"].shape[0] for s in streams)
        self.weights = jnp.array(
            [s["tokens"].shape[0] / n_total for s in streams], jnp.float32)

    def round_batches(self, t: int, k_max: int) -> dict:
        rng = np.random.default_rng((self.seed, t))
        toks, labs = [], []
        for s in self.streams:
            n = s["tokens"].shape[0]
            idx = rng.integers(0, n, (k_max, self.batch_size))
            toks.append(np.asarray(s["tokens"])[idx])
            labs.append(np.asarray(s["labels"])[idx])
        return {"tokens": jnp.asarray(np.stack(toks)),
                "labels": jnp.asarray(np.stack(labs))}


def eval_metric(metric_fn: Callable, params, data: Dataset,
                batch: int = 1024) -> float:
    """Mean of ``metric_fn(params, {"x","y"})`` over the dataset."""
    n = len(data)
    total, count = 0.0, 0
    for s in range(0, n, batch):
        b = {"x": data.x[s:s + batch], "y": data.y[s:s + batch]}
        k = b["y"].shape[0]
        total += float(metric_fn(params, b)) * k
        count += k
    return total / max(count, 1)
