"""Federated round batching: assemble the (M, k_max, batch…) microbatch
tensors that the round engine (core/rounds.py) scans over.

Each client re-samples with replacement from its own partition — clients own
disjoint index sets, so the per-round tensor is fully determined by (round,
seed) and regenerable on any host (important for the SPMD path, where each
data slice materializes only its own clients' rows).

Two sampler families (DESIGN.md §9):

* **Host batchers** (`FederatedBatcher`, `LMFederatedBatcher`) draw numpy
  indices on host and transfer the gathered rows each round — the
  pinned-equivalence compat mode (``sampler="host"``).
* **`DeviceBatcher`** keeps the dataset resident on device and draws
  per-``(seed, round, client)`` indices with ``jax.random`` *inside* the
  jitted round chunk (core/engine.py) — no per-round host gather or
  transfer.  Client *i*'s key is ``fold_in(fold_in(key(seed), t), i)``, so
  row *i* of wave *t* is identical whether the full wave is materialized
  (synchronous engine) or a single row (the async engine's per-dispatch
  gather).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import Dataset


class FederatedBatcher:
    """Per-round microbatch sampler over client partitions."""

    def __init__(self, data: Dataset, parts: list[np.ndarray],
                 batch_size: int, seed: int = 0):
        self.data = data
        self.parts = parts
        self.m = len(parts)
        self.batch_size = batch_size
        self.seed = seed
        n_total = sum(len(p) for p in parts)
        self.weights = jnp.array([len(p) / n_total for p in parts],
                                 jnp.float32)
        # full-dataset views cached ONCE: re-converting device arrays to
        # numpy inside round_batches copied the whole dataset every round
        self._x = np.asarray(data.x)
        self._y = np.asarray(data.y)

    def round_indices(self, t: int, k_max: int) -> np.ndarray:
        """(M, k_max, B) dataset row indices for round ``t``."""
        rng = np.random.default_rng((self.seed, t))
        return np.stack([
            part[rng.integers(0, len(part), (k_max, self.batch_size))]
            for part in self.parts])

    def round_batches(self, t: int, k_max: int) -> dict:
        """(M, k_max, B, …) feature/label tensors for round ``t``."""
        idx = self.round_indices(t, k_max)
        return {"x": jnp.asarray(self._x[idx]),
                "y": jnp.asarray(self._y[idx])}

    def chunk_batches(self, t0: int, r: int, k_max: int) -> dict:
        """(R, M, k_max, B, …) stacked rounds ``t0 … t0+r-1`` — one gather
        and one host→device transfer per chunk instead of one per round.
        Round ``t``'s slice is bit-identical to ``round_batches(t, k_max)``.
        """
        idx = np.stack([self.round_indices(t0 + j, k_max)
                        for j in range(r)])
        return {"x": jnp.asarray(self._x[idx]),
                "y": jnp.asarray(self._y[idx])}

    # -- cohort-indexed sampling (partial participation, DESIGN.md §10) ------

    def client_indices(self, t: int, i: int, k_max: int) -> np.ndarray:
        """(k_max, B) dataset rows for client ``i``'s round-``t`` draw from a
        per-``(seed, t, i)`` stream — client i's batches are identical no
        matter which cohort it lands in (unlike ``round_indices``, whose
        single per-round stream couples clients sequentially)."""
        rng = np.random.default_rng((self.seed, t, i))
        part = self.parts[i]
        return part[rng.integers(0, len(part), (k_max, self.batch_size))]

    def cohort_indices(self, t: int, cohort: np.ndarray,
                       k_max: int) -> np.ndarray:
        """(C, k_max, B) rows for the sampled cohort only — O(C) not O(M)."""
        return np.stack([self.client_indices(t, int(i), k_max)
                         for i in cohort])

    def cohort_batches(self, t: int, cohort: np.ndarray, k_max: int) -> dict:
        idx = self.cohort_indices(t, cohort, k_max)
        return {"x": jnp.asarray(self._x[idx]),
                "y": jnp.asarray(self._y[idx])}

    def chunk_cohort_batches(self, t0: int, cohorts: np.ndarray,
                             k_max: int) -> dict:
        """(R, C, k_max, B, …) stacked cohort rounds; ``cohorts`` is the
        (R, C) id matrix for rounds ``t0 … t0+R-1``."""
        idx = np.stack([self.cohort_indices(t0 + j, cohorts[j], k_max)
                        for j in range(cohorts.shape[0])])
        return {"x": jnp.asarray(self._x[idx]),
                "y": jnp.asarray(self._y[idx])}


class LMFederatedBatcher:
    """Token-stream version: each client owns a topic-skewed stream."""

    def __init__(self, streams: list[dict], batch_size: int, seed: int = 0):
        self.streams = streams                              # per-client dicts
        self.m = len(streams)
        self.batch_size = batch_size
        self.seed = seed
        n_total = sum(s["tokens"].shape[0] for s in streams)
        self.weights = jnp.array(
            [s["tokens"].shape[0] / n_total for s in streams], jnp.float32)
        # stream arrays cached once (previously re-converted per round)
        self._toks = [np.asarray(s["tokens"]) for s in streams]
        self._labs = [np.asarray(s["labels"]) for s in streams]

    def round_batches(self, t: int, k_max: int) -> dict:
        rng = np.random.default_rng((self.seed, t))
        toks, labs = [], []
        for tok, lab in zip(self._toks, self._labs):
            idx = rng.integers(0, tok.shape[0], (k_max, self.batch_size))
            toks.append(tok[idx])
            labs.append(lab[idx])
        return {"tokens": jnp.asarray(np.stack(toks)),
                "labels": jnp.asarray(np.stack(labs))}

    def cohort_batches(self, t: int, cohort: np.ndarray, k_max: int) -> dict:
        """(C, k_max, B, …) streams for the sampled cohort only (per-(t, i)
        draw streams, independent of cohort membership — DESIGN.md §10)."""
        toks, labs = [], []
        for i in cohort:
            i = int(i)
            rng = np.random.default_rng((self.seed, t, i))
            idx = rng.integers(0, self._toks[i].shape[0],
                               (k_max, self.batch_size))
            toks.append(self._toks[i][idx])
            labs.append(self._labs[i][idx])
        return {"tokens": jnp.asarray(np.stack(toks)),
                "labels": jnp.asarray(np.stack(labs))}

    def chunk_cohort_batches(self, t0: int, cohorts: np.ndarray,
                             k_max: int) -> dict:
        waves = [self.cohort_batches(t0 + j, cohorts[j], k_max)
                 for j in range(cohorts.shape[0])]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *waves)


class DeviceBatcher:
    """Device-resident deterministic sampler (DESIGN.md §9).

    The dataset and the (padded) per-client index table live on device;
    ``sample(t, k_max)`` is traceable and runs *inside* the jitted round
    chunk, so a chunked run performs zero per-round host work.  Sampling is
    with replacement from each client's partition, deterministic in
    ``(seed, round, client)`` — NOT bit-matched to the numpy host batcher
    (different RNG), which remains the golden-pinned compat mode.
    """

    def __init__(self, data: Dataset, parts: list[np.ndarray],
                 batch_size: int, seed: int = 0):
        self.data = data
        self.parts = parts
        self.m = len(parts)
        self.batch_size = batch_size
        self.seed = seed
        sizes = np.array([len(p) for p in parts], np.int64)
        n_total = int(sizes.sum())
        self.weights = jnp.asarray(sizes / n_total, jnp.float32)
        # rectangular (M, L) index table; the pad slots are never drawn
        # because idx < sizes[i] by construction
        padded = np.zeros((self.m, int(sizes.max())), np.int32)
        for i, p in enumerate(parts):
            padded[i, :len(p)] = p
        self._table = jnp.asarray(padded)
        self._sizes = jnp.asarray(sizes, jnp.int32)
        self._x = jnp.asarray(data.x)
        self._y = jnp.asarray(data.y)
        self._key = jax.random.PRNGKey(seed)

    # -- traceable samplers (round index / client id may be traced ints) ----

    def row_indices(self, t, i, k_max: int) -> jax.Array:
        """(k_max, B) dataset rows for client ``i``'s round-``t`` draw."""
        key = jax.random.fold_in(jax.random.fold_in(self._key, t), i)
        u = jax.random.randint(key, (k_max, self.batch_size), 0,
                               self._sizes[i])
        return self._table[i, u]

    def sample_row(self, t, i, k_max: int) -> dict:
        """One client's (k_max, B, …) microbatches — the async engine's
        per-dispatch gather (wave ``t``, client ``i``)."""
        idx = self.row_indices(t, i, k_max)
        return {"x": self._x[idx], "y": self._y[idx]}

    def sample(self, t, k_max: int) -> dict:
        """Full (M, k_max, B, …) wave for round ``t`` — the synchronous
        engine's in-scan sampler; row ``i`` equals ``sample_row(t, i)``."""
        return jax.vmap(lambda i: self.sample_row(t, i, k_max))(
            jnp.arange(self.m))

    def sample_cohort(self, t, cohort, k_max: int) -> dict:
        """(C, k_max, B, …) microbatches for a sampled cohort — the cohort
        chunk's in-scan sampler (DESIGN.md §10).  Row j equals
        ``sample_row(t, cohort[j])``: a client's draw is independent of
        cohort membership, so memory is O(C) with full-wave consistency."""
        return jax.vmap(lambda i: self.sample_row(t, i, k_max))(cohort)

    # -- host-compatible API (eager; used by the chunk_rounds=1 path) -------

    def round_batches(self, t: int, k_max: int) -> dict:
        return self.sample(jnp.int32(t), k_max)


class DeviceLMBatcher:
    """Device-resident LM token sampler: the ``DeviceBatcher`` contract
    (traceable ``sample`` / ``sample_row`` / ``sample_cohort`` drawn
    per-``(seed, round, client)`` with ``jax.random`` inside the scanned
    chunk) over per-client token streams — what lets the real LM configs
    run on the chunked sync engine, the cohort engine AND the buffered-
    async engine (which needs ``sample_row``; the host
    ``LMFederatedBatcher`` has no per-row API).  Streams of unequal length
    pad into one rectangular (M, N_max, S) tensor; pad rows are never
    drawn (``idx < sizes[i]``).  NOT bit-matched to the numpy host
    batcher (different RNG), same as ``DeviceBatcher``."""

    def __init__(self, streams: list[dict], batch_size: int, seed: int = 0):
        self.m = len(streams)
        self.batch_size = batch_size
        self.seed = seed
        sizes = np.array([np.asarray(s["tokens"]).shape[0]
                          for s in streams], np.int64)
        self.weights = jnp.asarray(sizes / sizes.sum(), jnp.float32)
        n_max = int(sizes.max())
        seq = np.asarray(streams[0]["tokens"]).shape[1]
        toks = np.zeros((self.m, n_max, seq), np.int32)
        labs = np.zeros((self.m, n_max, seq), np.int32)
        for i, s in enumerate(streams):
            toks[i, :sizes[i]] = np.asarray(s["tokens"])
            labs[i, :sizes[i]] = np.asarray(s["labels"])
        self._toks = jnp.asarray(toks)
        self._labs = jnp.asarray(labs)
        self._sizes = jnp.asarray(sizes, jnp.int32)
        self._key = jax.random.PRNGKey(seed)

    def sample_row(self, t, i, k_max: int) -> dict:
        """One client's (k_max, B, S) microbatches for wave ``t``."""
        key = jax.random.fold_in(jax.random.fold_in(self._key, t), i)
        idx = jax.random.randint(key, (k_max, self.batch_size), 0,
                                 self._sizes[i])
        return {"tokens": self._toks[i, idx], "labels": self._labs[i, idx]}

    def sample(self, t, k_max: int) -> dict:
        """(M, k_max, B, S) full wave; row ``i`` == ``sample_row(t, i)``."""
        return jax.vmap(lambda i: self.sample_row(t, i, k_max))(
            jnp.arange(self.m))

    def sample_cohort(self, t, cohort, k_max: int) -> dict:
        """(C, k_max, B, S) for a sampled cohort; a client's draw is
        independent of cohort membership (DESIGN.md §10)."""
        return jax.vmap(lambda i: self.sample_row(t, i, k_max))(cohort)

    # -- host-compatible API (eager; the chunk_rounds=1 path) ---------------

    def round_batches(self, t: int, k_max: int) -> dict:
        return self.sample(jnp.int32(t), k_max)


def eval_metric(metric_fn: Callable, params, data: Dataset,
                batch: int = 1024) -> float:
    """Mean of ``metric_fn(params, {"x","y"})`` over the dataset."""
    n = len(data)
    total, count = 0.0, 0
    for s in range(0, n, batch):
        b = {"x": data.x[s:s + batch], "y": data.y[s:s + batch]}
        k = b["y"].shape[0]
        total += float(metric_fn(params, b)) * k
        count += k
    return total / max(count, 1)
