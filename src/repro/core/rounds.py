"""The FedaGrac round engine (Algorithm 1), SPMD-ready — facade.

Since the layered-engine refactor the actual machinery lives in
``core/stages.py``: four composable stages (client update → aggregation →
orientation → server optimizer) with ``Algorithm`` naming a composition
instead of flag branches (DESIGN.md §2).  This module keeps the original
public surface — ``make_round`` / ``init_state`` and the tree helpers — so
``fed/simulation.py``, ``launch/train.py`` and the benchmarks are unchanged.

One implementation serves both execution modes:

* **CPU simulation** — ``jax.vmap`` over the client axis on one device
  (examples / paper-experiment benchmarks).
* **Pod-scale SPMD** — the same vmap with ``spmd_axis_name`` mapping the
  client axis onto mesh data axes; per-client model replicas live on data
  slices, round aggregation lowers to all-reduces (see launch/train.py).

Step asynchronism under SPMD is masking: the scan runs ``k_max`` steps and
client *i* applies updates only for ``k < K_i`` (DESIGN.md §3).  ``K_i`` is a
traced input, so heterogeneity schedules change per round without recompiles.

The averaged local gradient is *recovered from the parameter delta*
(paper §4.2):   ν̄⁽ⁱ⁾ = (x̃_t − x⁽ⁱ⁾_{K_i}) / (η K_i) − λ c⁽ⁱ⁾,
so a round carries only x⁽ⁱ⁾, c⁽ⁱ⁾ and (for first-gradient strategies) g₀⁽ⁱ⁾
per client — the single-buffer trick that keeps big-model state ≤ 3×params.
``track_nu="explicit"`` instead accumulates ν̄⁽ⁱ⁾ in the loop (used by tests
to validate the delta recovery and by float-sensitive small runs).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import compress
from repro.core import robust as robust_mod
from repro.core.fedopt import Algorithm
from repro.core.stages import make_layered_round, quantize_int8
from repro.core.tree_util import tree_stack_zeros, tree_zeros

__all__ = ["init_state", "make_round", "quantize_int8", "tree_zeros",
           "tree_stack_zeros"]

PyTree = Any


def init_state(params: PyTree, n_clients: int, algo: Algorithm,
               compression=None, spec=None, robust=None) -> dict:
    """Server + client state.  ν/ν⁽ⁱ⁾ start at zero: the first round then
    runs plain (uncalibrated) local SGD, matching the paper's init where
    ν⁽ⁱ⁾ = ∇f_i(x₁) is unknown before any gradient is computed.

    With an active ``compression`` (core/compress.py, DESIGN.md §14) the
    error-feedback accumulators are allocated as flat-layout leaves — an
    (M, P) row block per uplink quantity, (P,) per broadcast — on BOTH
    param layouts (the tree round compresses through the view table, so
    its residuals are flat too); ``spec`` supplies (P, dtype).  An active
    ``robust`` config with quarantine on (core/robust.py, DESIGN.md §16)
    adds the per-client (M,) health vectors — layout-independent, so no
    spec is needed."""
    state = {"params": params, "round": jnp.zeros((), jnp.int32)}
    if algo.uses_nu:
        state["nu"] = tree_zeros(params)
        state["nu_i"] = tree_stack_zeros(params, n_clients)
    if algo.server_opt == "momentum":
        state["server_m"] = tree_zeros(params)
    elif algo.server_opt == "adam":
        state["server_m"] = tree_zeros(params)
        state["server_v"] = tree_zeros(params)
    if compression is not None and compression.active:
        if spec is None:
            raise ValueError("compression requires a FlatSpec (built on "
                             "both layouts by the engines)")
        compress.init_compression_state(state, compression, n_clients,
                                        spec.p, spec.dtype, algo.uses_nu)
    robust_mod.init_robust_state(state, robust, n_clients)
    return state


def make_round(loss_fn: Callable[[PyTree, PyTree], jax.Array],
               algo: Algorithm, *, lr: float, k_max: int,
               track_nu: str = "delta",
               spmd_axis_name=None,
               quantize_transmit: bool = False,
               compression=None, spec=None, robust=None, attack=None,
               param_constraint: Optional[Callable[[PyTree, int], PyTree]] = None):
    """Build ``round_fn(state, batches, k_steps, weights[, lam]) ->
    (state, metrics)`` by composing the stages for ``algo``.

    batches: pytree with leading dims (M, k_max, ...) — one microbatch per
    client per local step.  k_steps: (M,) int32.  weights: (M,) fp32 ω_i.
    The optional trailing ``lam`` is a traced λ (defaults to ``algo.lam``) —
    λ-schedules reuse one compiled round.  ``param_constraint(tree,
    n_client_dims)`` optionally pins shardings at round boundaries.
    ``compression`` (+ its ``spec``) inserts the wire-compression stage
    (core/compress.py, DESIGN.md §14); ``attack``/``robust`` bracket the
    same wire boundary with payload corruption and the robust-aggregation
    defense (core/robust.py, DESIGN.md §16).  None bakes the unchanged
    round.
    """
    return make_layered_round(
        loss_fn, algo, lr=lr, k_max=k_max, track_nu=track_nu,
        spmd_axis_name=spmd_axis_name, quantize_transmit=quantize_transmit,
        compression=compression, spec=spec, robust=robust, attack=attack,
        param_constraint=param_constraint)
