"""Layered round engine: the four composable stages (DESIGN.md §2).

A federated round factors into four pure stages, each independently
pluggable and each reused verbatim by BOTH execution modes (synchronous
rounds in ``fed/simulation.py`` + ``launch/train.py``, and buffered
semi-asynchronous serving in ``fed/async_engine.py``):

1. **client update** (``make_client_update``) — the masked-K_i scan with
   λ-calibration; vmap over the client axis (optionally SPMD-mapped).  The
   anchor x̃ may be shared (synchronous: every client starts the round at
   the same global model) or per-client (asynchronous: each client starts
   from the — possibly stale — model version it was dispatched with).
2. **aggregation** (``AGGREGATORS`` / ``BUFFERED_AGGREGATORS``) — weighted
   average or FedNova-normalized; the buffered variants operate on
   pseudo-deltas δᵢ = xᵢ − anchorᵢ so stale anchors aggregate correctly.
3. **orientation** (``orientation_transmit`` + ``SELECTORS``) — recover the
   averaged local gradient from the parameter delta (paper §4.2) and select
   what each client transmits toward the next global ν (avg / first /
   fedagrac / reverse), with optional int8 fake-quantization.
4. **server optimizer** (``SERVER_OPTIMIZERS``) — FedOpt step on the round
   pseudo-gradient (sgd / momentum / adam; Reddi et al. 2021).

``Algorithm`` (core/fedopt.py) names a composition — ``algo.aggregator``,
``algo.selector``, ``algo.server_opt`` index these registries; there are no
per-algorithm branches below, only per-stage ones.  λ is an ARGUMENT of the
built round function (traced), so λ-schedules never retrace.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import compress
from repro.core import robust as robust_mod
from repro.core.fedopt import Algorithm
from repro.core.tree_util import expand, tree_wsum, tree_zeros

PyTree = Any


def _flat_bridge(spec):
    """Tree-layout access to the flat view table (for the compression
    stage, DESIGN.md §14): ravel/unravel closures over ``spec``.  Imported
    at build time — core.flat imports this module, so the dependency must
    stay function-level."""
    from repro.core import flat as _flat
    return (lambda t: _flat.ravel(spec, t),
            lambda t: _flat.ravel(spec, t, client_dims=1),
            lambda a: _flat.unravel(spec, a),
            lambda a: _flat.unravel(spec, a, client_dims=1))


def _typed_scale(lam, c: jax.Array) -> jax.Array:
    """λ·c in c's dtype.  A traced λ arrives as a STRONG f32 scalar and
    would otherwise promote the whole scan carry (bf16 round state) to f32;
    a baked python-float λ is weak-typed and multiplies in c's dtype, which
    this reproduces exactly (f32 leaves: bit-identical either way)."""
    if isinstance(lam, jax.Array) and lam.dtype != c.dtype:
        return lam.astype(c.dtype) * c
    return lam * c


# ---------------------------------------------------------------------------
# stage 1: client update
# ---------------------------------------------------------------------------

def make_client_update(loss_fn: Callable[[PyTree, PyTree], jax.Array],
                       algo: Algorithm, *, lr: float, k_max: int,
                       track_nu: str = "delta",
                       spmd_axis_name=None,
                       per_client_anchor: bool = False):
    """Build the vmapped per-client local-SGD stage.

    Returns ``f(anchor, c_all, batches, k_steps, lam) ->
    (x_i, g0_i, acc_i, loss0)`` where ``anchor`` is the start model — shared
    (synchronous) or stacked per client (``per_client_anchor=True``, the
    buffered-async path where client *i* starts from its dispatch-time model
    version).  Step asynchronism is masking: the scan runs ``k_max`` steps
    and client *i* applies updates only for ``k < K_i`` (DESIGN.md §3);
    ``K_i`` and ``lam`` are traced, so heterogeneity and λ-schedules change
    per round without recompiles.

    The same mask is the **effective-steps mask** of partial-work recovery
    (fed/scenarios.py, DESIGN.md §12): a mid-round dropout passes its
    effective k′ < K_i as ``k_steps`` and this stage computes exactly the
    k′-step prefix of the client's trajectory — no separate abort path.
    ``K_i ≥ 1`` is a contract: downstream FedNova normalization and the
    ν̄⁽ⁱ⁾ recovery (``recover_avg_grad``) divide by K_i.
    """
    needs_first = algo.selector in ("fedagrac", "first", "reverse")
    grad_fn = jax.value_and_grad(loss_fn)

    def client_run(anchor, c_i, batch_i, K_i, lam):
        lam_c = (jax.tree.map(lambda c: _typed_scale(lam, c), c_i)
                 if algo.uses_nu else None)

        def step(carry, xs):
            k, batch_k = xs
            x, g0, nu_acc = carry
            loss, g = grad_fn(x, batch_k)
            if algo.prox_mu:
                g = jax.tree.map(lambda gg, xx, x0: gg + algo.prox_mu * (xx - x0),
                                 g, x, anchor)
            active = k < K_i
            if algo.uses_nu:
                upd = jax.tree.map(lambda xx, gg, cc: xx - lr * (gg + cc),
                                   x, g, lam_c)
            else:
                upd = jax.tree.map(lambda xx, gg: xx - lr * gg, x, g)
            x = jax.tree.map(lambda old, new: jnp.where(active, new, old),
                             x, upd)
            if needs_first:
                g0 = jax.tree.map(lambda a, gg: jnp.where(k == 0, gg, a),
                                  g0, g)
            if track_nu == "explicit" and algo.uses_nu:
                w = jnp.where(active, 1.0 / K_i.astype(jnp.float32), 0.0)
                nu_acc = jax.tree.map(lambda a, gg: a + w * gg, nu_acc, g)
            return (x, g0, nu_acc), loss

        g0_0 = tree_zeros(anchor) if needs_first else jnp.zeros(())
        acc_0 = (tree_zeros(anchor)
                 if (track_nu == "explicit" and algo.uses_nu)
                 else jnp.zeros(()))
        (x, g0, nu_acc), losses = jax.lax.scan(
            step, (anchor, g0_0, acc_0),
            (jnp.arange(k_max), batch_i))
        return x, g0, nu_acc, losses[0]

    anchor_axis = 0 if per_client_anchor else None
    return jax.vmap(client_run, in_axes=(anchor_axis, 0, 0, 0, None),
                    spmd_axis_name=spmd_axis_name)


def zero_corrections(params: PyTree, m: int) -> PyTree:
    """Zero-size per-client correction placeholder for algorithms without ν
    — keeps the client-update vmap signature uniform."""
    return jax.tree.map(
        lambda a: jnp.zeros((m,) + (0,) * a.ndim, a.dtype), params)


# ---------------------------------------------------------------------------
# stage 2: aggregation
# ---------------------------------------------------------------------------

def aggregate_mean(params0: PyTree, x_i: PyTree, kf: jax.Array,
                   weights: jax.Array, kbar: jax.Array) -> PyTree:
    """Plain weighted average  Σ ω_i x⁽ⁱ⁾."""
    return tree_wsum(weights, x_i)


def aggregate_fednova(params0: PyTree, x_i: PyTree, kf: jax.Array,
                      weights: jax.Array, kbar: jax.Array) -> PyTree:
    """FedNova:  x̃ + K̄ Σ ω_i (x⁽ⁱ⁾ − x̃)/K_i  (Wang et al. 2020)."""
    deltas = jax.tree.map(
        lambda xi, p0: (xi.astype(jnp.float32) - p0[None])
        / expand(kf, xi), x_i, params0)
    return jax.tree.map(
        lambda p0, d: (p0 + kbar * jnp.einsum("m,m...->...", weights,
                                              d)).astype(p0.dtype),
        params0, deltas)


AGGREGATORS: dict[str, Callable] = {
    "mean": aggregate_mean,
    "fednova": aggregate_fednova,
}


def buffered_mean(params: PyTree, anchor_i: PyTree, x_i: PyTree,
                  kf: jax.Array, sweights: jax.Array,
                  kbar: jax.Array) -> PyTree:
    """Buffered pseudo-delta average:  x + Σ_{i∈B} w̃_i (x⁽ⁱ⁾ − anchorᵢ).

    ``sweights`` = ω_i·s(τ_i) are the staleness-discounted client weights
    (NOT renormalized): with buffer = M and zero staleness Σ w̃ = 1 and this
    reduces exactly to the synchronous weighted average."""
    deltas = jax.tree.map(
        lambda xi, ai: xi.astype(jnp.float32) - ai.astype(jnp.float32),
        x_i, anchor_i)
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32)
                      + jnp.einsum("m,m...->...", sweights, d)
                      ).astype(p.dtype), params, deltas)


def buffered_fednova(params: PyTree, anchor_i: PyTree, x_i: PyTree,
                     kf: jax.Array, sweights: jax.Array,
                     kbar: jax.Array) -> PyTree:
    """Buffered FedNova:  x + K̄_B Σ_{i∈B} w̃_i (x⁽ⁱ⁾ − anchorᵢ)/K_i with
    K̄_B the discount-weighted mean steps over the buffer."""
    deltas = jax.tree.map(
        lambda xi, ai: (xi.astype(jnp.float32) - ai.astype(jnp.float32))
        / expand(kf, xi), x_i, anchor_i)
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32)
                      + kbar * jnp.einsum("m,m...->...", sweights, d)
                      ).astype(p.dtype), params, deltas)


BUFFERED_AGGREGATORS: dict[str, Callable] = {
    "mean": buffered_mean,
    "fednova": buffered_fednova,
}


def delivered_weights(weights: jax.Array, k_eff: jax.Array,
                      k_sched: jax.Array) -> jax.Array:
    """Partial-work recovery weight rule (fed/scenarios.py, DESIGN.md §12):
    a mid-round dropout delivering k′ < K completed steps keeps its
    (FedNova-normalized) per-step direction but carries only the fraction
    of mass it earned, w̃ ← w̃ · k′/K — deliberately NOT renormalized, so
    lost work is lost mass: the pseudo-delta step shrinks and the ν
    mass-mix keeps (1 − Σw̃) of the previous calibration direction.  Shared
    by the in-scan cohort hook (core/engine.py), its host mirror
    (fed/simulation.py) and the async engine's report weighting."""
    frac = (k_eff.astype(jnp.float32)
            / jnp.maximum(k_sched.astype(jnp.float32), 1.0))
    return weights * frac


def nu_mass_mix(nu: PyTree, contrib: PyTree, mass: jax.Array) -> PyTree:
    """ν ← (1 − ρ) ν + (ρ/Σw̃)·Σ w̃ transmitᵢ with ρ = min(Σw̃, 1): keep ρ
    of the new signal, renormalized — convex even when duplicate reporters
    (or Horvitz–Thompson weights) push Σw̃ past 1; for Σw̃ ≤ 1 this is
    exactly (1 − Σw̃)·ν + Σ w̃ transmitᵢ, so the synchronous reduction
    (Σw̃ = 1) is untouched.  Shared by the buffered-async engine and the
    cohort round (DESIGN.md §5, §10)."""
    rho = jnp.minimum(mass, 1.0)
    return jax.tree.map(
        lambda n, c: ((1.0 - rho) * n.astype(jnp.float32)
                      + (rho / mass) * c.astype(jnp.float32)
                      ).astype(n.dtype), nu, contrib)


def scatter_nu_rows(nu_i: PyTree, new_nu: PyTree, avg_g: PyTree,
                    ids: jax.Array, nu_decay: float = 0.0) -> PyTree:
    """Write the participants' fresh ν̄⁽ⁱ⁾ rows into the population-sized
    state; non-participants' stale rows decay toward the new global ν at
    ``nu_decay`` per update — their correction c⁽ⁱ⁾ = ν − ν⁽ⁱ⁾ → 0, so cold
    clients degrade gracefully to plain local SGD (0 = frozen rows).  Decay
    first, scatter second: the overwrite keeps participants exact.  Shared
    by the cohort round and the buffered-async engine (DESIGN.md §10)."""
    def one(nui, nu, g):
        if nu_decay:
            nui = (nui.astype(jnp.float32)
                   + nu_decay * (nu[None].astype(jnp.float32)
                                 - nui.astype(jnp.float32)))
        return nui.at[ids].set(g.astype(nui.dtype)).astype(g.dtype)
    return jax.tree.map(one, nu_i, new_nu, avg_g)


# ---------------------------------------------------------------------------
# stage 3: orientation (transmit selection)
# ---------------------------------------------------------------------------

def quantize_int8(tree: PyTree) -> PyTree:
    """Per-client-per-leaf symmetric int8 fake-quantization of the
    transmitted orientation (beyond-paper comms ablation): scale =
    amax/127 over each client's tensor, round-to-nearest.  Halves the ν
    upload vs bf16; EXPERIMENTS.md reports the accuracy cost."""
    def q(a):
        red = tuple(range(1, a.ndim))
        scale = jnp.max(jnp.abs(a.astype(jnp.float32)), axis=red,
                        keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        return (jnp.round(a.astype(jnp.float32) / scale) * scale
                ).astype(a.dtype)
    return jax.tree.map(q, tree)


def _select_avg(g0_i, avg_g, fast):
    return avg_g


def _select_first(g0_i, avg_g, fast):
    return g0_i


def _select_fedagrac(g0_i, avg_g, fast):
    """Fast clients (K_i > K̄) send the first stochastic gradient, slow
    clients the averaged gradient (paper §4.2)."""
    return jax.tree.map(
        lambda f, a: jnp.where(expand(fast, a), f, a), g0_i, avg_g)


def _select_reverse(g0_i, avg_g, fast):
    return jax.tree.map(
        lambda f, a: jnp.where(expand(fast, a), a, f), g0_i, avg_g)


SELECTORS: dict[str, Callable] = {
    "avg": _select_avg,
    "first": _select_first,
    "fedagrac": _select_fedagrac,
    "reverse": _select_reverse,
}


def fast_mask(kf: jax.Array, kbar: jax.Array) -> jax.Array:
    """K_i > K̄ with a tie tolerance: K_i are integers (spacing 1) but K̄ is
    an f32 dot whose summation ORDER can leave it 1 ulp under an exact tie —
    without the epsilon, a client-permutation flips every tied client from
    "slow" (send averaged) to "fast" (send first), found by the
    permutation-invariance property test."""
    return kf > kbar + 1e-4 * jnp.maximum(kbar, 1.0)            # (M,)


def recover_avg_grad(params0: PyTree, x_i: PyTree, c_all: PyTree,
                     kf: jax.Array, lr: float, lam,
                     anchor_i: Optional[PyTree] = None) -> PyTree:
    """Delta recovery of the averaged local gradient (paper §4.2):
    ν̄⁽ⁱ⁾ = (x̃ − x⁽ⁱ⁾_{K_i}) / (η K_i) − λ c⁽ⁱ⁾ — the single-buffer trick
    that keeps big-model round state ≤ 3×params.  ``anchor_i`` (stacked)
    replaces the shared x̃ on the buffered-async path."""
    if anchor_i is None:
        return jax.tree.map(
            lambda x0, xi, ci: ((x0[None].astype(jnp.float32)
                                 - xi.astype(jnp.float32))
                                / (lr * expand(kf, xi))
                                - lam * ci.astype(jnp.float32)
                                ).astype(x0.dtype),
            params0, x_i, c_all)
    return jax.tree.map(
        lambda a0, xi, ci: ((a0.astype(jnp.float32)
                             - xi.astype(jnp.float32))
                            / (lr * expand(kf, xi))
                            - lam * ci.astype(jnp.float32)
                            ).astype(a0.dtype),
        anchor_i, x_i, c_all)


def orientation_transmit(algo: Algorithm, params0: PyTree, x_i: PyTree,
                         g0_i: PyTree, acc_i: PyTree, c_all: PyTree,
                         kf: jax.Array, kbar: jax.Array, lr: float, lam, *,
                         track_nu: str = "delta",
                         quantize_transmit: bool = False,
                         anchor_i: Optional[PyTree] = None):
    """Per-client (transmit, avg_g): what flows into the next global ν, and
    the local reference ν⁽ⁱ⁾ (Alg. 1 line 11 — always the averaged grad)."""
    if track_nu == "explicit":
        avg_g = acc_i
    else:
        avg_g = recover_avg_grad(params0, x_i, c_all, kf, lr, lam,
                                 anchor_i=anchor_i)
    transmit = SELECTORS[algo.selector](g0_i, avg_g, fast_mask(kf, kbar))
    if quantize_transmit:
        transmit = quantize_int8(transmit)
    return transmit, avg_g


# ---------------------------------------------------------------------------
# stage 4: server optimizer (FedOpt, Reddi et al. 2021)
# ---------------------------------------------------------------------------

def _server_sgd(algo, state, params0, agg, delta, new_state):
    """server_opt="sgd", server_lr=1 reproduces plain averaging exactly."""
    lr = algo.server_lr
    if lr == 1.0:
        return agg
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + lr * d).astype(p.dtype),
        params0, delta)


def _server_momentum(algo, state, params0, agg, delta, new_state):
    """FedAvgM."""
    lr, b1 = algo.server_lr, algo.server_beta1
    m = jax.tree.map(lambda mm, d: b1 * mm.astype(jnp.float32) + d,
                     state["server_m"], delta)
    new_state["server_m"] = jax.tree.map(
        lambda mm, p: mm.astype(p.dtype), m, params0)
    return jax.tree.map(
        lambda p, mm: (p.astype(jnp.float32) + lr * mm).astype(p.dtype),
        params0, m)


def _server_adam(algo, state, params0, agg, delta, new_state):
    """FedAdam."""
    lr, b1 = algo.server_lr, algo.server_beta1
    b2, eps = 0.999, 1e-8
    t = state["round"].astype(jnp.float32) + 1.0
    m = jax.tree.map(
        lambda mm, d: b1 * mm.astype(jnp.float32) + (1 - b1) * d,
        state["server_m"], delta)
    v = jax.tree.map(
        lambda vv, d: b2 * vv.astype(jnp.float32) + (1 - b2) * d * d,
        state["server_v"], delta)
    new_state["server_m"] = jax.tree.map(
        lambda mm, p: mm.astype(p.dtype), m, params0)
    new_state["server_v"] = jax.tree.map(
        lambda vv, p: vv.astype(p.dtype), v, params0)
    bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t
    return jax.tree.map(
        lambda p, mm, vv: (p.astype(jnp.float32)
                           + lr * (mm / bc1)
                           / (jnp.sqrt(vv / bc2) + eps)).astype(p.dtype),
        params0, m, v)


SERVER_OPTIMIZERS: dict[str, Callable] = {
    "sgd": _server_sgd,
    "momentum": _server_momentum,
    "adam": _server_adam,
}


def server_update(algo: Algorithm, state: dict, params0: PyTree,
                  agg: PyTree, new_state: dict) -> PyTree:
    """FedOpt server step on the round pseudo-gradient Δ = agg − x̃_t."""
    if algo.server_opt not in SERVER_OPTIMIZERS:
        raise ValueError(algo.server_opt)
    delta = jax.tree.map(
        lambda a, p: a.astype(jnp.float32) - p.astype(jnp.float32),
        agg, params0)
    return SERVER_OPTIMIZERS[algo.server_opt](algo, state, params0, agg,
                                              delta, new_state)


# ---------------------------------------------------------------------------
# composition: the synchronous round
# ---------------------------------------------------------------------------

def make_layered_round(loss_fn: Callable[[PyTree, PyTree], jax.Array],
                       algo: Algorithm, *, lr: float, k_max: int,
                       track_nu: str = "delta",
                       spmd_axis_name=None,
                       quantize_transmit: bool = False,
                       compression=None, spec=None, robust=None,
                       attack=None,
                       param_constraint: Optional[Callable[[PyTree, int],
                                                           PyTree]] = None):
    """Compose the four stages into the synchronous round function.

    ``round_fn(state, batches, k_steps, weights, lam=None) ->
    (state, metrics)``.  ``lam`` may be a traced scalar (λ-schedules reuse
    one compiled round — see fed/simulation.py); ``None`` bakes ``algo.lam``
    in as a compile-time constant.

    ``compression`` (core/compress.py, DESIGN.md §14) inserts the wire
    stage at trace time: the server→client broadcast is compressed before
    dispatch (clients anchor on — and the server aggregates against —
    what they actually received), the client→server delta and ν transmit
    are compressed with per-client error feedback, all through the flat
    view table of ``spec``.  None (or an all-"none" config) bakes the
    literally unchanged round — the golden bit-identity contract.

    ``attack`` (a payload-corrupting Scenario, fed/scenarios.py) and
    ``robust`` (a RobustConfig, core/robust.py, DESIGN.md §16) bracket the
    same wire boundary: corruption applies to what each client puts on the
    wire (delta + ν transmit, before uplink compression), the defense to
    what the server takes off it (after decompression, before the
    aggregators and the ν mix).  Both are trace-time gated like
    compression: None bakes the identical round.
    """
    client_update = make_client_update(
        loss_fn, algo, lr=lr, k_max=k_max, track_nu=track_nu,
        spmd_axis_name=spmd_axis_name)
    aggregate = AGGREGATORS[algo.aggregator]
    cs = compress.build_stages(compression, spec, algo.uses_nu)
    rb = robust_mod.build_round_robust(robust, spec, algo.uses_nu)
    atk = attack if (attack is not None
                     and attack.corrupts_payload) else None
    if atk is not None and spec is None:
        raise ValueError("payload-corruption scenarios require a FlatSpec "
                         "— the engines build one on both param layouts")
    wire = cs is not None or rb is not None or atk is not None
    if wire:
        _rv, _rvr, _ur, _urr = _flat_bridge(spec)
        n_true = spec.n
    down_on = cs is not None and cs.down is not None
    up_on = cs is not None and cs.up is not None

    def constrain(tree, client_dims):
        if param_constraint is None:
            return tree
        return param_constraint(tree, client_dims)

    def round_fn(state: dict, batches: PyTree, k_steps: jax.Array,
                 weights: jax.Array, lam=None):
        if lam is None:
            lam = algo.lam
        params0 = state["params"]
        m = k_steps.shape[0]
        kbar = jnp.dot(weights, k_steps.astype(jnp.float32))
        new_state = dict(state)

        # -- downlink: clients start from the compressed broadcast --------
        if down_on:
            bc_flat = cs.down(_rv(params0), state, new_state)
            anchor = _ur(bc_flat)
            nu_bc = (_ur(cs.down_nu(_rv(state["nu"]), state, new_state))
                     if algo.uses_nu else None)
        else:
            anchor = params0
            nu_bc = state["nu"] if algo.uses_nu else None

        if algo.uses_nu:
            c_all = jax.tree.map(lambda nu, nui: (nu[None] - nui) if nui.ndim
                                 else nu - nui, nu_bc, state["nu_i"])
        else:
            c_all = zero_corrections(params0, m)

        x_i, g0_i, acc_i, loss0 = client_update(anchor, c_all, batches,
                                                k_steps, lam)
        x_i = constrain(x_i, 1)
        kf = k_steps.astype(jnp.float32)

        # -- uplink: the server sees x̂ᵢ = anchor + C(Δᵢ + eᵢ) -------------
        w_agg = weights
        if wire:
            a_flat = bc_flat if down_on else _rv(params0)
            d = _rvr(x_i) - a_flat[None]
            if atk is not None:
                d = atk.corrupt_delta(state["round"], d, n_true,
                                      ids=jnp.arange(m, dtype=jnp.int32))
            if up_on:
                d = cs.up(d, state, new_state)
            if rb is not None:
                d, w_agg, qcount = rb.model(d, weights, state, new_state,
                                            state["round"],
                                            jnp.arange(m, dtype=jnp.int32))
            x_srv = _urr(a_flat[None] + d)
        else:
            x_srv = x_i

        agg = aggregate(anchor, x_srv, kf, w_agg, kbar)
        if down_on:
            # re-base onto the true master: the round pseudo-gradient is
            # measured against the broadcast the clients actually anchored
            # on, then applied to the uncompressed server model
            agg = jax.tree.map(
                lambda p0, a, an: (p0.astype(jnp.float32)
                                   + a.astype(jnp.float32)
                                   - an.astype(jnp.float32)
                                   ).astype(p0.dtype), params0, agg, anchor)
        new_params = server_update(algo, state, params0, agg, new_state)
        new_params = constrain(new_params, 0)
        new_state["params"] = new_params
        new_state["round"] = state["round"] + 1

        if algo.uses_nu:
            # avg_g (the client-local reference ν⁽ⁱ⁾) uses the TRUE local
            # iterate — it never crosses the wire; the transmit does, so
            # it alone is compressed (with its own error accumulator)
            transmit, avg_g = orientation_transmit(
                algo, anchor, x_i, g0_i, acc_i, c_all, kf, kbar, lr, lam,
                track_nu=track_nu, quantize_transmit=quantize_transmit)
            w_nu = weights
            if wire and (up_on or atk is not None or rb is not None):
                t_rows = _rvr(transmit)
                if atk is not None:
                    t_rows = atk.corrupt_nu(state["round"], t_rows, n_true,
                                            ids=jnp.arange(m,
                                                           dtype=jnp.int32))
                if up_on:
                    t_rows = cs.up_nu(t_rows, state, new_state)
                if rb is not None:
                    t_rows, w_nu = rb.nu(t_rows, weights, state,
                                         state["round"],
                                         jnp.arange(m, dtype=jnp.int32))
                transmit = _urr(t_rows)
            new_state["nu"] = constrain(tree_wsum(w_nu, transmit), 0)
            # Line 11: the *local* reference ν⁽ⁱ⁾ is always the averaged grad
            new_state["nu_i"] = constrain(avg_g, 1)

        if rb is not None:
            # final non-finite guard: a defended run never writes NaN into
            # the master (or the ν state calibration broadcasts from)
            new_state["params"] = rb.guard(new_state["params"], params0)
            if algo.uses_nu:
                new_state["nu"] = rb.guard(new_state["nu"], state["nu"])
                new_state["nu_i"] = rb.guard(new_state["nu_i"],
                                             state["nu_i"])

        metrics = {"loss": jnp.dot(weights, loss0), "kbar": kbar}
        if rb is not None:
            metrics["quarantined"] = qcount
        return new_state, metrics

    return round_fn


# ---------------------------------------------------------------------------
# composition: the cohort round (partial participation, DESIGN.md §10)
# ---------------------------------------------------------------------------

def make_cohort_round(loss_fn: Callable[[PyTree, PyTree], jax.Array],
                      algo: Algorithm, *, lr: float, k_max: int,
                      nu_decay: float = 0.0,
                      track_nu: str = "delta",
                      spmd_axis_name=None,
                      quantize_transmit: bool = False,
                      compression=None, spec=None, robust=None,
                      attack=None,
                      param_constraint: Optional[Callable[[PyTree, int],
                                                          PyTree]] = None):
    """The synchronous round over a sampled cohort of C ≤ M clients.

    ``round_fn(state, batches, cohort, k_steps, cweights, lam=None)`` —
    ``cohort`` is the (C,) int32 client-id draw (fed/population.py),
    ``batches``/``k_steps`` are cohort-indexed (leading C), ``cweights`` the
    renormalized w̃ (``ClientPopulation.cohort_weights``).  The server state
    stays population-sized: the cohort's ν⁽ⁱ⁾ rows are gathered on device,
    the k-step scan runs over the C axis, and updated rows scatter back.

    Aggregation is the pseudo-delta (Horvitz–Thompson) form
    ``x ← serveropt(x, Σ w̃_i (x⁽ⁱ⁾ − x))`` so Σ w̃ ≠ 1 stays unbiased, and
    ν mass-mixes exactly like the buffered-async engine:
    ``ν ← (1 − ρ) ν + (ρ/Σw̃) Σ w̃ transmitᵢ`` with ρ = min(Σw̃, 1) — at
    Σw̃ = 1 this is the synchronous update.  Non-participants' stale ν⁽ⁱ⁾
    rows decay toward the new global ν at rate ``nu_decay`` per round (their
    correction c⁽ⁱ⁾ = ν − ν⁽ⁱ⁾ → 0, i.e. cold clients degrade gracefully to
    plain local SGD); ``nu_decay=0`` keeps stale rows frozen.
    """
    client_update = make_client_update(
        loss_fn, algo, lr=lr, k_max=k_max, track_nu=track_nu,
        spmd_axis_name=spmd_axis_name)
    aggregate = BUFFERED_AGGREGATORS[algo.aggregator]
    cs = compress.build_stages(compression, spec, algo.uses_nu)
    rb = robust_mod.build_round_robust(robust, spec, algo.uses_nu)
    atk = attack if (attack is not None
                     and attack.corrupts_payload) else None
    if atk is not None and spec is None:
        raise ValueError("payload-corruption scenarios require a FlatSpec "
                         "— the engines build one on both param layouts")
    wire = cs is not None or rb is not None or atk is not None
    if wire:
        _rv, _rvr, _ur, _urr = _flat_bridge(spec)
        n_true = spec.n
    down_on = cs is not None and cs.down is not None
    up_on = cs is not None and cs.up is not None

    def constrain(tree, client_dims):
        if param_constraint is None:
            return tree
        return param_constraint(tree, client_dims)

    def round_fn(state: dict, batches: PyTree, cohort: jax.Array,
                 k_steps: jax.Array, cweights: jax.Array, lam=None):
        if lam is None:
            lam = algo.lam
        params0 = state["params"]
        c = cohort.shape[0]
        kf = k_steps.astype(jnp.float32)
        mass = jnp.sum(cweights)
        kbar = jnp.dot(cweights, kf) / mass          # cohort-weighted K̄
        new_state = dict(state)

        if down_on:
            bc_flat = cs.down(_rv(params0), state, new_state)
            anchor = _ur(bc_flat)
            nu_bc = (_ur(cs.down_nu(_rv(state["nu"]), state, new_state))
                     if algo.uses_nu else None)
        else:
            anchor = params0
            nu_bc = state["nu"] if algo.uses_nu else None

        if algo.uses_nu:
            # gather only the cohort's correction rows: compute is O(C)
            c_all = jax.tree.map(
                lambda nu, nui: (nu[None] - nui[cohort]) if nui.ndim
                else nu - nui, nu_bc, state["nu_i"])
        else:
            c_all = zero_corrections(params0, c)

        x_i, g0_i, acc_i, loss0 = client_update(anchor, c_all, batches,
                                                k_steps, lam)
        x_i = constrain(x_i, 1)

        # uplink compression: error-feedback rows gathered/scattered at
        # the cohort ids only — absentees' accumulators stay untouched
        w_agg = cweights
        if wire:
            a_flat = bc_flat if down_on else _rv(params0)
            d = _rvr(x_i) - a_flat[None]
            if atk is not None:
                d = atk.corrupt_delta(state["round"], d, n_true, ids=cohort)
            if up_on:
                d = cs.up(d, state, new_state, ids=cohort)
            if rb is not None:
                d, w_agg, qcount = rb.model(d, cweights, state, new_state,
                                            state["round"], cohort)
            x_srv = _urr(a_flat[None] + d)
        else:
            x_srv = x_i

        # pseudo-delta aggregation (unbiased under Σ w̃ ≠ 1): the buffered
        # aggregators with the shared broadcast as every client's anchor —
        # base = the TRUE master, deltas measured vs what clients received
        anchor1 = jax.tree.map(lambda p: p[None], anchor)
        agg = aggregate(params0, anchor1, x_srv, kf, w_agg, kbar)

        new_params = server_update(algo, state, params0, agg, new_state)
        new_params = constrain(new_params, 0)
        new_state["params"] = new_params
        new_state["round"] = state["round"] + 1

        if algo.uses_nu:
            transmit, avg_g = orientation_transmit(
                algo, anchor, x_i, g0_i, acc_i, c_all, kf, kbar, lr, lam,
                track_nu=track_nu, quantize_transmit=quantize_transmit)
            w_nu = cweights
            if wire and (up_on or atk is not None or rb is not None):
                t_rows = _rvr(transmit)
                if atk is not None:
                    t_rows = atk.corrupt_nu(state["round"], t_rows, n_true,
                                            ids=cohort)
                if up_on:
                    t_rows = cs.up_nu(t_rows, state, new_state, ids=cohort)
                if rb is not None:
                    # ν renorm preserves Σw̃ so ρ = min(mass, 1) below keeps
                    # its planned value; if the whole cohort is dropped,
                    # contrib = 0 and ν decays by (1 − ρ) toward zero — a
                    # safe calibration fade, never a poisoned mix
                    t_rows, w_nu = rb.nu(t_rows, cweights, state,
                                         state["round"], cohort)
                transmit = _urr(t_rows)
            contrib = tree_wsum(w_nu, transmit)
            new_nu = nu_mass_mix(state["nu"], contrib, mass)
            new_state["nu"] = constrain(new_nu, 0)
            new_state["nu_i"] = constrain(
                scatter_nu_rows(state["nu_i"], new_nu, avg_g, cohort,
                                nu_decay), 1)

        if rb is not None:
            new_state["params"] = rb.guard(new_state["params"], params0)
            if algo.uses_nu:
                new_state["nu"] = rb.guard(new_state["nu"], state["nu"])
                new_state["nu_i"] = rb.guard(new_state["nu_i"],
                                             state["nu_i"])

        metrics = {"loss": jnp.dot(cweights, loss0) / mass, "kbar": kbar,
                   "mass": mass}
        if rb is not None:
            metrics["quarantined"] = qcount
        return new_state, metrics

    return round_fn
