"""Federated optimizer zoo.

Every algorithm names a composition of the four round-engine stages
(core/stages.py — client update, aggregation, orientation, server
optimizer; DESIGN.md §2):

    local step   : x ← x − η (g + λ·(ν − ν⁽ⁱ⁾) [+ μ_prox (x − x̃_t)])
    aggregation  : weighted average (or FedNova normalized average)
    orientation  : what each client contributes to the next global ν

======================  λ    strategy    prox   normalize
FedAvg                  0    —           —      —
FedProx                 0    —           μ      —
FedNova                 0    —           —      yes
SCAFFOLD (=_avg)        1    avg         —      —
FedLin (approx.)        1    first       —      —
FedaGrac                λ    fedagrac    —      —
FedaGrac_first          λ    first       —      —
FedaGrac_reverse        λ    reverse     —      —

``strategy`` picks the transmitted gradient per client (paper §4.2):
fedagrac = fast clients (K_i > K̄) send the *first* stochastic gradient,
slow clients send the *averaged* gradient; ``reverse`` swaps them.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import FedConfig


@dataclasses.dataclass(frozen=True)
class Algorithm:
    name: str
    lam: float = 0.0               # calibration rate λ
    strategy: str = "none"         # none|avg|first|fedagrac|reverse
    prox_mu: float = 0.0
    normalize: bool = False        # FedNova-style normalized aggregation
    # FedOpt-style server optimizer (Reddi et al. 2021) applied to the
    # round pseudo-gradient Δ = x̃_t − Σ ω_i x_i: "sgd" (plain averaging),
    # "momentum" (FedAvgM) or "adam" (FedAdam).  Composes with every
    # client rule above — a beyond-paper extension (EXPERIMENTS.md).
    server_opt: str = "sgd"
    server_lr: float = 1.0
    server_beta1: float = 0.9

    @property
    def uses_nu(self) -> bool:
        return self.strategy != "none"

    # -- stage composition (core/stages.py registries, DESIGN.md §2) --------
    @property
    def aggregator(self) -> str:
        """Key into stages.AGGREGATORS / stages.BUFFERED_AGGREGATORS."""
        return "fednova" if self.normalize else "mean"

    @property
    def selector(self) -> str:
        """Key into stages.SELECTORS (orientation transmit choice)."""
        return self.strategy


def get_algorithm(name: str, fed: FedConfig) -> Algorithm:
    lam = fed.calibration_rate
    server = dict(server_opt=fed.server_opt, server_lr=fed.server_lr)
    table = {
        "fedavg": Algorithm("fedavg", **server),
        "fedprox": Algorithm("fedprox", prox_mu=fed.prox_mu, **server),
        "fednova": Algorithm("fednova", normalize=True, **server),
        "scaffold": Algorithm("scaffold", lam=1.0, strategy="avg", **server),
        "fedlin": Algorithm("fedlin", lam=1.0, strategy="first", **server),
        "fedagrac": Algorithm("fedagrac", lam=lam, strategy="fedagrac", **server),
        "fedagrac_avg": Algorithm("fedagrac_avg", lam=lam, strategy="avg", **server),
        "fedagrac_first": Algorithm("fedagrac_first", lam=lam,
                                    strategy="first", **server),
        "fedagrac_reverse": Algorithm("fedagrac_reverse", lam=lam,
                                      strategy="reverse", **server),
    }
    if name not in table:
        raise KeyError(f"unknown algorithm {name!r}; available: {sorted(table)}")
    return table[name]


ALGORITHMS = ("fedavg", "fedprox", "fednova", "scaffold", "fedlin",
              "fedagrac", "fedagrac_avg", "fedagrac_first", "fedagrac_reverse")
