"""Flat-parameter execution: single-buffer rounds (DESIGN.md §11).

The layered round (core/stages.py) runs every elementwise stage as a
``jax.tree.map`` chain — one XLA op group *per leaf* for the local update,
the aggregation einsum, the orientation recovery and the server step.  At
paper scale (small leaves, many of them) the round is op-count-bound, not
FLOP-bound, and the fused Pallas calibrated-update kernels
(kernels/calibrated_update/) were dead code in training.

This module collapses the model pytree to ONE contiguous lane-padded
buffer and runs the *entire* round on flat state:

* server vectors (params, ν, server_m/v) are ``(P,)`` buffers, per-client
  state (ν⁽ⁱ⁾, round-local x⁽ⁱ⁾/g₀⁽ⁱ⁾) are ``(M, P)`` matrices, with
  ``P = ceil(n / 128) · 128`` so the matrices feed the Pallas kernels
  directly (``kernel.LANES`` lane padding, zeros in the tail — every stage
  below is padding-preserving, so the tail stays exactly zero);
* the client k-step scan calls ``calibrated_update_2d`` /
  ``calibrated_update_prox_2d`` once per local step on the whole ``(M, P)``
  matrix — one fused launch instead of ``num_leaves`` tree_map dispatches —
  with the K_i masking and ν/g₀ accumulation as flat row ops;
* aggregation, orientation, ν mass-mix and the server optimizer REUSE the
  stage registries verbatim: the stage functions are pytree-polymorphic
  (``jax.tree.map`` over a bare array is the identity traversal), so a
  ``(M, P)`` matrix flows through ``AGGREGATORS`` / ``SELECTORS`` /
  ``SERVER_OPTIMIZERS`` as a one-leaf tree and every per-leaf einsum
  becomes a single ``(M, P)``-row einsum;
* the loss boundary is **flat-native** (DESIGN.md §13): the model apply
  consumes per-leaf *views* of the buffer — ``view_tree`` slices each leaf
  at its spec offset (``FlatSpec.offsets``, the view table) and casts to
  the leaf dtype — and ``flat_value_and_grad`` differentiates with respect
  to the views, accumulating the leaf cotangents straight back into ONE
  ``(P,)`` buffer (``flat_cotangent``, a region-write chain).  The round
  never holds the parameter tree as a value: the caller sees only the
  buffer, and a mixed-precision run (``master_dtype``) keeps the master
  buffer in f32 while every view — and therefore all model compute — is
  bf16, the cast riding the boundary slice instead of a separate pass.

Numerics: every stage performs the same elementwise arithmetic in the
same order as the tree round, only on a different memory layout.  The
agreement is golden-pinned by tests/test_flat_layout.py for all nine
algorithms on both engines at ULP scale: XLA contracts ``x − η·g`` into
an FMA (one rounding) in one program layout and not the other — an
LLVM fusion-context decision no jnp-level structuring controls — so f32
trajectories agree to ~1 ulp per local step rather than bit-for-bit
(verified: the tree path matches the fused-multiply-add reference, the
flat path the two-rounding one; same asymmetry test_calibrated_update_2d
documents).  In bf16 the kernels additionally accumulate in f32 and round
once at the end where the tree path rounds per op — one bf16 ulp.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress, stages
from repro.core import robust as robust_mod
from repro.core.fedopt import Algorithm
from repro.core.tree_util import tree_wsum
from repro.kernels.calibrated_update import ref as cu_ref
from repro.kernels.calibrated_update.kernel import (LANES,
                                                    calibrated_update_2d,
                                                    calibrated_update_prox_2d)
from repro.kernels.quantize import ops as qops

PyTree = Any


# ---------------------------------------------------------------------------
# layout spec + ravel / unravel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static description of the tree ↔ flat-buffer bijection.

    ``n`` true elements, lane-padded to ``p`` (multiple of kernel.LANES);
    ``dtype`` is the shared buffer dtype — the common leaf dtype when the
    tree is uniform (bf16 state stays bf16-sized), f32 otherwise, or the
    explicit ``master_dtype`` override (mixed precision: f32 master buffer
    over bf16 leaves, DESIGN.md §13).

    ``(offsets, shapes, dtypes, sizes)`` together form the **view table**:
    leaf *i* of the tree is ``flat[…, offsets[i] : offsets[i] + sizes[i]]``
    reshaped to ``shapes[i]`` and cast to ``dtypes[i]``.  Offsets are
    static, lane-padding lives entirely in the tail ``[n, p)`` — no view
    ever overlaps the pad, so padding-preserving stages keep it zero.
    """
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    sizes: tuple[int, ...]
    treedef: Any
    n: int
    p: int
    dtype: Any
    offsets: tuple[int, ...] = ()

    def __post_init__(self):
        if not self.offsets and self.sizes:
            # derive the view table for specs built positionally (older
            # call sites / tests): cumulative leaf offsets
            object.__setattr__(
                self, "offsets",
                tuple(int(o) for o in
                      np.concatenate([[0], np.cumsum(self.sizes)[:-1]])))


def make_flat_spec(tree: PyTree,
                   master_dtype: Optional[Any] = None) -> FlatSpec:
    """Build the spec from a concrete or abstract (eval_shape'd) tree.

    ``master_dtype`` overrides the buffer dtype (the *master* copy all
    round state lives in) without touching the per-leaf view dtypes — the
    mixed-precision configuration is bf16 leaves + f32 master: views read
    bf16, updates apply at f32, one rounding per boundary crossing."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(lv.shape) for lv in leaves)
    dtypes = tuple(jnp.dtype(lv.dtype) for lv in leaves)
    sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
    offsets = tuple(int(o) for o in
                    np.concatenate([[0], np.cumsum(sizes)[:-1]])
                    ) if sizes else ()
    n = int(sum(sizes))
    p = -(-max(n, 1) // LANES) * LANES
    if master_dtype is not None:
        dtype = jnp.dtype(master_dtype)
    else:
        dtype = dtypes[0] if all(d == dtypes[0] for d in dtypes) \
            else jnp.dtype(jnp.float32)
    return FlatSpec(shapes, dtypes, sizes, treedef, n, p, dtype, offsets)


def ravel(spec: FlatSpec, tree: PyTree, client_dims: int = 0) -> jax.Array:
    """Concat all leaves into ``(*lead, P)`` — ``client_dims`` leading axes
    (client / round stacking) are preserved; the tail pads with zeros."""
    leaves = jax.tree_util.tree_flatten(tree)[0]
    lead = tuple(leaves[0].shape[:client_dims])
    flat = jnp.concatenate(
        [lv.astype(spec.dtype).reshape(lead + (-1,)) for lv in leaves],
        axis=-1)
    if spec.p != spec.n:
        pad = jnp.zeros(lead + (spec.p - spec.n,), spec.dtype)
        flat = jnp.concatenate([flat, pad], axis=-1)
    return flat


def ravel_rows(spec: FlatSpec, tree: PyTree) -> jax.Array:
    """``ravel(spec, tree, client_dims=1)`` for the in-scan hot path,
    built from a ``dynamic_update_slice`` chain instead of one
    ``concatenate``: XLA:CPU fuses a multi-operand concat with its
    producers into per-element multi-way index selection (~5× the memcpy
    cost, measured on the round benchmark), while the DUS chain aliases
    the output buffer and lowers to one region write per leaf."""
    leaves = jax.tree_util.tree_flatten(tree)[0]
    m = leaves[0].shape[0]
    buf = jnp.zeros((m, spec.p), spec.dtype)
    off = 0
    for lv in leaves:
        rows = lv.astype(spec.dtype).reshape(m, -1)
        buf = jax.lax.dynamic_update_slice(buf, rows, (0, off))
        off += rows.shape[1]
    return buf


def unravel(spec: FlatSpec, flat: jax.Array, client_dims: int = 0) -> PyTree:
    """Inverse of ``ravel``: static slices + reshapes back to leaf dtypes
    (free at the loss boundary — XLA fuses slices of a contiguous buffer)."""
    lead = tuple(flat.shape[:client_dims])
    leaves, off = [], 0
    for shape, dtype, size in zip(spec.shapes, spec.dtypes, spec.sizes):
        piece = jax.lax.slice_in_dim(flat, off, off + size, axis=-1)
        leaves.append(piece.reshape(lead + shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


# ---------------------------------------------------------------------------
# view table: flat-native model apply (DESIGN.md §13)
# ---------------------------------------------------------------------------

def leaf_view(spec: FlatSpec, flat: jax.Array, i: int,
              client_dims: int = 0) -> jax.Array:
    """Leaf ``i`` as a view of the buffer: ``dynamic_slice`` at the view
    table's static offset, reshaped to the leaf shape and cast to the leaf
    dtype.  A contiguous slice of a contiguous buffer reshapes without
    moving data, so XLA folds the view into its consumer."""
    lead = tuple(flat.shape[:client_dims])
    piece = jax.lax.dynamic_slice_in_dim(flat, spec.offsets[i],
                                         spec.sizes[i], axis=-1)
    return piece.reshape(lead + spec.shapes[i]).astype(spec.dtypes[i])


def view_tree(spec: FlatSpec, flat: jax.Array,
              client_dims: int = 0) -> PyTree:
    """The model pytree as per-leaf VIEWS of the flat buffer — what the
    apply function consumes in place of real parameters.  Numerically this
    is ``unravel``; structurally it is the read half of the flat-native
    loss boundary: ``flat_value_and_grad`` differentiates with respect to
    these views (never through the slices), so the round's only tree is
    the transient one inside the loss jaxpr."""
    leaves = [leaf_view(spec, flat, i, client_dims)
              for i in range(len(spec.sizes))]
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def flat_cotangent(spec: FlatSpec, tree: PyTree,
                   client_dims: int = 0) -> jax.Array:
    """Accumulate per-leaf cotangents into ONE ``(*lead, P)`` buffer at the
    master dtype — the write half of the flat-native boundary.  A
    ``dynamic_update_slice`` chain (one region write per leaf, the
    ``ravel_rows`` rationale) rather than the slice-transpose pad+add
    chain ``jax.grad``-through-``view_tree`` would emit; the pad tail
    stays exactly zero."""
    leaves = jax.tree_util.tree_flatten(tree)[0]
    lead = tuple(leaves[0].shape[:client_dims])
    buf = jnp.zeros(lead + (spec.p,), spec.dtype)
    zeros = (0,) * len(lead)
    for lv, off in zip(leaves, spec.offsets):
        rows = lv.astype(spec.dtype).reshape(lead + (-1,))
        buf = jax.lax.dynamic_update_slice(buf, rows, zeros + (off,))
    return buf


def flat_apply(spec: FlatSpec, apply_fn: Callable, flat_params: jax.Array,
               *args, client_dims: int = 0, **kwargs):
    """Run a tree-signature model function on the flat buffer:
    ``apply_fn(params_tree, *args, **kwargs)`` with ``params_tree`` the
    view table's slices of ``flat_params`` — e.g.
    ``flat_apply(spec, functools.partial(lm_loss, cfg=cfg), buf, batch)``.
    The caller never materializes or owns the tree."""
    return apply_fn(view_tree(spec, flat_params, client_dims), *args,
                    **kwargs)


def flat_value_and_grad(spec: FlatSpec,
                        loss_fn: Callable[[PyTree, PyTree], jax.Array]):
    """``vag(flat_row, batch) -> (loss, flat_grad_row)`` — the flat-native
    ``value_and_grad``: loss evaluated on buffer views, gradient returned
    as one ``(P,)`` cotangent buffer.

    Differentiation is with respect to the *views* (the tree), not the
    buffer: the boundary slices/casts sit outside the differentiated
    function, so their transposes (per-leaf pad+add on the full buffer)
    never appear; the cotangent re-enters the flat layout through
    ``flat_cotangent``'s region writes.  With leaf dtype == master dtype
    this is op-for-op the classic unravel→grad→ravel boundary (the golden
    pins hold bit-for-bit); under ``master_dtype`` mixed precision the
    view cast is the ONLY f32→bf16 crossing and the cotangent accumulates
    at master (f32) precision."""
    vag = jax.value_and_grad(loss_fn)

    def run(flat_row: jax.Array, batch: PyTree):
        loss, g = vag(view_tree(spec, flat_row), batch)
        return loss, flat_cotangent(spec, g)

    return run


def quantize_int8_flat(spec: FlatSpec, mat: jax.Array) -> jax.Array:
    """``stages.quantize_int8`` natively on ``(M, P)`` rows: the scale is
    per-client-per-LEAF, so each view-table segment quantizes against its
    own row-wise amax — segment slices in, region writes out, keeping the
    exact tree semantics (amax is order-exact; the round/scale arithmetic
    runs in f32 and re-rounds through the leaf dtype) without the
    unravel→quantize→ravel tree round-trip the flat transmit used to pay.
    The pad tail is untouched (zeros), and each segment's amax runs through
    the shared masked reduction (``qops.row_scales``) so no scale can ever
    see a column outside its leaf's true extent."""
    m = mat.shape[0]
    out = jnp.zeros((m, spec.p), spec.dtype)
    for off, size, dtype in zip(spec.offsets, spec.sizes, spec.dtypes):
        seg = jax.lax.dynamic_slice_in_dim(mat, off, size, axis=-1)
        a = seg.astype(dtype)                       # the tree path's leaf
        af = a.astype(jnp.float32)
        scale = qops.row_scales(af, size, 127)
        q = (jnp.round(af / scale) * scale).astype(dtype)
        out = jax.lax.dynamic_update_slice(
            out, q.astype(spec.dtype), (0, off))
    return out


def flatten_state(spec: FlatSpec, state: dict) -> dict:
    """Tree round state → flat round state (same keys; params/ν/server
    moments become (P,) buffers, ν⁽ⁱ⁾ an (M, P) matrix).  Compression
    residuals / broadcast carries (``compress.FLAT_STATE_KEYS``) are
    flat-NATIVE on both layouts — the tree round compresses through the
    view table — so they pass through unchanged; the (M,) client-health
    vectors (``robust.ROBUST_STATE_KEYS``) are layout-independent and do
    the same."""
    out = {}
    for k, v in state.items():
        if (k == "round" or k in compress.FLAT_STATE_KEYS
                or k in robust_mod.ROBUST_STATE_KEYS):
            out[k] = v
        elif k == "nu_i":
            out[k] = ravel(spec, v, client_dims=1)
        else:
            out[k] = ravel(spec, v)
    return out


def unflatten_state(spec: FlatSpec, state: dict) -> dict:
    out = {}
    for k, v in state.items():
        if (k == "round" or k in compress.FLAT_STATE_KEYS
                or k in robust_mod.ROBUST_STATE_KEYS):
            out[k] = v
        elif k == "nu_i":
            out[k] = unravel(spec, v, client_dims=1)
        else:
            out[k] = unravel(spec, v)
    return out


def _use_pallas_default(use_pallas: Optional[bool]) -> bool:
    """The Pallas kernels are the TPU hot path; elsewhere the flat update
    runs the kernels package's jnp oracle — ONE fused XLA op on the flat
    buffer, bitwise-equal to the kernel (same convention as
    ``ops.calibrated_update_tree``; interpret-mode Pallas lowers to ~19
    HLO ops of grid bookkeeping, pure overhead inside a scanned round)."""
    return jax.default_backend() == "tpu" if use_pallas is None \
        else use_pallas


# ---------------------------------------------------------------------------
# stage 1 (flat): the kernel-backed client k-step scan
# ---------------------------------------------------------------------------

def make_flat_client_update(spec: FlatSpec,
                            loss_fn: Callable[[PyTree, PyTree], jax.Array],
                            algo: Algorithm, *, lr: float, k_max: int,
                            track_nu: str = "delta",
                            use_pallas: Optional[bool] = None,
                            interpret: Optional[bool] = None,
                            per_client_anchor: bool = False):
    """Flat analogue of ``stages.make_client_update``: ``f(anchor, c_all,
    batches, k_steps, lam) -> (x_i, g0_i, acc_i, loss0)`` on (M, P) rows.
    ``c_all`` is ignored for algorithms without ν.

    The k-scan runs directly on the (M, P) matrix and each local step is
    ONE fused calibrated-update launch instead of ``num_leaves`` tree_map
    dispatches — the Pallas kernel on TPU (``use_pallas``), its jnp
    oracle with the K_i mask folded in as a per-row step size elsewhere
    (interpret-mode Pallas lowers to ~19 HLO ops of grid bookkeeping,
    pure overhead inside a scanned round).  The per-step loss boundary is
    flat-native: ``flat_value_and_grad`` evaluates the loss on view-table
    slices of the row and returns the gradient as a (P,) cotangent buffer
    — the tree exists only inside the loss jaxpr (DESIGN.md §13).

    The per-row η mask doubles as the **effective-steps mask** of
    partial-work recovery (fed/scenarios.py, DESIGN.md §12): a mid-round
    dropout's k′ < K_i arrives as ``k_steps`` and rows past the abort get
    η = 0 — the flat path needs no separate fault machinery, matching the
    tree path's scan-length mask bit-for-bit at the same k′.
    """
    use_pallas = _use_pallas_default(use_pallas)
    needs_first = algo.selector in ("fedagrac", "first", "reverse")
    uses_nu = algo.uses_nu
    # the tree path adds the prox term into g BEFORE the g₀ select and the
    # explicit-ν accumulation (stages.make_client_update); when either
    # consumer exists the flat path must augment g the same way and use
    # the PLAIN update — fusing prox into the kernel is only valid when
    # nothing downstream reads the gradient (the FedProx-style baselines)
    fuse_prox = bool(algo.prox_mu) and not (
        needs_first or (track_nu == "explicit" and uses_nu))

    if use_pallas:
        interpret = (jax.default_backend() != "tpu" if interpret is None
                     else interpret)

        def masked_update(x, g, c, anchors, k, k_steps, lam):
            if fuse_prox:
                upd = calibrated_update_prox_2d(x, g, c, anchors, lr, lam,
                                                algo.prox_mu,
                                                interpret=interpret)
            else:
                upd = calibrated_update_2d(x, g, c, lr, lam,
                                           interpret=interpret)
            return jnp.where((k < k_steps)[:, None], upd, x)
    else:
        def masked_update(x, g, c, anchors, k, k_steps, lam):
            """Oracle with the K_i mask FOLDED into the update as a
            per-row step size η_i ∈ {η, 0}: an inactive row computes
            x − 0·(…) = x exactly (finite operands), so the separate
            (M, P) select — one extra full-state write per local step —
            disappears.  Same f32-internal arithmetic as the kernel."""
            eta = jnp.where(k < k_steps, jnp.float32(lr), 0.0)[:, None]
            xf = x.astype(jnp.float32)
            t = g.astype(jnp.float32)
            if uses_nu:
                t = t + lam * c.astype(jnp.float32)
            if fuse_prox:
                t = t + algo.prox_mu * (xf - anchors.astype(jnp.float32))
            return (xf - eta * t).astype(x.dtype)

    # flat-native loss boundary (DESIGN.md §13): losses on buffer VIEWS,
    # gradients straight back as (M, P) cotangent rows — the round never
    # holds the parameter tree, and under master_dtype mixed precision the
    # view cast is the only master→compute crossing
    grad_fn = jax.vmap(flat_value_and_grad(spec, loss_fn))

    def run(anchor, c_all, batches, k_steps, lam):
        m = k_steps.shape[0]
        anchors = (anchor if per_client_anchor
                   else jnp.broadcast_to(anchor[None], (m, spec.p)))
        # λ multiplies a zero c for ν-free algorithms — bake λ = 0 so the
        # kernel's λ·c term vanishes exactly (x − η(g + 0) ≡ x − ηg)
        lam_k = lam if uses_nu else 0.0
        c_k = (c_all if uses_nu
               else jnp.zeros((m, spec.p), spec.dtype))
        # (M, k_max, …) → (k_max, M, …): scan over local steps, whole
        # client axis per step (same order the vmapped tree scan lowers to)
        bk = jax.tree.map(lambda b: jnp.swapaxes(b, 0, 1), batches)

        def step(carry, xs):
            k, batch_k = xs
            x, g0, nu_acc = carry
            loss, g = grad_fn(x, batch_k)
            if algo.prox_mu and not fuse_prox:
                g = g + algo.prox_mu * (x - anchors)
            x = masked_update(x, g, c_k, anchors, k, k_steps, lam_k)
            if needs_first:
                g0 = jnp.where(k == 0, g, g0)
            if track_nu == "explicit" and uses_nu:
                w = jnp.where(k < k_steps,
                              1.0 / k_steps.astype(jnp.float32), 0.0)
                nu_acc = nu_acc + w[:, None] * g
            return (x, g0, nu_acc), loss

        if k_max == 1:
            # single-local-step rounds (FedSGD-style comm-bound regime):
            # no scan and no g₀ select — every client runs exactly its
            # one step (K_i ≥ 1), g₀ IS the only gradient
            b0 = jax.tree.map(lambda b: b[0], bk)
            loss, g = grad_fn(anchors, b0)
            # unfused prox needs no augmentation here: x ≡ x₀ at k = 0, so
            # the prox term μ(x − x₀) is exactly zero (as on the tree path)
            x = masked_update(anchors, g, c_k, anchors, jnp.int32(0),
                              k_steps, lam_k)
            g0 = g if needs_first else jnp.zeros(())
            if track_nu == "explicit" and uses_nu:
                w = 1.0 / k_steps.astype(jnp.float32)    # same rounding as
                nu_acc = w[:, None] * g                  # the in-scan path
            else:
                nu_acc = jnp.zeros(())
            return x, g0, nu_acc, loss

        g0_0 = (jnp.zeros((m, spec.p), spec.dtype) if needs_first
                else jnp.zeros(()))
        acc_0 = (jnp.zeros((m, spec.p), spec.dtype)
                 if (track_nu == "explicit" and uses_nu) else jnp.zeros(()))
        (x, g0, nu_acc), losses = jax.lax.scan(
            step, (anchors, g0_0, acc_0), (jnp.arange(k_max), bk))
        return x, g0, nu_acc, losses[0]

    return run


def _flat_transmit(spec: FlatSpec, algo: Algorithm, params0, x_i, g0_i,
                   acc_i, c_all, kf, kbar, lr, lam, *,
                   track_nu: str = "delta", quantize_transmit: bool = False,
                   anchor_i=None):
    """``stages.orientation_transmit`` on flat matrices.  The stage
    functions are array-polymorphic so this is a thin wrapper — except
    int8 fake-quantization, whose scale is per-client-per-LEAF:
    ``quantize_int8_flat`` runs it segment-wise on the view table (exact
    tree semantics, no unravel→ravel round-trip)."""
    if quantize_transmit:
        if track_nu == "explicit":
            avg_g = acc_i
        else:
            avg_g = stages.recover_avg_grad(params0, x_i, c_all, kf, lr,
                                            lam, anchor_i=anchor_i)
        transmit = stages.SELECTORS[algo.selector](
            g0_i, avg_g, stages.fast_mask(kf, kbar))
        transmit = quantize_int8_flat(spec, transmit)
        return transmit, avg_g
    return stages.orientation_transmit(
        algo, params0, x_i, g0_i, acc_i, c_all, kf, kbar, lr, lam,
        track_nu=track_nu, anchor_i=anchor_i)


# ---------------------------------------------------------------------------
# composition: the flat synchronous round
# ---------------------------------------------------------------------------

def make_flat_round(spec: FlatSpec,
                    loss_fn: Callable[[PyTree, PyTree], jax.Array],
                    algo: Algorithm, *, lr: float, k_max: int,
                    track_nu: str = "delta",
                    quantize_transmit: bool = False,
                    compression=None, robust=None, attack=None,
                    use_pallas: Optional[bool] = None,
                    param_constraint: Optional[Callable[[jax.Array, int],
                                                        jax.Array]] = None):
    """Flat twin of ``stages.make_layered_round``: same signature
    ``round_fn(state, batches, k_steps, weights, lam=None)``, state leaves
    flat (``flatten_state``).  Aggregation / orientation / server-opt call
    the SAME registry functions as the tree round — on one (M, P) leaf.
    The compression stage (core/compress.py) — and likewise the
    corruption/defense bracket (``attack``/``robust``, DESIGN.md §16) —
    is flat-NATIVE here: every transmitted quantity already lives on
    (rows, P), so the codecs apply with no ravel bridge."""
    client_update = make_flat_client_update(
        spec, loss_fn, algo, lr=lr, k_max=k_max, track_nu=track_nu,
        use_pallas=use_pallas)
    aggregate = stages.AGGREGATORS[algo.aggregator]
    cs = compress.build_stages(compression, spec, algo.uses_nu,
                               use_pallas=use_pallas)
    rb = robust_mod.build_round_robust(robust, spec, algo.uses_nu)
    atk = attack if (attack is not None
                     and attack.corrupts_payload) else None
    wire = cs is not None or rb is not None or atk is not None
    down_on = cs is not None and cs.down is not None
    up_on = cs is not None and cs.up is not None

    def constrain(arr, client_dims):
        if param_constraint is None:
            return arr
        return param_constraint(arr, client_dims)

    def round_fn(state: dict, batches: PyTree, k_steps: jax.Array,
                 weights: jax.Array, lam=None):
        if lam is None:
            lam = algo.lam
        params0 = state["params"]                          # (P,)
        kbar = jnp.dot(weights, k_steps.astype(jnp.float32))
        new_state = dict(state)

        if down_on:
            anchor = cs.down(params0, state, new_state)
            nu_bc = (cs.down_nu(state["nu"], state, new_state)
                     if algo.uses_nu else None)
        else:
            anchor = params0
            nu_bc = state["nu"] if algo.uses_nu else None

        c_all = (nu_bc[None] - state["nu_i"]
                 if algo.uses_nu else None)                # (M, P)

        x_i, g0_i, acc_i, loss0 = client_update(anchor, c_all, batches,
                                                k_steps, lam)
        x_i = constrain(x_i, 1)
        kf = k_steps.astype(jnp.float32)

        w_agg = weights
        if wire:
            d = x_i - anchor[None]
            if atk is not None:
                d = atk.corrupt_delta(state["round"], d, spec.n,
                                      ids=jnp.arange(x_i.shape[0],
                                                     dtype=jnp.int32))
            if up_on:
                d = cs.up(d, state, new_state)
            if rb is not None:
                d, w_agg, qcount = rb.model(
                    d, weights, state, new_state, state["round"],
                    jnp.arange(x_i.shape[0], dtype=jnp.int32))
            x_srv = anchor[None] + d
        else:
            x_srv = x_i

        agg = aggregate(anchor, x_srv, kf, w_agg, kbar)
        if down_on:
            # clients averaged around the broadcast x̂; re-base the result
            # onto the TRUE master so downlink error never accumulates
            # into the server trajectory: x⁺ = x + (agg − x̂)
            agg = (params0.astype(jnp.float32) + agg.astype(jnp.float32)
                   - anchor.astype(jnp.float32)).astype(spec.dtype)
        new_params = stages.server_update(algo, state, params0, agg,
                                          new_state)
        new_params = constrain(new_params, 0)
        new_state["params"] = new_params
        new_state["round"] = state["round"] + 1

        if algo.uses_nu:
            transmit, avg_g = _flat_transmit(
                spec, algo, anchor, x_i, g0_i, acc_i, c_all, kf, kbar, lr,
                lam, track_nu=track_nu,
                quantize_transmit=quantize_transmit)
            w_nu = weights
            if atk is not None:
                transmit = atk.corrupt_nu(
                    state["round"], transmit, spec.n,
                    ids=jnp.arange(x_i.shape[0], dtype=jnp.int32))
            if up_on:
                transmit = cs.up_nu(transmit, state, new_state)
            if rb is not None:
                transmit, w_nu = rb.nu(
                    transmit, weights, state, state["round"],
                    jnp.arange(x_i.shape[0], dtype=jnp.int32))
            new_state["nu"] = constrain(tree_wsum(w_nu, transmit), 0)
            new_state["nu_i"] = constrain(avg_g, 1)

        if rb is not None:
            new_state["params"] = rb.guard(new_state["params"], params0)
            if algo.uses_nu:
                new_state["nu"] = rb.guard(new_state["nu"], state["nu"])
                new_state["nu_i"] = rb.guard(new_state["nu_i"],
                                             state["nu_i"])

        metrics = {"loss": jnp.dot(weights, loss0), "kbar": kbar}
        if rb is not None:
            metrics["quarantined"] = qcount
        return new_state, metrics

    return round_fn


# ---------------------------------------------------------------------------
# composition: the flat cohort round (partial participation)
# ---------------------------------------------------------------------------

def make_flat_cohort_round(spec: FlatSpec,
                           loss_fn: Callable[[PyTree, PyTree], jax.Array],
                           algo: Algorithm, *, lr: float, k_max: int,
                           nu_decay: float = 0.0,
                           track_nu: str = "delta",
                           quantize_transmit: bool = False,
                           compression=None, robust=None, attack=None,
                           use_pallas: Optional[bool] = None,
                           param_constraint: Optional[Callable] = None):
    """Flat twin of ``stages.make_cohort_round``: the cohort's ν⁽ⁱ⁾ gather
    and the post-round scatter are pure ROW indexing on the (M_pop, P)
    matrix — no per-leaf gather chains (DESIGN.md §10, §11).  Uplink
    error-feedback rows gather/scatter at the cohort ids, so absentees'
    residuals wait untouched for their next report."""
    client_update = make_flat_client_update(
        spec, loss_fn, algo, lr=lr, k_max=k_max, track_nu=track_nu,
        use_pallas=use_pallas)
    aggregate = stages.BUFFERED_AGGREGATORS[algo.aggregator]
    cs = compress.build_stages(compression, spec, algo.uses_nu,
                               use_pallas=use_pallas)
    rb = robust_mod.build_round_robust(robust, spec, algo.uses_nu)
    atk = attack if (attack is not None
                     and attack.corrupts_payload) else None
    wire = cs is not None or rb is not None or atk is not None
    down_on = cs is not None and cs.down is not None
    up_on = cs is not None and cs.up is not None

    def constrain(arr, client_dims):
        if param_constraint is None:
            return arr
        return param_constraint(arr, client_dims)

    def round_fn(state: dict, batches: PyTree, cohort: jax.Array,
                 k_steps: jax.Array, cweights: jax.Array, lam=None):
        if lam is None:
            lam = algo.lam
        params0 = state["params"]
        kf = k_steps.astype(jnp.float32)
        mass = jnp.sum(cweights)
        kbar = jnp.dot(cweights, kf) / mass
        new_state = dict(state)

        if down_on:
            anchor = cs.down(params0, state, new_state)
            nu_bc = (cs.down_nu(state["nu"], state, new_state)
                     if algo.uses_nu else None)
        else:
            anchor = params0
            nu_bc = state["nu"] if algo.uses_nu else None

        c_all = (nu_bc[None] - state["nu_i"][cohort]
                 if algo.uses_nu else None)                # (C, P) rows

        x_i, g0_i, acc_i, loss0 = client_update(anchor, c_all, batches,
                                                k_steps, lam)
        x_i = constrain(x_i, 1)

        w_agg = cweights
        if wire:
            d = x_i - anchor[None]
            if atk is not None:
                d = atk.corrupt_delta(state["round"], d, spec.n, ids=cohort)
            if up_on:
                d = cs.up(d, state, new_state, ids=cohort)
            if rb is not None:
                d, w_agg, qcount = rb.model(d, cweights, state, new_state,
                                            state["round"], cohort)
            x_srv = anchor[None] + d
        else:
            x_srv = x_i

        # buffered aggregator takes base and anchors separately: base is
        # the TRUE master, deltas measured vs the broadcast — no re-base
        agg = aggregate(params0, anchor[None], x_srv, kf, w_agg, kbar)
        new_params = stages.server_update(algo, state, params0, agg,
                                          new_state)
        new_params = constrain(new_params, 0)
        new_state["params"] = new_params
        new_state["round"] = state["round"] + 1

        if algo.uses_nu:
            transmit, avg_g = _flat_transmit(
                spec, algo, anchor, x_i, g0_i, acc_i, c_all, kf, kbar, lr,
                lam, track_nu=track_nu,
                quantize_transmit=quantize_transmit)
            w_nu = cweights
            if atk is not None:
                transmit = atk.corrupt_nu(state["round"], transmit, spec.n,
                                          ids=cohort)
            if up_on:
                transmit = cs.up_nu(transmit, state, new_state, ids=cohort)
            if rb is not None:
                transmit, w_nu = rb.nu(transmit, cweights, state,
                                       state["round"], cohort)
            contrib = tree_wsum(w_nu, transmit)
            new_nu = stages.nu_mass_mix(state["nu"], contrib, mass)
            new_state["nu"] = constrain(new_nu, 0)
            new_state["nu_i"] = constrain(
                stages.scatter_nu_rows(state["nu_i"], new_nu, avg_g,
                                       cohort, nu_decay), 1)

        if rb is not None:
            new_state["params"] = rb.guard(new_state["params"], params0)
            if algo.uses_nu:
                new_state["nu"] = rb.guard(new_state["nu"], state["nu"])
                new_state["nu_i"] = rb.guard(new_state["nu_i"],
                                             state["nu_i"])

        metrics = {"loss": jnp.dot(cweights, loss0) / mass, "kbar": kbar,
                   "mass": mass}
        if rb is not None:
            metrics["quarantined"] = qcount
        return new_state, metrics

    return round_fn
