"""Closed-form predictions used to validate the implementation against the
paper's theory (Theorem 1 / Theorem 3) on deterministic quadratics.

F_i(x) = ½‖A_i x − b_i‖² has Hessian H_i = A_iᵀA_i and local optimum
x*_i = H_i⁻¹ A_iᵀ b_i.  With exact gradients, K_i local GD steps are the
affine map  x ↦ P_i x + (I − P_i) x*_i,  P_i = (I − ηH_i)^{K_i}.  FedAvg's
round map is the ω-average of these affine maps, whose fixed point is

    x̃_∞ = (I − Σ ω_i P_i)⁻¹ Σ ω_i (I − P_i) x*_i .

Theorem 1 says x̃_∞ ≠ x* exactly when step asynchronism (K_i ≠ K_j) meets
data heterogeneity (x*_i ≠ x*_j); tests/benchmarks assert both the fixed
point of the *simulated* FedAvg and FedaGrac's convergence to the true x*.
"""
from __future__ import annotations

import numpy as np


def local_optimum(A: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.linalg.solve(A.T @ A, A.T @ b)


def global_optimum(As, bs, weights) -> np.ndarray:
    H = sum(w * A.T @ A for w, A in zip(weights, As))
    g = sum(w * A.T @ b for w, A, b in zip(weights, As, bs))
    return np.linalg.solve(H, g)


def fedavg_fixed_point(As, bs, weights, k_steps, lr: float) -> np.ndarray:
    """Exact fixed point of FedAvg-with-step-asynchronism on quadratics."""
    d = As[0].shape[1]
    I = np.eye(d)
    M_sum = np.zeros((d, d))
    v_sum = np.zeros(d)
    for w, A, b, k in zip(weights, As, bs, k_steps):
        H = A.T @ A
        P = np.linalg.matrix_power(I - lr * H, int(k))
        x_loc = local_optimum(A, b)
        M_sum += w * P
        v_sum += w * (I - P) @ x_loc
    return np.linalg.solve(I - M_sum, v_sum)


def objective_inconsistency_rhs(As, bs, weights, k_steps,
                                x_star: np.ndarray) -> float:
    """RHS of Theorem 1 (up to the O(·) constant):
    Σ_i ω_i (K_i/K_min − 1) F_i(x*)."""
    k_min = min(k_steps)
    total = 0.0
    for w, A, b, k in zip(weights, As, bs, k_steps):
        r = A @ x_star - b
        total += w * (k / k_min - 1.0) * 0.5 * float(r @ r)
    return total


def suboptimality(As, bs, weights, x: np.ndarray, x_star: np.ndarray) -> float:
    """F(x) − F(x*)."""
    def F(v):
        return sum(0.5 * w * float((A @ v - b) @ (A @ v - b))
                   for w, A, b in zip(weights, As, bs))
    return F(x) - F(x_star)
