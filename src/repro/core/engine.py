"""Device-resident chunked execution (DESIGN.md §9).

Both execution engines were host-driven Python loops: one jit dispatch, one
host sync and (for host batchers) one dataset gather + transfer per round.
At paper scale (small models, many rounds) that makes every benchmark
dispatch-bound rather than compute-bound.  ``make_round_chunk`` moves the
round *loop* onto the device: R rounds run inside one jitted ``lax.scan``
with donated carry state, stacked per-round inputs, and per-round metrics
returned as ``(R,)`` arrays — the host syncs only at chunk boundaries
(the eval cadence).

The scan body is the unmodified layered round (core/stages.py), so a chunk
of R rounds is bit-identical to R sequential ``jit(round_fn)`` calls —
pinned for all nine algorithms by tests/test_golden_equivalence.py.

With ``sample_fn`` (a traceable ``t -> batches`` sampler, e.g.
``DeviceBatcher.sample``), batch *generation* also moves inside the scan:
the stacked-batches input degenerates to the ``(R,)`` round indices and the
chunk reads no host data at all.

The chunk is LAYOUT-agnostic: ``state`` may be the per-leaf tree round
state or the flat single-buffer state of core/flat.py (DESIGN.md §11) —
donation then reuses one (P,)/(M, P) buffer per state entry across chunk
calls, the cheapest possible carry (no per-leaf buffer bookkeeping).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax

PyTree = Any


def make_round_chunk(round_fn: Callable, r: Optional[int], *,
                     sample_fn: Optional[Callable] = None,
                     donate: bool = True) -> Callable:
    """Fuse ``r`` rounds of ``round_fn`` into one jitted ``lax.scan``.

    ``r=None`` builds a length-polymorphic chunk: the scan length follows
    the stacked inputs' leading dim (one jit specialization per distinct
    length — used by the pod trainer's tail chunk).

    Returns ``chunk_fn(state, batches, k_steps, weights, lam) ->
    (state, metrics)`` where every input is stacked per round:

    * ``batches`` — pytree with leading ``(r, M, k_max, …)`` (host-stacked
      rounds, e.g. ``FederatedBatcher.chunk_batches``); with ``sample_fn``
      it is instead the ``(r,)`` int32 round indices passed to
      ``sample_fn(t)`` inside the scan.
    * ``k_steps`` ``(r, M)`` int32, ``weights`` ``(r, M)`` f32,
      ``lam`` ``(r,)`` f32 — per-round K_i schedules / client weights / λ.
    * ``metrics`` — each entry a ``(r,)`` array (round-major).

    ``state`` is donated by default: the carry buffers are reused across
    chunk calls instead of reallocated (pass ``donate=False`` when the
    caller must keep its input state alive).
    """
    def chunk_fn(state: PyTree, batches: PyTree, k_steps: jax.Array,
                 weights: jax.Array, lam: jax.Array):
        assert r is None or k_steps.shape[0] == r, (
            f"chunk built for {r} rounds, got {k_steps.shape[0]}")

        def body(st, xs):
            b, k, w, l = xs
            if sample_fn is not None:
                b = sample_fn(b)
            return round_fn(st, b, k, w, l)

        return jax.lax.scan(body, state, (batches, k_steps, weights, lam))

    return jax.jit(chunk_fn, donate_argnums=(0,) if donate else ())


def make_population_chunk(round_fn: Callable, r: Optional[int], *,
                          cohort_fn: Optional[Callable] = None,
                          sample_fn: Optional[Callable] = None,
                          scenario_fn: Optional[Callable] = None,
                          donate: bool = True) -> Callable:
    """Fuse ``r`` cohort rounds (stages.make_cohort_round) into one jitted
    ``lax.scan`` — the partial-participation analogue of
    ``make_round_chunk`` (DESIGN.md §10).

    Two modes, mirroring the batcher families:

    * **device** (``cohort_fn`` + ``sample_fn`` given) — the cohort draw AND
      the batch generation both run inside the scan:
      ``chunk_fn(state, ts, k_rows, lam)`` with ``ts`` the ``(r,)`` round
      indices and ``k_rows`` the ``(r, M)`` population K-schedule rows.
      ``cohort_fn(t) -> (ids, w̃)`` (``ClientPopulation.cohort_and_weights``)
      and ``sample_fn(t, ids) -> (C, k_max, …) batches``
      (``DeviceBatcher.sample_cohort``) — the chunk reads no host data and
      materializes only O(C) batch rows.
    * **host** (neither given) — cohorts precomputed on host:
      ``chunk_fn(state, batches, cohorts, k_steps, cweights, lam)`` with
      every input stacked per round (leading ``(r,)``, client axis C).

    ``scenario_fn`` (device mode only) is the in-scan failure-scenario hook
    (fed/scenarios.py, DESIGN.md §12): ``scenario_fn(t, k_c, ids) ->
    k_eff`` maps the cohort's scheduled K rows to effective completed
    steps k′ ≤ K — an O(C) evaluation, since scenario draws are keyed per
    (round, client).  The round then runs the k′-step prefix and the
    cohort weights are scaled by the delivered fraction
    (``stages.delivered_weights``).  The host-precomputed paths apply the
    identical perturbation eagerly (fed/simulation.py), so chunked and
    per-round execution stay bit-identical.
    """
    if (cohort_fn is None) != (sample_fn is None):
        raise ValueError("cohort_fn and sample_fn come as a pair: in-scan "
                         "cohorts need an in-scan (device) batch sampler")
    if scenario_fn is not None and cohort_fn is None:
        raise ValueError("scenario_fn is an in-scan (device-mode) hook; "
                         "host-precomputed chunks perturb their stacked "
                         "inputs before the dispatch")

    if cohort_fn is not None:
        from repro.core.stages import delivered_weights

        def chunk_fn(state: PyTree, ts: jax.Array, k_rows: jax.Array,
                     lam: jax.Array):
            assert r is None or ts.shape[0] == r, (
                f"chunk built for {r} rounds, got {ts.shape[0]}")

            def body(st, xs):
                t, krow, l = xs
                ids, cw = cohort_fn(t)
                k_c = krow[ids]
                if scenario_fn is not None:
                    k_eff = scenario_fn(t, k_c, ids)
                    cw = delivered_weights(cw, k_eff, k_c)
                    k_c = k_eff
                return round_fn(st, sample_fn(t, ids), ids, k_c, cw, l)

            return jax.lax.scan(body, state, (ts, k_rows, lam))
    else:
        def chunk_fn(state: PyTree, batches: PyTree, cohorts: jax.Array,
                     k_steps: jax.Array, cweights: jax.Array,
                     lam: jax.Array):
            assert r is None or cohorts.shape[0] == r, (
                f"chunk built for {r} rounds, got {cohorts.shape[0]}")

            def body(st, xs):
                b, ids, k, w, l = xs
                return round_fn(st, b, ids, k, w, l)

            return jax.lax.scan(body, state,
                                (batches, cohorts, k_steps, cweights, lam))

    return jax.jit(chunk_fn, donate_argnums=(0,) if donate else ())
