"""Wire compression as a first-class round stage (DESIGN.md §14).

Production cross-device FL is bandwidth-bound on the client uplink, and
FedaGrac transmits TWO quantities per report — the parameter delta and
the ν orientation — so wire bytes, not FLOPs, are the scaling ceiling.
This module turns the old int8-ν ablation into an engine stage:

* ``COMPRESSORS`` — ``none`` / ``int8`` / ``int4`` / ``topk`` /
  ``topk+int8``, each a padding-masked fake-quant codec on the flat
  ``(rows, P)`` layout (the simulator runs compress→decompress in one
  program; the *wire* is modeled by ``payload_bytes``).  Tree-layout
  rounds ravel the transmitted quantity through the view table, compress,
  and unravel — both layouts share one codec and one error state.
* **Error feedback** (Karimireddy et al., SignSGD-EF; Stich et al.):
  ê = C(v + e),  e ← (v + e) − ê.  Per-CLIENT accumulators live as
  ``(M, P)`` rows in the round state (``ef_up`` for deltas, ``ef_nu``
  for ν transmits) so partial participation and buffered-async staleness
  compose correctly: a client's residual waits, untouched, until ITS next
  report — never renormalized, never leaked to other clients.  The
  server→client broadcast keeps single-vector accumulators (``ef_down``,
  ``ef_down_nu``): a broadcast is one compression event received by all.
* ``wire_cost`` / ``payload_bytes`` — the measured-bytes model behind
  ``History.bytes_up``/``bytes_down`` and
  ``roofline.analysis.bytes_on_the_wire``.

Every codec is **padding-preserving by construction**: inputs are masked
to the true n columns before any scale/threshold reduction (a poisoned
lane-padding tail can neither inflate a scale nor survive to the output)
— the invariant tests/test_compression.py pins for all compressors.

Builders take ``compression=None`` (or an all-"none" config) to mean NO
compression: they then bake the literally unchanged round code, keeping
the golden bit-identity of every existing path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.quantize import ops as qops

PyTree = Any

# int8: n code bytes + one 4-byte per-row scale.  int4: two codes per
# byte.  topk: k × (4-byte index + 4-byte value).  topk+int8: k × (4-byte
# index + 1-byte code) + scale.  fp32 ("none"): 4 bytes per element.
_QMAX = {"int8": 127, "int4": 7}


def payload_bytes(name: str, n: int, *, topk_frac: float = 0.05) -> float:
    """Wire bytes for ONE compressed length-n vector (scales included)."""
    if name == "none":
        return 4.0 * n
    if name == "int8":
        return float(n) + 4.0
    if name == "int4":
        return math.ceil(n / 2) + 4.0
    k = max(1, round(topk_frac * n))
    if name == "topk":
        return 8.0 * k
    if name == "topk+int8":
        return 5.0 * k + 4.0
    raise KeyError(f"unknown compressor {name!r}; valid options: "
                   f"{sorted(COMPRESSORS)}")


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Build-time description of the round's compression stage."""
    uplink: str = "none"            # client → server deltas AND ν updates
    downlink: str = "none"          # server → client (params, ν) broadcast
    error_feedback: bool = True
    topk_frac: float = 0.05

    @classmethod
    def from_fed(cls, fed) -> Optional["CompressionConfig"]:
        """None when the config requests no compression at all — builders
        then take the golden-pinned unchanged code path."""
        if fed.compressor == "none" and fed.broadcast_compressor == "none":
            return None
        return cls(uplink=fed.compressor,
                   downlink=fed.broadcast_compressor,
                   error_feedback=fed.error_feedback,
                   topk_frac=fed.topk_frac)

    @property
    def up_active(self) -> bool:
        return self.uplink != "none"

    @property
    def down_active(self) -> bool:
        return self.downlink != "none"

    @property
    def active(self) -> bool:
        return self.up_active or self.down_active


# ---------------------------------------------------------------------------
# codecs: fake-quant round-trips on (rows, P)
# ---------------------------------------------------------------------------

def _mask_true(x: jax.Array, n: int) -> jax.Array:
    """Zero the lane-padding tail [n, P) — the codec's defensive input
    mask; scale/threshold reductions additionally mask inside qops."""
    return jnp.where(jnp.arange(x.shape[-1]) < n, x, 0)


def _make_int_codec(n: int, qmax: int, use_pallas, interpret) -> Callable:
    def codec(mat: jax.Array) -> jax.Array:
        xm = _mask_true(mat.astype(jnp.float32), n)
        scale = qops.row_scales(xm, n, qmax)
        q = qops.quantize_2d(xm, scale, qmax=qmax, use_pallas=use_pallas,
                             interpret=interpret)
        return qops.dequantize_2d(q, scale, out_dtype=mat.dtype,
                                  use_pallas=use_pallas,
                                  interpret=interpret)
    return codec


def _make_topk_codec(n: int, k: int, use_pallas, interpret) -> Callable:
    def codec(mat: jax.Array) -> jax.Array:
        xm = _mask_true(mat.astype(jnp.float32), n)
        thresh = qops.topk_thresholds(xm, n, k)
        return qops.topk_mask_2d(xm, thresh, use_pallas=use_pallas,
                                 interpret=interpret).astype(mat.dtype)
    return codec


def _make_topk_int8_codec(n: int, k: int, use_pallas, interpret) -> Callable:
    topk = _make_topk_codec(n, k, use_pallas, interpret)
    quant = _make_int_codec(n, _QMAX["int8"], use_pallas, interpret)

    def codec(mat: jax.Array) -> jax.Array:
        # sparsify first, then quantize the survivors: the int8 scale is
        # the max SURVIVING magnitude — zeroed entries quantize to 0
        return quant(topk(mat))
    return codec


def _codec_none(n, topk_frac, use_pallas, interpret):
    return lambda mat: mat


def _codec_int8(n, topk_frac, use_pallas, interpret):
    return _make_int_codec(n, _QMAX["int8"], use_pallas, interpret)


def _codec_int4(n, topk_frac, use_pallas, interpret):
    return _make_int_codec(n, _QMAX["int4"], use_pallas, interpret)


def _topk_k(n: int, topk_frac: float) -> int:
    return max(1, min(n, round(topk_frac * n)))


def _codec_topk(n, topk_frac, use_pallas, interpret):
    return _make_topk_codec(n, _topk_k(n, topk_frac), use_pallas, interpret)


def _codec_topk_int8(n, topk_frac, use_pallas, interpret):
    return _make_topk_int8_codec(n, _topk_k(n, topk_frac), use_pallas,
                                 interpret)


# name → factory(n, topk_frac, use_pallas, interpret) → codec(mat) -> mat
COMPRESSORS: dict[str, Callable] = {
    "none": _codec_none,
    "int8": _codec_int8,
    "int4": _codec_int4,
    "topk": _codec_topk,
    "topk+int8": _codec_topk_int8,
}


def make_codec(name: str, n: int, *, topk_frac: float = 0.05,
               use_pallas: Optional[bool] = None,
               interpret: Optional[bool] = None) -> Callable:
    """Fake-quant codec ``(rows, P) -> (rows, P)`` for compressor ``name``
    over vectors of n true elements (P − n padding columns are masked out
    of every reduction and zero on output)."""
    if name not in COMPRESSORS:
        raise KeyError(f"unknown compressor {name!r}; valid options: "
                       f"{sorted(COMPRESSORS)}")
    return COMPRESSORS[name](n, topk_frac, use_pallas, interpret)


# ---------------------------------------------------------------------------
# error-feedback stage closures (what the round builders bake in)
# ---------------------------------------------------------------------------

def make_rows_stage(codec: Callable, error_feedback: bool,
                    key: str) -> Callable:
    """Uplink stage over per-client rows.  ``apply(rows, state, new_state,
    ids=None)``: compresses ``rows`` (shape (B, P)) with each reporting
    client's own accumulator — gathered at ``ids`` under partial
    participation / buffered-async, the full (M, P) block when ids is
    None — and scatters the new residuals back to THOSE rows only:
    a non-participant's accumulator is untouched by construction."""
    def apply(rows, state, new_state, ids=None):
        if error_feedback:
            ef = state[key]
            tgt = rows + (ef if ids is None else ef[ids])
            out = codec(tgt)
            resid = (tgt - out).astype(ef.dtype)
            new_state[key] = (resid if ids is None
                              else ef.at[ids].set(resid))
            return out
        return codec(rows)
    return apply


def make_vector_stage(codec: Callable, error_feedback: bool,
                      key: str) -> Callable:
    """Downlink (broadcast) stage over one (P,) server vector with a
    single server-side accumulator — a broadcast is ONE compression event
    received by every client."""
    def apply(vec, state, new_state):
        if error_feedback:
            tgt = vec + state[key]
            out = codec(tgt[None])[0]
            new_state[key] = (tgt - out).astype(state[key].dtype)
            return out
        return codec(vec[None])[0]
    return apply


def init_compression_state(state: dict, compression: CompressionConfig,
                           n_clients: int, p: int, dtype,
                           uses_nu: bool) -> None:
    """Allocate the error-feedback accumulators into the round state:
    (M, P) rows per uplink quantity, (P,) per broadcast quantity.  Keys
    exist iff error feedback is on for an active direction — the builders
    gate on the same predicate, and checkpoint/serialize round-trips them
    like any other state leaf."""
    if not compression.error_feedback:
        return
    if compression.up_active:
        state["ef_up"] = jnp.zeros((n_clients, p), dtype)
        if uses_nu:
            state["ef_nu"] = jnp.zeros((n_clients, p), dtype)
    if compression.down_active:
        state["ef_down"] = jnp.zeros((p,), dtype)
        if uses_nu:
            state["ef_down_nu"] = jnp.zeros((p,), dtype)


@dataclasses.dataclass(frozen=True)
class RoundCompression:
    """What a round builder bakes in: one stage closure per transmitted
    quantity (None = that direction uncompressed).  ``up``/``up_nu`` are
    row stages over per-client payloads with separate accumulators (the
    delta and the ν transmit are different wire quantities with different
    error dynamics); ``down``/``down_nu`` are broadcast vector stages."""
    config: CompressionConfig
    up: Optional[Callable]
    up_nu: Optional[Callable]
    down: Optional[Callable]
    down_nu: Optional[Callable]


def build_stages(compression: Optional[CompressionConfig], spec,
                 uses_nu: bool, *,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None
                 ) -> Optional[RoundCompression]:
    """Resolve a ``CompressionConfig`` against a ``FlatSpec`` into baked
    stage closures, or None when compression is off (builders then emit
    the literally unchanged round — the golden bit-identity path)."""
    if compression is None or not compression.active:
        return None
    if spec is None:
        raise ValueError("compression requires a FlatSpec — the engines "
                         "build one on both param layouts")
    ef = compression.error_feedback
    up = up_nu = down = down_nu = None
    if compression.up_active:
        codec = make_codec(compression.uplink, spec.n,
                           topk_frac=compression.topk_frac,
                           use_pallas=use_pallas, interpret=interpret)
        up = make_rows_stage(codec, ef, "ef_up")
        if uses_nu:
            up_nu = make_rows_stage(codec, ef, "ef_nu")
    if compression.down_active:
        codec = make_codec(compression.downlink, spec.n,
                           topk_frac=compression.topk_frac,
                           use_pallas=use_pallas, interpret=interpret)
        down = make_vector_stage(codec, ef, "ef_down")
        if uses_nu:
            down_nu = make_vector_stage(codec, ef, "ef_down_nu")
    return RoundCompression(compression, up, up_nu, down, down_nu)


EF_KEYS = ("ef_up", "ef_nu", "ef_down", "ef_down_nu")
# async-engine broadcast carry (fed/async_engine.py): the last compressed
# server broadcast, persisted in state so chunk boundaries and resumes see
# the same anchors the clients were dispatched with
BC_KEYS = ("bc_params", "bc_nu")
FLAT_STATE_KEYS = EF_KEYS + BC_KEYS


# ---------------------------------------------------------------------------
# bytes-on-the-wire accounting
# ---------------------------------------------------------------------------

def wire_cost(n: int, uses_nu: bool,
              compression: Optional[CompressionConfig]) -> dict:
    """Per-client wire bytes per round/update under the configured
    compressors.  Uplink carries the parameter delta plus (ν algorithms)
    the selected orientation transmit; downlink carries the model
    broadcast plus (ν algorithms) the global ν.  fp32 baseline = 4n per
    quantity.  Multiply by the per-round participant count (M, C, or the
    buffer B) for round totals — which is what the engines record into
    ``History.bytes_up`` / ``bytes_down``."""
    up_name = compression.uplink if compression is not None else "none"
    down_name = compression.downlink if compression is not None else "none"
    frac = compression.topk_frac if compression is not None else 0.05
    q = 2 if uses_nu else 1
    up = q * payload_bytes(up_name, n, topk_frac=frac)
    down = q * payload_bytes(down_name, n, topk_frac=frac)
    return {"uplink_per_client": up, "downlink_per_client": down,
            "uplink_fp32_per_client": q * 4.0 * n,
            "downlink_fp32_per_client": q * 4.0 * n}
