"""Byzantine-robust aggregation: DEFENSES registry, health quarantine,
and the final non-finite guard (DESIGN.md §16).

Threat model: a client payload — the delta rows ``x_i − anchor`` and the
ν transmit rows, i.e. exactly what crosses the wire — may be arbitrary:
NaN/Inf, maliciously scaled, sign-flipped, or resampled noise (the attack
models live in ``fed/scenarios.py``).  FedaGrac makes this worse than
plain FedAvg: one bad row poisons not just the model but the broadcast
orientation ν, deteriorating *every* client's local direction next round.
So every defense here composes at the same point on both payloads:

    delta rows ─ sanitize → quarantine → defend → HT-renormalize ─→ agg
    ν rows     ─ sanitize → quarantine → [defend if nu_defense] ─→ ν mix

``defense="none"`` with ``quarantine_window=0`` is trace-time gated:
``RobustConfig.from_fed`` returns ``None`` and the round builders bake the
literally unchanged round (same contract as ``core/compress.py``).

Pipeline contract (``RoundRobust.model`` / ``.nu``): inputs are ``(B, P)``
lane-padded rows and ``(B,)`` weights; padding columns are zeroed on
entry, rows with any non-finite value are dropped, quarantined clients
(``hz_until[id] > round``, read from PRE-round state) are dropped, the
defense transform may drop more (krum) or recentre (median/trimmed_mean),
and finally Horvitz–Thompson renormalization rescales the surviving
weights so their sum equals the original total — the downstream
aggregators (absolute weighted mean, fednova, ν mass-mixing) all key on
Σw, reusing the PR-4 population machinery unchanged.  If nothing
survives, the original weights are kept and ALL delta rows are zeroed:
the weighted mean then returns the anchor and the round is a no-op
(weight-zeroing alone would collapse the absolute mean to 0).

Health state (five ``(M,)`` vectors, layout-independent, checkpointed
bit-exactly; absent clients' rows untouched): running non-finite counts
and an EWMA of delta norms; a client is quarantined for
``quarantine_window`` rounds when its non-finite count reaches
``quarantine_nonfinite`` or its norm z-score exceeds ``quarantine_z``
after ``HEALTH_WARMUP`` finite reports.  Async caveat: duplicate ids in
one buffer flush scatter with ``.at[].add`` for counters and last-wins
``.at[].set`` for the EWMA — same contract as the ν⁽ⁱ⁾ scatter.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

_EPS = 1e-12
# finite sentinel for sort/distance padding — NOT inf, so the pairwise
# krum distances never produce inf − inf = NaN under masking
_BIG = 1e30
HEALTH_EWMA = 0.2        # EWMA step for the per-client delta-norm stats
HEALTH_WARMUP = 3        # finite reports required before z-score flagging

# extra (M,) engine-state vectors; flatten_state passes them through
# unchanged on the flat layout (same contract as compress.FLAT_STATE_KEYS)
ROBUST_STATE_KEYS = ("hz_nonfinite", "hz_mean", "hz_var", "hz_count",
                     "hz_until")


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Resolved robustness knobs; ``from_fed`` returns None when inactive
    so the round builders emit the identical jaxpr."""
    defense: str = "none"
    clip_norm: float = 0.0      # 0 → adaptive: median of surviving norms
    trim_frac: float = 0.2
    krum_f: int = 1
    nu_defense: bool = True     # ablation knob: defend ν too, not just x
    quarantine_window: int = 0
    quarantine_z: float = 4.0
    quarantine_nonfinite: int = 1

    @classmethod
    def from_fed(cls, fed) -> Optional["RobustConfig"]:
        if fed.defense == "none" and fed.quarantine_window == 0:
            return None
        return cls(defense=fed.defense, clip_norm=fed.defense_clip,
                   trim_frac=fed.trim_frac, krum_f=fed.krum_f,
                   nu_defense=fed.nu_defense,
                   quarantine_window=fed.quarantine_window,
                   quarantine_z=fed.quarantine_z,
                   quarantine_nonfinite=fed.quarantine_nonfinite)

    @property
    def defends(self) -> bool:
        return self.defense != "none"

    @property
    def quarantines(self) -> bool:
        return self.quarantine_window > 0


# ---------------------------------------------------------------------------
# defense transforms — factories (cfg, n) -> fn(rows, mask) -> (rows, mask)
#
# Invariants on entry: rows are f32, padding columns zeroed, dead rows'
# DATA zeroed (0·NaN = NaN in a downstream einsum, and jnp.median
# propagates NaN — masking the weight alone is not enough).  A transform
# may shrink the mask (krum) but never grows it.
# ---------------------------------------------------------------------------

def _none(cfg: RobustConfig, n: int):
    def fn(rows, mask):
        return rows, mask
    return fn


def _clip(cfg: RobustConfig, n: int):
    """Per-client norm clipping; threshold fixed (clip_norm > 0) or the
    median of the surviving rows' norms (adaptive)."""
    def fn(rows, mask):
        norms = jnp.sqrt(jnp.sum(rows * rows, axis=-1))
        if cfg.clip_norm > 0:
            tau = jnp.float32(cfg.clip_norm)
        else:
            med = jnp.nanmedian(jnp.where(mask, norms, jnp.nan))
            tau = jnp.nan_to_num(med, nan=0.0)
        scale = jnp.where(norms > tau, tau / jnp.maximum(norms, _EPS), 1.0)
        return rows * scale[:, None], mask
    return fn


def _median(cfg: RobustConfig, n: int):
    """Coordinate-wise median over surviving rows, broadcast back to every
    survivor — the weighted mean downstream then returns the median."""
    def fn(rows, mask):
        r = jnp.where(mask[:, None], rows, jnp.nan)
        center = jnp.nan_to_num(jnp.nanmedian(r, axis=0), nan=0.0)
        out = jnp.where(mask[:, None], center[None, :], 0.0)
        return out, mask
    return fn


def _trimmed_mean(cfg: RobustConfig, n: int):
    """Coordinate-wise trimmed mean: per column, sort the surviving values
    (dead rows pushed past the live range with a finite sentinel), drop the
    k smallest and k largest, average the middle."""
    def fn(rows, mask):
        b = rows.shape[0]
        k = max(1, int(round(cfg.trim_frac * b)))
        live = jnp.sum(mask.astype(jnp.int32))
        srt = jnp.sort(jnp.where(mask[:, None], rows, _BIG), axis=0)
        idx = jnp.arange(b)
        keep = (idx >= k) & (idx < live - k)
        denom = jnp.maximum(live - 2 * k, 1).astype(jnp.float32)
        center = jnp.sum(jnp.where(keep[:, None], srt, 0.0), axis=0) / denom
        out = jnp.where(mask[:, None], center[None, :], 0.0)
        return out, mask
    return fn


def _krum(cfg: RobustConfig, n: int):
    """Multi-krum distance filtering: score each row by the sum of squared
    distances to its q = B − f − 2 nearest survivors, keep the B − f
    lowest-scoring rows (drop the f most isolated)."""
    def fn(rows, mask):
        b = rows.shape[0]
        f = max(0, int(cfg.krum_f))
        sq = jnp.sum((rows[:, None, :] - rows[None, :, :]) ** 2, axis=-1)
        dead = ~mask
        sq = jnp.where(dead[:, None] | dead[None, :], _BIG, sq)
        sq = sq + jnp.eye(b, dtype=sq.dtype) * _BIG   # exclude self
        q = max(b - f - 2, 1)
        scores = jnp.sum(jnp.sort(sq, axis=1)[:, :q], axis=1)
        scores = jnp.where(mask, scores, jnp.inf)     # dead rows sort last
        keep_n = max(b - f, 1)
        sel = jnp.zeros((b,), bool).at[jnp.argsort(scores)[:keep_n]].set(True)
        new_mask = mask & sel
        return jnp.where(new_mask[:, None], rows, 0.0), new_mask
    return fn


DEFENSES = {
    "none": _none,
    "clip": _clip,
    "median": _median,
    "trimmed_mean": _trimmed_mean,
    "krum": _krum,
}


# ---------------------------------------------------------------------------
# pipeline pieces
# ---------------------------------------------------------------------------

def _renorm(rows_f, out_dtype, weights, mask):
    """Horvitz–Thompson renormalization: rescale surviving weights so
    Σw is preserved (the aggregators and ν mass-mixing key on it).  If
    nothing survives, keep the ORIGINAL weights and zero every row — the
    absolute weighted mean then returns the anchor (a no-op round)."""
    mf = mask.astype(jnp.float32)
    tot0 = jnp.sum(weights)
    w1 = weights * mf
    alive = jnp.sum(w1)
    ok = alive > 0
    scale = jnp.where(ok, tot0 / jnp.maximum(alive, _EPS), 0.0)
    w_out = jnp.where(ok, w1 * scale, weights)
    rows_out = jnp.where(ok, rows_f * mf[:, None], jnp.zeros_like(rows_f))
    return rows_out.astype(out_dtype), w_out


def _health_update(cfg: RobustConfig, state, new_state, ids, rfin, finite,
                   quar, r):
    """Update the per-client health vectors from this round's reports.

    ``rfin`` is finite-masked (NOT quarantine-masked): quarantined rows
    freeze their EWMA (``upd`` gate) so serving a quarantine never drags
    the baseline toward zero.  z-scores use the PRE-update stats, so a
    client cannot shift its own baseline in the round it attacks.
    """
    a = HEALTH_EWMA
    norms = jnp.sqrt(jnp.sum(rfin * rfin, axis=-1))
    nf1 = state["hz_nonfinite"].at[ids].add((~finite).astype(jnp.int32))
    mean_g = state["hz_mean"][ids]
    var_g = state["hz_var"][ids]
    cnt_g = state["hz_count"][ids]
    until_g = state["hz_until"][ids]
    upd = finite & ~quar
    z = (norms - mean_g) * jax.lax.rsqrt(var_g + jnp.float32(_EPS))
    zbad = upd & (cnt_g >= HEALTH_WARMUP) & (z > cfg.quarantine_z)
    nfbad = (~finite) & (nf1[ids] >= cfg.quarantine_nonfinite)
    flag = zbad | nfbad
    new_until = jnp.where(flag, r + 1 + cfg.quarantine_window, until_g)
    first = cnt_g == 0
    m1 = jnp.where(first, norms, (1 - a) * mean_g + a * norms)
    m1 = jnp.where(upd, m1, mean_g)
    v1 = jnp.where(first, jnp.zeros_like(var_g),
                   (1 - a) * var_g + a * (norms - m1) ** 2)
    v1 = jnp.where(upd, v1, var_g)
    new_state["hz_nonfinite"] = nf1
    new_state["hz_mean"] = state["hz_mean"].at[ids].set(m1)
    new_state["hz_var"] = state["hz_var"].at[ids].set(v1)
    new_state["hz_count"] = state["hz_count"].at[ids].add(
        upd.astype(jnp.int32))
    new_state["hz_until"] = state["hz_until"].at[ids].set(new_until)


def init_robust_state(state: dict, robust: Optional[RobustConfig],
                      n_clients: int) -> dict:
    """Allocate the (M,) health vectors when quarantine is on."""
    if robust is None or not robust.quarantines:
        return state
    state["hz_nonfinite"] = jnp.zeros((n_clients,), jnp.int32)
    state["hz_mean"] = jnp.zeros((n_clients,), jnp.float32)
    state["hz_var"] = jnp.zeros((n_clients,), jnp.float32)
    state["hz_count"] = jnp.zeros((n_clients,), jnp.int32)
    state["hz_until"] = jnp.zeros((n_clients,), jnp.int32)
    return state


@dataclasses.dataclass(frozen=True)
class RoundRobust:
    """Trace-time-resolved robust stages for one round builder.

    ``model(rows, weights, state, new_state, r, ids)`` →
    ``(rows, weights, quarantined)``; ``nu(rows, weights, state, r, ids)``
    → ``(rows, weights)``; ``guard(new, old)`` keeps ``old`` wherever
    ``new`` is non-finite (the final stage — a defended run never writes
    NaN into the flat master).
    """
    config: RobustConfig
    n: int
    model: Callable
    nu: Callable
    guard: Callable


def build_round_robust(robust: Optional[RobustConfig], spec,
                       uses_nu: bool) -> Optional[RoundRobust]:
    if robust is None:
        return None
    if spec is None:
        raise ValueError("robust aggregation requires a FlatSpec — the "
                         "engines build one on both param layouts")
    cfg = robust
    n = spec.n
    defense_fn = DEFENSES[cfg.defense](cfg, n)

    def _sanitize(rows):
        rf = rows.astype(jnp.float32)
        rf = jnp.where(jnp.arange(rf.shape[-1]) < n, rf, 0.0)
        return rf, jnp.all(jnp.isfinite(rf), axis=-1)

    def model(rows, weights, state, new_state, r, ids):
        rf0, finite = _sanitize(rows)
        if cfg.quarantines:
            quar = state["hz_until"][ids] > r
            qcount = jnp.sum(quar.astype(jnp.float32))
            rfin = jnp.where(finite[:, None], rf0, 0.0)
            _health_update(cfg, state, new_state, ids, rfin, finite, quar, r)
        else:
            quar = jnp.zeros(finite.shape, bool)
            qcount = jnp.zeros((), jnp.float32)
        mask = finite & ~quar
        rf = jnp.where(mask[:, None], rf0, 0.0)
        rf, mask = defense_fn(rf, mask)
        rows_out, w_out = _renorm(rf, rows.dtype, weights, mask)
        return rows_out, w_out, qcount

    def nu(rows, weights, state, r, ids):
        rf0, finite = _sanitize(rows)
        mask = finite
        if cfg.quarantines:
            mask = mask & ~(state["hz_until"][ids] > r)
        rf = jnp.where(mask[:, None], rf0, 0.0)
        if cfg.defends and cfg.nu_defense:
            rf, mask = defense_fn(rf, mask)
        return _renorm(rf, rows.dtype, weights, mask)

    def guard(new, old):
        return jax.tree.map(
            lambda a, b: jnp.where(jnp.isfinite(a), a, b), new, old)

    return RoundRobust(config=cfg, n=n, model=model, nu=nu, guard=guard)
