"""Small pytree helpers shared by the round-engine stages (DESIGN.md §2).

These were previously private to ``core/rounds.py`` (``_expand`` and
``_expand_b`` were byte-identical duplicates — now one ``expand``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def tree_zeros(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_stack_zeros(tree: PyTree, m: int) -> PyTree:
    """Zero tree with a new leading client axis of size ``m``."""
    return jax.tree.map(lambda a: jnp.zeros((m,) + a.shape, a.dtype), tree)


def expand(v: jax.Array, like: jax.Array) -> jax.Array:
    """(M,) -> (M, 1, 1, ...) broadcastable against ``like`` (M, ...)."""
    return v.reshape((-1,) + (1,) * (like.ndim - 1))


def tree_wsum(weights: jax.Array, tree: PyTree) -> PyTree:
    """Σ_m weights[m] · tree[m] per leaf, accumulated in f32, returned in
    the leaf dtype: f32 weights would otherwise promote the whole round
    state to f32 — doubling every activation/grad collective and breaking
    state-dtype stability across rounds (EXPERIMENTS.md §Perf #3)."""
    return jax.tree.map(
        lambda a: jnp.einsum("m,m...->...", weights,
                             a.astype(jnp.float32)).astype(a.dtype), tree)
