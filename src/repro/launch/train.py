"""Pod-scale FedaGrac training: the LM round step on the production mesh.

``build_train_round`` returns (round_fn, specs) where round_fn is the jit'd
SPMD FedaGrac round: client axis = mesh data axes (one client per data
slice), tensor parallelism over ``model``.  With ``chunk_rounds > 1`` the
returned function is instead the device-resident chunk (core/engine.py,
DESIGN.md §9): R rounds fused into one ``lax.scan`` dispatch over stacked
per-round inputs, shardings pinned by the in-scan ``param_constraint``
rather than explicit jit shardings.  ``main`` runs a small number of real
rounds on however many devices exist (the end-to-end example path).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import FedConfig, ModelConfig, ShapeConfig
from repro.core import engine, rounds, stages
from repro.core.fedopt import get_algorithm
from repro.dist import set_mesh_rules, use_mesh
from repro.launch import specs as specs_lib
from repro.launch.mesh import data_axes, mesh_rules, model_axes
from repro.models.model import lm_loss

PyTree = Any


def _model_size(mesh) -> int:
    out = 1
    for a in model_axes(mesh):
        out *= mesh.shape[a]
    return out


def make_param_constraint(mesh):
    msize = _model_size(mesh)
    cl = data_axes(mesh)

    def constraint(tree: PyTree, client_dims: int) -> PyTree:
        ps = specs_lib.tree_pspecs(tree, msize,
                                   client_axes=cl if client_dims else ())
        return jax.tree.map(
            lambda x, p: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, p)),
            tree, ps, is_leaf=lambda x: isinstance(x, P))

    return constraint


def make_flat_param_constraint(mesh, p: int):
    """Flat twin of ``make_param_constraint``: ONE sharding rule for every
    ``(…, P)`` buffer (specs_lib.flat_param_pspec) instead of the per-leaf
    name-aware table."""
    def constraint(arr, client_dims: int):
        ps = specs_lib.flat_param_pspec(mesh, p, client_dims)
        return jax.lax.with_sharding_constraint(arr,
                                                NamedSharding(mesh, ps))
    return constraint


def build_train_round(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      fed: FedConfig, *, k_max: int = 4,
                      chunk_rounds: int = 1):
    """Returns (jitted_round_fn, spec_bundle).  Call under ``with mesh:``.

    ``chunk_rounds > 1`` returns the scanned R-round chunk instead —
    ``chunk(state, batches, k_steps, weights, lam)`` with every input
    stacked per round (leading ``(R,)``), one dispatch and one host sync
    per chunk (DESIGN.md §9).

    ``fed.param_layout="flat"`` builds the single-buffer round
    (core/flat.py): state is (P,)/(M, P) flat buffers (the bundle carries
    ``flat_spec``), the model consumes view-table slices of the buffer
    (DESIGN.md §13), and ``fed.master_dtype`` keeps an f32 master over
    bf16 compute."""
    algo = get_algorithm(fed.algorithm, fed)
    set_mesh_rules(mesh, mesh_rules(mesh, kind="train"))

    loss_fn = functools.partial(lm_loss, cfg=cfg)
    if fed.param_layout == "flat":
        from repro.core import flat as flat_lib
        bundle = specs_lib.flat_train_specs(
            cfg, shape, mesh, algo, k_max=k_max,
            master_dtype=fed.master_dtype or None)
        fspec = bundle["flat_spec"]
        round_fn = flat_lib.make_flat_round(
            fspec, lambda p, b: loss_fn(p, b), algo, lr=fed.lr,
            k_max=k_max,
            param_constraint=make_flat_param_constraint(mesh, fspec.p))
    else:
        round_fn = rounds.make_round(
            lambda p, b: loss_fn(p, b), algo, lr=fed.lr, k_max=k_max,
            spmd_axis_name=data_axes(mesh) or None,
            param_constraint=make_param_constraint(mesh))
        bundle = specs_lib.train_specs(cfg, shape, mesh, algo, k_max=k_max)
    if chunk_rounds > 1:
        # sharding layouts are pinned by the in-scan param_constraint;
        # stacked inputs keep their per-round specs on the trailing axes.
        # Length-polymorphic: the final (shorter) tail chunk re-specializes
        return engine.make_round_chunk(round_fn, None), bundle
    sh = lambda tree: specs_lib.to_shardings(tree, mesh)
    ps = bundle["pspecs"]
    jitted = jax.jit(
        round_fn,
        in_shardings=(sh(ps["state"]), sh(ps["batches"]),
                      sh(ps["k_steps"]), sh(ps["weights"])),
        out_shardings=(sh(ps["state"]), None),
    )
    return jitted, bundle


def lower_train(cfg: ModelConfig, shape: ShapeConfig, mesh, fed: FedConfig,
                *, k_max: int = 4):
    """.lower() the round on ShapeDtypeStructs (no allocation)."""
    with use_mesh(mesh):
        jitted, bundle = build_train_round(cfg, shape, mesh, fed, k_max=k_max)
        s = bundle["specs"]
        lowered = jitted.lower(s["state"], s["batches"], s["k_steps"],
                               s["weights"])
    return lowered, bundle


def build_population_round(cfg: ModelConfig, shape: ShapeConfig, mesh,
                           fed: FedConfig, *, m_population: int,
                           k_max: int = 4):
    """The SPMD cohort round at population scale (DESIGN.md §10).

    The mesh's data slots host a cohort of C = n_clients(mesh) sampled
    clients; the calibration state ``nu_i`` keeps ``m_population`` rows,
    row-sharded over the data axes.  The per-round cohort gather / scatter
    of those rows lowers to collectives between the cohort layout and the
    population row sharding.  Returns ``(jitted_round_fn, spec_bundle)``
    with ``round_fn(state, batches, cohort, k_steps, cweights)`` — λ is
    baked in as ``algo.lam`` (the in_shardings cover exactly these five
    arguments).  Call under ``with mesh:``.
    """
    algo = get_algorithm(fed.algorithm, fed)
    set_mesh_rules(mesh, mesh_rules(mesh, kind="train"))
    loss_fn = functools.partial(lm_loss, cfg=cfg)
    round_fn = stages.make_cohort_round(
        lambda p, b: loss_fn(p, b), algo, lr=fed.lr, k_max=k_max,
        nu_decay=fed.cohort_nu_decay,
        spmd_axis_name=data_axes(mesh) or None,
        param_constraint=make_param_constraint(mesh))
    bundle = specs_lib.population_train_specs(cfg, shape, mesh, algo,
                                              m_population, k_max=k_max)
    sh = lambda tree: specs_lib.to_shardings(tree, mesh)
    ps = bundle["pspecs"]
    jitted = jax.jit(
        round_fn,
        in_shardings=(sh(ps["state"]), sh(ps["batches"]), sh(ps["cohort"]),
                      sh(ps["k_steps"]), sh(ps["cweights"])),
        out_shardings=(sh(ps["state"]), None),
    )
    return jitted, bundle


def lower_population(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     fed: FedConfig, *, m_population: int, k_max: int = 4):
    """.lower() the population cohort round on ShapeDtypeStructs."""
    with use_mesh(mesh):
        jitted, bundle = build_population_round(
            cfg, shape, mesh, fed, m_population=m_population, k_max=k_max)
        s = bundle["specs"]
        lowered = jitted.lower(s["state"], s["batches"], s["cohort"],
                               s["k_steps"], s["cweights"])
    return lowered, bundle


# ---------------------------------------------------------------------------
# real-execution driver (multi-host entry: scripts/launch_pod.sh train)
# ---------------------------------------------------------------------------

def _fit_mesh():
    """Production mesh when 256/512 devices exist; else the largest
    (data, model) grid over whatever this run has (CPU dev: 1×1)."""
    import numpy as np
    from repro.launch.mesh import make_production_mesh
    n = len(jax.devices())
    if n >= 512:
        return make_production_mesh(multi_pod=True)
    if n >= 256:
        return make_production_mesh()
    data = 1
    while data * 2 <= n and data < 16:
        data *= 2
    model = max(n // data, 1)
    return jax.make_mesh((data, model), ("data", "model"))


def main() -> None:
    import argparse
    import dataclasses

    from repro.configs.base import reduced
    from repro.configs.registry import ARCHS, get_arch
    from repro.configs.shapes import SHAPES
    from repro.data.synthetic import lm_sequences
    from repro.launch import specs as specs_lib
    from repro.launch.distributed import bootstrap, is_coordinator
    from repro.launch.mesh import n_clients

    ap = argparse.ArgumentParser(description="FedaGrac pod training")
    ap.add_argument("--arch", choices=sorted(ARCHS), default="llama3-8b")
    ap.add_argument("--shape", choices=sorted(SHAPES), default="train_4k")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--k-max", type=int, default=4)
    ap.add_argument("--chunk-rounds", type=int, default=1,
                    help="rounds fused into one lax.scan dispatch "
                         "(core/engine.py; host syncs per chunk)")
    ap.add_argument("--algo", default="fedagrac")
    ap.add_argument("--param-layout", choices=("tree", "flat"),
                    default="tree",
                    help="flat = single-buffer rounds with the view-table "
                         "loss boundary (core/flat.py, DESIGN.md §13)")
    ap.add_argument("--master-dtype", choices=("", "float32"), default="",
                    help="flat-only: master-buffer dtype override "
                         "(f32 master over bf16 compute)")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced model + tiny shape (CPU/dev runs)")
    args = ap.parse_args()

    bootstrap()
    mesh = _fit_mesh()
    cfg = get_arch(args.arch)
    shape = SHAPES[args.shape]
    if args.reduced:
        cfg = reduced(cfg)
        shape = dataclasses.replace(shape, seq_len=128,
                                    global_batch=2 * n_clients(mesh))
    cfg = specs_lib.bf16_config(cfg) if not args.reduced else cfg
    fed = FedConfig(algorithm=args.algo, lr=0.3 if args.reduced else 3e-2,
                    param_layout=args.param_layout,
                    master_dtype=args.master_dtype)

    with use_mesh(mesh):
        chunk = max(args.chunk_rounds, 1)
        jitted, bundle = build_train_round(cfg, shape, mesh, fed,
                                           k_max=args.k_max,
                                           chunk_rounds=chunk)
        m, b_local = bundle["m"], bundle["b_local"]
        from repro.core import rounds as rounds_lib
        from repro.models.model import init_params
        params = init_params(jax.random.PRNGKey(0), cfg)
        algo = get_algorithm(fed.algorithm, fed)
        if args.param_layout == "flat":
            from repro.core import flat as flat_lib
            params = flat_lib.ravel(bundle["flat_spec"], params)
        state = rounds_lib.init_state(params, m, algo)
        sh = lambda t: specs_lib.to_shardings(t, mesh)
        ps = bundle["pspecs"]
        state = jax.device_put(state, sh(ps["state"]))
        weights = jax.device_put(jnp.full((m,), 1.0 / m, jnp.float32),
                                 sh(ps["weights"]))
        key = jax.random.PRNGKey(1)

        def round_inputs(t):
            data = lm_sequences(jax.random.fold_in(key, t),
                                m * args.k_max * b_local, shape.seq_len,
                                cfg.vocab)
            batches = jax.tree.map(
                lambda a: jnp.reshape(a, (m, args.k_max, b_local, -1)), data)
            ks = jnp.clip(jax.random.poisson(jax.random.fold_in(key, 1000 + t),
                                             3, (m,)) + 1, 1, args.k_max
                          ).astype(jnp.int32)
            return batches, ks

        for t0 in range(0, args.rounds, chunk):
            r = min(chunk, args.rounds - t0)      # tail chunk may be short
            if chunk == 1:
                batches, ks = round_inputs(t0)
                state, metrics = jitted(
                    state, jax.device_put(batches, sh(ps["batches"])),
                    jax.device_put(ks, sh(ps["k_steps"])), weights)
                losses = [float(metrics["loss"])]
                kbars = [float(metrics["kbar"])]
            else:
                per_round = [round_inputs(t0 + j) for j in range(r)]
                batches = jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *(b for b, _ in per_round))
                ks = jnp.stack([k for _, k in per_round])
                state, metrics = jitted(
                    state, batches, ks,
                    jnp.broadcast_to(weights, (r, m)),
                    jnp.full((r,), algo.lam, jnp.float32))
                losses = [float(x) for x in metrics["loss"]]
                kbars = [float(x) for x in metrics["kbar"]]
            if is_coordinator():
                for j, (lo, kb) in enumerate(zip(losses, kbars)):
                    print(f"round {t0 + j + 1}/{args.rounds}  "
                          f"loss {lo:.4f}  kbar {kb:.2f}", flush=True)


if __name__ == "__main__":
    main()
