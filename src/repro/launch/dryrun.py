import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers, SPMD-partitions and compiles on the production mesh.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k [--multi-pod] [--out results.json]

    PYTHONPATH=src python -m repro.launch.dryrun --all

Per combo we record: compile ok, memory_analysis (per-device bytes),
cost_analysis (FLOPs / bytes), collective bytes by kind, and the three-term
roofline (§Roofline in EXPERIMENTS.md).  Nothing is executed and no real
buffer is allocated — inputs are ShapeDtypeStructs.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS, get_arch
from repro.configs.shapes import LONG_CONTEXT_OK, SHAPES
from repro.launch import serve as serve_lib
from repro.launch import specs as specs_lib
from repro.launch import train as train_lib
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as roofline


def shape_kind(shape_name: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "long"}[shape_name]


def skip_reason(arch: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return ("pure full attention at 524k decode — sub-quadratic variants "
                "only (DESIGN.md §4)")
    return None


def run_combo(arch: str, shape_name: str, *, multi_pod: bool,
              k_max: int = 4, algo: str = "fedagrac",
              keep_hlo: bool = False, variant: str = "tp16") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if variant != "tp16":
        mesh_name += f"/{variant}"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "algo": algo}
    reason = skip_reason(arch, shape_name)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    cfg = specs_lib.bf16_config(get_arch(arch))
    shape = SHAPES[shape_name]
    if variant == "auto":
        from repro.launch.mesh import recommended_variant
        variant = recommended_variant(cfg)
        rec["mesh"] = mesh_name.split("/")[0] + f"/auto->{variant}"
    mesh = make_production_mesh(multi_pod=multi_pod, variant=variant)
    chips = mesh.devices.size
    kind = shape_kind(shape_name)
    t0 = time.time()
    try:
        if kind == "train":
            fed = FedConfig(algorithm=algo, n_clients=0)  # M from mesh
            lowered, bundle = train_lib.lower_train(cfg, shape, mesh, fed,
                                                    k_max=k_max)
            tokens = shape.global_batch * shape.seq_len * k_max
            model_flops = roofline.train_model_flops(cfg, tokens)
        elif kind == "prefill":
            lowered, bundle = serve_lib.lower_serve(cfg, shape, mesh,
                                                    kind="prefill")
            model_flops = roofline.prefill_model_flops(
                cfg, shape.global_batch * shape.seq_len)
        else:
            lowered, bundle = serve_lib.lower_serve(cfg, shape, mesh,
                                                    kind=kind)
            model_flops = roofline.decode_model_flops(cfg, shape.global_batch)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        hlo = compiled.as_text()
        rl = roofline.from_compiled(compiled, chips, model_flops, hlo_text=hlo)
        rec["roofline"] = rl.as_dict()
        rec["memory"] = roofline.memory_stats(compiled)
        rec["status"] = "ok"
        if keep_hlo:
            rec["hlo_len"] = len(hlo)
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch, shape) on this mesh")
    ap.add_argument("--k-max", type=int, default=4)
    ap.add_argument("--algo", default="fedagrac")
    ap.add_argument("--mesh-variant", default="tp16",
                    choices=("tp16", "2d", "auto"))
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    combos = ([(a, s) for a in sorted(ARCHS) for s in SHAPES]
              if args.all else [(args.arch, args.shape)])
    if not args.all and (args.arch is None or args.shape is None):
        ap.error("--arch and --shape required (or --all)")

    for arch, shape_name in combos:
        rec = run_combo(arch, shape_name, multi_pod=args.multi_pod,
                        k_max=args.k_max, algo=args.algo,
                        variant=args.mesh_variant)
        status = rec["status"]
        extra = ""
        if status == "ok":
            rl = rec["roofline"]
            extra = (f" compute={rl['t_compute_s']:.3e}s"
                     f" memory={rl['t_memory_s']:.3e}s"
                     f" coll={rl['t_collective_s']:.3e}s"
                     f" dominant={rl['dominant']}")
        elif status == "failed":
            extra = " " + rec["error"]
        elif status == "skipped":
            extra = " " + rec["reason"]
        print(f"[{status:7s}] {arch:24s} {shape_name:12s} "
              f"{rec['mesh']:8s}{extra}", flush=True)
        if rec.get("traceback") and not args.out:
            print(rec["traceback"])
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
