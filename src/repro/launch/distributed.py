"""Multi-host bootstrap for real pods.

On hardware, every host runs the same program; `bootstrap()` wires
jax.distributed from standard cluster env vars (GKE/GCE TPU metadata or
explicit COORDINATOR_ADDRESS), then the launcher builds the production
mesh over jax.devices() exactly as the dry-run does over the 512
host-platform placeholders.

Host-local data feeding: each host materializes only the examples whose
client slices live on its addressable devices —
``host_client_slice(mesh)`` exposes that range; the batchers in
repro.data are deterministic in (seed, round), so no data service or
cross-host shuffle is needed (DESIGN.md §3).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import numpy as np


def bootstrap(coordinator: Optional[str] = None,
              num_processes: Optional[int] = None,
              process_id: Optional[int] = None) -> None:
    """Initialize jax.distributed.  No-ops on single-process runs.

    Env fallbacks: COORDINATOR_ADDRESS, NUM_PROCESSES, PROCESS_ID (set by
    scripts/launch_pod.sh); on Cloud TPU the args auto-detect."""
    coordinator = coordinator or os.environ.get("COORDINATOR_ADDRESS")
    num_processes = num_processes or _int_env("NUM_PROCESSES")
    process_id = process_id if process_id is not None else _int_env(
        "PROCESS_ID")
    if num_processes in (None, 1) and coordinator is None:
        return                                      # single-process / CPU dev
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_processes,
        process_id=process_id)


def _int_env(name: str) -> Optional[int]:
    v = os.environ.get(name)
    return int(v) if v is not None else None


def host_client_slice(mesh) -> tuple[int, int]:
    """[start, stop) client ids whose data-axis slices have devices on this
    host — the range of client datasets this host must materialize."""
    axes = [a for a in mesh.axis_names if a in ("pod", "data")]
    if not axes:
        return 0, 1
    local = set(d.id for d in jax.local_devices())
    dev_grid = mesh.devices
    names = list(mesh.axis_names)
    # collapse non-client axes: a client index is the flattened (pod, data)
    # coordinate; it is "local" if any of its devices is local
    client_axes_idx = [names.index(a) for a in axes]
    other_idx = [i for i in range(dev_grid.ndim) if i not in client_axes_idx]
    perm = client_axes_idx + other_idx
    grid = np.transpose(np.vectorize(lambda d: d.id)(dev_grid), perm)
    n_clients = 1
    for a in axes:
        n_clients *= mesh.shape[a]
    flat = grid.reshape(n_clients, -1)
    mine = [i for i in range(n_clients)
            if any(int(x) in local for x in flat[i])]
    if not mine:
        return 0, 0
    return min(mine), max(mine) + 1


def is_coordinator() -> bool:
    return jax.process_index() == 0


def sync_global_devices(tag: str) -> None:
    """Barrier across hosts (checkpoint boundaries, round epochs)."""
    if jax.process_count() == 1:
        return
    from jax.experimental import multihost_utils
    multihost_utils.sync_global_devices(tag)
