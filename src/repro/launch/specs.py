"""Parameter / state / input sharding specs + ShapeDtypeStruct stand-ins.

Everything the dry-run lowers is described here:

* ``param_pspec``        — name-aware tensor-parallel rules for every leaf of
                           the model zoo (embeddings/vocab, attention heads,
                           ffn hidden, MoE expert axis, SSM heads, …);
* ``abstract_params``    — jax.eval_shape'd parameter tree (no allocation);
* ``train_specs``        — FedaGrac round state + (M, k_max, B, …) batches;
* ``serve_specs``        — prefill / decode / long-decode inputs + KV caches.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import rounds
from repro.core.fedopt import Algorithm
from repro.launch.mesh import data_axes, model_axes, n_clients
from repro.models import model as model_lib

PyTree = Any

# last-path-component → preferred shard dim of the *logical* tensor
# (negative = from the end).  `None` entries fall through to the generic rule.
_NAME_RULES: dict[str, int] = {
    # output projections: contract dim holds heads/ffn shards
    "wo": -2, "out_proj": -2, "down": -2, "ff_down": -2,
    # input projections: output dim holds heads/ffn shards
    "wq": -1, "wk": -1, "wv": -1, "w_kv_up": -1, "up": -1, "ff_up": -1,
    "in_proj": -1, "W": -1, "w_gates": -1,
    # embeddings / lm heads: shard the vocab axis
    "embed": -2, "head": -1, "heads": -1,
    # sLSTM block-diagonal recurrence: shard heads
    "R": -3,
}


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def _stack_dims(path) -> int:
    """Leading scan-stack dims: segments params carry (n_groups, count)."""
    for p in path:
        if hasattr(p, "key") and str(p.key) == "segments":
            return 2
    return 0


def param_pspec(path, shape: tuple[int, ...], model_size: int) -> P:
    """PartitionSpec over the `model` mesh axis for one parameter leaf."""
    name = _leaf_name(path)
    stack = _stack_dims(path)
    logical = len(shape) - stack
    spec: list[Optional[str]] = [None] * len(shape)
    if model_size <= 1 or logical <= 0:
        return P(*spec)

    def try_dim(d: int) -> bool:
        if -logical <= d < 0:
            d = len(shape) + d
        elif d < stack:
            return False
        if shape[d] % model_size == 0 and shape[d] >= model_size:
            spec[d] = "model"
            return True
        return False

    # MoE expert tensors: shard the expert axis first (expert parallelism)
    if name in ("w_in", "w_gate", "w_out") and logical == 3:
        if try_dim(-3) or try_dim(-1 if name != "w_out" else -2):
            return P(*spec)
    if name in ("w_in", "w_gate"):
        if try_dim(-1):
            return P(*spec)
    if name == "w_out":
        if try_dim(-2):
            return P(*spec)
    rule = _NAME_RULES.get(name)
    if rule is not None and try_dim(rule):
        return P(*spec)
    # generic fallback: largest logical dim that divides
    order = sorted(range(stack, len(shape)), key=lambda d: -shape[d])
    for d in order:
        if try_dim(d - len(shape)):
            return P(*spec)
    return P(*spec)


def _prepend(pspec: P, axes) -> P:
    # single physical axis enters the spec as the bare name (same idiom as
    # cache_pspec), multi-axis as a tuple
    if not axes:
        return P(None, *pspec)
    return P(axes if len(axes) > 1 else axes[0], *pspec)


def tree_pspecs(tree: PyTree, model_size: int,
                client_axes: tuple[str, ...] = ()) -> PyTree:
    """Map every leaf to its PartitionSpec (optionally client-stacked)."""
    def one(path, leaf):
        ps = param_pspec(path, leaf.shape[1:] if client_axes else leaf.shape,
                         model_size)
        return _prepend(ps, client_axes) if client_axes else ps
    return jax.tree_util.tree_map_with_path(one, tree)


def to_shardings(pspecs: PyTree, mesh) -> PyTree:
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# abstract params / state
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig) -> PyTree:
    fn = functools.partial(model_lib.init_params, cfg=cfg)
    return jax.eval_shape(lambda key: fn(key), jax.random.PRNGKey(0))


def abstract_state(cfg: ModelConfig, algo: Algorithm, m: int) -> PyTree:
    params = abstract_params(cfg)
    return jax.eval_shape(
        lambda p: rounds.init_state(p, m, algo), params)


def state_pspecs(state: PyTree, mesh) -> PyTree:
    """Sharding for the round-engine state dict."""
    msize = 1
    for a in model_axes(mesh):
        msize *= mesh.shape[a]
    cl = data_axes(mesh)
    out = {"params": tree_pspecs(state["params"], msize),
           "round": P()}
    if "nu" in state:
        out["nu"] = tree_pspecs(state["nu"], msize)
        out["nu_i"] = tree_pspecs(state["nu_i"], msize, client_axes=cl)
    return out


# ---------------------------------------------------------------------------
# batch stand-ins
# ---------------------------------------------------------------------------

def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _client_batch(cfg: ModelConfig, b: int, s: int, *, labels: bool) -> dict:
    """Per-microbatch model inputs (no leading client/step dims)."""
    if cfg.frontend == "audio":
        out = {"codes": _sds((b, cfg.n_codebooks, s), jnp.int32)}
        if labels:
            out["labels"] = _sds((b, cfg.n_codebooks, s), jnp.int32)
        return out
    if cfg.frontend == "vision":
        out = {"embeds": _sds((b, s, cfg.d_model), cfg.dtype),
               "positions": _sds((b, 3, s), jnp.int32)}
        if labels:
            out["labels"] = _sds((b, s), jnp.int32)
        return out
    out = {"tokens": _sds((b, s), jnp.int32)}
    if labels:
        out["labels"] = _sds((b, s), jnp.int32)
    return out


def _batch_pspecs(batches: PyTree, mesh) -> PyTree:
    """(M|C, k, B, …) batch sharding: client dim over the data axes; the 2d
    mesh variant additionally shards the per-client microbatch dim over the
    "batch" axis (§Perf #4)."""
    cl = data_axes(mesh)
    has_batch = "batch" in mesh.axis_names

    def _bspec(x):
        spec = [cl if cl else None] + [None] * (x.ndim - 1)
        if has_batch and x.ndim >= 3 and x.shape[2] % mesh.shape["batch"] == 0:
            spec[2] = "batch"
        return P(*spec)

    return jax.tree.map(_bspec, batches)


def train_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, algo: Algorithm,
                k_max: int = 4) -> dict:
    """Round inputs: state, batches (M, k_max, B_local, …), k_steps, weights."""
    m = n_clients(mesh)
    assert shape.global_batch % m == 0, (shape.global_batch, m)
    b_local = shape.global_batch // m
    micro = _client_batch(cfg, b_local, shape.seq_len, labels=True)
    batches = jax.tree.map(
        lambda x: _sds((m, k_max) + x.shape, x.dtype), micro)
    state = abstract_state(cfg, algo, m)

    batch_ps = _batch_pspecs(batches, mesh)
    specs = {
        "state": state,
        "batches": batches,
        "k_steps": _sds((m,), jnp.int32),
        "weights": _sds((m,), jnp.float32),
    }
    pspecs = {
        "state": state_pspecs(state, mesh),
        "batches": batch_ps,
        "k_steps": P(),
        "weights": P(),
    }
    return {"specs": specs, "pspecs": pspecs, "m": m, "b_local": b_local}


def population_train_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                           algo: Algorithm, m_population: int,
                           k_max: int = 4) -> dict:
    """Cohort-round inputs at population scale (DESIGN.md §10).

    The mesh's data slots host the COHORT (C = n_clients(mesh)); the server
    state is POPULATION-sized — ``nu_i`` carries ``m_population`` rows,
    row-sharded over the data axes (each data slice owns M/dsize clients'
    calibration rows), while batches/cohort/k/cweights are cohort-sized.
    ``m_population`` must be a multiple of the data-parallel size for the
    row sharding to divide.
    """
    m = n_clients(mesh)
    if m_population < m:
        raise ValueError(f"population {m_population} smaller than the "
                         f"mesh cohort {m}")
    dsize = 1
    for a in data_axes(mesh):
        dsize *= mesh.shape[a]
    if dsize > 1 and m_population % dsize:
        raise ValueError(
            f"m_population={m_population} must divide over the data-"
            f"parallel size {dsize} for the ν⁽ⁱ⁾ row sharding")
    assert shape.global_batch % m == 0, (shape.global_batch, m)
    b_local = shape.global_batch // m
    micro = _client_batch(cfg, b_local, shape.seq_len, labels=True)
    batches = jax.tree.map(
        lambda x: _sds((m, k_max) + x.shape, x.dtype), micro)
    state = abstract_state(cfg, algo, m_population)

    batch_ps = _batch_pspecs(batches, mesh)
    specs = {
        "state": state,
        "batches": batches,
        "cohort": _sds((m,), jnp.int32),
        "k_steps": _sds((m,), jnp.int32),
        "cweights": _sds((m,), jnp.float32),
    }
    pspecs = {
        "state": state_pspecs(state, mesh),
        "batches": batch_ps,
        "cohort": P(),
        "k_steps": P(),
        "cweights": P(),
    }
    return {"specs": specs, "pspecs": pspecs, "m": m,
            "m_population": m_population, "b_local": b_local}


# ---------------------------------------------------------------------------
# flat-layout round state (core/flat.py, DESIGN.md §11)
# ---------------------------------------------------------------------------

def _flat_axis(mesh, p: int):
    """The mesh axes the lane-padded flat parameter axis shards over —
    the model axes when they divide P (P is a multiple of 128, so every
    power-of-two tensor-parallel size ≤ 128 divides), else replicated."""
    maxes = model_axes(mesh)
    msize = 1
    for a in maxes:
        msize *= mesh.shape[a]
    if msize <= 1 or p % msize:
        return None
    return maxes if len(maxes) > 1 else maxes[0]


def flat_param_pspec(mesh, p: int, client_dims: int = 0) -> P:
    """PartitionSpec of ONE ``(…, P)`` flat buffer: client rows over the
    data axes, the flat axis over the model axes — the single rule
    ``flat_state_pspecs`` applies per state entry, exposed for the flat
    round's in-scan param_constraint (launch/train.py)."""
    fx = _flat_axis(mesh, p)
    cl = data_axes(mesh)
    cl = (cl if len(cl) > 1 else cl[0]) if cl else None
    return P(cl, fx) if client_dims else P(fx)


def flat_state_pspecs(state: PyTree, mesh, p: int) -> PyTree:
    """Sharding for the FLAT round state: every (P,) server vector shards
    its single axis over the model axes; the (M, P) ν⁽ⁱ⁾ matrix shards
    client rows over the data axes and P over model — ONE rule instead of
    a name-aware table, the layout payoff at the specs layer."""
    fx = _flat_axis(mesh, p)
    cl = data_axes(mesh)
    cl = (cl if len(cl) > 1 else cl[0]) if cl else None
    out = {}
    for k, v in state.items():
        if k == "round":
            out[k] = P()
        elif k == "nu_i":
            out[k] = P(cl, fx)
        else:
            out[k] = P(fx)
    return out


def flat_train_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     algo: Algorithm, k_max: int = 4,
                     master_dtype=None) -> dict:
    """``train_specs`` for ``param_layout="flat"``: same batch stand-ins,
    but the round state collapses to (P,) / (M, P) buffers described by
    ``core.flat.make_flat_spec`` of the abstract parameter tree
    (``master_dtype`` = the mixed-precision master-buffer override)."""
    from repro.core import flat as flat_lib

    m = n_clients(mesh)
    assert shape.global_batch % m == 0, (shape.global_batch, m)
    b_local = shape.global_batch // m
    micro = _client_batch(cfg, b_local, shape.seq_len, labels=True)
    batches = jax.tree.map(
        lambda x: _sds((m, k_max) + x.shape, x.dtype), micro)
    fspec = flat_lib.make_flat_spec(abstract_params(cfg),
                                    master_dtype=master_dtype)
    state = jax.eval_shape(
        lambda: rounds.init_state(jnp.zeros((fspec.p,), fspec.dtype), m,
                                  algo))

    specs = {
        "state": state,
        "batches": batches,
        "k_steps": _sds((m,), jnp.int32),
        "weights": _sds((m,), jnp.float32),
    }
    pspecs = {
        "state": flat_state_pspecs(state, mesh, fspec.p),
        "batches": _batch_pspecs(batches, mesh),
        "k_steps": P(),
        "weights": P(),
    }
    return {"specs": specs, "pspecs": pspecs, "m": m, "b_local": b_local,
            "flat_spec": fspec}


# ---------------------------------------------------------------------------
# serve stand-ins (prefill / decode)
# ---------------------------------------------------------------------------

def cache_pspec(path, shape: tuple[int, ...], mesh, *, kind: str) -> P:
    """KV/SSM cache sharding.  Caches are stacked (n_groups, count, …leaf)."""
    name = _leaf_name(path)
    stack = 2
    msize = 1
    for a in model_axes(mesh):
        msize *= mesh.shape[a]
    d_ax = data_axes(mesh)
    dsize = 1
    for a in d_ax:
        dsize *= mesh.shape[a]
    spec: list = [None] * len(shape)
    if name in ("pos", "idx"):
        return P(*spec)
    bdim = stack
    seq_dim = stack + 1
    if kind == "long":
        # batch=1: shard the cache sequence axis over the data axes
        if name in ("k", "v", "ckv", "krope") and shape[seq_dim] % max(dsize, 1) == 0:
            spec[seq_dim] = d_ax if len(d_ax) > 1 else d_ax[0]
    else:
        if d_ax and shape[bdim] % dsize == 0 and shape[bdim] >= dsize:
            spec[bdim] = d_ax if len(d_ax) > 1 else d_ax[0]
    # model axis: prefer the head-like dim, else any remaining divisible dim
    prefer = {"k": stack + 2, "v": stack + 2, "ssm": stack + 1,
              "C": stack + 1, "n": stack + 1, "m": stack + 1,
              "conv": stack + 2, "ckv": None, "krope": None}
    cand = prefer.get(name, None)
    dims = ([cand] if cand is not None else []) + [
        d for d in range(stack, len(shape)) if spec[d] is None]
    for d in dims:
        if d is None or d >= len(shape) or spec[d] is not None:
            continue
        if shape[d] % msize == 0 and shape[d] >= msize:
            spec[d] = "model"
            break
    return P(*spec)


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    return jax.eval_shape(
        lambda: model_lib.init_caches(cfg, batch, max_len,
                                      jnp.dtype(cfg.dtype)))


def serve_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                kind: str) -> dict:
    """kind: "prefill" | "decode" | "long"."""
    msize = 1
    for a in model_axes(mesh):
        msize *= mesh.shape[a]
    params = abstract_params(cfg)
    param_ps = tree_pspecs(params, msize)
    b = shape.global_batch
    if kind == "prefill":
        batch = _client_batch(cfg, b, shape.seq_len, labels=False)
        d_ax = data_axes(mesh)
        batch_ps = jax.tree.map(
            lambda x: P(d_ax if d_ax else None, *([None] * (x.ndim - 1))),
            batch)
        caches = abstract_caches(cfg, b, shape.seq_len)
        cache_ps = jax.tree_util.tree_map_with_path(
            lambda p, x: cache_pspec(p, x.shape, mesh, kind="prefill"),
            caches)
        return {"params": params, "param_ps": param_ps, "batch": batch,
                "batch_ps": batch_ps, "caches": caches, "cache_ps": cache_ps}
    # decode: one token against a seq_len cache
    batch = _client_batch(cfg, b, 1, labels=False)
    d_ax = data_axes(mesh)
    lead = (d_ax if d_ax else None) if kind == "decode" else None
    batch_ps = jax.tree.map(
        lambda x: P(lead, *([None] * (x.ndim - 1))), batch)
    caches = abstract_caches(cfg, b, shape.seq_len)
    cache_ps = jax.tree_util.tree_map_with_path(
        lambda p, x: cache_pspec(p, x.shape, mesh, kind=kind), caches)
    return {"params": params, "param_ps": param_ps, "batch": batch,
            "batch_ps": batch_ps, "caches": caches, "cache_ps": cache_ps}


def bf16_config(cfg: ModelConfig) -> ModelConfig:
    """Production numerics: bf16 params/activations for the dry-run."""
    return dataclasses.replace(cfg, dtype="bfloat16")
