"""Pod-scale serving steps: prefill (prompt → KV caches + last logits) and
decode (one token against a seq_len cache, optionally sequence-sharded for
long contexts)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import set_mesh_rules, use_mesh
from repro.launch import specs as specs_lib
from repro.launch.mesh import mesh_rules
from repro.models.model import serve_decode, serve_prefill


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh):
    set_mesh_rules(mesh, mesh_rules(mesh, kind="prefill"))
    bundle = specs_lib.serve_specs(cfg, shape, mesh, kind="prefill")
    sh = lambda t: specs_lib.to_shardings(t, mesh)

    def step(params, batch, caches):
        return serve_prefill(params, batch, cfg, caches=caches)

    jitted = jax.jit(
        step,
        in_shardings=(sh(bundle["param_ps"]), sh(bundle["batch_ps"]),
                      sh(bundle["cache_ps"])),
        out_shardings=(None, sh(bundle["cache_ps"])),
    )
    return jitted, bundle


def build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                 kind: str = "decode"):
    """kind "decode" (batch over data) or "long" (cache seq over data)."""
    set_mesh_rules(mesh, mesh_rules(mesh, kind=kind))
    bundle = specs_lib.serve_specs(cfg, shape, mesh, kind=kind)
    sh = lambda t: specs_lib.to_shardings(t, mesh)
    seq_shard = kind == "long"

    def step(params, batch, caches, pos_offset):
        return serve_decode(params, batch, caches, pos_offset, cfg,
                            seq_shard=seq_shard)

    jitted = jax.jit(
        step,
        in_shardings=(sh(bundle["param_ps"]), sh(bundle["batch_ps"]),
                      sh(bundle["cache_ps"]), None),
        out_shardings=(None, sh(bundle["cache_ps"])),
    )
    return jitted, bundle


def lower_serve(cfg: ModelConfig, shape: ShapeConfig, mesh, *, kind: str):
    with use_mesh(mesh):
        if kind == "prefill":
            jitted, bundle = build_prefill(cfg, shape, mesh)
            lowered = jitted.lower(bundle["params"], bundle["batch"],
                                   bundle["caches"])
        else:
            jitted, bundle = build_decode(cfg, shape, mesh, kind=kind)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jitted.lower(bundle["params"], bundle["batch"],
                                   bundle["caches"], pos)
    return lowered, bundle


def build_personalized_decode(cfg: ModelConfig, shape: ShapeConfig, mesh,
                              spec):
    """Personalized decode tick at pod scale (serving/personalized.py):
    the `(P,)` flat base shards over the model axes with the SAME
    ``flat_param_pspec`` rule the flat training state uses, the per-slot
    `(B, P)` delta rows additionally shard their batch dim over the data
    axes, and the per-slot rows (base + delta) feed the vmapped view-table
    decode — one program serves every client's personalized view."""
    from repro.serving.personalized import personalized_decode

    set_mesh_rules(mesh, mesh_rules(mesh, kind="decode"))
    bundle = specs_lib.serve_specs(cfg, shape, mesh, kind="decode")
    sh = lambda t: specs_lib.to_shardings(t, mesh)
    b = shape.global_batch
    bundle["base"] = jax.ShapeDtypeStruct((spec.p,), spec.dtype)
    bundle["base_ps"] = specs_lib.flat_param_pspec(mesh, spec.p)
    bundle["deltas"] = jax.ShapeDtypeStruct((b, spec.p), spec.dtype)
    bundle["delta_ps"] = specs_lib.flat_param_pspec(mesh, spec.p,
                                                    client_dims=1)

    def step(base, deltas, batch, caches, pos_offset):
        rows = base[None] + deltas
        return personalized_decode(spec, cfg, rows, batch["tokens"],
                                   caches, pos_offset)

    jitted = jax.jit(
        step,
        in_shardings=(sh(bundle["base_ps"]), sh(bundle["delta_ps"]),
                      sh(bundle["batch_ps"]), sh(bundle["cache_ps"]), None),
        out_shardings=(None, sh(bundle["cache_ps"])),
    )
    return jitted, bundle


def lower_personalized_serve(cfg: ModelConfig, shape: ShapeConfig, mesh,
                             spec):
    with use_mesh(mesh):
        jitted, bundle = build_personalized_decode(cfg, shape, mesh, spec)
        pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        lowered = jitted.lower(bundle["base"], bundle["deltas"],
                               bundle["batch"], bundle["caches"], pos)
    return lowered, bundle
