"""Production mesh construction + logical-axis rules.

TPU v5e target: single pod = 16×16 = 256 chips, multi-pod = 2 pods = 512.
``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — callers (dryrun.py) set
``xla_force_host_platform_device_count`` before the first jax call.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, variant: str = "tp16"):
    """Same 256/512 chips, two logical factorizations:

    tp16 (baseline contract): (data=16, model=16) — 16-way tensor
        parallelism inside each client slice.
    2d   (§Perf #4): (data=16, batch=4, model=4) — the 16 chips of a client
        slice split into 4-way per-client batch parallelism × 4-way tensor
        parallelism; Megatron-style activation all-reduces shrink 4× in
        group width AND 4× in payload (batch-sharded activations).
    """
    if variant == "tp16":
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    elif variant == "2d":
        shape = (2, 16, 4, 4) if multi_pod else (16, 4, 4)
        axes = (("pod", "data", "batch", "model") if multi_pod
                else ("data", "batch", "model"))
    else:
        raise ValueError(variant)
    return jax.make_mesh(shape, axes)


def recommended_variant(cfg) -> str:
    """Per-family mesh factorization (EXPERIMENTS.md §Perf #4 negative
    finding): MoE archs need the WIDE model axis for expert parallelism
    (tp16); dense/MQA/SSM trainers gain 1.2–11.7× from the 2d variant."""
    return "tp16" if cfg.moe is not None else "2d"


def make_local_mesh(data: int = 2, model: int = 2):
    """Small mesh over host devices for tests (set device_count first)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """All mesh axes that carry batch/client parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a == "model")


def mesh_rules(mesh, *, kind: str) -> dict[str, tuple[str, ...]]:
    """Logical→physical rules per step kind (see dist/sharding.py).

    train   : client axis is consumed by vmap(spmd_axis_name=data axes);
              inside the per-client function dp is unmapped.
    prefill/decode : batch over data axes, tensor over model.
    long    : batch=1 ⇒ dp unmapped, KV-cache sequence over data ("sp").
    """
    batch = ("batch",) if "batch" in mesh.axis_names else ()
    if kind == "train":
        return {"dp": batch, "mp": model_axes(mesh), "sp": ()}
    if kind in ("prefill", "decode"):
        return {"dp": data_axes(mesh) + batch, "mp": model_axes(mesh),
                "sp": ()}
    if kind == "long":
        return {"dp": batch, "mp": model_axes(mesh), "sp": data_axes(mesh)}
    raise ValueError(kind)


def n_clients(mesh) -> int:
    """Training clients = product of data-like axes (one client per slice)."""
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out
