"""Attention: MHA / GQA / MQA, sliding-window (Gemma3), MLA (DeepSeek-V2),
M-RoPE (Qwen2-VL).  Logical sharding constraints throughout; training /
prefill runs the Pallas flash kernel on TPU (scores stay in VMEM — the
§Perf structural fix for the memory-bound trainers) with a q-block-scan
jnp fallback elsewhere; decode attends a positional KV cache (optionally
sequence-sharded for long contexts).

``REPRO_FLASH_ATTENTION``: ``auto`` (default — kernel on TPU only),
``interpret`` (force the kernel in interpret mode; tests), ``off``.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import axis_size, constrain
from repro.models.layers import apply_rope, dense_init, rms_norm, softcap


def _flash_mode() -> str:
    return os.environ.get("REPRO_FLASH_ATTENTION", "auto")


def _flash_ok(S: int, logit_cap: float, q_pos) -> bool:
    """Kernel path applies to full in-flight attention (training/prefill):
    contiguous positions, no soft-capping, tile-aligned sequence."""
    mode = _flash_mode()
    if mode == "off":
        return False
    if mode == "auto" and jax.default_backend() != "tpu":
        return False
    return logit_cap == 0.0 and S % 128 == 0

Params = dict[str, Any]
NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        k1, k2, k3, k4 = jax.random.split(key, 4)
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "wq": dense_init(k1, d, H * qk_dim, dtype),
            "w_kv_down": dense_init(k2, d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
            "w_kv_up": dense_init(k3, m.kv_lora_rank,
                                  H * (m.qk_nope_head_dim + m.v_head_dim), dtype),
            "wo": dense_init(k4, H * m.v_head_dim, d, dtype),
            "ckv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        }
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(k1, d, H * hd, dtype),
        "wk": dense_init(k2, d, Hkv * hd, dtype),
        "wv": dense_init(k3, d, Hkv * hd, dtype),
        "wo": dense_init(k4, H * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    return p


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
               window_only: bool = False) -> Params:
    """Positional KV cache.  ``pos[b, s]`` holds the absolute position
    written to slot ``s`` of row ``b`` (-1 = empty) — PER ROW, so a
    continuous-batching engine can hold requests at different phases in
    one pool; local layers use a rolling buffer of size
    ``sliding_window``."""
    size = min(max_len, cfg.sliding_window) if window_only and cfg.sliding_window else max_len
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, size, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, size, m.qk_rope_head_dim), dtype),
            "pos": jnp.full((batch, size), -1, jnp.int32),
            "idx": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),
        "idx": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# masked softmax attention cores
# ---------------------------------------------------------------------------

def _mask(q_pos, kv_pos, window, is_global):
    """Causal + optional sliding-window mask.  q_pos (Q,), kv_pos (K,)."""
    causal = kv_pos[None, :] <= q_pos[:, None]
    valid = kv_pos[None, :] >= 0
    if window:
        local = kv_pos[None, :] > q_pos[:, None] - window
        win = jnp.logical_and(causal, local)
        sel = jnp.where(is_global, causal, win)
    else:
        sel = causal
    return jnp.logical_and(sel, valid)


def _mask_rows(q_pos, kv_pos, window, is_global):
    """Per-row decode mask.  q_pos (B,), kv_pos (B, S) -> (B, S)."""
    causal = kv_pos <= q_pos[:, None]
    valid = kv_pos >= 0
    if window:
        local = kv_pos > q_pos[:, None] - window
        win = jnp.logical_and(causal, local)
        sel = jnp.where(is_global, causal, win)
    else:
        sel = causal
    return jnp.logical_and(sel, valid)


def blocked_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                      is_global=True, logit_cap: float = 0.0,
                      block_q: int = 512) -> jax.Array:
    """Causal attention, scanned over query blocks (bounded score memory).

    q (B, Sq, H, D); k, v (B, Skv, Hkv, D); GQA broadcast via head groups.
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = D ** -0.5
    bq = min(block_q, Sq)
    n_blk = -(-Sq // bq)
    pad = n_blk * bq - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=-1)
    qb = q.reshape(B, n_blk, bq, Hkv, g, D).transpose(1, 0, 2, 3, 4, 5)
    pb = q_pos.reshape(n_blk, bq)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # sliding-window layers only touch a (window + bq)-wide kv band per q
    # block — computing full S-wide scores and masking wasted 62% of the
    # local layers' score traffic on gemma3-12b/train_4k (§Perf #7)
    Skv = k.shape[1]
    band = (min(Skv, window + bq)
            if (window and is_global is False and Skv == q.shape[1]) else 0)
    starts = (jnp.clip(jnp.arange(n_blk) * bq + bq - band, 0, Skv - band)
              if band else jnp.zeros((n_blk,), jnp.int32))

    def body(_, inp):
        qi, pi, start = inp
        if band:
            kk = jax.lax.dynamic_slice_in_dim(kf, start, band, axis=1)
            vv = jax.lax.dynamic_slice_in_dim(vf, start, band, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kv_pos, start, band, axis=0)
        else:
            kk, vv, kp = kf, vf, kv_pos
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qi.astype(jnp.float32) * scale,
                       kk)
        s = softcap(s, logit_cap)
        m = _mask(pi, kp, window, is_global)
        s = jnp.where(m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vv)
        return None, o.astype(q.dtype)

    # remat the per-block body: without it the backward pass stores the f32
    # softmax probabilities of EVERY block — S²-sized residuals that made
    # the memory roofline term 51 s/round on llama3-8b/train_4k
    # (EXPERIMENTS.md §Perf #2); recomputing them costs ~⅓ extra attention
    # FLOPs on a compute term 10× smaller than the memory term.
    _, out = jax.lax.scan(jax.checkpoint(body), None, (qb, pb, starts))
    Dv = v.shape[-1]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, n_blk * bq, H, Dv)
    return out[:, :Sq]


def full_attention(q, k, v, q_pos, *, window: int = 0, is_global=True,
                   logit_cap: float = 0.0) -> jax.Array:
    """In-flight (q_pos == kv_pos, contiguous) attention: Pallas flash
    kernel when eligible, q-block scan otherwise.  Causal/window masks
    depend only on relative position, so any contiguous offset is exact."""
    S = q.shape[1]
    if q_pos.ndim == 2:        # (B, S) row positions: masks are relative,
        q_pos = q_pos[0]       # so any row's positions give the same mask
    # is_global is a static Python bool at every call site
    win = 0 if (is_global is True or not window) else window
    if isinstance(is_global, bool) and _flash_ok(S, logit_cap, q_pos):
        from repro.kernels.flash_attention.ops import flash_attention_diff
        interpret = None if _flash_mode() == "auto" else True
        return flash_attention_diff(q, k, v, causal=True, window=win,
                                    interpret=interpret)
    return blocked_attention(q, k, v, q_pos, q_pos, window=window,
                             is_global=is_global, logit_cap=logit_cap)


def decode_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                     is_global=True, logit_cap: float = 0.0) -> jax.Array:
    """Single-position attention against a (possibly sequence-sharded) cache.

    q (B, 1, H, D); k, v (B, S, Hkv, D).  Softmax over S: when the cache is
    sharded over ``sp`` XLA inserts the max/sum all-reduces (flash-decode
    combine) automatically.
    """
    B, _, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = D ** -0.5
    qr = q.reshape(B, Hkv, g, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    s = softcap(s, logit_cap)
    m = _mask_rows(q_pos, kv_pos, window, is_global)        # (B, S)
    s = jnp.where(m[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, H, v.shape[-1]).astype(q.dtype)


def _cache_insert(buf: jax.Array, new: jax.Array, start) -> jax.Array:
    """Write ``new`` (B, S, …) at per-row ring slots
    ``(start[b] + arange(S)) % size``.  ``start`` (B,) int32.

    Writes covering the whole ring (prefill) become a size-bounded gather
    instead of an S-sized batched scatter — the scatter partitions badly
    under SPMD (gemma3 prefill collective term 5.2 → 21.8 s; §Perf #9)."""
    B, size = buf.shape[0], buf.shape[1]
    S = new.shape[1]
    if S >= size:
        # ring slot j of row b ends up holding in-flight index
        # (j − start_b − S) mod size of the last `size` tokens
        tail = new[:, -size:]
        idx = (jnp.arange(size)[None] - start[:, None] - S) % size
        return tail[jnp.arange(B)[:, None], idx].astype(buf.dtype)
    slots = (start[:, None] + jnp.arange(S)) % size              # (B, S)
    rows = jnp.arange(B)[:, None]
    return buf.at[rows, slots].set(new.astype(buf.dtype))


def _pos_insert(pos: jax.Array, q_pos: jax.Array, start) -> jax.Array:
    """pos (B, size); q_pos (B, S) absolute positions; start (B,)."""
    B, size = pos.shape
    S = q_pos.shape[1]
    if S >= size:
        tail = q_pos[:, -size:]
        idx = (jnp.arange(size)[None] - start[:, None] - S) % size
        return tail[jnp.arange(B)[:, None], idx].astype(jnp.int32)
    slots = (start[:, None] + jnp.arange(S)) % size
    rows = jnp.arange(B)[:, None]
    return pos.at[rows, slots].set(q_pos.astype(jnp.int32))


# ---------------------------------------------------------------------------
# full attention layer (standard / GQA path)
# ---------------------------------------------------------------------------

def attention(params: Params, x: jax.Array, cfg: ModelConfig, *,
              angles: jax.Array, q_pos: jax.Array, is_global=True,
              cache: Optional[Params] = None,
              seq_shard: bool = False) -> tuple[jax.Array, Optional[Params]]:
    if cfg.mla is not None:
        return mla_attention(params, x, cfg, angles=angles, q_pos=q_pos,
                             cache=cache, seq_shard=seq_shard)
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = apply_rope(q, angles)
    k = apply_rope(k, angles)
    # dist.constrain drops any axis that does not divide (see sharding.py);
    # kv heads stay replicated on meshes wider than Hkv.
    q = constrain(q, "dp", None, "mp", None)
    k = constrain(k, "dp", None, "mp", None)

    window = cfg.sliding_window
    if cache is None:
        out = full_attention(q, k, v, q_pos, window=window,
                             is_global=is_global,
                             logit_cap=cfg.attn_logit_softcap)
        new_cache = None
    else:
        slot = cache["idx"]                          # (B,)
        q_pos_rows = (q_pos if q_pos.ndim == 2
                      else jnp.broadcast_to(q_pos[None], (B, S)))
        cache = dict(cache)
        cache["k"] = _cache_insert(cache["k"], k, slot)
        cache["v"] = _cache_insert(cache["v"], v, slot)
        cache["pos"] = _pos_insert(cache["pos"], q_pos_rows, slot)
        cache["idx"] = cache["idx"] + S
        new_cache = cache
        if S > 1:
            # prefill-into-cache: cache was empty, so attending over the
            # in-flight sequence is exact
            out = full_attention(q, k, v, q_pos, window=window,
                                 is_global=is_global,
                                 logit_cap=cfg.attn_logit_softcap)
        else:
            kc, vc = cache["k"], cache["v"]
            if seq_shard:
                kc = constrain(kc, "dp", "sp", None, None)
                vc = constrain(vc, "dp", "sp", None, None)
            out = decode_attention(q, kc, vc, q_pos_rows[:, 0],
                                   cache["pos"], window=window,
                                   is_global=is_global,
                                   logit_cap=cfg.attn_logit_softcap)
    out = constrain(out, "dp", None, "mp", None)
    y = jnp.einsum("bse,ed->bsd", out.reshape(B, S, H * hd), params["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_decode_absorbed(params, cfg: ModelConfig, q_nope, q_rope, cache,
                        q_pos, *, seq_shard: bool) -> jax.Array:
    """Weight-absorbed MLA decode (§Perf #5).

    Scores and outputs are computed in the r-dimensional latent space:
        q̃ = q_nope · W_uk            (B, H, r)
        s  = q̃ · ckvᵀ + q_rope · k_ropeᵀ        (B, H, S)
        õ  = softmax(s) · ckv         (B, H, r)
        o  = õ · W_uv                 (B, H, dv)
    vs the naive path's per-token up-projection of the WHOLE cache
    (O(S·H·(dn+dv)·r) → O(S·H·r)): ~(dn+dv)=256× less decode compute.
    Exactly equivalent in exact arithmetic — W_uk/W_uv are linear.
    """
    m = cfg.mla
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    B = q_nope.shape[0]
    w_up = params["w_kv_up"].reshape(m.kv_lora_rank, H, dn + dv)
    w_uk = w_up[..., :dn]                                    # (r, H, dn)
    w_uv = w_up[..., dn:]                                    # (r, H, dv)

    ckv = cache["ckv"]                                       # (B, S, r)
    krope = cache["krope"]                                   # (B, S, dr)
    if seq_shard:
        ckv = constrain(ckv, "dp", "sp", None)
        krope = constrain(krope, "dp", "sp", None)

    scale = (dn + dr) ** -0.5
    # keep the big cache operands in their storage dtype and accumulate in
    # f32 (native MXU behaviour) — an explicit astype(f32) would double the
    # cache-read bytes, the dominant roofline term of MLA decode (§Perf #6)
    f32 = jnp.float32
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope, w_uk,
                       preferred_element_type=f32)           # (B, H, r)
    s = (jnp.einsum("bhr,bsr->bhs", q_abs.astype(ckv.dtype), ckv,
                    preferred_element_type=f32)
         + jnp.einsum("bhd,bsd->bhs", q_rope, krope,
                      preferred_element_type=f32)) * scale
    s = softcap(s, cfg.attn_logit_softcap)
    mask = _mask_rows(q_pos, cache["pos"], 0, True)          # (B, S)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p.astype(ckv.dtype), ckv,
                       preferred_element_type=f32)
    o = jnp.einsum("bhr,rhd->bhd", o_lat.astype(ckv.dtype), w_uv,
                   preferred_element_type=f32)
    return o.reshape(B, 1, H, dv).astype(ckv.dtype)

def mla_attention(params: Params, x: jax.Array, cfg: ModelConfig, *,
                  angles: jax.Array, q_pos: jax.Array,
                  cache: Optional[Params] = None,
                  seq_shard: bool = False) -> tuple[jax.Array, Optional[Params]]:
    m = cfg.mla
    assert m is not None
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ang_r = angles[..., : dr // 2]
    q_rope = apply_rope(q_rope, ang_r)

    kv = jnp.einsum("bsd,de->bse", x, params["w_kv_down"])
    ckv, k_rope = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    ckv = rms_norm(ckv, params["ckv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], ang_r)        # (B,S,1,dr)

    def expand(ckv_seq):
        up = jnp.einsum("bsl,le->bse", ckv_seq, params["w_kv_up"])
        up = up.reshape(B, -1, H, dn + dv)
        return up[..., :dn], up[..., dn:]

    if cache is None:
        k_nope, v = expand(ckv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))],
                            axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        qq = constrain(qq, "dp", None, "mp", None)
        out = full_attention(qq, k, v, q_pos,
                             logit_cap=cfg.attn_logit_softcap)
        new_cache = None
    else:
        size = cache["ckv"].shape[1]
        slot = cache["idx"]                          # (B,)
        q_pos_rows = (q_pos if q_pos.ndim == 2
                      else jnp.broadcast_to(q_pos[None], (B, S)))
        cache = dict(cache)
        cache["ckv"] = _cache_insert(cache["ckv"], ckv, slot)
        cache["krope"] = _cache_insert(cache["krope"], k_rope[:, :, 0, :], slot)
        cache["pos"] = _pos_insert(cache["pos"], q_pos_rows, slot)
        cache["idx"] = cache["idx"] + S
        new_cache = cache
        if S > 1:
            k_nope, v = expand(ckv)
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
            qq = jnp.concatenate([q_nope, q_rope], axis=-1)
            out = full_attention(qq, k, v, q_pos,
                                 logit_cap=cfg.attn_logit_softcap)
        elif not m.absorb:
            ckv_c = cache["ckv"]
            if seq_shard:
                ckv_c = constrain(ckv_c, "dp", "sp", None)
            # Naive MLA decode: up-project the whole cache per token —
            # O(S·H·(dn+dv)·r) FLOPs; kept as the §Perf #5 A/B baseline
            # (useful_ratio 0.001 on deepseek-v2-lite/decode_32k).
            k_nope, v = expand(ckv_c)
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(cache["krope"][:, :, None, :],
                                          (B, size, H, dr))], axis=-1)
            qq = jnp.concatenate([q_nope, q_rope], axis=-1)
            out = decode_attention(qq, k, v, q_pos_rows[:, 0], cache["pos"],
                                   logit_cap=cfg.attn_logit_softcap)
        else:
            out = mla_decode_absorbed(params, cfg, q_nope[:, 0], q_rope[:, 0],
                                      cache, q_pos_rows[:, 0],
                                      seq_shard=seq_shard)
    out = constrain(out, "dp", None, "mp", None)
    y = jnp.einsum("bse,ed->bsd", out.reshape(B, S, H * dv), params["wo"])
    return y, new_cache
