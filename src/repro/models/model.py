"""Composable LM assembly: embeddings → scanned block segments → head.

Layers are grouped into *segments* of identical block kinds so every
architecture — dense, MoE, SSM, hybrid (weight-shared attention), xLSTM,
local/global sliding window — lowers as lax.scan over stacked params:

    uniform  : [(kind, 1, shared=False)] × n_layers
    zamba2   : [("mamba2", E, False), ("attn", 1, shared=True)] × (L / E)
    xlstm    : [("mlstm", E-1, False), ("slstm", 1, False)] × (L / E)
    gemma3   : [("attn_local", E-1, False), ("attn_global", 1, False)] × (L / E)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import constrain
from repro.models.blocks import apply_block, init_block, init_block_cache
from repro.models.layers import (apply_norm, embed_init, init_norm,
                                 mrope_angles, rope_angles)

Params = dict[str, Any]


def group_spec(cfg: ModelConfig) -> tuple[list[tuple[str, int, bool]], int]:
    if cfg.hybrid_attn_every:
        e = cfg.hybrid_attn_every
        assert cfg.n_layers % e == 0, (cfg.n_layers, e)
        return [("mamba2", e, False), ("attn", 1, cfg.hybrid_shared_attn)], cfg.n_layers // e
    if cfg.xlstm is not None:
        e = cfg.xlstm.slstm_every
        assert cfg.n_layers % e == 0
        return [("mlstm", e - 1, False), ("slstm", 1, False)], cfg.n_layers // e
    if cfg.sliding_window and cfg.global_every:
        e = cfg.global_every
        assert cfg.n_layers % e == 0
        return [("attn_local", e - 1, False), ("attn_global", 1, False)], cfg.n_layers // e
    kind = "mamba2" if (cfg.family == "ssm" and cfg.xlstm is None) else "attn"
    return [(kind, 1, False)], cfg.n_layers


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig, dtype=None) -> Params:
    dtype = jnp.dtype(dtype or cfg.dtype)
    segments, n_groups = group_spec(cfg)
    keys = jax.random.split(key, len(segments) + 4)

    params: Params = {"segments": []}
    for si, (kind, count, shared) in enumerate(segments):
        if shared:
            params["segments"].append({})
            params["shared_attn"] = init_block(keys[si], kind, cfg, dtype)
            continue
        n = n_groups * count
        ks = jax.random.split(keys[si], n)
        stacked = jax.vmap(lambda kk: init_block(kk, kind, cfg, dtype))(ks)
        stacked = jax.tree.map(
            lambda a: a.reshape((n_groups, count) + a.shape[1:]), stacked)
        params["segments"].append(stacked)

    ek = keys[len(segments)]
    if cfg.frontend == "audio":
        params["embed"] = jnp.stack(
            [embed_init(k, cfg.vocab, cfg.d_model, dtype)
             for k in jax.random.split(ek, cfg.n_codebooks)])
        params["heads"] = jnp.stack(
            [embed_init(k, cfg.d_model, cfg.vocab, dtype).reshape(cfg.d_model, cfg.vocab)
             for k in jax.random.split(keys[len(segments) + 1], cfg.n_codebooks)])
    else:
        params["embed"] = embed_init(ek, cfg.vocab, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            params["head"] = embed_init(keys[len(segments) + 1], cfg.d_model,
                                        cfg.vocab, dtype).reshape(cfg.d_model, cfg.vocab)
    params["final_norm"] = init_norm(keys[-1], cfg.d_model, cfg.norm, dtype)
    return params


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype) -> list:
    segments, n_groups = group_spec(cfg)
    caches = []
    for kind, count, _shared in segments:
        proto = init_block_cache(kind, cfg, batch, max_len, dtype)
        caches.append(jax.tree.map(
            lambda a: jnp.tile(a[None, None],
                               (n_groups, count) + (1,) * a.ndim), proto))
    return caches


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _row_positions(B: int, S: int, pos_offset) -> jax.Array:
    """(B, S) absolute positions from a scalar or per-row (B,) offset —
    per-row offsets let a continuous-batching engine hold requests at
    different phases in one cache pool (serving/engine.py)."""
    off = jnp.asarray(pos_offset, jnp.int32)
    if off.ndim == 0:
        off = jnp.broadcast_to(off[None], (B,))
    return off[:, None] + jnp.arange(S, dtype=jnp.int32)[None]


def _embed(params: Params, batch: dict, cfg: ModelConfig, pos_offset):
    if cfg.frontend == "vision":
        h = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        B, S = h.shape[0], h.shape[1]
        positions = batch["positions"]                       # (B, 3, S)
        angles = mrope_angles(positions.transpose(1, 0, 2),
                              cfg.resolved_head_dim, cfg.rope_theta,
                              cfg.mrope_sections)
        return h, _row_positions(B, S, pos_offset), angles
    if cfg.frontend == "audio":
        codes = batch["codes"]                               # (B, K, S)
        B, S = codes.shape[0], codes.shape[-1]
        h = sum(params["embed"][k][codes[:, k]]
                for k in range(cfg.n_codebooks))
        q_pos = _row_positions(B, S, pos_offset)
        angles = rope_angles(q_pos, cfg.resolved_head_dim, cfg.rope_theta)
        return h, q_pos, angles
    tokens = batch["tokens"]
    B, S = tokens.shape[0], tokens.shape[-1]
    h = params["embed"][tokens]
    q_pos = _row_positions(B, S, pos_offset)
    angles = rope_angles(q_pos, cfg.resolved_head_dim, cfg.rope_theta)
    return h, q_pos, angles


def forward(params: Params, batch: dict, cfg: ModelConfig, *,
            caches: Optional[list] = None, pos_offset=0,
            seq_shard: bool = False, last_only: bool = False
            ) -> tuple[jax.Array, Optional[list], jax.Array]:
    """Returns (logits, new_caches, aux_loss).  ``last_only`` computes the
    LM head only for the final position (serving prefill)."""
    segments, n_groups = group_spec(cfg)
    h, q_pos, angles = _embed(params, batch, cfg, pos_offset)
    h = constrain(h, "dp", None, None)
    aux0 = jnp.zeros((), jnp.float32)

    def group_fn(carry, xs):
        hh, aux = carry
        seg_p, seg_c = xs
        new_cs = []
        for si, (kind, count, shared) in enumerate(segments):
            if shared:
                c = seg_c[si]
                c1 = jax.tree.map(lambda a: a[0], c) if jax.tree.leaves(c) else None
                hh, nc, a = apply_block(params["shared_attn"], kind, hh, cfg,
                                        angles=angles, q_pos=q_pos, cache=c1,
                                        seq_shard=seq_shard)
                new_cs.append(jax.tree.map(lambda x: x[None], nc) if nc is not None else {})
                aux = aux + a
            else:
                def layer_fn(inner, xs2):
                    h2, a2 = inner
                    p2, c2 = xs2
                    c2 = c2 if jax.tree.leaves(c2) else None
                    h2, nc2, al = apply_block(p2, kind, h2, cfg, angles=angles,
                                              q_pos=q_pos, cache=c2,
                                              seq_shard=seq_shard)
                    return (h2, a2 + al), (nc2 if nc2 is not None else {})
                (hh, aux), ncs = jax.lax.scan(layer_fn, (hh, aux),
                                              (seg_p[si], seg_c[si]))
                new_cs.append(ncs)
        return (hh, aux), new_cs

    if cfg.remat:
        group_fn = jax.checkpoint(group_fn)

    seg_caches = caches if caches is not None else [{} for _ in segments]
    (h, aux), new_caches = jax.lax.scan(group_fn, (h, aux0),
                                        (params["segments"], seg_caches))

    h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    if cfg.frontend == "audio":
        logits = jnp.einsum("bsd,kdv->bskv", h, params["heads"])
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["head"])
    logits = constrain(logits, "dp", None, "mp")
    return logits, (new_caches if caches is not None else None), aux


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits (..., V) fp-any; labels (...) int32.

    The label pick is a masked reduction, NOT ``take_along_axis``: XLA:CPU
    lowers the 1-element gather to a SERIAL while loop over every (row,
    label) pair — ~2.3 ms per round on the benchmark tasks, longer than
    the entire k-step scan it feeds (found profiling the flat-layout
    round, DESIGN.md §11).  The select+sum picks the identical value
    (adding exact zeros), vectorized."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    mask = labels[..., None] == jnp.arange(v, dtype=labels.dtype)
    ll = jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)
    return jnp.mean(lse - ll)


def lm_loss(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    logits, _, aux = forward(params, batch, cfg)
    if cfg.frontend == "audio":
        labels = batch["labels"]                            # (B, K, S)
        loss = cross_entropy(logits, labels.transpose(0, 2, 1))
    else:
        loss = cross_entropy(logits, batch["labels"])
    return loss + aux


def serve_prefill(params: Params, batch: dict, cfg: ModelConfig,
                  caches: Optional[list] = None):
    """Fill the KV caches for the prompt, return last-position logits."""
    logits, new_caches, _ = forward(params, batch, cfg, caches=caches,
                                    last_only=True)
    return logits, new_caches


def serve_decode(params: Params, batch: dict, caches: list, pos_offset,
                 cfg: ModelConfig, seq_shard: bool = False):
    logits, new_caches, _ = forward(params, batch, cfg, caches=caches,
                                    pos_offset=pos_offset, seq_shard=seq_shard)
    return logits, new_caches
