"""Shared low-level layers: norms, initializers, rotary embeddings (RoPE,
partial RoPE for MLA, M-RoPE for Qwen2-VL)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


def init_norm(key, d: int, kind: str, dtype):
    del key
    if kind == "rms":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(params, x, kind: str, eps: float):
    if kind == "rms":
        return rms_norm(x, params["scale"], eps)
    return layer_norm(x, params["scale"], params["bias"], eps)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.relu(x)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jax.Array:
    """(dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions (..., S) -> angles (..., S, dim/2)."""
    inv = rope_freqs(dim, theta)
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x (B, S, H, D) with D even; angles (B, S, D/2) or (S, D/2).

    Rotates pairs (x[..., :D/2], x[..., D/2:]) — the "rotate_half" layout
    used by Llama/Gemma/Qwen.
    """
    dt = x.dtype
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2].astype(jnp.float32), x[..., d2:].astype(jnp.float32)
    if angles.ndim == 2:          # (S, D/2) broadcast over batch
        cos = jnp.cos(angles)[None, :, None, :]
        sin = jnp.sin(angles)[None, :, None, :]
    else:                          # (B, S, D/2)
        cos = jnp.cos(angles)[:, :, None, :]
        sin = jnp.sin(angles)[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def mrope_angles(positions: jax.Array, dim: int, theta: float,
                 sections: tuple[int, ...]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL).

    positions: (3, B, S) — temporal / height / width position ids.
    sections:  per-axis number of *frequency pairs*; sum == dim // 2.
    Returns angles (B, S, dim/2) where frequency slot j uses the position id
    of the axis that owns slot j.
    """
    assert sum(sections) == dim // 2, (sections, dim)
    inv = rope_freqs(dim, theta)                       # (dim/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv   # (3, B, S, dim/2)
    parts = []
    start = 0
    for axis, width in enumerate(sections):
        parts.append(ang[axis, :, :, start:start + width])
        start += width
    return jnp.concatenate(parts, axis=-1)             # (B, S, dim/2)
