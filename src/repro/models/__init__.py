from repro.models.model import (cross_entropy, forward, group_spec,
                                init_caches, init_params, lm_loss,
                                serve_decode, serve_prefill)

__all__ = ["cross_entropy", "forward", "group_spec", "init_caches",
           "init_params", "lm_loss", "serve_decode", "serve_prefill"]
