"""Small models matching the paper's experimental suite:

* logistic regression (convex — a9a / Fashion-MNIST LR experiments)
* MLP and 2-layer CNN (non-convex — Fashion-MNIST CNN experiments)
* quadratic objectives with a closed-form optimum (Theorem 1/3 validation)

All are functional: ``init(key, ...) -> params``, ``loss(params, batch) -> scalar``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import cross_entropy


# -- logistic regression ----------------------------------------------------

def lr_init(key, n_features: int, n_classes: int) -> dict:
    return {"w": jnp.zeros((n_features, n_classes), jnp.float32),
            "b": jnp.zeros((n_classes,), jnp.float32)}


def lr_loss(params: dict, batch: dict) -> jax.Array:
    logits = batch["x"] @ params["w"] + params["b"]
    return cross_entropy(logits, batch["y"])


# -- MLP ---------------------------------------------------------------------

def mlp_init(key, n_features: int, hidden: int, n_classes: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (n_features, hidden)) * (2.0 / n_features) ** 0.5,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, n_classes)) * (2.0 / hidden) ** 0.5,
        "b2": jnp.zeros((n_classes,)),
    }


def mlp_loss(params: dict, batch: dict) -> jax.Array:
    h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    return cross_entropy(logits, batch["y"])


def mlp_accuracy(params: dict, batch: dict) -> jax.Array:
    h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    return jnp.mean(jnp.argmax(logits, -1) == batch["y"])


def lr_accuracy(params: dict, batch: dict) -> jax.Array:
    logits = batch["x"] @ params["w"] + params["b"]
    return jnp.mean(jnp.argmax(logits, -1) == batch["y"])


# -- 2-layer CNN (paper Table 3, adapted to 28x28x1 synthetic images) ---------

def cnn_init(key, n_classes: int = 10) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "c1": jax.random.normal(ks[0], (5, 5, 1, 10)) * 0.1,
        "c2": jax.random.normal(ks[1], (5, 5, 10, 20)) * 0.1,
        "w1": jax.random.normal(ks[2], (320, 50)) * (2.0 / 320) ** 0.5,
        "b1": jnp.zeros((50,)),
        "w2": jax.random.normal(ks[3], (50, n_classes)) * (2.0 / 50) ** 0.5,
        "b2": jnp.zeros((n_classes,)),
    }


def _cnn_logits(params: dict, x: jax.Array) -> jax.Array:
    def conv(h, w):
        return jax.lax.conv_general_dilated(
            h, w, (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    def pool(h):
        return jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")

    h = pool(jax.nn.relu(conv(x, params["c1"])))          # (B,12,12,10)
    h = pool(jax.nn.relu(conv(h, params["c2"])))          # (B,4,4,20)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def cnn_loss(params: dict, batch: dict) -> jax.Array:
    return cross_entropy(_cnn_logits(params, batch["x"]), batch["y"])


def cnn_accuracy(params: dict, batch: dict) -> jax.Array:
    return jnp.mean(jnp.argmax(_cnn_logits(params, batch["x"]), -1) == batch["y"])


# -- client quadratics (Theorem 1 / 3 closed forms) ---------------------------

def quad_loss(params: dict, batch: dict) -> jax.Array:
    """F_i(x) = 0.5 ||A x - b||^2 + c0, strongly convex, non-negative."""
    x = params["x"]
    r = batch["A"] @ x - batch["b"]
    return 0.5 * jnp.dot(r, r) + batch["c0"]


def quad_global_opt(As: jax.Array, bs: jax.Array, weights: jax.Array) -> jax.Array:
    """argmin Σ_i w_i * 0.5||A_i x − b_i||² = (Σ w_i A_iᵀA_i)⁻¹ Σ w_i A_iᵀ b_i."""
    H = jnp.einsum("i,iab,iac->bc", weights, As, As)
    g = jnp.einsum("i,iab,ia->b", weights, As, bs)
    return jnp.linalg.solve(H, g)
