"""Residual blocks over the mixer zoo, with a uniform (params, cache) calling
convention so model.py can lax.scan stacked layers."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import apply_norm, init_norm
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe

Params = dict[str, Any]

ATTN_KINDS = ("attn", "attn_local", "attn_global")


def init_block(key, kind: str, cfg: ModelConfig, dtype) -> Params:
    keys = jax.random.split(key, 4)
    if kind in ATTN_KINDS:
        p = {
            "norm1": init_norm(keys[0], cfg.d_model, cfg.norm, dtype),
            "attn": attn_mod.init_attention(keys[1], cfg, dtype),
            "norm2": init_norm(keys[2], cfg.d_model, cfg.norm, dtype),
        }
        if cfg.moe is not None:
            p["moe"] = init_moe(keys[3], cfg, dtype)
        elif cfg.d_ff:
            p["mlp"] = init_mlp(keys[3], cfg, dtype)
        return p
    if kind == "mamba2":
        return {"norm": init_norm(keys[0], cfg.d_model, cfg.norm, dtype),
                "mamba": mamba_mod.init_mamba(keys[1], cfg, dtype)}
    if kind == "mlstm":
        return {"norm": init_norm(keys[0], cfg.d_model, cfg.norm, dtype),
                "mlstm": xlstm_mod.init_mlstm(keys[1], cfg, dtype)}
    if kind == "slstm":
        return {"norm": init_norm(keys[0], cfg.d_model, cfg.norm, dtype),
                "slstm": xlstm_mod.init_slstm(keys[1], cfg, dtype)}
    raise ValueError(kind)


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype) -> Params:
    if kind in ATTN_KINDS:
        return attn_mod.init_cache(cfg, batch, max_len, dtype,
                                   window_only=(kind == "attn_local"))
    if kind == "mamba2":
        return mamba_mod.init_mamba_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm_mod.init_mlstm_cache(cfg, batch, dtype)
    if kind == "slstm":
        return xlstm_mod.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def apply_block(params: Params, kind: str, x: jax.Array, cfg: ModelConfig, *,
                angles, q_pos, cache: Optional[Params], seq_shard: bool
                ) -> tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ATTN_KINDS:
        h = apply_norm(params["norm1"], x, cfg.norm, cfg.norm_eps)
        is_global = kind != "attn_local" if cfg.sliding_window else True
        a, new_cache = attn_mod.attention(
            params["attn"], h, cfg, angles=angles, q_pos=q_pos,
            is_global=is_global, cache=cache, seq_shard=seq_shard)
        x = x + a
        h = apply_norm(params["norm2"], x, cfg.norm, cfg.norm_eps)
        if cfg.moe is not None:
            m, aux = moe(params["moe"], h, cfg)
        elif cfg.d_ff:
            m = mlp(params["mlp"], h, cfg)
        else:
            m = jnp.zeros_like(x)
        return x + m, new_cache, aux
    h = apply_norm(params["norm"], x, cfg.norm, cfg.norm_eps)
    if kind == "mamba2":
        y, new_cache = mamba_mod.mamba(params["mamba"], h, cfg, cache)
    elif kind == "mlstm":
        y, new_cache = xlstm_mod.mlstm(params["mlstm"], h, cfg, cache)
    elif kind == "slstm":
        y, new_cache = xlstm_mod.slstm(params["slstm"], h, cfg, cache)
    else:
        raise ValueError(kind)
    return x + y, new_cache, aux
