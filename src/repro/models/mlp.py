"""Dense feed-forward: GLU (SwiGLU / GeGLU) or vanilla 2-layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import constrain
from repro.models.layers import activation, dense_init


def init_mlp(key, cfg: ModelConfig, dtype, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_in": dense_init(k1, d, f, dtype),
         "w_out": dense_init(k2, f, d, dtype)}
    if cfg.glu:
        p["w_gate"] = dense_init(k3, d, f, dtype)
    if cfg.mlp_bias:
        p["b_in"] = jnp.zeros((f,), dtype)
        p["b_out"] = jnp.zeros((d,), dtype)
    return p


def mlp(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    if cfg.mlp_bias:
        h = h + params["b_in"]
    if cfg.glu:
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = activation(g, cfg.act) * h
    else:
        h = activation(h, cfg.act)
    h = constrain(h, "dp", None, "mp")
    y = jnp.einsum("bsf,fd->bsd", h, params["w_out"])
    if cfg.mlp_bias:
        y = y + params["b_out"]
    return y
