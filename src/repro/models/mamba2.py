"""Mamba2 (State-Space Duality) block — chunked-parallel training scan,
O(1)-state recurrent decode.  Follows the SSD "minimal" formulation of
Dao & Gu (2024), adapted to jnp + logical sharding (heads over ``mp``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import constrain
from repro.models.layers import dense_init, rms_norm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    assert s is not None
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nh, conv_ch


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    s, d_in, nh, conv_ch = _dims(cfg)
    keys = jax.random.split(key, 4)
    d = cfg.d_model
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + nh
    return {
        "in_proj": dense_init(keys[0], d, proj_out, dtype),
        "conv_w": (jax.random.normal(keys[1], (s.d_conv, conv_ch), jnp.float32)
                   * (1.0 / s.d_conv) ** 0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(keys[2], d_in, d, dtype),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s, d_in, nh, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d: x (B, S, C), w (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b


def _segsum(x: jax.Array) -> jax.Array:
    """x (..., L) -> (..., L, L) with entry [z, s] = sum_{j=s+1..z} x_j
    (lower triangle incl. diagonal = 0; -inf above)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.

    x (b, l, h, p); dt (b, l, h) (post-softplus); A (h,) negative;
    B, C (b, l, g, n) with g groups broadcast over heads.
    Returns y (b, l, h, p), final state (b, h, p, n).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    L = min(chunk, l)
    assert l % L == 0, (l, L)
    c = l // L
    rep = h // g

    xb = (x * dt[..., None]).reshape(b, c, L, h, p).astype(jnp.float32)
    dA = (dt * A[None, None, :]).reshape(b, c, L, h)          # (b,c,L,h)
    Bc = jnp.repeat(B.reshape(b, c, L, g, n), rep, axis=3).astype(jnp.float32)
    Cc = jnp.repeat(C.reshape(b, c, L, g, n), rep, axis=3).astype(jnp.float32)

    dA_t = dA.transpose(0, 1, 3, 2)                           # (b,c,h,L)
    cum = jnp.cumsum(dA_t, axis=-1)                           # (b,c,h,L)
    Lmat = jnp.exp(_segsum(dA_t))                             # (b,c,h,L,L)

    # intra-chunk (diagonal blocks)
    CB = jnp.einsum("bczhn,bcshn->bchzs", Cc, Bc)
    y_diag = jnp.einsum("bchzs,bcshp->bczhp", CB * Lmat, xb)

    # per-chunk final states
    decay_end = jnp.exp(cum[..., -1:] - cum)                  # (b,c,h,L)
    S_chunk = jnp.einsum("bcshn,bchs,bcshp->bchpn", Bc,
                         decay_end, xb)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[..., -1])                       # (b,c,h)

    def scan_fn(S_prev, inp):
        S_c, dec = inp
        S_new = S_c + dec[..., None, None] * S_prev
        return S_new, S_prev

    S0 = jnp.zeros((b, h, p, n), jnp.float32)
    S_last, S_prevs = jax.lax.scan(
        scan_fn, S0,
        (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)                # (b,c,h,p,n)

    # inter-chunk (off-diagonal) contribution
    y_off = jnp.einsum("bczhn,bchz,bchpn->bczhp", Cc, jnp.exp(cum), S_prevs)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, S_last


def mamba(params: dict, x: jax.Array, cfg: ModelConfig,
          cache: Optional[dict] = None) -> tuple[jax.Array, Optional[dict]]:
    """x (B, S, d) -> (y (B, S, d), cache)."""
    s, d_in, nh, conv_ch = _dims(cfg)
    B_, S_, d = x.shape
    G, N, P = s.n_groups, s.d_state, s.head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: d_in + conv_ch]
    dt_raw = zxbcdt[..., d_in + conv_ch:]                     # (B,S,nh)

    new_cache = None
    if cache is None or S_ > 1:
        xbc_raw = xbc
        xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        if cache is not None:                                  # prefill
            K = params["conv_w"].shape[0]
            new_cache = {"conv": xbc_raw[:, -(K - 1):, :]}
    else:
        window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, K, C)
        xbc = (jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
               + params["conv_b"].astype(jnp.float32))[:, None, :].astype(x.dtype)
        new_cache = {"conv": window[:, 1:, :]}
    xbc = jax.nn.silu(xbc)

    xs = xbc[..., :d_in].reshape(B_, S_, nh, P)
    Bm = xbc[..., d_in: d_in + G * N].reshape(B_, S_, G, N)
    Cm = xbc[..., d_in + G * N:].reshape(B_, S_, G, N)
    xs = constrain(xs, "dp", None, "mp", None)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])

    if cache is None or S_ > 1:
        y, S_last = ssd_chunked(xs, dt, A, Bm, Cm, s.chunk)
        if new_cache is not None:
            new_cache["ssm"] = S_last
    else:
        # single-step recurrence
        rep = nh // G
        Bh = jnp.repeat(Bm[:, 0], rep, axis=1).astype(jnp.float32)   # (B,nh,N)
        Ch = jnp.repeat(Cm[:, 0], rep, axis=1).astype(jnp.float32)
        x0 = xs[:, 0].astype(jnp.float32)                             # (B,nh,P)
        dt0 = dt[:, 0]                                                # (B,nh)
        decay = jnp.exp(dt0 * A[None, :])                             # (B,nh)
        Snew = (decay[..., None, None] * cache["ssm"]
                + jnp.einsum("bhp,bhn->bhpn", x0 * dt0[..., None], Bh))
        y = jnp.einsum("bhn,bhpn->bhp", Ch, Snew)[:, None]
        new_cache["ssm"] = Snew
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B_, S_, d_in).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, new_cache
