"""Mixture-of-Experts with top-k routing.

Dispatch is *sort-based with a capacity limit* (honest active-FLOPs: no dense
one-hot matmuls): token→expert assignments are argsorted by expert id, each
expert processes a fixed-capacity (E, C, d) buffer, and outputs are combined
by gather + weighted sum.  Expert weights carry an expert axis sharded over
``mp`` — the (E, C, d) buffers are sharding-constrained on that axis so the
SPMD partitioner inserts the all-to-alls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import constrain
from repro.models.layers import activation, dense_init
from repro.models.mlp import init_mlp, mlp


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    assert cfg.moe is not None
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff, m.n_experts
    keys = jax.random.split(key, 6)
    scale = (1.0 / d) ** 0.5
    p = {
        "router": dense_init(keys[0], d, E, jnp.float32),
        "w_in": (jax.random.normal(keys[1], (E, d, f), jnp.float32) * scale).astype(dtype),
        "w_out": (jax.random.normal(keys[2], (E, f, d), jnp.float32) * (1.0 / f) ** 0.5).astype(dtype),
    }
    if cfg.glu:
        p["w_gate"] = (jax.random.normal(keys[3], (E, d, f), jnp.float32) * scale).astype(dtype)
    if m.n_shared_experts:
        p["shared"] = init_mlp(keys[4], cfg, dtype, d_ff=m.n_shared_experts * f)
    return p


def route(router_w: jax.Array, x: jax.Array, top_k: int):
    """x (T, d) -> (weights (T,k), ids (T,k), aux_loss, router_probs)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # switch-style load-balance aux loss
    E = router_w.shape[-1]
    me = jnp.mean(probs, axis=0)                              # mean prob / expert
    one_hot = jax.nn.one_hot(top_ids[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)                            # token fraction / expert
    aux = E * jnp.sum(me * ce)
    return top_p, top_ids, aux


def moe(params: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (y, aux_loss)."""
    assert cfg.moe is not None
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    k = m.top_k
    E = m.n_experts
    xt = x.reshape(T, d)

    weights, ids, aux = route(params["router"], xt, k)        # (T,k)

    flat_ids = ids.reshape(-1)                                # (T*k,)
    order = jnp.argsort(flat_ids)                             # stable
    sorted_ids = flat_ids[order]
    # position of each assignment within its expert's queue
    pos_in_expert = jnp.arange(T * k) - jnp.searchsorted(sorted_ids,
                                                         sorted_ids, side="left")
    capacity = int(max(1, round(T * k / E * m.capacity_factor)))
    keep = pos_in_expert < capacity

    token_of = order // k                                     # source token
    dst = jnp.where(keep, sorted_ids * capacity + pos_in_expert, E * capacity)

    # scatter tokens into (E*C, d) buffers (row E*C is a dropped-token sink)
    buf = jnp.zeros((E * capacity + 1, d), x.dtype)
    buf = buf.at[dst].set(xt[token_of], mode="drop")
    buf = buf[: E * capacity].reshape(E, capacity, d)
    buf = constrain(buf, "mp", None, None)                    # all-to-all here

    h = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    if cfg.glu:
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        h = activation(g, cfg.act) * h
    else:
        h = activation(h, cfg.act)
    out = jnp.einsum("ecf,efd->ecd", h, params["w_out"])      # (E,C,d)
    out = constrain(out, "mp", None, None)
    out_flat = jnp.concatenate(
        [out.reshape(E * capacity, d), jnp.zeros((1, d), out.dtype)], axis=0)

    # gather back: assignment j of token t reads row dst[inv_order[t*k+j]]
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(T * k))
    rows = out_flat[dst[inv]].reshape(T, k, d)
    y = jnp.einsum("tkd,tk->td", rows.astype(jnp.float32),
                   weights.astype(jnp.float32)).astype(x.dtype)

    if m.n_shared_experts:
        y = y + mlp(params["shared"], x, cfg).reshape(T, d)
    return y.reshape(B, S, d), aux * m.aux_loss_coef
