"""xLSTM blocks (Beck et al., arXiv:2405.04517).

mLSTM: matrix-memory LSTM with exponential gating.  Training uses the
stabilized *parallel* form — exactly equivalent to the recurrence because the
stabilizer m_t = F_t + cummax(log i_s − F_s) equals the recurrent running max
(see tests/test_xlstm.py).  Decode is the O(1)-state recurrence.

sLSTM: scalar-memory LSTM with block-diagonal recurrent weights — inherently
sequential, trained with lax.scan over time.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import constrain
from repro.models.layers import dense_init, rms_norm


def _mdims(cfg: ModelConfig):
    x = cfg.xlstm
    assert x is not None
    d_in = int(x.proj_factor * cfg.d_model)
    H = cfg.n_heads
    return x, d_in, H, d_in // H


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, dtype) -> dict:
    x, d_in, H, hd = _mdims(cfg)
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    return {
        "up": dense_init(keys[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(keys[1], (x.conv_dim, d_in), jnp.float32)
                   * (1.0 / x.conv_dim) ** 0.5).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": dense_init(keys[2], d_in, d_in, dtype),
        "wk": dense_init(keys[3], d_in, d_in, dtype),
        "wv": dense_init(keys[4], d_in, d_in, dtype),
        "w_gates": dense_init(keys[5], d_in, 2 * H, jnp.float32),
        "b_gates": jnp.concatenate([jnp.zeros((H,), jnp.float32),
                                    3.0 + jnp.arange(H, dtype=jnp.float32)]),
        "out_norm": jnp.zeros((d_in,), dtype),
        "down": dense_init(keys[6], d_in, d, dtype),
    }


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    x, d_in, H, hd = _mdims(cfg)
    return {
        "conv": jnp.zeros((batch, x.conv_dim - 1, d_in), dtype),
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
    }


def _conv_causal(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype), (1,), "VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b


def _mlstm_parallel(q, k, v, log_i, log_f, block_q: int = 256):
    """q,k,v (B,S,H,hd); log_i, log_f (B,S,H).  Stabilized parallel form."""
    B, S, H, hd = q.shape
    scale = hd ** -0.5
    F = jnp.cumsum(log_f, axis=1)                   # (B,S,H)
    a = log_i - F                                   # log ĩ_s − F_s
    amax = jax.lax.cummax(a, axis=1)                # running max
    m = F + amax                                    # recurrent-equal stabilizer

    kf = (k.astype(jnp.float32) * scale)
    vf = v.astype(jnp.float32)
    bq = min(block_q, S)
    nb = -(-S // bq)
    pad = nb * bq - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        F = jnp.pad(F, ((0, 0), (0, pad), (0, 0)))
        amax = jnp.pad(amax, ((0, 0), (0, pad), (0, 0)))
    qb = q.reshape(B, nb, bq, H, hd).transpose(1, 0, 2, 3, 4)
    ab = amax.reshape(B, nb, bq, H).transpose(1, 0, 2, 3)
    pos = jnp.arange(nb * bq).reshape(nb, bq)
    s_pos = jnp.arange(S)

    def body(_, inp):
        qi, amax_i, pi = inp
        sc = jnp.einsum("bqhd,bshd->bhqs", qi.astype(jnp.float32), kf)
        dec = jnp.exp(a.transpose(0, 2, 1)[:, :, None, :]
                      - amax_i.transpose(0, 2, 1)[:, :, :, None])   # (B,H,q,s)
        mask = s_pos[None, :] <= pi[:, None]
        st = sc * dec * mask[None, None]
        num = jnp.einsum("bhqs,bshd->bqhd", st, vf)
        den = jnp.abs(jnp.sum(st, axis=-1)).transpose(0, 2, 1)      # (B,q,H)
        return None, (num, den)

    _, (nums, dens) = jax.lax.scan(body, None, (qb, ab, pos))
    num = nums.transpose(1, 0, 2, 3, 4).reshape(B, nb * bq, H, hd)[:, :S]
    den = dens.transpose(1, 0, 2, 3).reshape(B, nb * bq, H)[:, :S]
    den = jnp.maximum(den, jnp.exp(-m))
    return num / den[..., None]


def mlstm(params: dict, x: jax.Array, cfg: ModelConfig,
          cache: Optional[dict] = None) -> tuple[jax.Array, Optional[dict]]:
    xx, d_in, H, hd = _mdims(cfg)
    B, S, d = x.shape
    up = jnp.einsum("bsd,de->bse", x, params["up"])
    h_path, z = up[..., :d_in], up[..., d_in:]

    new_cache = None
    if cache is None or S > 1:
        conv_out = _conv_causal(h_path, params["conv_w"], params["conv_b"])
        if cache is not None:                                  # prefill
            K = params["conv_w"].shape[0]
            new_cache = {"conv": h_path[:, -(K - 1):]}
    else:
        window = jnp.concatenate([cache["conv"], h_path], axis=1)
        conv_out = (jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                               params["conv_w"].astype(jnp.float32))
                    + params["conv_b"].astype(jnp.float32))[:, None].astype(x.dtype)
        new_cache = {"conv": window[:, 1:]}
    conv_out = jax.nn.silu(conv_out)

    q = jnp.einsum("bse,ef->bsf", conv_out, params["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bse,ef->bsf", conv_out, params["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bse,ef->bsf", h_path, params["wv"]).reshape(B, S, H, hd)
    gates = (jnp.einsum("bse,eg->bsg", h_path.astype(jnp.float32),
                        params["w_gates"]) + params["b_gates"])
    log_i = gates[..., :H]
    log_f = jax.nn.log_sigmoid(gates[..., H:])

    if cache is None or S > 1:
        h = _mlstm_parallel(q, k, v, log_i, log_f)
        if new_cache is not None:                   # prefill: closed-form state
            scale = hd ** -0.5
            F = jnp.cumsum(log_f, axis=1)
            a = log_i - F                                       # (B,S,H)
            amax = jnp.max(a, axis=1)                           # (B,H)
            w = jnp.exp(a - amax[:, None])                      # (B,S,H)
            kf = k.astype(jnp.float32) * scale
            vf = v.astype(jnp.float32)
            new_cache["C"] = jnp.einsum("bsh,bshd,bshe->bhde", w, kf, vf)
            new_cache["n"] = jnp.einsum("bsh,bshd->bhd", w, kf)
            new_cache["m"] = F[:, -1] + amax
    else:
        scale = hd ** -0.5
        m_prev, C_prev, n_prev = cache["m"], cache["C"], cache["n"]
        li, lf = log_i[:, 0], log_f[:, 0]                       # (B,H)
        m_new = jnp.maximum(lf + m_prev, li)
        i_s = jnp.exp(li - m_new)
        f_s = jnp.exp(lf + m_prev - m_new)
        k0 = k[:, 0].astype(jnp.float32) * scale
        v0 = v[:, 0].astype(jnp.float32)
        q0 = q[:, 0].astype(jnp.float32)
        C_new = (f_s[..., None, None] * C_prev
                 + i_s[..., None, None] * jnp.einsum("bhd,bhe->bhde", k0, v0))
        n_new = f_s[..., None] * n_prev + i_s[..., None] * k0
        num = jnp.einsum("bhd,bhde->bhe", q0, C_new)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q0)),
                          jnp.exp(-m_new))
        h = (num / den[..., None])[:, None].reshape(B, 1, H, hd)
        new_cache.update({"C": C_new, "n": n_new, "m": m_new})

    h = h.reshape(B, S, d_in).astype(x.dtype)
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    h = h * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", h, params["down"]), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, dtype) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    keys = jax.random.split(key, 5)
    f_ff = 2 * d
    return {
        "W": dense_init(keys[0], d, 4 * d, jnp.float32),
        "R": (jax.random.normal(keys[1], (H, hd, 4 * hd), jnp.float32)
              * (1.0 / hd) ** 0.5),
        "b": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                              jnp.ones((d,), jnp.float32),
                              jnp.zeros((d,), jnp.float32)]),
        "out_norm": jnp.zeros((d,), dtype),
        "ff_up": dense_init(keys[2], d, 2 * f_ff, dtype),
        "ff_down": dense_init(keys[3], f_ff, d, dtype),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    del dtype
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_step(params, cfg, state, wx):
    """One sLSTM timestep.  wx (B, 4d) = W x_t + b;  state c/n/h/m (B, d)."""
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    c, n, h, m = state
    B = wx.shape[0]
    rec = jnp.einsum("bhp,hpq->bhq", h.reshape(B, H, hd), params["R"])
    pre = wx + rec.reshape(B, 4 * d)
    z_t = jnp.tanh(pre[:, :d])
    i_t = pre[:, d: 2 * d]
    f_t = pre[:, 2 * d: 3 * d]
    o_t = jax.nn.sigmoid(pre[:, 3 * d:])
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + m, i_t)
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z_t
    n_new = f_s * n + i_s
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm(params: dict, x: jax.Array, cfg: ModelConfig,
          cache: Optional[dict] = None) -> tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    wx = (jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["W"])
          + params["b"])
    if cache is None:
        state = (jnp.zeros((B, d), jnp.float32), jnp.ones((B, d), jnp.float32),
                 jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), jnp.float32))
    else:
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
    step = lambda st, w: _slstm_step(params, cfg, st, w)
    state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2)
    new_cache = None if cache is None else dict(zip(("c", "n", "h", "m"), state))
    h = h.astype(x.dtype)
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", h, params["ff_up"])
    f_ff = params["ff_down"].shape[0]
    gate, val = up[..., :f_ff], up[..., f_ff:]
    y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(gate) * val, params["ff_down"])
    return y, new_cache
