"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from repro.configs import (deepseek_v2_lite, gemma3_12b, gemma_2b,
                           granite_moe_1b, llama3_8b, musicgen_medium,
                           qwen15_32b, qwen2_vl_2b, xlstm_125m, zamba2_27b)
from repro.configs.base import ModelConfig

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (
        musicgen_medium.CONFIG,
        gemma_2b.CONFIG,
        qwen15_32b.CONFIG,
        granite_moe_1b.CONFIG,
        zamba2_27b.CONFIG,
        gemma3_12b.CONFIG,
        xlstm_125m.CONFIG,
        deepseek_v2_lite.CONFIG,
        qwen2_vl_2b.CONFIG,
        llama3_8b.CONFIG,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
