"""Model / shape / federated configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``.  The model zoo
(`repro.models.model`) consumes only this dataclass, so a new architecture is
one new file in this package.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

BlockKind = Literal["attn", "mamba2", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden width
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 => full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # decode-time weight absorption (§Perf #5): score/output matmuls run in
    # the compressed latent space instead of up-projecting the whole cache
    # per token.  False = paper-faithful naive decode (the A/B baseline).
    absorb: bool = True


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 4           # one sLSTM per this many blocks
    proj_factor: float = 2.0       # up-projection inside mLSTM
    conv_dim: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    act: Literal["silu", "gelu", "relu"] = "silu"
    glu: bool = True
    qkv_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    norm: Literal["rms", "ln"] = "rms"
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    max_seq: int = 131_072
    # -- sliding window / local-global pattern (gemma3) ---------------------
    sliding_window: int = 0        # 0 => all-global full attention
    global_every: int = 0          # e.g. 6 => layers 5,11,... are global
    attn_logit_softcap: float = 0.0
    # -- architecture-specific sub-configs ----------------------------------
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    xlstm: XLSTMConfig | None = None
    # -- hybrid layout (zamba2): attn block shared + every N ssm layers ------
    hybrid_attn_every: int = 0     # 0 => not hybrid
    hybrid_shared_attn: bool = True
    # -- modality frontends (stubs per the carve-out) ------------------------
    frontend: Literal["none", "audio", "vision"] = "none"
    n_codebooks: int = 0           # musicgen: EnCodec codebooks
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl: (t, h, w) rope splits
    # -- numerics ------------------------------------------------------------
    dtype: str = "float32"         # activation / param dtype for this config
    remat: bool = True
    scan_layers: bool = True
    source: str = ""               # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_pattern(self) -> list[BlockKind]:
        """Per-layer block kinds, grouped later into scanned segments."""
        if self.family == "hybrid" and self.hybrid_attn_every:
            out: list[BlockKind] = []
            for i in range(self.n_layers):
                out.append("mamba2")
                if (i + 1) % self.hybrid_attn_every == 0:
                    out.append("attn")
            return out
        if self.xlstm is not None:
            k = self.xlstm.slstm_every
            return ["slstm" if (i + 1) % k == 0 else "mlstm"
                    for i in range(self.n_layers)]
        if self.family == "ssm" and self.ssm is not None and self.xlstm is None:
            return ["mamba2"] * self.n_layers
        return ["attn"] * self.n_layers

    def is_global_layer(self, idx: int) -> bool:
        """Sliding-window pattern: True if layer attends globally."""
        if self.sliding_window == 0:
            return True
        if self.global_every == 0:
            return False
        return (idx + 1) % self.global_every == 0

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, v = self.d_model, self.vocab
        total = v * d if self.tie_embeddings else 2 * v * d
        if self.frontend == "audio" and self.n_codebooks:
            total += (self.n_codebooks - 1) * v * d          # extra cb embeds
            total += (self.n_codebooks - 1) * v * d          # extra heads
        hd = self.resolved_head_dim
        for i, kind in enumerate(self.layer_pattern()):
            if kind == "attn":
                if self.hybrid_attn_every and self.hybrid_shared_attn and i != self.layer_pattern().index("attn"):
                    continue                                  # weight-shared
                total += self._attn_params(hd) + self._mlp_params() + 2 * d
            elif kind == "mamba2":
                total += self._mamba_params() + d
                if not self.hybrid_attn_every:
                    total += self._mlp_params() + d if self.d_ff else 0
            elif kind == "mlstm":
                total += self._mlstm_params() + d
            elif kind == "slstm":
                total += self._slstm_params() + d
        return total

    def _attn_params(self, hd: int) -> int:
        d = self.d_model
        if self.mla is not None:
            m = self.mla
            q = d * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            kv_down = d * (m.kv_lora_rank + m.qk_rope_head_dim)
            kv_up = m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            o = self.n_heads * m.v_head_dim * d
            return q + kv_down + kv_up + o
        qkv = d * hd * (self.n_heads + 2 * self.n_kv_heads)
        if self.qkv_bias:
            qkv += hd * (self.n_heads + 2 * self.n_kv_heads)
        return qkv + self.n_heads * hd * d

    def _mlp_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            m = self.moe
            per = (3 if self.glu else 2) * d * m.d_ff
            return d * m.n_experts + (m.n_experts + m.n_shared_experts) * per
        if self.d_ff == 0:
            return 0
        return (3 if self.glu else 2) * d * self.d_ff

    def _mamba_params(self) -> int:
        assert self.ssm is not None
        s = self.ssm
        d_in = s.expand * self.d_model
        nh = d_in // s.head_dim
        in_proj = self.d_model * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
        conv = s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
        return in_proj + conv + 2 * nh + d_in + d_in * self.d_model

    def _mlstm_params(self) -> int:
        assert self.xlstm is not None
        d = self.d_model
        d_in = int(self.xlstm.proj_factor * d)
        hd = d_in // self.n_heads
        return d * 2 * d_in + d_in * 3 * d_in + 3 * self.n_heads * d_in // max(hd, 1) + d_in * d + d_in

    def _slstm_params(self) -> int:
        d = self.d_model
        return 4 * d * d + 4 * d * d + 8 * d + (3 if self.glu else 2) * d * (d * 4 // 3)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        per = (3 if self.glu else 2) * self.d_model * m.d_ff
        dense_like = self.param_count() - self.n_layers * (m.n_experts + m.n_shared_experts) * per
        return dense_like + self.n_layers * (m.top_k + m.n_shared_experts) * per


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """FedaGrac / baseline round configuration."""
    algorithm: str = "fedagrac"            # fedavg|fednova|scaffold|fedprox|fedlin|fedagrac[_avg/_first/_reverse]
    n_clients: int = 16
    k_mean: int = 4                        # local steps per round (mean)
    k_var: float = 0.0                     # Gaussian variance of K_i (paper §6.1)
    k_mode: Literal["fixed", "random"] = "fixed"
    lr: float = 0.05
    calibration_rate: float = 0.05         # λ
    prox_mu: float = 0.1                   # FedProx regularizer
    weights: Literal["uniform", "data"] = "uniform"
    server_opt: Literal["sgd", "momentum", "adam"] = "sgd"
    server_lr: float = 1.0                 # FedOpt server step size
    seed: int = 0
    # -- buffered semi-asynchronous execution (fed/async_engine.py) ----------
    # buffer_size M' ≤ M: the server updates once M' client reports arrive
    # (Nguyen et al. FedBuff).  0 ⇒ fully synchronous rounds; 1 ⇒ FedAsync;
    # M ⇒ reduces to the synchronous round (DESIGN.md §5).  Under partial
    # participation the async buffer is capped at the concurrency C (and 0
    # defaults to C): one update never spans more than one cohort sweep.
    buffer_size: int = 0
    staleness: Literal["constant", "hinge", "poly"] = "constant"
    staleness_a: float = 0.5               # discount decay rate (hinge/poly)
    staleness_b: int = 4                   # hinge: free staleness budget
    # client wall-clock model (fed/clock.py): per-client step rates
    speed_dist: Literal["fixed", "uniform", "lognormal", "bimodal"] = "lognormal"
    speed_sigma: float = 0.5               # lognormal σ of client step rates
    comm_latency: float = 0.0              # fixed per-report overhead (s)
    # -- client population / partial participation (fed/population.py) -------
    # cohort_size C ≤ M: each synchronous round runs a sampled cohort of C
    # clients (the async engine caps concurrency at C).  0 ⇒ C = M.
    # sampler "all" with C = M is the golden-pinned full-participation
    # path; with C < M it resolves to "uniform" (cohort_size alone opts
    # into partial participation).
    cohort_size: int = 0
    cohort_sampler: Literal["all", "uniform", "weighted", "availability",
                            "round_robin"] = "all"
    availability: float = 1.0              # mean client up-probability
    cohort_nu_decay: float = 0.0           # stale ν⁽ⁱ⁾ decay toward ν per round
    # -- parameter layout (core/flat.py, DESIGN.md §11) -----------------------
    # "tree" runs the per-leaf layered round; "flat" ravels the model pytree
    # into one lane-padded (P,) buffer (clients/ν⁽ⁱ⁾: (M, P) rows) and runs
    # the whole round on flat state — the client step calls the fused Pallas
    # calibrated-update kernels, every aggregator/server op is a single
    # (M, P)-row einsum, and the pytree materializes only at the loss.
    param_layout: Literal["tree", "flat"] = "tree"
    # mixed precision on the flat layout (DESIGN.md §13): dtype of the
    # MASTER flat buffer all round state lives in, independent of the
    # per-leaf view dtypes the model computes in.  "" keeps the spec's
    # inferred dtype (= the leaf dtype).  The production LM configuration
    # is bf16 params/compute + "float32" master: every view read is the
    # only f32→bf16 crossing, updates and ν state apply at f32.
    master_dtype: Literal["", "float32", "bfloat16", "float16"] = ""
    # -- failure scenarios (fed/scenarios.py, DESIGN.md §12) ------------------
    # "baseline" leaves both engines on their unperturbed (golden-pinned)
    # paths; other names inject faults as pure functions of
    # (seed, round, client): "dropout" = mid-round aborts delivering k′ < K_i
    # completed steps (partial-work recovery), "spike" = adversarial
    # straggler bursts, "flaky" = network latency bursts, "diurnal" =
    # correlated availability phases.  "trace" needs explicit tables — build
    # via scenarios.trace_scenario and pass scenario= to the engine.
    scenario: str = "baseline"
    dropout_rate: float = 0.1              # dropout: per-(round, client) abort prob
    scenario_rate: float = 0.1             # spike/flaky: per-event probability
    scenario_magnitude: float = 10.0       # spike slowdown × / flaky mean burst (s)
    scenario_period: float = 64.0          # diurnal availability period (rounds)
    rejoin_delay: float = 0.0              # post-abort downtime (simulated s)
    # -- wire compression (core/compress.py, DESIGN.md §14) -------------------
    # compressor: client→server payloads (parameter delta + ν transmit),
    # broadcast_compressor: server→client broadcast (params + ν) — each one
    # of the COMPRESSORS registry ("none" | "int8" | "int4" | "topk" |
    # "topk+int8").  error_feedback carries per-client (M, P) residual
    # accumulators in the round state (ê = C(v + e), e ← v + e − ê), so
    # compression error is re-transmitted by the SAME client later instead
    # of lost; topk_frac is the kept fraction k/n of the top-k compressors.
    compressor: str = "none"
    broadcast_compressor: str = "none"
    error_feedback: bool = True
    topk_frac: float = 0.05
    # DEPRECATED: the old ν-only int8 fake-quant flag.  True maps onto
    # compressor="int8" (which now compresses the delta AND ν, with error
    # feedback) and warns; use compressor= directly.
    quantize_transmit: bool = False
    # -- Byzantine-robust aggregation (core/robust.py, DESIGN.md §16) ---------
    # defense: one of the DEFENSES registry ("none" | "clip" | "median" |
    # "trimmed_mean" | "krum"), applied to the client→server delta rows
    # (and, when nu_defense, the ν transmit rows) before the aggregators.
    # Attacks are scenarios: scenario ∈ {"nan_inject", "inf_inject",
    # "scale_attack", "sign_flip", "garbage"} with scenario_rate the
    # corrupt-client fraction and scenario_magnitude the attack strength.
    # quarantine_window > 0 turns on per-client health tracking: a client
    # whose payload is non-finite quarantine_nonfinite times, or whose
    # delta-norm z-score exceeds quarantine_z after warmup, is excluded
    # from aggregation and ν mixing for that many rounds (weights are
    # Horvitz–Thompson renormalized over the survivors).  defense="none"
    # with quarantine_window=0 is trace-time gated: the round builders
    # emit the identical (golden-pinned) jaxpr.
    defense: str = "none"
    defense_clip: float = 0.0              # clip: fixed norm; 0 ⇒ adaptive (median of norms)
    trim_frac: float = 0.2                 # trimmed_mean: trim fraction per tail, in [0, 0.5)
    krum_f: int = 1                        # krum: assumed Byzantine count f
    nu_defense: bool = True                # also defend ν (ablation: False = model-only)
    quarantine_window: int = 0             # rounds a flagged client sits out (0 = off)
    quarantine_z: float = 4.0              # delta-norm z-score threshold
    quarantine_nonfinite: int = 1          # non-finite reports before quarantine

    def __post_init__(self):
        """Fail at construction, not as a registry KeyError inside jit:
        every registry-backed field is validated against its live registry
        (imported lazily — the registries live downstream of this module)."""
        import warnings

        from repro.core.compress import COMPRESSORS
        from repro.core.fedopt import ALGORITHMS
        from repro.core.robust import DEFENSES
        from repro.core.stages import SERVER_OPTIMIZERS
        from repro.fed.population import SAMPLERS
        from repro.fed.scenarios import SCENARIOS

        def _check(field: str, value, valid) -> None:
            if value not in valid:
                raise ValueError(f"unknown {field} {value!r}; valid "
                                 f"options: {sorted(valid)}")

        if self.quantize_transmit:
            warnings.warn(
                "FedConfig.quantize_transmit is deprecated; use "
                "compressor='int8' (first-class delta + ν compression with "
                "error feedback, core/compress.py)", DeprecationWarning,
                stacklevel=2)
            if self.compressor == "none":
                object.__setattr__(self, "compressor", "int8")
        _check("compressor", self.compressor, COMPRESSORS)
        _check("broadcast_compressor", self.broadcast_compressor,
               COMPRESSORS)
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac {self.topk_frac} not in (0, 1]")

        _check("algorithm", self.algorithm, ALGORITHMS)
        _check("cohort_sampler", self.cohort_sampler, SAMPLERS)
        _check("param_layout", self.param_layout, ("tree", "flat"))
        _check("master_dtype", self.master_dtype,
               ("", "float32", "bfloat16", "float16"))
        if self.master_dtype and self.param_layout != "flat":
            raise ValueError(
                f"master_dtype={self.master_dtype!r} requires "
                f"param_layout='flat' (the master buffer IS the flat "
                f"buffer); the tree layout keeps per-leaf dtypes")
        _check("server_opt", self.server_opt, SERVER_OPTIMIZERS)
        _check("scenario", self.scenario, SCENARIOS)
        _check("defense", self.defense, DEFENSES)
        if not 0.0 <= self.trim_frac < 0.5:
            raise ValueError(f"trim_frac {self.trim_frac} not in [0, 0.5) "
                             f"(trimming both tails must leave rows)")
        if self.defense_clip < 0:
            raise ValueError(f"defense_clip must be ≥ 0 (0 = adaptive), "
                             f"got {self.defense_clip}")
        if self.krum_f < 0:
            raise ValueError(f"krum_f must be ≥ 0, got {self.krum_f}")
        if self.quarantine_window < 0:
            raise ValueError(f"quarantine_window must be ≥ 0, "
                             f"got {self.quarantine_window}")
        if self.quarantine_nonfinite < 1:
            raise ValueError(f"quarantine_nonfinite must be ≥ 1, "
                             f"got {self.quarantine_nonfinite}")
        if self.quarantine_z <= 0:
            raise ValueError(f"quarantine_z must be > 0, "
                             f"got {self.quarantine_z}")
        _check("staleness", self.staleness, ("constant", "hinge", "poly"))
        _check("speed_dist", self.speed_dist,
               ("fixed", "uniform", "lognormal", "bimodal", "trace"))
        _check("weights", self.weights, ("uniform", "data"))
        _check("k_mode", self.k_mode, ("fixed", "random"))


def reduced(cfg: ModelConfig, n_layers: int = 2, d_model: int = 128,
            max_experts: int = 4, vocab: int = 512) -> ModelConfig:
    """Smoke-test variant of the same family (per instructions)."""
    ratio = max(d_model // 64, 1)
    n_heads = min(cfg.n_heads, max(2, ratio))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    head_dim = d_model // n_heads
    changes: dict = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=head_dim, d_ff=0 if cfg.d_ff == 0 else d_model * 4,
        vocab=min(cfg.vocab, vocab), max_seq=4096, dtype="float32",
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        global_every=min(cfg.global_every, 2) if cfg.global_every else 0,
        hybrid_attn_every=min(cfg.hybrid_attn_every, 2) if cfg.hybrid_attn_every else 0,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, max_experts),
            top_k=min(cfg.moe.top_k, 2), d_ff=d_model * 2,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1))
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=head_dim,
                                   qk_rope_head_dim=16, v_head_dim=head_dim)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32,
                                             chunk=16)
    if cfg.xlstm is not None:
        changes["xlstm"] = dataclasses.replace(
            cfg.xlstm, slstm_every=min(cfg.xlstm.slstm_every, n_layers))
    if cfg.mrope_sections:
        changes["mrope_sections"] = _mrope_sections(head_dim)
    return dataclasses.replace(cfg, **changes)


def _mrope_sections(head_dim: int) -> tuple[int, int, int]:
    half = head_dim // 2
    t = half - 2 * (half // 4)
    return (t, half // 4, half // 4)
