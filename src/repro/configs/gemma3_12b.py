"""Gemma3-12B [hf:google/gemma-3-1b-pt family]: 48L d=3840 16H (kv=8)
ff=15360 vocab=262144, 5:1 local:global sliding-window (window 1024), 128k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262_144,
    act="gelu",
    glu=True,
    tie_embeddings=True,
    sliding_window=1024,
    global_every=6,          # layers 6,12,... global -> 5:1 local:global
    rope_theta=1_000_000.0,
    max_seq=131_072,
    source="hf:google/gemma-3-1b-pt",
)
