"""xLSTM-125M [arXiv:2405.04517]: 12L d=768 4H, sLSTM + mLSTM blocks (3:1),
vocab=50304, d_ff=0 (projections live inside the blocks)."""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50_304,
    norm="ln",
    xlstm=XLSTMConfig(slstm_every=4, proj_factor=2.0),
    source="arXiv:2405.04517",
)
