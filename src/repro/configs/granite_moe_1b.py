"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base]:
24L d=1024 16H (kv=8) per-expert ff=512, 32 experts top-8, vocab=49155."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49_155,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8, d_ff=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
