from repro.configs.base import (FedConfig, MLAConfig, MoEConfig, ModelConfig,
                                ShapeConfig, SSMConfig, XLSTMConfig, reduced)
from repro.configs.shapes import LONG_CONTEXT_OK, SHAPES

__all__ = ["FedConfig", "MLAConfig", "MoEConfig", "ModelConfig", "ShapeConfig",
           "SSMConfig", "XLSTMConfig", "reduced", "SHAPES", "LONG_CONTEXT_OK"]
