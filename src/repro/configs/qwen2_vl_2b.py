"""Qwen2-VL-2B [arXiv:2409.12191]: 28L d=1536 12H (kv=2) ff=8960
vocab=151936, M-RoPE (t/h/w sections), dynamic resolution. The ViT/SigLIP
vision encoder + projector is a stub per the carve-out: input_specs()
provides precomputed patch embeddings + 3D position grids."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    frontend="vision",
    mrope_sections=(16, 24, 24),   # t/h/w halves of head_dim=128 rotary dims
    source="arXiv:2409.12191",
)
