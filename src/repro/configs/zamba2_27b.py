"""Zamba2-2.7B [arXiv:2411.15242]: Mamba2 backbone + weight-shared attention
block interleaved. 54L d=2560 32H (kv=32) ff=10240 vocab=32000 ssm_state=64."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32_000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=128),
    hybrid_attn_every=6,
    hybrid_shared_attn=True,
    source="arXiv:2411.15242",
)
