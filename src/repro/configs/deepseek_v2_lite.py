"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434]: 27L d=2048 16H MLA kv_lora=512,
per-expert ff=1408, 64 routed experts top-6 + 2 shared, vocab=102400."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102_400,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared_experts=2),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    source="arXiv:2405.04434",
)
