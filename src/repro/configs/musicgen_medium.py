"""MusicGen-medium decoder backbone over EnCodec tokens [arXiv:2306.05284].

48L d_model=1536 24H (GQA kv=24 == MHA) d_ff=6144 vocab=2048, 4 codebooks.
The mel/EnCodec frontend is a stub per the carve-out: input_specs() provides
codebook token ids directly.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    act="gelu",
    glu=False,
    norm="ln",
    frontend="audio",
    n_codebooks=4,
    tie_embeddings=False,
    source="arXiv:2306.05284",
)
