"""Gemma-2B [arXiv:2403.08295]: 18L d=2048 8H MQA(kv=1) GeGLU ff=16384,
head_dim=256, vocab=256000, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256_000,
    act="gelu",
    glu=True,
    tie_embeddings=True,
    source="arXiv:2403.08295",
)
