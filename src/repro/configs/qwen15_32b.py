"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B family]: 64L d=5120 40H (kv=40)
ff=27392, vocab=152064, QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152_064,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)
