"""Buffered semi-asynchronous federated execution (DESIGN.md §5).

The synchronous engine (fed/simulation.py) advances in lock-step rounds —
the straggler defines the round clock.  This engine drops the barrier:
clients train continuously, each report arrives after its simulated duration
(fed/clock.py), and the server updates once a **buffer** of M' ≤ M reports
has accumulated (Nguyen et al., FedBuff).  Arrived updates may be **stale**
— computed against a model version τ updates old — and are discounted by a
staleness weight s(τ) (Xie et al., FedAsync):

    constant : s(τ) = 1
    hinge    : s(τ) = 1                 τ ≤ b,   else 1 / (1 + a (τ − b))
    poly     : s(τ) = (1 + τ)^(−a)

The buffered server update on arrivals B with global weights ω and
discounts s_i = s(τ_i), w̃_i = ω_i s_i:

    x       ← serveropt( x,  Σ_{i∈B} w̃_i (x⁽ⁱ⁾ − x_{v_i}) )       (pseudo-deltas)
    ν       ← (1 − Σ_{i∈B} w̃_i) ν  +  Σ_{i∈B} w̃_i transmitᵢ      (mass-mixed)
    ν⁽ⁱ⁾    ← ν̄⁽ⁱ⁾   for i ∈ B only                              (row scatter)

All three reuse the synchronous stages verbatim (core/stages.py): the
client-update scan runs with *per-client anchors* (the stale model version
each client was dispatched with), aggregation uses the pseudo-delta
variants, and orientation recovers ν̄⁽ⁱ⁾ against the same stale anchor.
With buffer = M, identical client speeds and zero staleness, every quantity
above reduces to the synchronous round — FedaGrac-vs-FedAsync-vs-FedBuff is
one config switch (``FedConfig.buffer_size`` / ``staleness``).
"""
from __future__ import annotations

import heapq
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import rounds, stages
from repro.core.fedopt import get_algorithm
from repro.core.tree_util import tree_wsum
from repro.data.partition import gaussian_k_schedule
from repro.fed.clock import ClientClock, make_clock
from repro.fed.simulation import History

PyTree = Any


def staleness_weight(tau, mode: str = "constant", a: float = 0.5,
                     b: int = 4) -> np.ndarray:
    """Staleness discount s(τ) ≥ 0, s(0) = 1 (FedAsync §5 shapes)."""
    tau = np.asarray(tau, np.float64)
    if mode == "constant":
        return np.ones_like(tau)
    if mode == "poly":
        return (1.0 + tau) ** (-a)
    if mode == "hinge":
        return 1.0 / (1.0 + a * np.maximum(tau - b, 0.0))
    raise ValueError(f"unknown staleness mode {mode!r}")


class BufferedAsyncSimulation:
    """``run(T)`` executes T buffered server updates of ``fed.algorithm``.

    Mirrors ``FederatedSimulation``'s constructor so benchmarks can switch
    engines on ``fed.buffer_size`` alone.  ``clock`` defaults to the
    ``fed.speed_dist`` wall-clock model; ``k_schedule`` rows index per-client
    *dispatches* (client *i*'s d-th task uses row d), so with buffer = M and
    identical speeds the data stream matches the synchronous engine's.
    """

    def __init__(self, loss_fn: Callable[[PyTree, PyTree], jax.Array],
                 params: PyTree, fed: FedConfig, batcher,
                 eval_fn: Optional[Callable[[PyTree], float]] = None,
                 k_schedule: Optional[np.ndarray] = None,
                 lam_schedule: Optional[Callable[[int], float]] = None,
                 clock: Optional[ClientClock] = None,
                 t_max: int = 10_000):
        m = fed.n_clients
        self.fed = fed
        self.algo = get_algorithm(fed.algorithm, fed)
        self.batcher = batcher
        self.eval_fn = eval_fn
        self.lam_schedule = lam_schedule
        self.buffer = fed.buffer_size if fed.buffer_size > 0 else m
        if not 1 <= self.buffer <= m:
            raise ValueError(f"buffer_size {self.buffer} not in [1, {m}]")
        if k_schedule is None:
            k_schedule = gaussian_k_schedule(
                m, fed.k_mean, fed.k_var, t_max,
                mode=fed.k_mode, seed=fed.seed)
        self.k_schedule = k_schedule
        self.k_max = int(k_schedule.max())
        self.clock = clock if clock is not None else make_clock(
            m, dist=fed.speed_dist, sigma=fed.speed_sigma,
            latency=fed.comm_latency, seed=fed.seed)
        self.weights = (np.asarray(batcher.weights)
                        if fed.weights == "data"
                        else np.full((m,), 1.0 / m, np.float32))
        self.state = rounds.init_state(params, m, self.algo)
        self.version = 0
        # model-version history for stale anchors: version -> (params, nu);
        # pruned to the oldest version still referenced by an in-flight task
        self._hist = {0: (self.state["params"], self.state.get("nu"))}
        self._batch_cache: dict[int, PyTree] = {}
        self._step = jax.jit(self._make_step(loss_fn))

    # -- the jitted buffered update (one trace: buffer size is static) ------

    def _make_step(self, loss_fn):
        algo, lr, buffer = self.algo, self.fed.lr, self.buffer
        client_update = stages.make_client_update(
            loss_fn, algo, lr=lr, k_max=self.k_max, per_client_anchor=True)
        aggregate = stages.BUFFERED_AGGREGATORS[algo.aggregator]

        def step(state, anchor_i, nu_anchor, batches, k_steps, sw, idx, lam):
            params = state["params"]
            kf = k_steps.astype(jnp.float32)
            # Σ w̃ — usually in (0, 1], but a high-weight fast client
            # reporting twice into one buffer can push it past 1
            mass = jnp.sum(sw)
            kbar = jnp.dot(sw, kf) / mass            # buffer-local K̄

            if algo.uses_nu:
                # correction each client ran with: c⁽ⁱ⁾ = ν_{v_i} − ν⁽ⁱ⁾
                # (ν⁽ⁱ⁾ rows change only when client i itself reports, so the
                # current row still holds the dispatch-time value)
                c_b = jax.tree.map(lambda na, nui: na - nui[idx],
                                   nu_anchor, state["nu_i"])
            else:
                c_b = stages.zero_corrections(params, buffer)

            x_b, g0_b, acc_b, loss0 = client_update(anchor_i, c_b, batches,
                                                    k_steps, lam)

            agg = aggregate(params, anchor_i, x_b, kf, sw, kbar)
            new_state = dict(state)
            new_params = stages.server_update(algo, state, params, agg,
                                              new_state)
            new_state["params"] = new_params
            new_state["round"] = state["round"] + 1

            if algo.uses_nu:
                transmit, avg_g = stages.orientation_transmit(
                    algo, params, x_b, g0_b, acc_b, c_b, kf, kbar, lr, lam,
                    anchor_i=anchor_i)
                contrib = tree_wsum(sw, transmit)
                # convex mix even when mass > 1 (duplicate reporters): keep
                # ρ = min(mass, 1) of the new signal, renormalized — for
                # mass ≤ 1 this is exactly (1 − mass)·ν + contrib, so the
                # synchronous reduction (mass = 1) is untouched
                rho = jnp.minimum(mass, 1.0)
                new_state["nu"] = jax.tree.map(
                    lambda nu, c: ((1.0 - rho) * nu.astype(jnp.float32)
                                   + (rho / mass) * c.astype(jnp.float32)
                                   ).astype(nu.dtype),
                    state["nu"], contrib)
                # duplicate idx (a fast client reporting twice into one
                # buffer) resolves arbitrarily between its two same-buffer
                # reports — both are current to within one update
                new_state["nu_i"] = jax.tree.map(
                    lambda nui, g: nui.at[idx].set(g.astype(nui.dtype)),
                    state["nu_i"], avg_g)

            metrics = {"loss": jnp.dot(sw, loss0) / mass, "kbar": kbar,
                       "mass": mass}
            return new_state, metrics

        return step

    # -- host-side event loop ------------------------------------------------

    def _client_batch(self, client: int, d: int, future_readers) -> PyTree:
        """Row ``client`` of the d-th dispatch wave.

        ``round_batches`` generates the full (M, …) wave; rows for the other
        clients still in flight on wave d (``future_readers``) are cached so
        the wave is generated once, and every entry is consumed exactly once
        at its owner's arrival — cache size stays ≤ #in-flight tasks."""
        row = self._batch_cache.pop((d, client), None)
        if row is None:
            wave = self.batcher.round_batches(d, self.k_max)
            for j in future_readers:
                if j != client and (d, j) not in self._batch_cache:
                    self._batch_cache[(d, j)] = jax.tree.map(
                        lambda a: a[j], wave)
            row = jax.tree.map(lambda a: a[client], wave)
        return row

    def run(self, t_updates: int, eval_every: int = 1,
            verbose: bool = False) -> History:
        hist = History()
        m = self.clock.m
        fed = self.fed
        heap: list[tuple[float, int, int]] = []
        # i -> (ver, K, wave, t_dispatch)
        inflight: dict[int, tuple[int, int, int, float]] = {}
        waves = np.zeros(m, np.int64)
        seq = 0

        def dispatch(i: int, t_now: float, version: int) -> None:
            nonlocal seq
            d = int(waves[i])
            k = int(self.k_schedule[d % len(self.k_schedule), i])
            inflight[i] = (version, k, d, t_now)
            waves[i] += 1
            heapq.heappush(heap, (t_now + self.clock.duration(i, k), seq, i))
            seq += 1

        for i in range(m):
            dispatch(i, 0.0, 0)

        for upd in range(t_updates):
            # Event-accurate fill: pop one report at a time and re-dispatch
            # its client IMMEDIATELY on the current (pre-update) model — the
            # server only steps when the buffer fills, so a fast client's
            # next report can land inside this same buffer (as in FedBuff,
            # where 'M' reports' counts reports, not distinct clients).
            pending: list[tuple[float, int, tuple]] = []
            while len(pending) < self.buffer:
                t_arr, _, i = heapq.heappop(heap)
                pending.append((t_arr, i, inflight.pop(i)))
                dispatch(i, t_arr, self.version)
            now = pending[-1][0]
            ids = [p[1] for p in pending]
            vs, ks, ds, _ = zip(*(p[2] for p in pending))

            tau = self.version - np.asarray(vs)
            s = staleness_weight(tau, fed.staleness, fed.staleness_a,
                                 fed.staleness_b)
            sw = jnp.asarray(self.weights[ids] * s, jnp.float32)

            if len(set(vs)) == 1:
                # common low-staleness regime (and the buffer = M sanity
                # path): one shared anchor broadcast, not B stacked copies
                anchors = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None],
                                               (len(vs),) + a.shape),
                    self._hist[vs[0]][0])
            else:
                anchors = jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *(self._hist[v][0] for v in vs))
            if not self.algo.uses_nu:
                nu_anchor = jnp.zeros(())
            elif len(set(vs)) == 1:
                nu_anchor = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None],
                                               (len(vs),) + a.shape),
                    self._hist[vs[0]][1])
            else:
                nu_anchor = jax.tree.map(lambda *xs: jnp.stack(xs),
                                         *(self._hist[v][1] for v in vs))
            readers: dict[int, set[int]] = {}
            for j, (_, _, dj, _) in inflight.items():
                readers.setdefault(dj, set()).add(j)
            for j, dj in zip(ids, ds):
                readers.setdefault(dj, set()).add(j)
            batches = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *(self._client_batch(i, d, readers[d])
                  for i, d in zip(ids, ds)))

            lam = (float(self.lam_schedule(self.version))
                   if self.lam_schedule else self.algo.lam)
            t0 = time.perf_counter()
            self.state, metrics = self._step(
                self.state, anchors, nu_anchor, batches,
                jnp.asarray(ks, jnp.int32), sw,
                jnp.asarray(ids, jnp.int32), jnp.float32(lam))
            pre_version = self.version
            self.version += 1
            self._hist[self.version] = (self.state["params"],
                                        self.state.get("nu"))
            # Tie upgrade: a client whose report landed at the very instant
            # the buffer filled was re-dispatched and the server stepped at
            # the same timestamp — it receives the FRESH model (zero elapsed
            # time on its new task, so only the anchor version changes).
            # With buffer = M and equal speeds every arrival ties at ``now``,
            # preserving the exact synchronous reduction.
            for t_arr, i, _ in pending:
                if t_arr == now and i in inflight:
                    ver, k, d, t_disp = inflight[i]
                    if ver == pre_version and t_disp == t_arr:
                        inflight[i] = (self.version, k, d, t_disp)

            # prune model versions no in-flight task references — a
            # straggler pins its old version while the head advances, so
            # prune to the referenced SET (≤ M + 1 entries with the current
            # version), not a low-water mark.  (The batch cache self-
            # consumes: every entry is popped at its owner's arrival.)
            live = {v for v, _, _, _ in inflight.values()} | {self.version}
            for v in [v for v in self._hist if v not in live]:
                del self._hist[v]

            hist.loss.append(float(metrics["loss"]))
            hist.kbar.append(float(metrics["kbar"]))
            hist.wall.append(time.perf_counter() - t0)
            hist.sim_time.append(now)
            hist.staleness.append(float(tau.mean()))
            if self.eval_fn is not None and (upd + 1) % eval_every == 0:
                hist.metric.append(float(self.eval_fn(self.state["params"])))
            if verbose and (upd % 10 == 0 or upd == t_updates - 1):
                mtr = hist.metric[-1] if hist.metric else float("nan")
                print(f"  update {upd:4d}  t={now:8.2f}  "
                      f"loss={hist.loss[-1]:.4f}  metric={mtr:.4f}  "
                      f"stale={tau.mean():.1f}")
        return hist

    @property
    def params(self) -> PyTree:
        return self.state["params"]
