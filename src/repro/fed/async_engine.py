"""Buffered semi-asynchronous federated execution (DESIGN.md §5, §9).

The synchronous engine (fed/simulation.py) advances in lock-step rounds —
the straggler defines the round clock.  This engine drops the barrier:
clients train continuously, each report arrives after its simulated duration
(fed/clock.py), and the server updates once a **buffer** of M' ≤ M reports
has accumulated (Nguyen et al., FedBuff).  Arrived updates may be **stale**
— computed against a model version τ updates old — and are discounted by a
staleness weight s(τ) (Xie et al., FedAsync):

    constant : s(τ) = 1
    hinge    : s(τ) = 1                 τ ≤ b,   else 1 / (1 + a (τ − b))
    poly     : s(τ) = (1 + τ)^(−a)

The buffered server update on arrivals B with global weights ω and
discounts s_i = s(τ_i), w̃_i = ω_i s_i:

    x       ← serveropt( x,  Σ_{i∈B} w̃_i (x⁽ⁱ⁾ − x_{v_i}) )       (pseudo-deltas)
    ν       ← (1 − Σ_{i∈B} w̃_i) ν  +  Σ_{i∈B} w̃_i transmitᵢ      (mass-mixed)
    ν⁽ⁱ⁾    ← ν̄⁽ⁱ⁾   for i ∈ B only                              (row scatter)

All three reuse the synchronous stages verbatim (core/stages.py): the
client-update scan runs with *per-client anchors* (the stale model version
each client was dispatched with), aggregation uses the pseudo-delta
variants, and orientation recovers ν̄⁽ⁱ⁾ against the same stale anchor.
With buffer = M, identical client speeds and zero staleness, every quantity
above reduces to the synchronous round — FedaGrac-vs-FedAsync-vs-FedBuff is
one config switch (``FedConfig.buffer_size`` / ``staleness``).

Execution is device-resident (DESIGN.md §9).  The event ordering is
deterministic given ``(k_schedule, clock, buffer_size)``, so the whole
heapq simulation is precomputed by ``fed/clock.py::simulate_timeline`` into
numpy arrays; ``run`` then executes updates in scanned chunks.  Stale
anchors come from a bounded device-resident **anchor buffer** of M + 1
model versions — one row per client (its dispatch-time ``(params, ν)``,
rewritten at each re-dispatch) plus a scratch row that absorbs the masked
writes of duplicate same-buffer reporters — replacing the host-side
version→pytree dict.  Reports dispatched *within* the update that consumes
them (duplicate reporters, version == update index) read the live model
instead of the buffer.
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import compress, flat, robust, rounds, stages
from repro.core.fedopt import get_algorithm
from repro.core.tree_util import tree_wsum
from repro.data.partition import gaussian_k_schedule
from repro.fed.clock import ClientClock, Timeline, make_clock, \
    simulate_timeline
from repro.fed.population import ClientPopulation
from repro.fed.scenarios import Scenario, make_scenario
from repro.fed.simulation import History, _check_finite_metric

PyTree = Any


def staleness_weight(tau, mode: str = "constant", a: float = 0.5,
                     b: int = 4) -> np.ndarray:
    """Staleness discount s(τ) ≥ 0, s(0) = 1 (FedAsync §5 shapes)."""
    tau = np.asarray(tau, np.float64)
    if mode == "constant":
        return np.ones_like(tau)
    if mode == "poly":
        return (1.0 + tau) ** (-a)
    if mode == "hinge":
        return 1.0 / (1.0 + a * np.maximum(tau - b, 0.0))
    raise ValueError(f"unknown staleness mode {mode!r}")


class BufferedAsyncSimulation:
    """``run(T)`` executes T buffered server updates of ``fed.algorithm``.

    Mirrors ``FederatedSimulation``'s constructor so benchmarks can switch
    engines on ``fed.buffer_size`` alone.  ``clock`` defaults to the
    ``fed.speed_dist`` wall-clock model; ``k_schedule`` rows index per-client
    *dispatches* (client *i*'s d-th task uses row d), so with buffer = M and
    identical speeds the data stream matches the synchronous engine's.

    Each ``run`` call simulates a fresh timeline from the CURRENT model
    (every client re-dispatched at simulated t = 0 on version 0, anchors
    reset to the current state).
    """

    def __init__(self, loss_fn: Callable[[PyTree, PyTree], jax.Array],
                 params: PyTree, fed: FedConfig, batcher,
                 eval_fn: Optional[Callable[[PyTree], float]] = None,
                 k_schedule: Optional[np.ndarray] = None,
                 lam_schedule: Optional[Callable[[int], float]] = None,
                 clock: Optional[ClientClock] = None,
                 population: Optional[ClientPopulation] = None,
                 scenario: Optional[Scenario] = None,
                 t_max: int = 10_000):
        m = fed.n_clients
        self.fed = fed
        self.algo = get_algorithm(fed.algorithm, fed)
        self.batcher = batcher
        self.eval_fn = eval_fn
        self.lam_schedule = lam_schedule
        self.buffer = fed.buffer_size if fed.buffer_size > 0 else m
        if not 1 <= self.buffer <= m:
            raise ValueError(f"buffer_size {self.buffer} not in [1, {m}]")
        if k_schedule is None:
            k_schedule = gaussian_k_schedule(
                m, fed.k_mean, fed.k_var, t_max,
                mode=fed.k_mode, seed=fed.seed)
        self.k_schedule = k_schedule
        self.k_max = int(k_schedule.max())
        self.clock = clock if clock is not None else make_clock(
            m, dist=fed.speed_dist, sigma=fed.speed_sigma,
            latency=fed.comm_latency, seed=fed.seed)
        self.weights = (np.asarray(batcher.weights)
                        if fed.weights == "data"
                        else np.full((m,), 1.0 / m, np.float32))
        # partial participation (fed/population.py, DESIGN.md §10): the
        # timeline keeps only C = cohort_size tasks in flight, re-filling
        # each freed slot by the population sampler; sampler "all" (C = M)
        # reproduces the legacy always-in-flight stream bit-for-bit
        self.population = (population if population is not None
                           else ClientPopulation.from_config(
                               fed, m=m, weights=self.weights))
        if self.population is not None:
            if self.population.m != m:
                raise ValueError(
                    f"population of {self.population.m} clients does not "
                    f"match fed.n_clients={m}")
            c = self.population.cohort_size
            if not self.population.full_participation:
                # only C tasks are in flight: the buffer must not span more
                # than one concurrency sweep, or Σ w̃ ≈ B/C > 1 and the raw
                # pseudo-delta step overshoots by that factor
                if fed.buffer_size <= 0:
                    self.buffer = c
                elif self.buffer > c:
                    raise ValueError(
                        f"buffer_size {self.buffer} exceeds the population "
                        f"concurrency C={c}; use buffer_size ≤ C (0 "
                        f"defaults to C under partial participation)")
            if clock is None and np.any(self.population.step_rate != 1.0):
                # the population's step-rate profile modulates the clock
                self.clock = ClientClock(
                    speeds=self.clock.speeds * self.population.step_rate,
                    latency=self.clock.latency)
        # failure scenario (fed/scenarios.py, DESIGN.md §12): perturbs the
        # timeline (k′ aborts, slowdowns, latency bursts, rejoin downtime)
        # and scales report weights by the delivered fraction k′/K; None
        # ("baseline") leaves the whole pipeline untouched
        self.scenario = (scenario if scenario is not None
                         else make_scenario(fed))
        if self.scenario is not None:
            if self.scenario.m != m:
                raise ValueError(
                    f"scenario for {self.scenario.m} clients does not "
                    f"match fed.n_clients={m}")
            if (self.scenario.availability_fn is not None
                    and self.population is not None):
                self.population.availability_fn = \
                    self.scenario.availability_fn
        # robust aggregation (core/robust.py, DESIGN.md §16): payload
        # corruption brackets the same wire boundary as compression, the
        # defense + quarantine sit just before the buffered aggregator
        self._attack = (self.scenario
                        if self.scenario is not None
                        and self.scenario.corrupts_payload else None)
        self.robust = robust.RobustConfig.from_fed(fed)
        # private copy: the scanned chunk donates its carry (state + anchor
        # buffers), which would delete a caller-owned params tree
        params = jax.tree.map(jnp.array, params)
        # param_layout="flat" (core/flat.py, DESIGN.md §11): state vectors
        # and BOTH anchor buffers become flat (M+1, P) matrices, so the
        # stale-anchor gather and the re-dispatch scatter are pure row
        # indexing — the gather/scatter closures below are already
        # array-polymorphic, only the client update swaps implementations
        if fed.param_layout not in ("tree", "flat"):
            raise ValueError(f"unknown param_layout {fed.param_layout!r}; "
                             f"choose 'tree' or 'flat'")
        self.layout = fed.param_layout
        # wire compression (core/compress.py, DESIGN.md §14): uplink EF
        # rows follow the REPORTING ids; the downlink broadcast is carried
        # in state ("bc_params"/"bc_nu") so chunk boundaries and resumes
        # see the same anchors the clients were dispatched with
        self.compression = compress.CompressionConfig.from_fed(fed)
        self._down_on = (self.compression is not None
                         and self.compression.down_active)
        if self.layout == "flat":
            self._spec = flat.make_flat_spec(
                params, master_dtype=fed.master_dtype or None)
        elif (self.compression is not None or self.robust is not None
                or self._attack is not None):
            self._spec = flat.make_flat_spec(params)
        else:
            self._spec = None
        self._n_true = (self._spec.n if self._spec is not None else
                        int(sum(int(np.prod(lv.shape, dtype=np.int64))
                                for lv in jax.tree.leaves(params))))
        self._wire = compress.wire_cost(self._n_true, self.algo.uses_nu,
                                        self.compression)
        if self.layout == "flat":
            params = flat.ravel(self._spec, params)
        self.state = rounds.init_state(params, m, self.algo,
                                       compression=self.compression,
                                       spec=self._spec,
                                       robust=self.robust)
        self.version = 0
        self._device_sampler = callable(getattr(batcher, "sample_row", None))
        self._loss_fn = loss_fn
        self._chunk: Optional[Callable] = None
        self._anchors: Optional[PyTree] = None
        self._nu_anchors: Optional[PyTree] = None
        # host-sampler wave cache: per-wave index tensors, dropped after
        # their last in-timeline consumer and LRU-capped at M + 1 waves —
        # under heavy speed skew a straggler's wave can be re-requested
        # thousands of updates after the fast clients consumed it, and an
        # unbounded first-to-last-consumer residency would grow O(horizon);
        # an evicted wave is simply regenerated (the pre-refactor engine
        # made the same bounded-memory-for-regeneration trade)
        self._wave_cache: dict[int, Any] = {}
        self._wave_left: Optional[np.ndarray] = None

    # -- device-resident anchor buffer --------------------------------------

    def _bridge(self):
        """(ravel, ravel_rows, unravel, unravel_rows) — identities on the
        flat layout, view-table crossings on the tree layout."""
        if self.layout == "flat":
            ident = lambda a: a
            return ident, ident, ident, ident
        spec = self._spec
        return (lambda t: flat.ravel(spec, t),
                lambda t: flat.ravel(spec, t, client_dims=1),
                lambda a: flat.unravel(spec, a),
                lambda a: flat.unravel(spec, a, client_dims=1))

    def _broadcast_init(self) -> None:
        """The t = 0 dispatch ships a genuine compressed broadcast: one
        codec event through ``ef_down``(/``ef_down_nu``), persisted as the
        ``bc_params``/``bc_nu`` state carry the chunk body reads."""
        cs = compress.build_stages(self.compression, self._spec,
                                   self.algo.uses_nu)
        _rv = self._bridge()[0]
        uses_nu = self.algo.uses_nu

        def bcast(state):
            new_state = dict(state)
            new_state["bc_params"] = cs.down(_rv(state["params"]), state,
                                             new_state)
            if uses_nu:
                new_state["bc_nu"] = cs.down_nu(_rv(state["nu"]), state,
                                                new_state)
            return new_state

        self.state = jax.jit(bcast)(self.state)

    def _reset_anchors(self) -> None:
        """(M+1)-row anchor buffer: rows 0…M-1 hold each client's
        dispatch-time (params, ν); row M is the duplicate-write scratch.
        Under downlink compression the dispatch-time model is the
        COMPRESSED broadcast, not the raw master."""
        rows = self.clock.m + 1
        _ur = self._bridge()[2]
        p0 = (_ur(self.state["bc_params"]) if self._down_on
              else self.state["params"])
        nu0 = ((_ur(self.state["bc_nu"]) if self._down_on
                else self.state["nu"]) if self.algo.uses_nu else None)
        self._anchors = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (rows,) + p.shape), p0)
        self._nu_anchors = (jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (rows,) + p.shape),
            nu0) if self.algo.uses_nu else jnp.zeros(()))

    # -- the jitted scanned chunk (one trace per chunk length) --------------

    def _chunk_fn(self) -> Callable:
        """One jitted chunk serves every chunk length (jit re-specializes
        on the stacked leading dim; equal-length chunks reuse the trace)."""
        if self._chunk is None:
            self._chunk = self._make_chunk()
        return self._chunk

    def _make_chunk(self):
        algo, lr, buffer = self.algo, self.fed.lr, self.buffer
        uses_nu = algo.uses_nu
        device = self._device_sampler
        batcher, k_max = self.batcher, self.k_max
        # stale-ν⁽ⁱ⁾ decay is a PARTIAL-participation rule (DESIGN.md §10):
        # with every client in flight each row refreshes on its own report
        nu_decay = (self.fed.cohort_nu_decay
                    if self.population is not None
                    and not self.population.full_participation else 0.0)
        if self.layout == "flat":
            client_update = flat.make_flat_client_update(
                self._spec, self._loss_fn, algo, lr=lr, k_max=k_max,
                per_client_anchor=True)
        else:
            client_update = stages.make_client_update(
                self._loss_fn, algo, lr=lr, k_max=k_max,
                per_client_anchor=True)
        aggregate = stages.BUFFERED_AGGREGATORS[algo.aggregator]
        cs = compress.build_stages(self.compression, self._spec, uses_nu)
        down_on = cs is not None and cs.down is not None
        up_on = cs is not None and cs.up is not None
        rb = robust.build_round_robust(self.robust, self._spec, uses_nu)
        atk = self._attack
        wire = cs is not None or rb is not None or atk is not None
        if wire:
            _rv, _rvr, _ur, _urr = self._bridge()
            n_true = self._spec.n

        def body(carry, xs):
            state, A, N = carry
            ids, k_steps, sw = xs["ids"], xs["k"], xs["sw"]
            cur, fresh, wids = xs["cur"], xs["fresh"], xs["write_ids"]
            lam = xs["lam"]
            params = state["params"]
            new_state = dict(state)
            # what a client dispatched on THIS version actually received:
            # the compressed broadcast carried in state, or the raw model
            cur_p = _ur(state["bc_params"]) if down_on else params
            cur_nu = ((_ur(state["bc_nu"]) if down_on else state["nu"])
                      if uses_nu else None)

            def gather(buf, current):
                # dispatch-time anchors; reports dispatched within THIS
                # update (cur: version == update index) read the live model
                return jax.tree.map(
                    lambda b, c: jnp.where(
                        cur.reshape((buffer,) + (1,) * c.ndim),
                        jnp.broadcast_to(c[None], (buffer,) + c.shape),
                        b[ids]),
                    buf, current)

            anchor_i = gather(A, cur_p)
            if device:
                batches = jax.vmap(
                    lambda d, i: batcher.sample_row(d, i, k_max))(
                        xs["waves"], ids)
            else:
                batches = xs["batches"]

            kf = k_steps.astype(jnp.float32)
            # Σ w̃ — usually in (0, 1], but a high-weight fast client
            # reporting twice into one buffer can push it past 1
            mass = jnp.sum(sw)
            kbar = jnp.dot(sw, kf) / mass            # buffer-local K̄

            if uses_nu:
                # correction each client ran with: c⁽ⁱ⁾ = ν_{v_i} − ν⁽ⁱ⁾.
                # With nu_decay = 0 the current ν⁽ⁱ⁾ row IS the
                # dispatch-time value (rows change only when their client
                # reports); with decay the row has drifted toward ν by
                # (1 − (1−d)^τ) since dispatch — an accepted approximation
                # (the drift shrinks the correction, never grows it) that
                # avoids a second (M+1)-row snapshot buffer
                nu_anchor = gather(N, cur_nu)
                c_b = jax.tree.map(lambda na, nui: na - nui[ids],
                                   nu_anchor, state["nu_i"])
            else:
                c_b = stages.zero_corrections(params, buffer)

            x_b, g0_b, acc_b, loss0 = client_update(anchor_i, c_b, batches,
                                                    k_steps, lam)

            # uplink wire path at the REPORTING ids: each reporter's
            # error-feedback row rides its own reports (a duplicate
            # same-buffer reporter resolves last-wins, the nu_i caveat);
            # payload corruption lands on the same pseudo-delta rows the
            # codec sees, and the defense screens what reaches the
            # buffered aggregator
            sw_eff = sw
            if wire:
                a_rows = _rvr(anchor_i)
                d = _rvr(x_b) - a_rows
                if atk is not None:
                    d = atk.corrupt_delta(state["round"], d, n_true,
                                          ids=ids)
                if up_on:
                    d = cs.up(d, state, new_state, ids=ids)
                if rb is not None:
                    d, sw_eff, qcount = rb.model(d, sw, state, new_state,
                                                 state["round"], ids)
                x_srv = _urr(a_rows + d)
            else:
                x_srv = x_b

            agg = aggregate(params, anchor_i, x_srv, kf, sw_eff, kbar)
            new_params = stages.server_update(algo, state, params, agg,
                                              new_state)
            if rb is not None:
                # final non-finite guard BEFORE the broadcast / re-dispatch
                # anchors read the new model: a defended run never ships a
                # poisoned version to any client
                new_params = rb.guard(new_params, params)
            new_state["params"] = new_params
            new_state["round"] = state["round"] + 1

            if uses_nu:
                transmit, avg_g = stages.orientation_transmit(
                    algo, params, x_b, g0_b, acc_b, c_b, kf, kbar, lr, lam,
                    anchor_i=anchor_i)
                w_nu = sw
                if wire and (up_on or atk is not None or rb is not None):
                    t_rows = _rvr(transmit)
                    if atk is not None:
                        t_rows = atk.corrupt_nu(state["round"], t_rows,
                                                n_true, ids=ids)
                    if up_on:
                        t_rows = cs.up_nu(t_rows, state, new_state,
                                          ids=ids)
                    if rb is not None:
                        t_rows, w_nu = rb.nu(t_rows, sw, state,
                                             state["round"], ids)
                    transmit = _urr(t_rows)
                # ν renorm preserves Σw̃ so the mass-mix ρ keeps its
                # planned value; an all-dropped buffer contributes 0 and
                # ν decays by (1 − ρ) — a safe fade, never a poisoned mix
                contrib = tree_wsum(w_nu, transmit)
                new_state["nu"] = stages.nu_mass_mix(state["nu"], contrib,
                                                     mass)
                if rb is not None:
                    # guard ν before the scatter/broadcast below read it
                    new_state["nu"] = rb.guard(new_state["nu"],
                                               state["nu"])
                # duplicate idx (a fast client reporting twice into one
                # buffer) resolves arbitrarily between its two same-buffer
                # reports — both are current to within one update
                new_state["nu_i"] = stages.scatter_nu_rows(
                    state["nu_i"], new_state["nu"], avg_g, ids, nu_decay)
                if rb is not None:
                    new_state["nu_i"] = rb.guard(new_state["nu_i"],
                                                 state["nu_i"])

            # this update's broadcast: ONE compression event through the
            # server-side accumulator, persisted for the next gather and
            # written into re-dispatched anchors below
            if down_on:
                new_bc = cs.down(_rv(new_params), state, new_state)
                new_state["bc_params"] = new_bc
                old_anchor, new_anchor = cur_p, _ur(new_bc)
            else:
                old_anchor, new_anchor = params, new_params

            def scatter(buf, old, new):
                # re-dispatch anchors: the pre-update model, or the
                # post-update one for tie-upgraded reporters; a duplicate
                # reporter writes once (its stale non-last occurrences are
                # routed to the scratch row M by ``write_ids``)
                return jax.tree.map(
                    lambda b, o, n: b.at[wids].set(
                        jnp.where(fresh.reshape((buffer,) + (1,) * o.ndim),
                                  jnp.broadcast_to(n[None],
                                                   (buffer,) + n.shape),
                                  jnp.broadcast_to(o[None],
                                                   (buffer,) + o.shape)
                                  ).astype(b.dtype)),
                    buf, old, new)

            A = scatter(A, old_anchor, new_anchor)
            if uses_nu:
                if down_on:
                    new_bc_nu = cs.down_nu(_rv(new_state["nu"]), state,
                                           new_state)
                    new_state["bc_nu"] = new_bc_nu
                    N = scatter(N, cur_nu, _ur(new_bc_nu))
                else:
                    N = scatter(N, state["nu"], new_state["nu"])

            metrics = {"loss": jnp.dot(sw, loss0) / mass, "kbar": kbar,
                       "mass": mass}
            if rb is not None:
                metrics["quarantined"] = qcount
            return (new_state, A, N), metrics

        def chunk(carry, xs):
            return jax.lax.scan(body, carry, xs)

        return jax.jit(chunk, donate_argnums=(0,))

    # -- host-sampler batch assembly ----------------------------------------

    def _wave(self, d: int):
        """Index tensor (or full batch wave) ``d``, cached until its last
        consumer in the precomputed timeline has arrived (LRU-capped,
        see __init__)."""
        wave = self._wave_cache.pop(d, None)
        if wave is None:
            if hasattr(self.batcher, "round_indices"):
                wave = self.batcher.round_indices(d, self.k_max)
            else:
                wave = self.batcher.round_batches(d, self.k_max)
        self._wave_left[d] -= 1
        if self._wave_left[d] > 0:
            self._wave_cache[d] = wave        # re-insert: most recent
            while len(self._wave_cache) > self.clock.m + 1:
                self._wave_cache.pop(next(iter(self._wave_cache)))
        return wave

    def _host_batches(self, tl: Timeline, u0: int, r: int) -> PyTree:
        """(R, B, k_max, batch, …) gathered rows for updates u0 … u0+r-1 —
        one host→device transfer per chunk."""
        if hasattr(self.batcher, "round_indices"):
            idx = np.empty((r, self.buffer, self.k_max,
                            self.batcher.batch_size), np.int64)
            for a in range(r):
                for j in range(self.buffer):
                    idx[a, j] = self._wave(int(tl.waves[u0 + a, j]))[
                        int(tl.ids[u0 + a, j])]
            return {"x": jnp.asarray(self.batcher._x[idx]),
                    "y": jnp.asarray(self.batcher._y[idx])}
        rows = [jax.tree.map(
            lambda x, i=int(tl.ids[u0 + a, j]): x[i],
            self._wave(int(tl.waves[u0 + a, j])))
            for a in range(r) for j in range(self.buffer)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
        return jax.tree.map(
            lambda x: x.reshape((r, self.buffer) + x.shape[1:]), stacked)

    # -- the timeline-driven chunked executor --------------------------------

    def run(self, t_updates: int, eval_every: int = 1,
            verbose: bool = False,
            chunk_updates: Optional[int] = None) -> History:
        hist = History()
        fed = self.fed
        tl = simulate_timeline(self.k_schedule, self.clock, self.buffer,
                               t_updates, population=self.population,
                               scenario=self.scenario)
        tau = tl.staleness
        s = staleness_weight(tau, fed.staleness, fed.staleness_a,
                             fed.staleness_b)
        # per-report base weights: raw ω for full participation, the
        # population's per-sampler renormalization (Horvitz–Thompson /
        # uniform-1/C) under partial participation (DESIGN.md §10)
        base_w = (self.weights
                  if self.population is None
                  or self.population.full_participation
                  else self.population.report_weights())
        sw = base_w[tl.ids] * s
        if self.scenario is not None:
            # partial-work recovery (DESIGN.md §12): an aborted report's
            # FedNova-normalized per-step direction keeps only the mass it
            # earned — w̃ · k′/K feeds BOTH the pseudo-delta aggregation
            # and the ν mass-mix (stages.delivered_weights rule)
            sw = sw * (tl.k_steps / np.maximum(tl.k_sched, 1))
        sw_all = sw.astype(np.float32)
        cur_all = tl.versions == np.arange(t_updates)[:, None]
        # duplicate dispatches: only the LAST occurrence re-writes the
        # client's anchor row; earlier ones land in the scratch row M
        write_ids = tl.dispatch_ids.copy()
        for u in range(t_updates):
            seen: set[int] = set()
            for j in range(self.buffer - 1, -1, -1):
                i = int(tl.dispatch_ids[u, j])
                if i in seen:
                    write_ids[u, j] = self.clock.m
                else:
                    seen.add(i)
        lam_all = np.asarray(
            [float(self.lam_schedule(u)) if self.lam_schedule
             else self.algo.lam for u in range(t_updates)], np.float32)
        if self._down_on:
            self._broadcast_init()
        self._reset_anchors()
        if not self._device_sampler:
            self._wave_cache = {}
            self._wave_left = np.bincount(tl.waves.ravel())

        chunk = max(int(chunk_updates if chunk_updates is not None
                        else eval_every), 1)
        if (chunk_updates is not None and chunk > eval_every
                and self.eval_fn is not None):
            warnings.warn(
                f"chunk_updates={chunk_updates} is clamped to the eval "
                f"cadence (eval_every={eval_every}): the host must sync at "
                f"every eval boundary", stacklevel=2)
        u = 0
        while u < t_updates:
            r = min(chunk, t_updates - u)
            if self.eval_fn is not None:
                r = min(r, eval_every - u % eval_every)
            sl = slice(u, u + r)
            xs = {"ids": jnp.asarray(tl.ids[sl], jnp.int32),
                  "k": jnp.asarray(tl.k_steps[sl], jnp.int32),
                  "sw": jnp.asarray(sw_all[sl]),
                  "cur": jnp.asarray(cur_all[sl]),
                  "fresh": jnp.asarray(tl.fresh[sl]),
                  "write_ids": jnp.asarray(write_ids[sl], jnp.int32),
                  "lam": jnp.asarray(lam_all[sl])}
            if self._device_sampler:
                xs["waves"] = jnp.asarray(tl.waves[sl], jnp.int32)
            else:
                xs["batches"] = self._host_batches(tl, u, r)
            fn = self._chunk_fn()
            tic = time.perf_counter()
            carry, metrics = fn((self.state, self._anchors,
                                 self._nu_anchors), xs)
            self.state, self._anchors, self._nu_anchors = carry
            # timed region covers the compute, not the async dispatch
            jax.block_until_ready(self.state)
            dt = time.perf_counter() - tic
            hist.loss.extend(np.asarray(metrics["loss"],
                                        np.float64).tolist())
            hist.kbar.extend(np.asarray(metrics["kbar"],
                                        np.float64).tolist())
            hist.mass.extend(np.asarray(metrics["mass"],
                                        np.float64).tolist())
            hist.wall.extend([dt / r] * r)
            hist.sim_time.extend(tl.arrival_t[sl, -1].tolist())
            hist.staleness.extend(tau[sl].mean(axis=1).tolist())
            # wire traffic per update: B reports up, B re-dispatch
            # downloads of the (possibly compressed) new broadcast
            hist.bytes_up.extend(
                [self.buffer * self._wire["uplink_per_client"]] * r)
            hist.bytes_down.extend(
                [self.buffer * self._wire["downlink_per_client"]] * r)
            if self.scenario is not None:
                hist.dropped.extend(
                    tl.aborted[sl].mean(axis=1).tolist())
            if "quarantined" in metrics:
                hist.quarantined.extend(
                    np.asarray(metrics["quarantined"],
                               np.float64).tolist())
            u += r
            if self.eval_fn is not None and u % eval_every == 0:
                value = float(self.eval_fn(self.params))
                _check_finite_metric(value, u)
                hist.metric.append(value)
            if verbose and (u % 10 < r or u == t_updates):
                mtr = hist.metric[-1] if hist.metric else float("nan")
                print(f"  update {u - 1:4d}  t={hist.sim_time[-1]:8.2f}  "
                      f"loss={hist.loss[-1]:.4f}  metric={mtr:.4f}  "
                      f"stale={hist.staleness[-1]:.1f}")
        self.version += t_updates
        return hist

    @property
    def params(self) -> PyTree:
        """Current global model as a pytree (flat layout unravels)."""
        if self.layout == "flat":
            return flat.unravel(self._spec, self.state["params"])
        return self.state["params"]
