"""Single-host federated simulator.

Drives the SPMD round engine (core/rounds.py) with vmap-over-clients on one
device: samples K_i schedules, assembles per-round microbatches, runs T
rounds jitted, and records loss / eval metrics.  This is the harness behind
the paper-experiment benchmarks (Tables 1/2/6, Figures 2/3/5)."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import rounds
from repro.core.fedopt import get_algorithm
from repro.data.partition import gaussian_k_schedule

PyTree = Any


@dataclasses.dataclass
class History:
    loss: list[float] = dataclasses.field(default_factory=list)
    metric: list[float] = dataclasses.field(default_factory=list)
    kbar: list[float] = dataclasses.field(default_factory=list)
    wall: list[float] = dataclasses.field(default_factory=list)
    per_client: list[list[float]] = dataclasses.field(default_factory=list)
    # buffered-async engine (fed/async_engine.py): simulated arrival time of
    # each server update and the mean staleness of its buffer
    sim_time: list[float] = dataclasses.field(default_factory=list)
    staleness: list[float] = dataclasses.field(default_factory=list)

    def fairness(self) -> Optional[dict]:
        """FL fairness of the final round: worst-client metric and the
        across-client std (Li et al. q-FFL reporting convention)."""
        if not self.per_client:
            return None
        last = self.per_client[-1]
        return {"worst": min(last), "best": max(last),
                "std": float(np.std(last))}

    def rounds_to_target(self, target: float, higher_is_better=True
                         ) -> Optional[int]:
        for t, v in enumerate(self.metric):
            if (v >= target) if higher_is_better else (v <= target):
                return t + 1
        return None


class FederatedSimulation:
    """``run(T)`` executes T rounds of ``fed.algorithm`` on one device."""

    def __init__(self, loss_fn: Callable[[PyTree, PyTree], jax.Array],
                 params: PyTree, fed: FedConfig, batcher,
                 eval_fn: Optional[Callable[[PyTree], float]] = None,
                 eval_per_client: Optional[Callable[[PyTree],
                                                    list]] = None,
                 k_schedule: Optional[np.ndarray] = None,
                 lam_schedule: Optional[Callable[[int], float]] = None,
                 t_max: int = 10_000):
        self.fed = fed
        self.algo = get_algorithm(fed.algorithm, fed)
        self.batcher = batcher
        self.eval_fn = eval_fn
        self.eval_per_client = eval_per_client
        self.lam_schedule = lam_schedule
        if k_schedule is None:
            k_schedule = gaussian_k_schedule(
                fed.n_clients, fed.k_mean, fed.k_var, t_max,
                mode=fed.k_mode, seed=fed.seed)
        self.k_schedule = k_schedule
        self.k_max = int(k_schedule.max())
        self.weights = (jnp.asarray(batcher.weights)
                        if fed.weights == "data"
                        else jnp.full((fed.n_clients,),
                                      1.0 / fed.n_clients, jnp.float32))
        self.state = rounds.init_state(params, fed.n_clients, self.algo)
        self._round: Optional[Callable] = None
        self._loss_fn = loss_fn

    def _round_fn(self) -> Callable:
        """One jitted round for EVERY λ: the round function takes λ as a
        traced scalar argument, so ``lam_schedule`` never retraces (the old
        cache was keyed on the float λ — one fresh ``jax.jit`` trace per
        round under any non-constant schedule)."""
        if self._round is None:
            fn = rounds.make_round(self._loss_fn, self.algo, lr=self.fed.lr,
                                   k_max=self.k_max)
            self._round = jax.jit(fn)
        return self._round

    def run(self, t_rounds: int, eval_every: int = 1,
            verbose: bool = False) -> History:
        hist = History()
        for t in range(t_rounds):
            lam = (float(self.lam_schedule(t)) if self.lam_schedule
                   else self.algo.lam)
            round_fn = self._round_fn()
            k_t = jnp.asarray(self.k_schedule[t % len(self.k_schedule)])
            batches = self.batcher.round_batches(t, self.k_max)
            t0 = time.perf_counter()
            self.state, metrics = round_fn(self.state, batches, k_t,
                                           self.weights, jnp.float32(lam))
            loss = float(metrics["loss"])
            hist.loss.append(loss)
            hist.kbar.append(float(metrics["kbar"]))
            hist.wall.append(time.perf_counter() - t0)
            if self.eval_fn is not None and (t + 1) % eval_every == 0:
                hist.metric.append(float(self.eval_fn(self.state["params"])))
            if self.eval_per_client is not None and \
                    (t + 1) % eval_every == 0:
                hist.per_client.append(
                    [float(v) for v in
                     self.eval_per_client(self.state["params"])])
            if verbose and (t % 10 == 0 or t == t_rounds - 1):
                m = hist.metric[-1] if hist.metric else float("nan")
                print(f"  round {t:4d}  loss={loss:.4f}  metric={m:.4f}")
        return hist

    @property
    def params(self) -> PyTree:
        return self.state["params"]


def compare_algorithms(algorithms: list[str], make_sim: Callable[[str],
                       FederatedSimulation], t_rounds: int,
                       eval_every: int = 1) -> dict[str, History]:
    """Run the same task under several algorithms (benchmark helper)."""
    out = {}
    for name in algorithms:
        sim = make_sim(name)
        out[name] = sim.run(t_rounds, eval_every=eval_every)
    return out
