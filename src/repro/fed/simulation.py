"""Single-host federated simulator.

Drives the SPMD round engine (core/rounds.py) with vmap-over-clients on one
device: samples K_i schedules, assembles per-round microbatches, runs T
rounds jitted, and records loss / eval metrics.  This is the harness behind
the paper-experiment benchmarks (Tables 1/2/6, Figures 2/3/5).

Execution is chunked (DESIGN.md §9): ``run`` drives blocks of
``chunk_rounds`` rounds through one jitted ``lax.scan``
(core/engine.py), syncing to host only at chunk boundaries — the eval
cadence defines the default chunk size, so the legacy behavior
(``eval_every=1`` ⇒ one dispatch + one sync per round) is the
``chunk_rounds=1`` compat path, bit-identical by construction and pinned by
tests/test_golden_equivalence.py.  With a ``DeviceBatcher`` the per-round
microbatches are also drawn inside the scan; host batchers stack R rounds
into a single transfer."""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import compress, engine, flat, robust, rounds, stages
from repro.core.fedopt import get_algorithm
from repro.data.partition import gaussian_k_schedule
from repro.fed.population import ClientPopulation
from repro.fed.scenarios import Scenario, make_scenario

PyTree = Any


def _check_finite_metric(value: float, t: int) -> None:
    """Fail loudly at the eval boundary: a non-finite metric means the run
    diverged or was poisoned — silently logging NaN into History lets a
    corrupted model ship (defenses/quarantine: core/robust.py, §16)."""
    if not np.isfinite(value):
        raise FloatingPointError(
            f"evaluation metric is non-finite ({value}) after round {t}: "
            f"the run has diverged or been poisoned; configure a defense "
            f"(FedConfig.defense / quarantine_window, core/robust.py)")


@dataclasses.dataclass
class History:
    loss: list[float] = dataclasses.field(default_factory=list)
    metric: list[float] = dataclasses.field(default_factory=list)
    kbar: list[float] = dataclasses.field(default_factory=list)
    wall: list[float] = dataclasses.field(default_factory=list)
    per_client: list[list[float]] = dataclasses.field(default_factory=list)
    # buffered-async engine (fed/async_engine.py): simulated arrival time of
    # each server update, the mean staleness of its buffer, and the buffer
    # mass Σ w̃ (discount-weighted participation)
    sim_time: list[float] = dataclasses.field(default_factory=list)
    staleness: list[float] = dataclasses.field(default_factory=list)
    mass: list[float] = dataclasses.field(default_factory=list)
    # failure scenarios (fed/scenarios.py): per-round/update fraction of
    # mid-round dropouts (k′ < K_i) — population-level for the sync engine,
    # buffer-level for the async engine; empty without a scenario
    dropped: list[float] = dataclasses.field(default_factory=list)
    # Byzantine robustness (core/robust.py, DESIGN.md §16): number of
    # participants excluded by an active quarantine each round/update;
    # empty unless defense/quarantine is configured
    quarantined: list[float] = dataclasses.field(default_factory=list)
    # wire bytes per round/update under the configured compressors
    # (core/compress.py wire_cost × participants) — recorded on EVERY run,
    # fp32 cost when compression is off, so baselines compare directly
    bytes_up: list[float] = dataclasses.field(default_factory=list)
    bytes_down: list[float] = dataclasses.field(default_factory=list)

    def fairness(self) -> Optional[dict]:
        """FL fairness of the final round: worst-client metric and the
        across-client std (Li et al. q-FFL reporting convention)."""
        if not self.per_client:
            return None
        last = self.per_client[-1]
        return {"worst": min(last), "best": max(last),
                "std": float(np.std(last))}

    def rounds_to_target(self, target: float, higher_is_better=True
                         ) -> Optional[int]:
        for t, v in enumerate(self.metric):
            if (v >= target) if higher_is_better else (v <= target):
                return t + 1
        return None

    def bytes_to_target(self, target: float, higher_is_better=True
                        ) -> Optional[float]:
        """Cumulative uplink bytes spent when the eval metric first hits
        ``target`` (the compression headline: bytes, not rounds, are the
        cross-device cost model) — None if the target is never reached."""
        r = self.rounds_to_target(target, higher_is_better)
        if r is None or not self.bytes_up or not self.metric:
            return None
        per_eval = max(1, len(self.bytes_up) // len(self.metric))
        return float(sum(self.bytes_up[:r * per_eval]))


class FederatedSimulation:
    """``run(T)`` executes T rounds of ``fed.algorithm`` on one device."""

    def __init__(self, loss_fn: Callable[[PyTree, PyTree], jax.Array],
                 params: PyTree, fed: FedConfig, batcher,
                 eval_fn: Optional[Callable[[PyTree], float]] = None,
                 eval_per_client: Optional[Callable[[PyTree],
                                                    list]] = None,
                 k_schedule: Optional[np.ndarray] = None,
                 lam_schedule: Optional[Callable[[int], float]] = None,
                 population: Optional[ClientPopulation] = None,
                 scenario: Optional[Scenario] = None,
                 t_max: int = 10_000):
        self.fed = fed
        self.algo = get_algorithm(fed.algorithm, fed)
        self.batcher = batcher
        self.eval_fn = eval_fn
        self.eval_per_client = eval_per_client
        self.lam_schedule = lam_schedule
        if k_schedule is None:
            k_schedule = gaussian_k_schedule(
                fed.n_clients, fed.k_mean, fed.k_var, t_max,
                mode=fed.k_mode, seed=fed.seed)
        self.k_schedule = k_schedule
        self.k_max = int(k_schedule.max())
        self.weights = (jnp.asarray(batcher.weights)
                        if fed.weights == "data"
                        else jnp.full((fed.n_clients,),
                                      1.0 / fed.n_clients, jnp.float32))
        # private copy: chunked execution donates the state buffers to the
        # scan (core/engine.py), which would delete a caller-owned ``params``
        # tree shared with other simulations
        params = jax.tree.map(jnp.array, params)
        # param_layout="flat" (core/flat.py, DESIGN.md §11): the round state
        # lives as one lane-padded (P,) buffer per vector (ν⁽ⁱ⁾: (M, P)) and
        # the flat round twins plug into the SAME run loop — only the eval
        # boundary (``self.params``) unravels back to the pytree
        if fed.param_layout not in ("tree", "flat"):
            raise ValueError(f"unknown param_layout {fed.param_layout!r}; "
                             f"choose 'tree' or 'flat'")
        self.layout = fed.param_layout
        # failure scenario (fed/scenarios.py, DESIGN.md §12): None for
        # "baseline" — every run path below then takes its literally
        # unperturbed (golden-pinned) branch.  Resolved BEFORE the spec
        # decision: payload-corruption scenarios work on wire rows, so the
        # tree layout needs the flat view table, exactly like compression.
        self.scenario = (scenario if scenario is not None
                         else make_scenario(fed))
        if self.scenario is not None and self.scenario.m != fed.n_clients:
            raise ValueError(
                f"scenario for {self.scenario.m} clients does not "
                f"match fed.n_clients={fed.n_clients}")
        self._attack = (self.scenario
                        if self.scenario is not None
                        and self.scenario.corrupts_payload else None)
        # Byzantine-robust aggregation (core/robust.py, DESIGN.md §16):
        # None when defense="none" and quarantine is off — the builders
        # then bake the identical (golden-pinned) round
        self.robust = robust.RobustConfig.from_fed(fed)
        # wire compression (core/compress.py, DESIGN.md §14): None when the
        # config requests no compression — every builder below then bakes
        # its literally unchanged (golden-pinned) round
        self.compression = compress.CompressionConfig.from_fed(fed)
        if self.layout == "flat":
            self._spec = flat.make_flat_spec(
                params, master_dtype=fed.master_dtype or None)
        elif (self.compression is not None or self.robust is not None
                or self._attack is not None):
            # the tree round works the wire rows through the view table:
            # it needs the spec (and any flat EF/health state) even though
            # params stay a pytree
            self._spec = flat.make_flat_spec(params)
        else:
            self._spec = None
        self._n_true = (self._spec.n if self._spec is not None else
                        int(sum(int(np.prod(lv.shape, dtype=np.int64))
                                for lv in jax.tree.leaves(params))))
        self._wire = compress.wire_cost(self._n_true, self.algo.uses_nu,
                                        self.compression)
        if self.layout == "flat":
            params = flat.ravel(self._spec, params)
        self.state = rounds.init_state(params, fed.n_clients, self.algo,
                                       compression=self.compression,
                                       spec=self._spec, robust=self.robust)
        self._round: Optional[Callable] = None
        self._chunks: dict[int, Callable] = {}
        self._loss_fn = loss_fn
        # a DeviceBatcher exposes a traceable in-scan sampler; host batchers
        # remain the pinned-equivalence compat mode (DESIGN.md §9)
        self._device_sampler = callable(getattr(batcher, "sample", None))
        # partial participation (fed/population.py, DESIGN.md §10): each
        # round runs a sampled cohort of C ≤ M clients; sampler "all" stays
        # on the golden-pinned full-participation path above
        self.population = (population if population is not None
                           else ClientPopulation.from_config(
                               fed, m=fed.n_clients,
                               weights=np.asarray(self.weights)))
        self._partial = (self.population is not None
                         and not self.population.full_participation)
        if (self.population is not None
                and self.population.m != fed.n_clients):
            raise ValueError(
                f"population of {self.population.m} clients does not match "
                f"fed.n_clients={fed.n_clients}")
        if (self.scenario is not None
                and self.scenario.availability_fn is not None
                and self.population is not None):
            self.population.availability_fn = self.scenario.availability_fn
        self._dw = None       # lazily-jitted delivered-weights host mirror

    def _build_round(self) -> Callable:
        """The ONE synchronous-round builder every execution path shares —
        the tree round or (``param_layout="flat"``) its single-buffer twin;
        both expose ``round_fn(state, batches, k_steps, weights, lam)``, so
        the run loop below is layout-agnostic."""
        if self.layout == "flat":
            return flat.make_flat_round(
                self._spec, self._loss_fn, self.algo, lr=self.fed.lr,
                k_max=self.k_max, compression=self.compression,
                robust=self.robust, attack=self._attack)
        return rounds.make_round(self._loss_fn, self.algo, lr=self.fed.lr,
                                 k_max=self.k_max,
                                 compression=self.compression,
                                 spec=self._spec, robust=self.robust,
                                 attack=self._attack)

    def _round_fn(self) -> Callable:
        """One jitted round for EVERY λ: the round function takes λ as a
        traced scalar argument, so ``lam_schedule`` never retraces (the old
        cache was keyed on the float λ — one fresh ``jax.jit`` trace per
        round under any non-constant schedule)."""
        if self._round is None:
            self._round = jax.jit(self._build_round())
        return self._round

    def _chunk_fn(self, r: int) -> Callable:
        """The r-round scanned chunk (cached per chunk length)."""
        if r not in self._chunks:
            fn = self._build_round()
            sample = (lambda t: self.batcher.sample(t, self.k_max)) \
                if self._device_sampler else None
            self._chunks[r] = engine.make_round_chunk(fn, r,
                                                      sample_fn=sample)
        return self._chunks[r]

    def _make_pop_round(self) -> Callable:
        """The ONE cohort-round builder both population paths share — the
        compat round and every chunk length compute the identical round."""
        if self.layout == "flat":
            return flat.make_flat_cohort_round(
                self._spec, self._loss_fn, self.algo, lr=self.fed.lr,
                k_max=self.k_max, nu_decay=self.fed.cohort_nu_decay,
                compression=self.compression, robust=self.robust,
                attack=self._attack)
        return stages.make_cohort_round(
            self._loss_fn, self.algo, lr=self.fed.lr, k_max=self.k_max,
            nu_decay=self.fed.cohort_nu_decay,
            compression=self.compression, spec=self._spec,
            robust=self.robust, attack=self._attack)

    def _pop_round_fn(self) -> Callable:
        """One jitted cohort round (partial participation, DESIGN.md §10)."""
        if self._round is None:
            self._round = jax.jit(self._make_pop_round())
        return self._round

    def _pop_chunk_fn(self, r: int) -> Callable:
        """The r-round scanned cohort chunk: with a DeviceBatcher the cohort
        draw AND the batch generation run inside the scan (O(C) memory);
        host batchers feed precomputed (r, C, …) cohort tensors."""
        if r not in self._chunks:
            fn = self._make_pop_round()
            pop, k_max = self.population, self.k_max
            if self._device_sampler:
                scn = self.scenario
                scenario_fn = (
                    (lambda t, k_c, ids: scn.k_eff(t, k_c, ids=ids))
                    if scn is not None and scn.perturbs_k else None)
                self._chunks[r] = engine.make_population_chunk(
                    fn, r, cohort_fn=pop.cohort_and_weights,
                    sample_fn=lambda t, ids: self.batcher.sample_cohort(
                        t, ids, k_max),
                    scenario_fn=scenario_fn)
            else:
                self._chunks[r] = engine.make_population_chunk(fn, r)
        return self._chunks[r]

    def _lam(self, t: int) -> float:
        return (float(self.lam_schedule(t)) if self.lam_schedule
                else self.algo.lam)

    # -- failure-scenario host mirrors (fed/scenarios.py, DESIGN.md §12) ----

    def _sched_row(self, t: int) -> np.ndarray:
        return np.asarray(self.k_schedule[t % len(self.k_schedule)])

    def _k_row(self, t: int) -> np.ndarray:
        """Round t's effective K row: the schedule row, perturbed to k′ by
        the scenario's host mirror — the SAME jax draw the in-scan hook
        evaluates, so host and device paths stay bit-identical."""
        row = self._sched_row(t)
        if self.scenario is None or not self.scenario.perturbs_k:
            return row
        return self.scenario.host_k_eff(t, row)

    def _delivered(self, cw: np.ndarray, k_eff: np.ndarray,
                   k_sched: np.ndarray) -> np.ndarray:
        """Host mirror of the in-scan delivered-fraction weight scaling."""
        if self._dw is None:
            self._dw = jax.jit(stages.delivered_weights)
        return np.asarray(self._dw(jnp.asarray(cw),
                                   jnp.asarray(k_eff, jnp.int32),
                                   jnp.asarray(k_sched, jnp.int32)))

    def _record_dropped(self, hist: History, t0: int, r: int) -> None:
        """Population-level abort fraction per round (pure in (seed, t))."""
        if self.scenario is None:
            return
        if not self.scenario.perturbs_k:
            hist.dropped.extend([0.0] * r)
            return
        hist.dropped.extend(
            float(np.mean(self._k_row(t0 + j) < self._sched_row(t0 + j)))
            for j in range(r))

    def _record_bytes(self, hist: History, r: int, participants: int
                      ) -> None:
        """Measured wire traffic for r rounds of ``participants`` reports
        each (fp32 cost when compression is off — the baseline series)."""
        hist.bytes_up.extend(
            [participants * self._wire["uplink_per_client"]] * r)
        hist.bytes_down.extend(
            [participants * self._wire["downlink_per_client"]] * r)

    def _chunk_inputs(self, t0: int, r: int):
        """Stacked (k_steps, weights, lam) + batches for rounds t0…t0+r-1."""
        ks = jnp.asarray(np.stack(
            [self._k_row(t0 + j) for j in range(r)]).astype(np.int32))
        lams = jnp.asarray([self._lam(t0 + j) for j in range(r)],
                           jnp.float32)
        weights = jnp.broadcast_to(self.weights, (r,) + self.weights.shape)
        if self._device_sampler:
            batches = jnp.arange(t0, t0 + r, dtype=jnp.int32)
        elif hasattr(self.batcher, "chunk_batches"):
            batches = self.batcher.chunk_batches(t0, r, self.k_max)
        else:
            waves = [self.batcher.round_batches(t0 + j, self.k_max)
                     for j in range(r)]
            batches = jax.tree.map(lambda *xs: jnp.stack(xs), *waves)
        return batches, ks, weights, lams

    def _run_round(self, t: int, hist: History) -> None:
        """The chunk_rounds=1 compat path: one dispatch + one host sync per
        round, bit-identical to the pre-chunking loop (golden-pinned)."""
        lam = self._lam(t)
        round_fn = self._round_fn()
        k_t = (jnp.asarray(self.k_schedule[t % len(self.k_schedule)])
               if self.scenario is None else jnp.asarray(self._k_row(t)))
        batches = self.batcher.round_batches(t, self.k_max)
        t0 = time.perf_counter()
        self.state, metrics = round_fn(self.state, batches, k_t,
                                       self.weights, jnp.float32(lam))
        # the timed region must cover the COMPUTE, not the async dispatch:
        # without the block, hist.wall under-reports by the entire round
        jax.block_until_ready(self.state)
        hist.wall.append(time.perf_counter() - t0)
        hist.loss.append(float(metrics["loss"]))
        hist.kbar.append(float(metrics["kbar"]))
        if "quarantined" in metrics:
            hist.quarantined.append(float(metrics["quarantined"]))
        self._record_dropped(hist, t, 1)
        self._record_bytes(hist, 1, self.fed.n_clients)

    def _run_chunk(self, t0: int, r: int, hist: History) -> None:
        chunk_fn = self._chunk_fn(r)
        batches, ks, weights, lams = self._chunk_inputs(t0, r)
        tic = time.perf_counter()
        self.state, metrics = chunk_fn(self.state, batches, ks, weights,
                                       lams)
        jax.block_until_ready(self.state)
        dt = time.perf_counter() - tic
        hist.loss.extend(np.asarray(metrics["loss"], np.float64).tolist())
        hist.kbar.extend(np.asarray(metrics["kbar"], np.float64).tolist())
        if "quarantined" in metrics:
            hist.quarantined.extend(
                np.asarray(metrics["quarantined"], np.float64).tolist())
        hist.wall.extend([dt / r] * r)
        self._record_dropped(hist, t0, r)
        self._record_bytes(hist, r, self.fed.n_clients)

    # -- partial-participation execution (fed/population.py, DESIGN.md §10) --

    def _run_pop_round(self, t: int, hist: History) -> None:
        """chunk_rounds=1 cohort path: cohort drawn on host (identical to
        the in-scan draw — same jax.random function of (seed, t))."""
        lam = self._lam(t)
        fn = self._pop_round_fn()
        ids, cw = self.population.host_cohort(t)
        k_c = self._sched_row(t)[ids]
        if self.scenario is not None and self.scenario.perturbs_k:
            # same perturbation the in-scan hook applies (values identical
            # by the per-(round, client) keying): run the k′ prefix and
            # scale w̃ by the delivered fraction
            k_eff = self._k_row(t)[ids]
            cw = self._delivered(cw, k_eff, k_c)
            k_c = k_eff
        if self._device_sampler:
            batches = self.batcher.sample_cohort(
                jnp.int32(t), jnp.asarray(ids, jnp.int32), self.k_max)
        else:
            batches = self.batcher.cohort_batches(t, ids, self.k_max)
        t0 = time.perf_counter()
        self.state, metrics = fn(self.state, batches,
                                 jnp.asarray(ids, jnp.int32),
                                 jnp.asarray(k_c, jnp.int32),
                                 jnp.asarray(cw), jnp.float32(lam))
        jax.block_until_ready(self.state)
        hist.wall.append(time.perf_counter() - t0)
        hist.loss.append(float(metrics["loss"]))
        hist.kbar.append(float(metrics["kbar"]))
        hist.mass.append(float(metrics["mass"]))
        if "quarantined" in metrics:
            hist.quarantined.append(float(metrics["quarantined"]))
        self._record_dropped(hist, t, 1)
        self._record_bytes(hist, 1, self.population.cohort_size)

    def _run_pop_chunk(self, t0: int, r: int, hist: History) -> None:
        chunk_fn = self._pop_chunk_fn(r)
        perturb = self.scenario is not None and self.scenario.perturbs_k
        lams = jnp.asarray([self._lam(t0 + j) for j in range(r)],
                           jnp.float32)
        if self._device_sampler:
            # cohort draw + batch sampling both happen inside the scan —
            # with a scenario, so does the k′ perturbation
            # (engine.make_population_chunk's scenario_fn); the host ships
            # only the (r,) round indices and (r, M) SCHEDULED K rows
            ts = jnp.arange(t0, t0 + r, dtype=jnp.int32)
            k_rows = jnp.asarray(np.stack(
                [self._sched_row(t0 + j)
                 for j in range(r)]).astype(np.int32))
            args = (ts, k_rows, lams)
        else:
            drawn = [self.population.host_cohort(t0 + j) for j in range(r)]
            cohorts = np.stack([ids for ids, _ in drawn])
            cws = np.stack([w for _, w in drawn])
            ks = np.stack(
                [self._sched_row(t0 + j)[cohorts[j]]
                 for j in range(r)]).astype(np.int32)
            if perturb:
                keffs = np.stack(
                    [self._k_row(t0 + j)[cohorts[j]]
                     for j in range(r)]).astype(np.int32)
                cws = self._delivered(cws, keffs, ks)
                ks = keffs
            batches = self.batcher.chunk_cohort_batches(t0, cohorts,
                                                        self.k_max)
            args = (batches, jnp.asarray(cohorts, jnp.int32),
                    jnp.asarray(ks), jnp.asarray(cws), lams)
        tic = time.perf_counter()
        self.state, metrics = chunk_fn(self.state, *args)
        jax.block_until_ready(self.state)
        dt = time.perf_counter() - tic
        hist.loss.extend(np.asarray(metrics["loss"], np.float64).tolist())
        hist.kbar.extend(np.asarray(metrics["kbar"], np.float64).tolist())
        hist.mass.extend(np.asarray(metrics["mass"], np.float64).tolist())
        if "quarantined" in metrics:
            hist.quarantined.extend(
                np.asarray(metrics["quarantined"], np.float64).tolist())
        hist.wall.extend([dt / r] * r)
        self._record_dropped(hist, t0, r)
        self._record_bytes(hist, r, self.population.cohort_size)

    def run(self, t_rounds: int, eval_every: int = 1,
            verbose: bool = False,
            chunk_rounds: Optional[int] = None,
            publish_fn: Optional[Callable[[dict], None]] = None,
            publish_every: int = 0) -> History:
        """``chunk_rounds=None`` chunks at the eval cadence (``eval_every``);
        ``1`` forces the per-round compat loop.  Eval hooks fire at the same
        rounds regardless of chunking — chunks never cross an eval
        boundary, so an explicit ``chunk_rounds`` larger than ``eval_every``
        is clamped (raise ``eval_every`` to actually chunk).

        ``publish_fn(snapshot)`` fires every ``publish_every`` rounds with a
        versioned serving snapshot (``publish_snapshot``) — the hot-swap
        feed for serving/personalized.py.  Chunks never cross a publish
        boundary either, so publications see exact round states."""
        chunk = max(int(chunk_rounds if chunk_rounds is not None
                        else eval_every), 1)
        if (chunk_rounds is not None and chunk > eval_every
                and (self.eval_fn is not None
                     or self.eval_per_client is not None)):
            warnings.warn(
                f"chunk_rounds={chunk_rounds} is clamped to the eval "
                f"cadence (eval_every={eval_every}): the host must sync at "
                f"every eval boundary", stacklevel=2)
        hist = History()
        t = 0
        while t < t_rounds:
            r = min(chunk, t_rounds - t)
            if self.eval_fn is not None or self.eval_per_client is not None:
                r = min(r, eval_every - t % eval_every)
            if publish_fn is not None and publish_every > 0:
                r = min(r, publish_every - t % publish_every)
            if self._partial and r == 1:
                self._run_pop_round(t, hist)
            elif self._partial:
                self._run_pop_chunk(t, r, hist)
            elif r == 1:
                self._run_round(t, hist)
            else:
                self._run_chunk(t, r, hist)
            t += r
            if publish_fn is not None and publish_every > 0 \
                    and t % publish_every == 0:
                publish_fn(self.publish_snapshot())
            if t % eval_every == 0:
                if self.eval_fn is not None:
                    value = float(self.eval_fn(self.params))
                    _check_finite_metric(value, t)
                    hist.metric.append(value)
                if self.eval_per_client is not None:
                    hist.per_client.append(
                        [float(v) for v in
                         self.eval_per_client(self.params)])
            if verbose and (t % 10 < r or t == t_rounds):
                m = hist.metric[-1] if hist.metric else float("nan")
                print(f"  round {t - 1:4d}  loss={hist.loss[-1]:.4f}  "
                      f"metric={m:.4f}")
        return hist

    @property
    def params(self) -> PyTree:
        """Current global model as a pytree (flat layout unravels — the
        only place the flat engine materializes the tree outside the
        loss boundary)."""
        if self.layout == "flat":
            return flat.unravel(self._spec, self.state["params"])
        return self.state["params"]

    @property
    def flat_spec(self) -> flat.FlatSpec:
        """The FlatSpec describing this model's `(P,)` layout.  Flat runs
        (and compressed tree runs) already own one; a plain tree run
        builds and caches it on first use — the spec is pure shape
        metadata, so this never perturbs the round state."""
        if self._spec is None:
            self._spec = flat.make_flat_spec(self.state["params"])
        return self._spec

    def publish_snapshot(self) -> dict:
        """A versioned serving snapshot of the CURRENT training state:
        the `(P,)` flat master plus the per-client calibration signal
        (ν, ν⁽ⁱ⁾ rows) when the algorithm maintains one.  Version = round
        counter, so every publication is totally ordered.  Consumed by
        serving/personalized.py (view resolution + hot-swap)."""
        spec = self.flat_spec
        # snapshots OWN their buffers: chunked execution donates the state
        # arrays to the next scan, which would delete aliased references
        if self.layout == "flat":
            master = jnp.array(self.state["params"])
        else:
            master = flat.ravel(spec, self.state["params"])
        snap = {"version": np.int32(int(self.state["round"])),
                "flat_master": master}
        if self.algo.uses_nu and "nu" in self.state:
            nu, nu_i = self.state["nu"], self.state["nu_i"]
            if self.layout != "flat":
                nu = flat.ravel(spec, nu)
                nu_i = flat.ravel(spec, nu_i, client_dims=1)
            else:
                nu, nu_i = jnp.array(nu), jnp.array(nu_i)
            snap["nu"] = nu
            snap["nu_i"] = nu_i
        return snap

    def save_snapshot(self, path: str) -> dict:
        """Publish + persist (checkpoint/serialize.py msgpack); the serving
        side restores with ``serving.personalized.load_snapshot``."""
        from repro.checkpoint import serialize
        snap = self.publish_snapshot()
        serialize.save(path, snap)
        return snap


def compare_algorithms(algorithms: list[str], make_sim: Callable[[str],
                       FederatedSimulation], t_rounds: int,
                       eval_every: int = 1) -> dict[str, History]:
    """Run the same task under several algorithms (benchmark helper)."""
    out = {}
    for name in algorithms:
        sim = make_sim(name)
        out[name] = sim.run(t_rounds, eval_every=eval_every)
    return out
