"""Client population: partial participation and cohort sampling (DESIGN.md §10).

Every run used to be full-participation: both engines marched all ``M``
clients through every round.  ``ClientPopulation`` makes *who runs* a
first-class property: the server keeps per-client metadata (data mass ω_i,
step-rate profile, availability) for the FULL population while each round
executes only a sampled **cohort** of ``C ≤ M`` clients.  The synchronous
engine draws the cohort *inside* the scanned round chunk (core/engine.py),
the buffered-async engine at dispatch time (fed/clock.py) — so the
timeline's concurrency cap becomes a population property.

Samplers are pluggable through ``SAMPLERS`` (name → draw function).  Every
sampler is a pure ``jax.random`` function of ``(seed, round)``: cohorts are
reproducible, identical on host and inside a jitted scan, and cheap at
population scale — the uniform draw is an O(C) keyed-permutation evaluation
(Feistel + cycle-walking), so round cost never grows with M; weighted and
availability draws are O(M) but memory-bound (a cumsum / a Bernoulli mask),
not RNG-bound.

Weight renormalization (the unbiasedness rule, DESIGN.md §10): cohort
aggregation runs in pseudo-delta form  x ← x + Σ_{i∈S} w̃_i (x⁽ⁱ⁾ − x), and
``cohort_weights`` picks w̃ per sampler so the update is an unbiased
estimate of the full-participation direction Σ ω_i (x⁽ⁱ⁾ − x):

    all           w̃_i = ω_i                 (Σ w̃ = 1 — the exact round)
    uniform       w̃_i = ω_i · M/C           (Horvitz–Thompson, π_i = C/M)
    round_robin   w̃_i = ω_i · M/C           (exact over every M/C-round cycle)
    weighted      w̃_i = 1/C                 (draws ∝ ω_i with replacement —
                                             the Li et al. FedAvg scheme II)
    availability  w̃_i = ω_i / Σ_{j∈S} ω_j   (self-normalized; biased toward
                                             available clients by design)

The same w̃ feeds the orientation mass-mix  ν ← (1 − ρ) ν + (ρ/Σw̃)·Σ w̃ νᵢ
(ρ = min(Σw̃, 1)), so the calibration direction stays an estimate of the
population direction with non-participants represented by the previous ν.

Samplers and weights are LAYOUT-agnostic: under ``param_layout="flat"``
(core/flat.py, DESIGN.md §11) the population's ν⁽ⁱ⁾ store is one
``(M, P)`` matrix, so the cohort gather and post-round scatter this
module's draws index into become single-row operations instead of
per-leaf gather chains.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

PRNGKey = jax.Array


# ---------------------------------------------------------------------------
# sampler registry — fn(pop, key, t) -> (C,) int32 client ids
# ---------------------------------------------------------------------------

def _sample_all(pop: "ClientPopulation", key: PRNGKey, t) -> jax.Array:
    return jnp.arange(pop.m, dtype=jnp.int32)


def _mix(x: jax.Array, k: jax.Array) -> jax.Array:
    """murmur3-style uint32 finalizer — the Feistel round function."""
    x = (x ^ k) * jnp.uint32(0x9E3779B9)
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def _permutation_points(key: PRNGKey, m: int, points: jax.Array
                        ) -> jax.Array:
    """Evaluate a keyed pseudorandom permutation of [0, m) at ``points`` —
    O(|points|), never materializing the M-sized domain.

    A 4-round Feistel network over 2·half bits gives a bijection of
    [0, 2^{2·half}) ⊇ [0, m); cycle-walking (re-encrypt while the image
    lands in the padding) restricts it to a bijection of [0, m).  Walking
    from a point < m terminates: the point's own cycle contains it.
    """
    half = (max((m - 1).bit_length(), 2) + 1) // 2
    mask = jnp.uint32((1 << half) - 1)
    rks = jax.random.bits(key, (4,), jnp.uint32)

    def enc(v):
        left, right = v >> half, v & mask
        for i in range(4):
            left, right = right, left ^ (_mix(right, rks[i]) & mask)
        return (left << half) | right

    def walk(v):
        return jax.lax.while_loop(lambda u: u >= m, enc, enc(v))

    return jax.vmap(walk)(points.astype(jnp.uint32)).astype(jnp.int32)


def _sample_uniform(pop: "ClientPopulation", key: PRNGKey, t) -> jax.Array:
    """Uniform WITHOUT replacement in O(C): the cohort is a keyed
    pseudorandom permutation of [0, M) evaluated at points 0…C-1 — distinct
    by bijectivity, uniform to PRP quality, and never touching M elements
    (an O(M) Gumbel draw alone costs ~1.5 ms at M = 100k on CPU, dominating
    the whole cohort round)."""
    return _permutation_points(
        key, pop.m, jnp.arange(pop.cohort_size, dtype=jnp.uint32))


def _sample_weighted(pop: "ClientPopulation", key: PRNGKey, t) -> jax.Array:
    """Weight-proportional WITH replacement (p = ω) — the classic unbiased
    FedAvg scheme: aggregate with uniform 1/C weights."""
    return jax.random.choice(key, pop.m, (pop.cohort_size,), replace=True,
                             p=pop.weights).astype(jnp.int32)


def _sample_availability(pop: "ClientPopulation", key: PRNGKey, t
                         ) -> jax.Array:
    """Availability trace: client i is up this round w.p. availability_i;
    the cohort is a uniform draw among available clients (unavailable ones
    fill the cohort only when fewer than C are up — their Gumbel scores are
    pushed below every available client's).  A scenario availability hook
    (fed/scenarios.py, e.g. correlated diurnal phases) multiplies the
    static profile by a traceable function of the round."""
    k_up, k_pick = jax.random.split(key)
    p = pop.availability
    if pop.availability_fn is not None:
        p = p * pop.availability_fn(t)
    up = jax.random.uniform(k_up, (pop.m,)) < p
    score = jax.random.gumbel(k_pick, (pop.m,)) + jnp.where(up, 0.0, -1e9)
    return jax.lax.top_k(score, pop.cohort_size)[1].astype(jnp.int32)


def _sample_round_robin(pop: "ClientPopulation", key: PRNGKey, t
                        ) -> jax.Array:
    """Deterministic cyclic blocks (tests / exact-coverage sweeps): round t
    runs clients [tC, tC + C) mod M — every client exactly once per M/C
    rounds when C divides M."""
    t = jnp.asarray(t, jnp.int32)
    return (t * pop.cohort_size
            + jnp.arange(pop.cohort_size, dtype=jnp.int32)) % pop.m


SAMPLERS: dict[str, Callable] = {
    "all": _sample_all,
    "uniform": _sample_uniform,
    "weighted": _sample_weighted,
    "availability": _sample_availability,
    "round_robin": _sample_round_robin,
}


class ClientPopulation:
    """Per-client metadata + the cohort draw for a population of M clients.

    ``weights`` is the data mass ω (normalized to sum 1), ``step_rate`` the
    relative local-step speed profile (consumed by the async clock),
    ``availability`` the per-client up-probability used by the
    ``availability`` sampler.  Scalars broadcast to (M,).
    """

    def __init__(self, m: int, *, cohort_size: Optional[int] = None,
                 sampler: str = "uniform", seed: int = 0,
                 weights=None, step_rate=None, availability=1.0):
        if sampler not in SAMPLERS:
            raise ValueError(f"unknown sampler {sampler!r}; available: "
                             f"{sorted(SAMPLERS)}")
        self.m = int(m)
        self.cohort_size = int(cohort_size) if cohort_size else self.m
        if not 1 <= self.cohort_size <= self.m:
            raise ValueError(
                f"cohort_size {self.cohort_size} not in [1, {self.m}]")
        if sampler == "all" and self.cohort_size != self.m:
            raise ValueError(
                f"sampler='all' requires C == M (got C={self.cohort_size}, "
                f"M={self.m}); pick a partial-participation sampler from "
                f"{sorted(set(SAMPLERS) - {'all'})}")
        self.sampler = sampler
        self.seed = int(seed)
        w = (np.full((self.m,), 1.0 / self.m) if weights is None
             else np.asarray(weights, np.float64))
        self.weights = jnp.asarray(w / w.sum(), jnp.float32)
        self.step_rate = np.broadcast_to(
            np.asarray(1.0 if step_rate is None else step_rate, np.float64),
            (self.m,)).copy()
        self.availability = jnp.broadcast_to(
            jnp.asarray(availability, jnp.float32), (self.m,))
        self._avail_np = np.asarray(self.availability, np.float64)
        self._key = jax.random.PRNGKey(self.seed)
        self._host_cw = None          # lazily-jitted host-side cohort draw
        self._rr_next = 0             # round-robin dispatch pointer (async)
        self._cdf = None              # lazily-built dispatch-profile CDF
        # time-varying availability multiplier (fed/scenarios.py): a
        # traceable ``t -> (M,)`` hook attached by the engines when a
        # scenario (e.g. diurnal) modulates availability; None = static
        self.availability_fn = None
        self._avail_jit = None        # its host mirror (eager jit)
        self._cdf_cache: dict[int, np.ndarray] = {}

    @property
    def full_participation(self) -> bool:
        """True when the cohort machinery is a no-op: the legacy
        full-participation round is the golden-pinned special case."""
        return self.sampler == "all"

    @classmethod
    def from_config(cls, fed, m: Optional[int] = None, weights=None
                    ) -> Optional["ClientPopulation"]:
        """Build from ``FedConfig`` cohort fields; None when the config asks
        for plain full participation (cohort_size ∈ {0, M}, sampler 'all').
        ``cohort_size < M`` alone implies partial participation, so the
        default sampler 'all' resolves to 'uniform' there — explicit
        ``ClientPopulation(…, sampler="all", cohort_size<M)`` still raises."""
        m = int(m if m is not None else fed.n_clients)
        c = fed.cohort_size if fed.cohort_size > 0 else m
        sampler = fed.cohort_sampler
        if sampler == "all":
            if c == m:
                return None
            sampler = "uniform"
        return cls(m, cohort_size=c, sampler=sampler,
                   seed=fed.seed, weights=weights,
                   availability=fed.availability)

    # -- traceable draws (run on host AND inside jitted scans) ---------------

    def cohort(self, t) -> jax.Array:
        """(C,) int32 cohort for round ``t`` — pure in ``(seed, t)``."""
        key = jax.random.fold_in(self._key, jnp.asarray(t, jnp.int32))
        return SAMPLERS[self.sampler](self, key, t)

    def cohort_weights(self, cohort: jax.Array) -> jax.Array:
        """(C,) renormalized aggregation weights w̃ (module docstring)."""
        w = self.weights[cohort]
        if self.sampler == "all":
            return w
        if self.sampler == "weighted":
            return jnp.full((self.cohort_size,),
                            1.0 / self.cohort_size, jnp.float32)
        if self.sampler == "availability":
            return w / jnp.sum(w)
        return w * (self.m / self.cohort_size)      # HT: uniform/round_robin

    def cohort_and_weights(self, t) -> tuple[jax.Array, jax.Array]:
        ids = self.cohort(t)
        return ids, self.cohort_weights(ids)

    def host_cohort(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """Host-side cohort: the SAME jax draw evaluated eagerly, so host
        and in-scan cohorts are identical for any ``(seed, round)``."""
        if self._host_cw is None:
            self._host_cw = jax.jit(self.cohort_and_weights)
        ids, w = self._host_cw(jnp.int32(t))
        return np.asarray(ids), np.asarray(w, np.float32)

    # -- async-engine hooks (host-side event loop, fed/clock.py) -------------

    def report_weights(self) -> np.ndarray:
        """(M,) base per-REPORT aggregation weights for the buffered-async
        engine (staleness discount multiplies on top) — the same per-sampler
        renormalization as ``cohort_weights``, with the buffer playing the
        cohort's role (availability has no per-buffer normalizer host-side;
        it shares the HT rule, see DESIGN.md §10)."""
        w = np.asarray(self.weights, np.float64)
        if self.sampler == "all":
            return w.astype(np.float32)
        if self.sampler == "weighted":
            return np.full((self.m,), 1.0 / self.cohort_size, np.float32)
        return (w * (self.m / self.cohort_size)).astype(np.float32)

    def initial_dispatch(self, rng: np.random.Generator) -> np.ndarray:
        """The C distinct clients in flight at t = 0."""
        if self.sampler == "all":
            return np.arange(self.m)
        if self.sampler == "round_robin":
            self._rr_next = self.cohort_size % self.m
            return np.arange(self.cohort_size) % self.m
        p = self._dispatch_profile()
        if np.count_nonzero(p) < self.cohort_size:
            # fewer ever-available clients than slots: pad the profile so a
            # distinct draw exists (mirrors the cohort sampler's fill rule)
            p = (p + 1.0 / self.m) / (p.sum() + 1.0)
        return rng.choice(self.m, self.cohort_size, replace=False, p=p)

    def pick_dispatch(self, rng: np.random.Generator, busy: np.ndarray,
                      freed: int, phase: int = 0) -> int:
        """Choose the next client to dispatch among idle (``~busy``)
        clients — the buffered-async analogue of the cohort draw (one slot
        frees per report, so concurrency stays capped at C).

        O(1) expected per event: stochastic samplers draw from the
        precomputed profile CDF and reject busy clients (busy mass ≈ C/M),
        falling back to an explicit O(M) scan only on a pathological
        streak; ``all`` re-dispatches the reporter with NO rng draw (the
        legacy always-in-flight stream, bit-for-bit) and ``round_robin``
        walks its cyclic pointer past busy clients.  ``phase`` (the server
        update index) only matters with an ``availability_fn`` scenario
        hook: the dispatch profile then follows the time-varying
        availability (diurnal clients stop being dispatched at night)."""
        if self.sampler == "all":
            return int(freed)                  # the only idle client
        if self.sampler == "round_robin":
            for _ in range(self.m):
                i = self._rr_next
                self._rr_next = (i + 1) % self.m
                if not busy[i]:
                    return i
            raise RuntimeError("no idle client (caller must free one)")
        cdf = self._profile_cdf(phase)
        for _ in range(64):
            i = min(int(np.searchsorted(cdf, rng.random(), side="right")),
                    self.m - 1)
            if not busy[i]:
                return i
        ids = np.flatnonzero(~busy)
        p = self._dispatch_profile(phase)[ids]
        if p.sum() <= 0:                 # every idle client unavailable:
            p = np.ones(len(ids))        # fall back to a uniform pick
        return int(rng.choice(ids, p=p / p.sum()))

    def _avail_profile(self, phase: int) -> np.ndarray:
        p = self._avail_np.copy()
        if self.availability_fn is not None:
            if self._avail_jit is None:
                self._avail_jit = jax.jit(self.availability_fn)
            p = p * np.asarray(self._avail_jit(jnp.int32(phase)),
                               np.float64)
        return p

    def _dispatch_profile(self, phase: int = 0) -> np.ndarray:
        if self.sampler == "weighted":
            p = np.asarray(self.weights, np.float64)
        elif self.sampler == "availability":
            p = self._avail_profile(phase)
        else:                                   # all / uniform / round_robin
            p = np.ones(self.m)
        s = p.sum()
        return p / s if s > 0 else np.full(self.m, 1.0 / self.m)

    def _profile_cdf(self, phase: int = 0) -> np.ndarray:
        if self.availability_fn is None or self.sampler != "availability":
            if self._cdf is None:
                self._cdf = np.cumsum(self._dispatch_profile())
                self._cdf[-1] = 1.0
            return self._cdf
        cdf = self._cdf_cache.pop(phase, None)
        if cdf is None:
            cdf = np.cumsum(self._dispatch_profile(phase))
            cdf[-1] = 1.0
        self._cdf_cache[phase] = cdf
        while len(self._cdf_cache) > 32:
            self._cdf_cache.pop(next(iter(self._cdf_cache)))
        return cdf
