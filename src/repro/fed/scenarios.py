"""Failure-scenario layer: fault injection as a pure function of
``(seed, round, client)`` (DESIGN.md §12).

Both engines (fed/simulation.py, fed/async_engine.py) assumed a fantasy
fleet: every dispatched client finishes its K_i local steps and device
models are static synthetic draws (fed/clock.py).  Production cross-device
FL is defined by churn — Fraboni et al.'s general async theory covers
exactly the arbitrary-delay / heterogeneous-update regime, and the FedNova
normalization already in ``core/stages.py`` is the recovery rule that makes
accepting a dropout's *partial* work sound.  ``SCENARIOS`` names the fault
models; a :class:`Scenario` perturbs three per-round quantities:

* **effective steps** k′ ≤ K_i — mid-round dropout: the client aborts after
  k′ completed steps but its partial delta is still delivered.  Recovery is
  three existing mechanisms fed with k′ instead of K_i (partial-work
  recovery): the client-update mask runs only k′ steps (per-row η on the
  flat path, scan mask on the tree path), FedNova-style aggregation
  normalizes by k′, and the aggregation / ν mass-mix weights are scaled by
  the delivered fraction k′/K_i (``stages.delivered_weights``) so lost work
  means lost mass, never a biased step.  k′ ≥ 1 always: a client that did
  NOTHING is an availability event, not a dropout (k′ = 0 would divide the
  FedNova normalizer and the ν̄⁽ⁱ⁾ recovery by zero).
* **speed factor / latency extra** — straggler spikes and flaky-network
  bursts: multiplicative slowdowns and additive upload delays consumed by
  the async ``simulate_timeline`` (they shift arrivals → staleness); the
  synchronous engine is insensitive to timing by construction.
* **availability multiplier** — correlated diurnal phases: modulates the
  ``availability`` cohort sampler and the async dispatch profile.
* **payload corruption** — Byzantine clients (DESIGN.md §16): a fixed
  ``rate``-fraction of the fleet (drawn once per seed from a dedicated
  stream, independent of the round index) corrupts what crosses the wire —
  the delta rows AND the ν transmit rows — with NaN/Inf injection, ×mag
  scaling, sign flips, or resampled noise.  Timing is untouched, so the
  async timeline and all k′/speed/latency paths stay bit-identical to
  baseline; the damage (and the defense, ``core/robust.py``) is purely in
  the aggregation payload.

Every draw is keyed ``fold_in(fold_in(fold_in(base, round), tag), client)``
so any *subset* of clients evaluates to the same values as the full row —
the in-scan cohort hook (core/engine.py) touches only O(C) clients while
the host mirrors (eager jit, the ``host_cohort`` precedent) evaluate full
rows, bit-identically.  ``scenario="baseline"`` maps to ``None``: the
engines take their literally unchanged (golden-pinned) code paths.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# base-key salt: scenario draws must never collide with the cohort/batcher
# streams, which fold the raw config seed
_SALT = 0x5CE7A510
# the persistent corrupt-client set gets its OWN PRNG stream (not a
# fold_in tag on the per-round key, which could collide with a round
# index): membership must be constant across rounds/waves
_CORRUPT_SALT = 0x0BAD5EED


def _client_uniform(key: jax.Array, ids: jax.Array, n: int = 1) -> jax.Array:
    """(len(ids), n) U[0,1) draws keyed per client id — evaluating any
    subset of ids yields the same per-id values as the full row."""
    return jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(key, i), (n,)))(
            ids.astype(jnp.int32))


class Scenario:
    """A named device-fault model: pure per-round perturbation hooks.

    Hooks (any may be None = identity):

    * ``k_eff(key_t, t, ids, k_ids) -> int`` effective completed steps,
      ``1 ≤ k′ ≤ K`` elementwise (partial-work recovery contract).
    * ``speed(key_t, t, ids) -> f32`` multiplicative speed factors (> 0).
    * ``latency(key_t, t, ids) -> f32`` additive report delays (≥ 0).
    * ``avail(t) -> (M,)`` availability multipliers in [0, 1]
      (deterministic full row — samplers need the whole profile).

    ``key_t`` is ONE folded key per (scenario, round) shared by all hooks,
    so correlated draws (e.g. a spike hitting both k′ and speed) see the
    same events; hooks derive sub-streams with their own fold_in tags.
    In the async engine the "round" index is the client's dispatch *wave*
    (the same index that selects its ``k_schedule`` row).
    """

    def __init__(self, name: str, m: int, seed: int = 0, *,
                 k_eff: Optional[Callable] = None,
                 speed: Optional[Callable] = None,
                 latency: Optional[Callable] = None,
                 avail: Optional[Callable] = None,
                 corrupt: Optional[Callable] = None,
                 rejoin_delay: float = 0.0):
        self.name = str(name)
        self.m = int(m)
        self.seed = int(seed)
        self._k_eff = k_eff
        self._speed = speed
        self._latency = latency
        self._avail = avail
        self._corrupt = corrupt
        self.rejoin_delay = float(rejoin_delay)
        if self.rejoin_delay < 0:
            raise ValueError(f"rejoin_delay must be ≥ 0, "
                             f"got {self.rejoin_delay}")
        self._base = jax.random.PRNGKey(self.seed ^ _SALT)
        self._host: dict = {}

    @property
    def perturbs_k(self) -> bool:
        return self._k_eff is not None

    @property
    def corrupts_payload(self) -> bool:
        return self._corrupt is not None

    @property
    def availability_fn(self) -> Optional[Callable]:
        """Traceable ``t -> (M,)`` availability multiplier, or None."""
        return self._avail

    def _key(self, t) -> jax.Array:
        return jax.random.fold_in(self._base, jnp.asarray(t, jnp.int32))

    # -- traceable hooks (run on host AND inside jitted scans) ---------------

    def _ids(self, ids) -> jax.Array:
        return (jnp.arange(self.m, dtype=jnp.int32) if ids is None
                else jnp.asarray(ids, jnp.int32))

    def k_eff(self, t, k, ids=None) -> jax.Array:
        """Effective steps k′ for round/wave ``t``.  ``ids=None``: ``k`` is
        the full (M,) schedule row; else ``k`` holds the values at ``ids``
        (the O(C) in-scan cohort form)."""
        k = jnp.asarray(k, jnp.int32)
        if self._k_eff is None:
            return k
        return self._k_eff(self._key(t), t, self._ids(ids), k)

    def speed_factor(self, t, ids=None) -> jax.Array:
        ids_ = self._ids(ids)
        if self._speed is None:
            return jnp.ones(ids_.shape, jnp.float32)
        return self._speed(self._key(t), t, ids_)

    def latency_extra(self, t, ids=None) -> jax.Array:
        ids_ = self._ids(ids)
        if self._latency is None:
            return jnp.zeros(ids_.shape, jnp.float32)
        return self._latency(self._key(t), t, ids_)

    def _corrupt_rows(self, t, rows, n, ids, tag: int) -> jax.Array:
        """Apply the payload-corruption hook to ``(B, P)`` wire rows.

        ``tag`` derives a sub-stream per payload kind (0 = delta, 1 = ν)
        so the two corruptions of one round are independent draws; the
        hook signature is ``corrupt(key, ids, rows, n)`` with ``rows``
        pre-cast to f32 and ``n`` the true (unpadded) column count.  The
        result is cast back to the wire dtype, so NaN/Inf survive and
        scaling respects the transport precision.
        """
        if self._corrupt is None:
            return rows
        key = jax.random.fold_in(self._key(t), tag)
        out = self._corrupt(key, self._ids(ids), rows.astype(jnp.float32), n)
        return out.astype(rows.dtype)

    def corrupt_delta(self, t, rows, n, ids=None) -> jax.Array:
        """Corrupt the client→server delta rows for round/wave ``t``."""
        return self._corrupt_rows(t, rows, n, ids, 0)

    def corrupt_nu(self, t, rows, n, ids=None) -> jax.Array:
        """Corrupt the client→server ν transmit rows for round ``t``."""
        return self._corrupt_rows(t, rows, n, ids, 1)

    # -- host mirrors: the SAME jax functions evaluated eagerly, so host
    # precomputation (timeline, chunk inputs) and in-scan evaluation are
    # bit-identical for any (seed, round) — the host_cohort precedent ------

    def _hjit(self, tag: str, fn: Callable) -> Callable:
        if tag not in self._host:
            self._host[tag] = jax.jit(fn)
        return self._host[tag]

    def host_k_eff(self, t: int, k_row: np.ndarray) -> np.ndarray:
        fn = self._hjit("k", lambda tt, kk: self.k_eff(tt, kk))
        return np.asarray(fn(jnp.int32(t), jnp.asarray(k_row, jnp.int32)))

    def host_speed_factor(self, t: int) -> np.ndarray:
        fn = self._hjit("s", lambda tt: self.speed_factor(tt))
        return np.asarray(fn(jnp.int32(t)), np.float64)

    def host_latency_extra(self, t: int) -> np.ndarray:
        fn = self._hjit("l", lambda tt: self.latency_extra(tt))
        return np.asarray(fn(jnp.int32(t)), np.float64)

    def host_avail(self, t: int) -> np.ndarray:
        if self._avail is None:
            return np.ones(self.m)
        fn = self._hjit("a", lambda tt: self._avail(tt))
        return np.asarray(fn(jnp.int32(t)), np.float64)

    def round_time(self, clock, t: int, k_row: np.ndarray) -> float:
        """Synchronous-round duration under this scenario: the (possibly
        slowed) straggler defines the round; aborted clients only run k′."""
        k = self.host_k_eff(t, k_row).astype(np.float64)
        f = self.host_speed_factor(t)
        lx = self.host_latency_extra(t)
        return float(np.max(k / (np.asarray(clock.speeds) * f)
                            + np.asarray(clock.latency) + lx))


# ---------------------------------------------------------------------------
# named scenario builders
# ---------------------------------------------------------------------------

def dropout_scenario(m: int, *, rate: float = 0.1, seed: int = 0,
                     rejoin_delay: float = 0.0) -> Scenario:
    """Mid-round dropout: each (round, client) aborts w.p. ``rate`` after a
    uniform k′ ∈ {1, …, K_i − 1} completed steps (K_i = 1 clients cannot
    abort mid-round — there is no prefix to deliver).  ``rejoin_delay``
    keeps an aborted client offline for that many simulated seconds before
    its next async dispatch starts."""
    rate = float(rate)
    if not 0.0 <= rate <= 1.0:
        raise ValueError(f"dropout rate must be in [0, 1], got {rate}")

    def k_eff(key, t, ids, k_ids):
        u = _client_uniform(jax.random.fold_in(key, 1), ids, 2)
        drop = u[:, 0] < rate
        part = 1 + jnp.floor(
            u[:, 1] * (k_ids.astype(jnp.float32) - 1.0)).astype(k_ids.dtype)
        return jnp.where(drop, jnp.minimum(part, k_ids), k_ids)

    return Scenario("dropout", m, seed, k_eff=k_eff,
                    rejoin_delay=rejoin_delay)


def spike_scenario(m: int, *, rate: float = 0.1, magnitude: float = 10.0,
                   frac: float = 0.25, seed: int = 0) -> Scenario:
    """Adversarial straggler spikes: w.p. ``rate`` a round is *spiked* — a
    random ``frac`` of clients runs ``magnitude``× slower.  Sync semantics
    are deadline-based: a spiked client only completes ⌈K_i/magnitude⌉
    steps inside the round window (partial work); async semantics slow its
    report by ``magnitude``× (→ staleness).  One shared event draw keeps
    the k′ and timing perturbations hitting the SAME clients."""
    if magnitude < 1.0:
        raise ValueError(f"spike magnitude must be ≥ 1, got {magnitude}")

    def _hit(key, ids):
        kr = jax.random.fold_in(key, 1)
        spiked_round = jax.random.uniform(kr) < rate
        u = _client_uniform(jax.random.fold_in(key, 2), ids)[:, 0]
        return spiked_round & (u < frac)

    def k_eff(key, t, ids, k_ids):
        slow = jnp.ceil(k_ids.astype(jnp.float32)
                        / magnitude).astype(k_ids.dtype)
        return jnp.where(_hit(key, ids), jnp.maximum(slow, 1), k_ids)

    def speed(key, t, ids):
        return jnp.where(_hit(key, ids), 1.0 / jnp.float32(magnitude),
                         1.0).astype(jnp.float32)

    return Scenario("spike", m, seed, k_eff=k_eff, speed=speed)


def flaky_scenario(m: int, *, rate: float = 0.1, magnitude: float = 5.0,
                   seed: int = 0) -> Scenario:
    """Flaky-network latency bursts: each (wave, client) report is delayed
    by an extra U[0, 2·magnitude] seconds w.p. ``rate`` (mean burst =
    ``magnitude``).  Pure timing noise — local work is unaffected, so the
    synchronous engine is bit-identical to baseline and all damage arrives
    as async staleness."""

    def latency(key, t, ids):
        u = _client_uniform(jax.random.fold_in(key, 1), ids, 2)
        burst = u[:, 0] < rate
        return jnp.where(burst, 2.0 * jnp.float32(magnitude) * u[:, 1],
                         0.0).astype(jnp.float32)

    return Scenario("flaky", m, seed, latency=latency)


def diurnal_scenario(m: int, *, period: float = 64.0, floor: float = 0.05,
                     seed: int = 0) -> Scenario:
    """Correlated diurnal availability: two hemispheres in antiphase —
    client i's up-probability is multiplied by
    ``floor + (1−floor)·½(1 + cos 2π(t/period + φ_i))`` with φ = 0 for the
    first half of the fleet and φ = ½ for the second.  Deterministic in
    (round, client); consumed by the ``availability`` cohort sampler and
    the async dispatch profile (phase = update index)."""
    if period <= 0:
        raise ValueError(f"diurnal period must be > 0, got {period}")
    phase = (np.arange(m) >= m - m // 2).astype(np.float32) * 0.5

    def avail(t):
        tt = jnp.asarray(t, jnp.float32)
        wave = 0.5 * (1.0 + jnp.cos(2.0 * jnp.pi
                                    * (tt / period + jnp.asarray(phase))))
        return jnp.float32(floor) + jnp.float32(1.0 - floor) * wave

    return Scenario("diurnal", m, seed, avail=avail)


def trace_scenario(speed_factors, *, latency_extras=None, avail=None,
                   name: str = "trace", seed: int = 0) -> Scenario:
    """Trace-driven device model: an explicit (T₀, M) table of per-round
    speed *factors* (round t uses row ``t mod T₀``), optionally with
    matching latency-extra and availability tables.  Combine with
    ``make_clock(dist="trace", speeds=…)`` for absolute empirical speeds:
    the clock carries the static profile, this scenario its time variation.
    """
    tbl = np.asarray(speed_factors, np.float32)
    if tbl.ndim != 2:
        raise ValueError(f"speed_factors must be (T, M), got shape "
                         f"{tbl.shape}")
    if not np.all(tbl > 0):
        raise ValueError("trace speed factors must be positive")
    t0, m = tbl.shape
    jtbl = jnp.asarray(tbl)

    def _table_hook(table):
        jt = jnp.asarray(np.asarray(table, np.float32))
        if jt.shape != (t0, m):
            raise ValueError(f"trace tables must share shape ({t0}, {m}), "
                             f"got {jt.shape}")
        return jt

    def speed(key, t, ids):
        return jtbl[jnp.asarray(t, jnp.int32) % t0][ids]

    latency = None
    if latency_extras is not None:
        jlat = _table_hook(latency_extras)
        if not np.all(np.asarray(latency_extras) >= 0):
            raise ValueError("trace latency extras must be ≥ 0")

        def latency(key, t, ids):                        # noqa: F811
            return jlat[jnp.asarray(t, jnp.int32) % t0][ids]

    avail_fn = None
    if avail is not None:
        jav = _table_hook(avail)

        def avail_fn(t):                                 # noqa: F811
            return jav[jnp.asarray(t, jnp.int32) % t0]

    return Scenario(name, m, seed, speed=speed, latency=latency,
                    avail=avail_fn)


# ---------------------------------------------------------------------------
# payload-corruption (Byzantine) scenario builders — DESIGN.md §16
# ---------------------------------------------------------------------------

def _corrupt_set(m: int, seed: int, rate: float) -> jax.Array:
    """(M,) bool: the persistent corrupt-client set.  Drawn per client id
    from a dedicated stream so membership is identical for any subset of
    ids, any chunk split, and any engine — and constant across rounds."""
    rate = float(rate)
    if not 0.0 <= rate <= 1.0:
        raise ValueError(f"corrupt rate must be in [0, 1], got {rate}")
    key = jax.random.PRNGKey(seed ^ _SALT ^ _CORRUPT_SALT)
    u = _client_uniform(key, jnp.arange(m, dtype=jnp.int32))[:, 0]
    return u < rate


def _value_inject_scenario(name: str, value: float, m: int, *,
                           rate: float, seed: int) -> Scenario:
    hit_all = _corrupt_set(m, seed, rate)

    def corrupt(key, ids, rows, n):
        bad = hit_all[ids][:, None] & (jnp.arange(rows.shape[-1]) < n)[None]
        return jnp.where(bad, jnp.float32(value), rows)

    return Scenario(name, m, seed, corrupt=corrupt)


def nan_inject_scenario(m: int, *, rate: float = 0.1,
                        seed: int = 0) -> Scenario:
    """Corrupt clients report all-NaN payloads (crashed accumulator /
    overflowed local training)."""
    return _value_inject_scenario("nan_inject", float("nan"), m,
                                  rate=rate, seed=seed)


def inf_inject_scenario(m: int, *, rate: float = 0.1,
                        seed: int = 0) -> Scenario:
    """Corrupt clients report all-Inf payloads."""
    return _value_inject_scenario("inf_inject", float("inf"), m,
                                  rate=rate, seed=seed)


def scale_attack_scenario(m: int, *, rate: float = 0.1,
                          magnitude: float = 10.0,
                          seed: int = 0) -> Scenario:
    """Corrupt clients scale their payload ×``magnitude`` — the classic
    model-boosting attack that drags the weighted mean (and through ν,
    every client's calibration) toward the attacker's direction."""
    if magnitude <= 0:
        raise ValueError(f"scale magnitude must be > 0, got {magnitude}")
    hit_all = _corrupt_set(m, seed, rate)

    def corrupt(key, ids, rows, n):
        f = jnp.where(hit_all[ids], jnp.float32(magnitude), 1.0)
        return rows * f[:, None]

    return Scenario("scale_attack", m, seed, corrupt=corrupt)


def sign_flip_scenario(m: int, *, rate: float = 0.1,
                       seed: int = 0) -> Scenario:
    """Corrupt clients negate their payload — an unbounded-norm-free
    attack that survives naive clipping (the flipped row has an honest
    norm) and targets the aggregate's direction instead."""
    hit_all = _corrupt_set(m, seed, rate)

    def corrupt(key, ids, rows, n):
        f = jnp.where(hit_all[ids], jnp.float32(-1.0), 1.0)
        return rows * f[:, None]

    return Scenario("sign_flip", m, seed, corrupt=corrupt)


def garbage_scenario(m: int, *, rate: float = 0.1, magnitude: float = 10.0,
                     seed: int = 0) -> Scenario:
    """Corrupt clients replace their payload with fresh Gaussian noise
    rescaled to ``magnitude``× the honest row's norm — per (round, client,
    payload-kind) draws keyed exactly like every other scenario, so
    corrupted runs stay bit-identical across chunk splits and resumes."""
    if magnitude <= 0:
        raise ValueError(f"garbage magnitude must be > 0, got {magnitude}")
    hit_all = _corrupt_set(m, seed, rate)

    def corrupt(key, ids, rows, n):
        cols = jnp.arange(rows.shape[-1]) < n
        noise = jax.vmap(
            lambda i: jax.random.normal(jax.random.fold_in(key, i),
                                        (rows.shape[-1],)))(ids)
        noise = jnp.where(cols[None, :], noise, 0.0)
        rn = jnp.sqrt(jnp.sum(rows * rows, axis=-1))
        nn = jnp.sqrt(jnp.sum(noise * noise, axis=-1))
        g = noise * (jnp.float32(magnitude) * rn
                     / jnp.maximum(nn, 1e-12))[:, None]
        return jnp.where(hit_all[ids][:, None], g, rows)

    return Scenario("garbage", m, seed, corrupt=corrupt)


def _trace_from_config(fed, m: int) -> Scenario:
    raise ValueError(
        "scenario='trace' needs explicit per-round device data that a "
        "FedConfig cannot carry; build it with "
        "repro.fed.scenarios.trace_scenario(speed_factors, ...) and pass "
        "scenario=... to the engine (or use make_clock(dist='trace', "
        "speeds=...) for a static empirical speed profile)")


# registry — name -> builder(fed_config, m) -> Scenario | None
SCENARIOS: dict[str, Callable] = {
    "baseline": lambda fed, m: None,
    "dropout": lambda fed, m: dropout_scenario(
        m, rate=fed.dropout_rate, seed=fed.seed,
        rejoin_delay=fed.rejoin_delay),
    "diurnal": lambda fed, m: diurnal_scenario(
        m, period=fed.scenario_period, seed=fed.seed),
    "spike": lambda fed, m: spike_scenario(
        m, rate=fed.scenario_rate, magnitude=fed.scenario_magnitude,
        seed=fed.seed),
    "flaky": lambda fed, m: flaky_scenario(
        m, rate=fed.scenario_rate, magnitude=fed.scenario_magnitude,
        seed=fed.seed),
    "trace": _trace_from_config,
    # payload-corruption (Byzantine) models — fed.scenario_rate is the
    # corrupt-client fraction, fed.scenario_magnitude the attack strength
    "nan_inject": lambda fed, m: nan_inject_scenario(
        m, rate=fed.scenario_rate, seed=fed.seed),
    "inf_inject": lambda fed, m: inf_inject_scenario(
        m, rate=fed.scenario_rate, seed=fed.seed),
    "scale_attack": lambda fed, m: scale_attack_scenario(
        m, rate=fed.scenario_rate, magnitude=fed.scenario_magnitude,
        seed=fed.seed),
    "sign_flip": lambda fed, m: sign_flip_scenario(
        m, rate=fed.scenario_rate, seed=fed.seed),
    "garbage": lambda fed, m: garbage_scenario(
        m, rate=fed.scenario_rate, magnitude=fed.scenario_magnitude,
        seed=fed.seed),
}


def make_scenario(fed, m: Optional[int] = None) -> Optional[Scenario]:
    """Resolve ``fed.scenario`` to a :class:`Scenario` — None for
    ``"baseline"`` so the engines keep their unperturbed (golden-pinned)
    code paths."""
    if fed.scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {fed.scenario!r}; valid "
                         f"options: {sorted(SCENARIOS)}")
    return SCENARIOS[fed.scenario](fed, int(m if m is not None
                                            else fed.n_clients))
