"""Client wall-clock model for the buffered semi-asynchronous engine.

The paper's *step* asynchronism keeps rounds synchronous in wall-clock time:
fast hardware spends the same round duration on more local steps (K_i ∝
speed).  *Round* asynchronism (Xie et al. FedAsync; Nguyen et al. FedBuff)
is the complementary regime modeled here: K_i is fixed by the schedule and
heterogeneous hardware makes report times diverge, so the server sees a
stream of stale updates instead of aligned rounds (DESIGN.md §5).

``ClientClock`` maps (client, K_i) → simulated duration; the async engine
orders report events with it.  Speeds are *steps per unit time*; a fixed
per-report ``latency`` models the upload/download overhead.

``simulate_timeline`` is the event loop itself: the buffered-async
execution order is fully determined by ``(k_schedule, clock, buffer_size)``
— no model state enters the arrival ordering — so the entire heapq
simulation is precomputed here in one host pass and the engine
(fed/async_engine.py) merely *executes* the resulting arrays in scanned
chunks (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientClock:
    """Per-client execution-speed model."""
    speeds: np.ndarray                    # (M,) local steps per unit time
    latency: np.ndarray                   # (M,) fixed per-report overhead

    @property
    def m(self) -> int:
        return len(self.speeds)

    def duration(self, client: int, k_steps: int) -> float:
        """Simulated seconds between dispatch and report of one task."""
        return float(k_steps / self.speeds[client]
                     + self.latency[client])

    def round_time(self, k_steps: np.ndarray) -> float:
        """Synchronous-round duration: the straggler defines the round."""
        k = np.broadcast_to(np.asarray(k_steps, np.float64), (self.m,))
        return float(np.max(k / self.speeds + self.latency))


@dataclasses.dataclass(frozen=True)
class Timeline:
    """Precomputed buffered-async execution schedule for T server updates.

    Row ``u`` describes update ``u``'s buffer of B reports in arrival order
    (heap order: time, then dispatch sequence):

    * ``ids``        (T, B) int — reporting client of each buffer slot.
    * ``versions``   (T, B) int — model version the report was dispatched
      with (tie-upgrade rule applied, so ∈ {dispatch update, +1}).
    * ``waves``      (T, B) int — dispatch wave d: the report trained on row
      ``ids`` of batch wave ``d`` and K = ``k_schedule[d % len, id]``.
    * ``k_steps``    (T, B) int — that K, denormalized for convenience.
    * ``staleness``  (T, B) int — τ = u − version.
    * ``arrival_t``  (T, B) f64 — simulated arrival times; ``arrival_t[u,-1]``
      is the server-update timestamp (``History.sim_time``).
    * ``fresh``      (T, B) bool — the task DISPATCHED at this event carries
      the post-update model (the tie-upgrade rule fired), i.e. its anchor is
      the update's output rather than its input.
    * ``dispatch_ids`` (T, B) int — the client dispatched at each report
      event.  Without a population this is ``ids`` (the reporter is
      re-dispatched immediately); with partial participation
      (fed/population.py) the freed slot goes to a sampler-chosen client, so
      the concurrency cap C becomes a population property (DESIGN.md §10).
    * ``k_sched``    (T, B) int — the SCHEDULED K_i of each report; equals
      ``k_steps`` except under a failure scenario (fed/scenarios.py), where
      ``k_steps`` carries the effective k′ ≤ K_i actually completed.
    * ``aborted``    (T, B) bool — the report is a mid-round dropout
      (k′ < K_i); its partial delta still enters the buffer
      (partial-work recovery, DESIGN.md §12).
    """
    ids: np.ndarray
    versions: np.ndarray
    waves: np.ndarray
    k_steps: np.ndarray
    staleness: np.ndarray
    arrival_t: np.ndarray
    fresh: np.ndarray
    dispatch_ids: np.ndarray
    k_sched: np.ndarray = None
    aborted: np.ndarray = None

    @property
    def t_updates(self) -> int:
        return self.ids.shape[0]

    @property
    def buffer(self) -> int:
        return self.ids.shape[1]


def simulate_timeline(k_schedule: np.ndarray, clock: ClientClock,
                      buffer: int, t_updates: int,
                      population=None, scenario=None) -> Timeline:
    """Run the FedBuff event loop for ``t_updates`` server updates.

    Event-accurate semantics (identical to the engine's original in-line
    loop, pinned by tests/test_async_engine.py): every popped report frees a
    concurrency slot which is re-filled IMMEDIATELY on the current
    (pre-update) model — the server only steps when the buffer fills, so a
    fast client's next report can land inside this same buffer ('M reports'
    counts reports, not distinct clients).  A task dispatched at the very
    instant the buffer filled starts as the server steps at the same
    timestamp — it receives the FRESH post-update model (zero elapsed time,
    so only the anchor version changes).  With buffer = M and equal speeds
    every arrival ties, preserving the exact synchronous reduction.

    Without ``population`` the freed slot goes back to the reporter (all M
    clients always in flight — the legacy full-participation stream).  With
    a ``ClientPopulation`` only C = ``population.cohort_size`` tasks are in
    flight and each freed slot is re-filled by ``population.pick_dispatch``
    (the sampler choosing among idle clients) — partial participation as a
    property of the dispatch process.  ``sampler="all"`` (C = M) leaves the
    reporter as the only idle client, reproducing the legacy stream
    bit-for-bit (the golden-pinned special case, DESIGN.md §10).

    With a ``scenario`` (fed/scenarios.py, DESIGN.md §12) each dispatch is
    perturbed by the scenario's pure per-(wave, client) draws: the task
    runs only k′ ≤ K effective steps (mid-round dropout — the report is an
    **abort event** whose partial work is still delivered), its duration is
    ``k′ / (speed · factor) + latency + extra``, and an aborted client
    **rejoins** only after ``scenario.rejoin_delay`` simulated seconds of
    downtime (its next task starts late by the remaining downtime).  The
    dispatched-client scatter thus follows the survivors: slots freed by
    aborts re-fill immediately, but the aborted client itself is penalized.
    ``scenario=None`` leaves every code path and float untouched.
    """
    m = clock.m
    k_schedule = np.asarray(k_schedule)
    heap: list[tuple[float, int, int]] = []
    # client -> (version, K_eff, wave, t_dispatch, K_sched)
    inflight: dict[int, tuple[int, int, int, float, int]] = {}
    wave_ctr = np.zeros(m, np.int64)
    busy = np.zeros(m, bool)
    down_until = np.zeros(m, np.float64)   # abort rejoin gates (scenario)
    seq = 0

    # per-wave scenario rows (k′ / speed factor / latency extra), evaluated
    # once per wave by the scenario's host mirrors and LRU-cached — clients
    # reach the same wave index at very different sim times under speed
    # skew, so regeneration (one eager jit call) backs a bounded cache
    scn_cache: dict[int, tuple] = {}

    def scn_rows(d: int) -> tuple:
        rows = scn_cache.pop(d, None)
        if rows is None:
            base = np.asarray(k_schedule[d % len(k_schedule)])
            rows = (scenario.host_k_eff(d, base),
                    scenario.host_speed_factor(d),
                    scenario.host_latency_extra(d))
        scn_cache[d] = rows
        while len(scn_cache) > 128:
            scn_cache.pop(next(iter(scn_cache)))
        return rows

    def dispatch(i: int, t_now: float, version: int) -> None:
        nonlocal seq
        d = int(wave_ctr[i])
        k_s = int(k_schedule[d % len(k_schedule), i])
        if scenario is None:
            k = k_s
            dur = clock.duration(i, k)
        else:
            keff, f, lx = scn_rows(d)
            k = int(keff[i])
            dur = float(k / (clock.speeds[i] * f[i])
                        + clock.latency[i] + lx[i])
            wait = down_until[i] - t_now
            if wait > 0:                   # still offline after an abort
                dur += wait
            if k < k_s and scenario.rejoin_delay > 0:
                down_until[i] = t_now + dur + scenario.rejoin_delay
        inflight[i] = (version, k, d, t_now, k_s)
        wave_ctr[i] += 1
        busy[i] = True
        heapq.heappush(heap, (t_now + dur, seq, i))
        seq += 1

    if population is None:
        initial = np.arange(m)
        rng = None
    else:
        if population.m != m:
            raise ValueError(f"population of {population.m} clients does "
                             f"not match the clock's m={m}")
        rng = np.random.default_rng((population.seed, 0x5eed))
        initial = population.initial_dispatch(rng)
    for i in initial:
        dispatch(int(i), 0.0, 0)

    shape = (t_updates, buffer)
    ids = np.zeros(shape, np.int64)
    dispatch_ids = np.zeros(shape, np.int64)
    versions = np.zeros(shape, np.int64)
    waves = np.zeros(shape, np.int64)
    k_steps = np.zeros(shape, np.int64)
    k_sched = np.zeros(shape, np.int64)
    arrival_t = np.zeros(shape, np.float64)
    fresh = np.zeros(shape, bool)

    for u in range(t_updates):
        pending: list[tuple[float, int, int, tuple]] = []
        while len(pending) < buffer:
            t_arr, _, i = heapq.heappop(heap)
            task = inflight.pop(i)
            busy[i] = False
            nxt = (i if population is None
                   else population.pick_dispatch(rng, busy, i, phase=u))
            pending.append((t_arr, i, nxt, task))
            dispatch(nxt, t_arr, u)
        now = pending[-1][0]
        for j, (t_arr, i, nxt, (v, k, d, _, k_s)) in enumerate(pending):
            ids[u, j] = i
            dispatch_ids[u, j] = nxt
            versions[u, j] = v
            waves[u, j] = d
            k_steps[u, j] = k
            k_sched[u, j] = k_s
            arrival_t[u, j] = t_arr
        # tie upgrade (see docstring); idempotent for duplicate dispatches —
        # the check always lands on the client's NEWEST in-flight task
        for t_arr, _, nxt, _ in pending:
            if t_arr == now and nxt in inflight:
                ver, k, d, t_disp, k_s = inflight[nxt]
                if ver == u and t_disp == t_arr:
                    inflight[nxt] = (u + 1, k, d, t_disp, k_s)
        # a dispatched task already consumed within this same buffer (and
        # whose client was not re-dispatched) has no in-flight entry: its
        # anchor row is rewritten before it is ever read again
        fresh[u] = [nxt in inflight and inflight[nxt][0] == u + 1
                    for nxt in dispatch_ids[u]]

    staleness = np.arange(t_updates, dtype=np.int64)[:, None] - versions
    return Timeline(ids=ids, versions=versions, waves=waves,
                    k_steps=k_steps, staleness=staleness,
                    arrival_t=arrival_t, fresh=fresh,
                    dispatch_ids=dispatch_ids,
                    k_sched=k_sched, aborted=k_steps < k_sched)


def make_clock(m: int, *, dist: str = "lognormal", sigma: float = 0.5,
               latency: float = 0.0, seed: int = 0,
               speeds=None) -> ClientClock:
    """Sample per-client speeds.

    fixed     : every client identical (async arrivals degenerate to
                dispatch order — the sync-equivalence regime).
    uniform   : speeds ~ U[0.5, 1.5].
    lognormal : speeds ~ LogNormal(0, σ) — the long-tail straggler regime
                reported for production FL fleets.
    bimodal   : m−1 unit-speed devices + one 10× "GPU client" (the paper's
                Raspberry-Pi + GPU hardware mix, §6.1).
    trace     : an explicit per-client ``speeds`` array (steps per unit
                time) measured from a real fleet — the empirical-trace
                entry point; ``latency`` may also be a (m,) array there.
    """
    if dist == "trace":
        if speeds is None:
            raise ValueError("dist='trace' needs an explicit speeds array "
                             "(per-client steps per unit time)")
        speeds = np.asarray(speeds, np.float64)
        if speeds.shape != (m,):
            raise ValueError(f"trace speeds must have shape ({m},), got "
                             f"{speeds.shape}")
        if not np.all(speeds > 0):
            raise ValueError("trace speeds must be positive")
    elif speeds is not None:
        raise ValueError(f"explicit speeds are only valid with "
                         f"dist='trace' (got dist={dist!r})")
    rng = np.random.default_rng(seed)
    if dist == "trace":
        pass
    elif dist == "fixed":
        speeds = np.ones(m)
    elif dist == "uniform":
        speeds = rng.uniform(0.5, 1.5, m)
    elif dist == "lognormal":
        speeds = rng.lognormal(0.0, sigma, m)
    elif dist == "bimodal":
        speeds = np.ones(m)
        speeds[-1] = 10.0
    else:
        raise ValueError(f"unknown speed_dist {dist!r}; valid options: "
                         f"['bimodal', 'fixed', 'lognormal', 'trace', "
                         f"'uniform']")
    lat = np.broadcast_to(np.asarray(latency, np.float64), (m,)).copy()
    if not np.all(lat >= 0):
        raise ValueError("latency must be ≥ 0")
    return ClientClock(speeds=speeds, latency=lat)
