"""Client wall-clock model for the buffered semi-asynchronous engine.

The paper's *step* asynchronism keeps rounds synchronous in wall-clock time:
fast hardware spends the same round duration on more local steps (K_i ∝
speed).  *Round* asynchronism (Xie et al. FedAsync; Nguyen et al. FedBuff)
is the complementary regime modeled here: K_i is fixed by the schedule and
heterogeneous hardware makes report times diverge, so the server sees a
stream of stale updates instead of aligned rounds (DESIGN.md §5).

``ClientClock`` maps (client, K_i) → simulated duration; the async engine
orders report events with it.  Speeds are *steps per unit time*; a fixed
per-report ``latency`` models the upload/download overhead.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientClock:
    """Per-client execution-speed model."""
    speeds: np.ndarray                    # (M,) local steps per unit time
    latency: np.ndarray                   # (M,) fixed per-report overhead

    @property
    def m(self) -> int:
        return len(self.speeds)

    def duration(self, client: int, k_steps: int) -> float:
        """Simulated seconds between dispatch and report of one task."""
        return float(k_steps / self.speeds[client]
                     + self.latency[client])

    def round_time(self, k_steps: np.ndarray) -> float:
        """Synchronous-round duration: the straggler defines the round."""
        k = np.broadcast_to(np.asarray(k_steps, np.float64), (self.m,))
        return float(np.max(k / self.speeds + self.latency))


def make_clock(m: int, *, dist: str = "lognormal", sigma: float = 0.5,
               latency: float = 0.0, seed: int = 0) -> ClientClock:
    """Sample per-client speeds.

    fixed     : every client identical (async arrivals degenerate to
                dispatch order — the sync-equivalence regime).
    uniform   : speeds ~ U[0.5, 1.5].
    lognormal : speeds ~ LogNormal(0, σ) — the long-tail straggler regime
                reported for production FL fleets.
    bimodal   : m−1 unit-speed devices + one 10× "GPU client" (the paper's
                Raspberry-Pi + GPU hardware mix, §6.1).
    """
    rng = np.random.default_rng(seed)
    if dist == "fixed":
        speeds = np.ones(m)
    elif dist == "uniform":
        speeds = rng.uniform(0.5, 1.5, m)
    elif dist == "lognormal":
        speeds = rng.lognormal(0.0, sigma, m)
    elif dist == "bimodal":
        speeds = np.ones(m)
        speeds[-1] = 10.0
    else:
        raise ValueError(f"unknown speed_dist {dist!r}")
    return ClientClock(speeds=speeds,
                       latency=np.full(m, float(latency)))
