from repro.fed.simulation import (FederatedSimulation, History,
                                  compare_algorithms)

__all__ = ["FederatedSimulation", "History", "compare_algorithms"]
