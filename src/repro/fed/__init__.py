from repro.fed.async_engine import BufferedAsyncSimulation, staleness_weight
from repro.fed.clock import (ClientClock, Timeline, make_clock,
                             simulate_timeline)
from repro.fed.population import SAMPLERS, ClientPopulation
from repro.fed.scenarios import (SCENARIOS, Scenario, diurnal_scenario,
                                 dropout_scenario, flaky_scenario,
                                 garbage_scenario, inf_inject_scenario,
                                 make_scenario, nan_inject_scenario,
                                 scale_attack_scenario,
                                 sign_flip_scenario, spike_scenario,
                                 trace_scenario)
from repro.fed.simulation import (FederatedSimulation, History,
                                  compare_algorithms)

__all__ = ["FederatedSimulation", "History", "compare_algorithms",
           "BufferedAsyncSimulation", "staleness_weight", "ClientClock",
           "ClientPopulation", "SAMPLERS",
           "Timeline", "make_clock", "simulate_timeline",
           "SCENARIOS", "Scenario", "make_scenario", "dropout_scenario",
           "diurnal_scenario", "spike_scenario", "flaky_scenario",
           "trace_scenario", "nan_inject_scenario", "inf_inject_scenario",
           "scale_attack_scenario", "sign_flip_scenario",
           "garbage_scenario"]
