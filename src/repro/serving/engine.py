"""Continuous-batching serving engine.

Slot-based scheduler over the model zoo's (prefill, decode) steps: a fixed
pool of B cache slots; arriving requests prefill into free slots (padded
to a bucket length to bound recompiles); every engine tick decodes ONE
token for ALL slots in a single batched call — the cache layer keeps
per-row ring positions (models/attention.py), so slots at different
phases coexist in one pool and finished requests free their slot
immediately (no head-of-line blocking).  vLLM's loop, reduced to the
positional ring cache.

Single-host execution; the pod-scale serve path (launch/serve.py) lowers
the same step functions with sharded caches.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S,) int32 token ids
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1 = never stops early


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: list[int]
    prompt_len: int
    ticks: int                         # decode ticks consumed


class ServeEngine:
    """``submit()`` requests, ``run()`` until drained."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512, prefill_buckets=(32, 64, 128, 256),
                 sampler: Optional[Callable] = None):
        assert cfg.frontend == "none", "engine serves text archs"
        assert cfg.ssm is None and cfg.xlstm is None, \
            "right-padded prefill is exact for KV caches only; SSM state " \
            "needs unpadded scans (use per-bucket prefill instead)"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.buckets = tuple(b for b in sorted(prefill_buckets)
                             if b <= max_len)
        self.sampler = sampler or (lambda logits, key: jnp.argmax(logits, -1))

        self.caches = model_lib.init_caches(cfg, slots, max_len,
                                            jnp.dtype(cfg.dtype))
        self.pos = np.zeros(slots, np.int32)        # next position per slot
        self.active: list[Optional[Request]] = [None] * slots
        self.emitted: dict[int, list[int]] = {}
        self.started: dict[int, int] = {}
        self.queue: list[Request] = []
        self.done: list[Completion] = []
        self.ticks = 0

        # full logits (not last_only): with right-padding the last REAL
        # position differs per request
        self._prefill = jax.jit(
            lambda p, toks, caches: model_lib.forward(
                p, {"tokens": toks}, cfg, caches=caches)[:2])
        self._decode = jax.jit(
            lambda p, toks, caches, offs: model_lib.serve_decode(
                p, {"tokens": toks}, caches, offs, cfg))

    # -- public api ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        assert len(req.prompt) <= max(self.buckets), "prompt too long"
        self.queue.append(req)

    def run(self, max_ticks: int = 10_000) -> list[Completion]:
        while (self.queue or any(a is not None for a in self.active)) \
                and self.ticks < max_ticks:
            self._admit()
            self._tick()
        return self.done

    @property
    def utilization(self) -> float:
        return sum(a is not None for a in self.active) / self.slots

    # -- internals -----------------------------------------------------------

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            n = len(req.prompt)
            b = self._bucket(n)
            padded = np.zeros(b, np.int32)
            padded[:n] = req.prompt                    # RIGHT-pad: prompt
            # tokens never attend pads (causal), pads are invalidated below
            single = model_lib.init_caches(self.cfg, 1, self.max_len,
                                           jnp.dtype(self.cfg.dtype))
            logits, single = self._prefill(self.params,
                                           jnp.asarray(padded)[None], single)
            single = _invalidate_pads(single, n, b)
            self.caches = _write_slot(self.caches, single, s)
            tok = int(np.asarray(self.sampler(
                logits[:, n - 1], jax.random.PRNGKey(req.uid)))[0])
            self.active[s] = req
            self.pos[s] = n
            self.emitted[req.uid] = [tok]
            self.started[req.uid] = self.ticks

    def _tick(self) -> None:
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return
        self.ticks += 1
        toks = np.zeros((self.slots, 1), np.int32)
        for s in live:
            toks[s, 0] = self.emitted[self.active[s].uid][-1]
        # ONE batched decode at per-slot offsets; idle slots decode a
        # dummy token into their own (soon-overwritten) rows
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(self.pos, jnp.int32))
        arr = np.asarray(self.sampler(logits[:, 0],
                                      jax.random.PRNGKey(self.ticks)))
        for s in live:
            req = self.active[s]
            tok = int(arr[s])
            self.emitted[req.uid].append(tok)
            self.pos[s] += 1
            n = len(self.emitted[req.uid])
            if n >= req.max_new_tokens or tok == req.eos_id:
                self.done.append(Completion(
                    uid=req.uid, tokens=self.emitted.pop(req.uid),
                    prompt_len=len(req.prompt),
                    ticks=self.ticks - self.started.pop(req.uid)))
                self.active[s] = None
        for s in range(self.slots):
            if self.active[s] is None:
                self.pos[s] = 0         # park idle slots at position 0


def _invalidate_pads(single, n: int, b: int):
    """Mark the ring slots holding right-pad tokens as empty (pos = -1) so
    the per-row valid mask hides them from every later decode."""
    def fix(path, leaf):
        name = ""
        for part in reversed(path):
            if hasattr(part, "key"):
                name = str(part.key)
                break
        if name == "pos" and leaf.ndim >= 2:
            size = leaf.shape[-1]
            sl = jnp.arange(size)
            mask = jnp.logical_and(sl >= n % max(size, 1), sl < b) \
                if size < b else jnp.logical_and(sl >= n, sl < b)
            return jnp.where(mask, -1, leaf)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, single)


def _write_slot(pool, single, s: int):
    """Splice a 1-row cache pytree into row ``s`` of the pool.  Cache
    leaves carry (n_groups, count) stack dims, then the batch row."""
    def w(p, o):
        if p.ndim >= 3 and o.ndim == p.ndim and o.shape[2] == 1 \
                and p.shape[:2] == o.shape[:2]:
            return p.at[:, :, s:s + 1].set(o.astype(p.dtype))
        return p
    return jax.tree.map(w, pool, single)
