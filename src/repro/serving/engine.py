"""Continuous-batching serving engine.

Slot-based scheduler over the model zoo's (prefill, decode) steps: a fixed
pool of B cache slots; arriving requests prefill into free slots (padded
to a bucket length to bound recompiles); every engine tick decodes ONE
token for ALL slots in a single batched call — the cache layer keeps
per-row ring positions (models/attention.py), so slots at different
phases coexist in one pool and finished requests free their slot
immediately (no head-of-line blocking).  vLLM's loop, reduced to the
positional ring cache.

Sampling is a pure function of the REQUEST, never of co-scheduled
traffic: each sampled token draws from ``fold_in(PRNGKey(uid), step)``
(step = tokens already emitted), so a request's completion is
bit-identical whatever else shares the pool and whatever order admissions
happen in.  The admission hot path is O(1) per admit: a deque queue and
ONE preallocated single-slot cache template reused for every prefill (the
prefill step is functional — the template is never written).

Single-host execution; the pod-scale serve path (launch/serve.py) lowers
the same step functions with sharded caches.  Per-client personalized
parameter views and checkpoint hot-swap live in the subclass
(serving/personalized.py), which overrides the ``_prefill_slot`` /
``_decode_tick`` / ``_slot_version`` hooks below.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S,) int32 token ids
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1 = never stops early
    client_id: int = 0                 # personalization key (serving/personalized.py)


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: list[int]
    prompt_len: int
    ticks: int                         # decode ticks consumed
    client_id: int = 0
    version: int = 0                   # snapshot the request was served under


class ServeEngine:
    """``submit()`` requests, ``run()`` until drained.

    ``sampler(logits, key) -> token`` operates on ONE row of (V,) logits
    with that request's per-step key; the engine vmaps it over the slot
    pool.  Default: greedy argmax (key unused).
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512, prefill_buckets=(32, 64, 128, 256),
                 sampler: Optional[Callable] = None,
                 max_pending: int = 0):
        assert cfg.frontend == "none", "engine serves text archs"
        assert cfg.ssm is None and cfg.xlstm is None, \
            "right-padded prefill is exact for KV caches only; SSM state " \
            "needs unpadded scans (use per-bucket prefill instead)"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.buckets = tuple(b for b in sorted(prefill_buckets)
                             if b <= max_len)
        self.sampler = sampler or (lambda logits, key: jnp.argmax(logits, -1))

        self.caches = model_lib.init_caches(cfg, slots, max_len,
                                            jnp.dtype(cfg.dtype))
        # ONE reusable single-slot cache: prefill is functional (returns
        # fresh arrays), so the pristine template serves every admission —
        # no per-admit init_caches pytree allocation
        self._single = model_lib.init_caches(cfg, 1, max_len,
                                             jnp.dtype(cfg.dtype))
        self.pos = np.zeros(slots, np.int32)        # next position per slot
        self.active: list[Optional[Request]] = [None] * slots
        self.emitted: dict[int, list[int]] = {}
        self.started: dict[int, int] = {}
        self.queue: deque[Request] = deque()
        self.done: list[Completion] = []
        self.ticks = 0
        # admission bound: with max_pending > 0 the queue is capped and a
        # submit into a full queue is SHED (counted, not raised) — an
        # overloaded replica degrades by refusing work, never by growing
        # an unbounded backlog; 0 keeps the legacy unbounded queue
        self.max_pending = max_pending
        self.dropped = 0

        # full logits (not last_only): with right-padding the last REAL
        # position differs per request
        self._prefill = jax.jit(
            lambda p, toks, caches: model_lib.forward(
                p, {"tokens": toks}, cfg, caches=caches)[:2])
        self._decode = jax.jit(
            lambda p, toks, caches, offs: model_lib.serve_decode(
                p, {"tokens": toks}, caches, offs, cfg))
        # per-(request, step) sampling keys: completions are bit-identical
        # regardless of batch composition and admission order
        self._keys_for = jax.jit(jax.vmap(
            lambda uid, step: jax.random.fold_in(jax.random.PRNGKey(uid),
                                                 step)))
        self._sample = jax.jit(jax.vmap(self.sampler))

    # -- public api ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        assert len(req.prompt) <= max(self.buckets), "prompt too long"
        if self.max_pending > 0 and len(self.queue) >= self.max_pending:
            self.dropped += 1
            return
        self.queue.append(req)

    def step(self) -> None:
        """One scheduler step: admit waiting requests into free slots, then
        decode one token for every live slot.  Public for trace-driven
        drivers (serving/loadgen.py)."""
        self._admit()
        self._tick()

    def run(self, max_ticks: int = 10_000) -> list[Completion]:
        while (self.queue or any(a is not None for a in self.active)) \
                and self.ticks < max_ticks:
            self.step()
        return self.done

    @property
    def utilization(self) -> float:
        return sum(a is not None for a in self.active) / self.slots

    # -- internals -----------------------------------------------------------

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            n = len(req.prompt)
            b = self._bucket(n)
            padded = np.zeros(b, np.int32)
            padded[:n] = req.prompt                    # RIGHT-pad: prompt
            # tokens never attend pads (causal), pads are invalidated below
            logits, single = self._prefill_slot(
                s, req, jnp.asarray(padded)[None], self._single)
            single = _invalidate_pads(single, n, b)
            self.caches = _write_slot(self.caches, single, s)
            key = self._keys_for(jnp.asarray([req.uid], jnp.int32),
                                 jnp.asarray([0], jnp.int32))
            tok = int(np.asarray(self._sample(logits[:, n - 1], key))[0])
            self.active[s] = req
            self.pos[s] = n
            self.emitted[req.uid] = [tok]
            self.started[req.uid] = self.ticks

    def _tick(self) -> None:
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return
        self.ticks += 1
        toks = np.zeros((self.slots, 1), np.int32)
        uids = np.zeros(self.slots, np.int32)
        steps = np.zeros(self.slots, np.int32)
        for s in live:
            req = self.active[s]
            toks[s, 0] = self.emitted[req.uid][-1]
            uids[s] = req.uid
            steps[s] = len(self.emitted[req.uid])
        logits = self._decode_tick(toks, live)
        keys = self._keys_for(jnp.asarray(uids), jnp.asarray(steps))
        arr = np.asarray(self._sample(logits, keys))
        for s in live:
            req = self.active[s]
            tok = int(arr[s])
            self.emitted[req.uid].append(tok)
            self.pos[s] += 1
            n = len(self.emitted[req.uid])
            if n >= req.max_new_tokens or tok == req.eos_id:
                self.done.append(Completion(
                    uid=req.uid, tokens=self.emitted.pop(req.uid),
                    prompt_len=len(req.prompt),
                    ticks=self.ticks - self.started.pop(req.uid),
                    client_id=req.client_id,
                    version=self._slot_version(s)))
                self.active[s] = None
        for s in range(self.slots):
            if self.active[s] is None:
                self.pos[s] = 0         # park idle slots at position 0

    # -- subclass hooks (serving/personalized.py) ----------------------------

    def _prefill_slot(self, s: int, req: Request, toks, caches):
        """Prefill into slot ``s`` — subclasses resolve per-request
        parameter views here.  Returns (full logits, filled 1-row cache)."""
        return self._prefill(self.params, toks, caches)

    def _decode_tick(self, toks: np.ndarray, live: list[int]) -> jax.Array:
        """ONE batched decode at per-slot offsets; idle slots decode a
        dummy token into their own (soon-overwritten) rows.  Returns the
        (B, V) next-token logits."""
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(self.pos, jnp.int32))
        return logits[:, 0]

    def _slot_version(self, s: int) -> int:
        return 0


def _invalidate_pads(single, n: int, b: int):
    """Mark the ring slots holding right-pad tokens as empty (pos = -1) so
    the per-row valid mask hides them from every later decode."""
    def fix(path, leaf):
        name = ""
        for part in reversed(path):
            if hasattr(part, "key"):
                name = str(part.key)
                break
        if name == "pos" and leaf.ndim >= 2:
            size = leaf.shape[-1]
            sl = jnp.arange(size)
            mask = jnp.logical_and(sl >= n % max(size, 1), sl < b) \
                if size < b else jnp.logical_and(sl >= n, sl < b)
            return jnp.where(mask, -1, leaf)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, single)


def _write_slot(pool, single, s: int):
    """Splice a 1-row cache pytree into row ``s`` of the pool.  Cache
    leaves carry (n_groups, count) stack dims, then the batch row."""
    def w(p, o):
        if p.ndim >= 3 and o.ndim == p.ndim and o.shape[2] == 1 \
                and p.shape[:2] == o.shape[:2]:
            return p.at[:, :, s:s + 1].set(o.astype(p.dtype))
        return p
    return jax.tree.map(w, pool, single)
