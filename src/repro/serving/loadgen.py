"""Deterministic load generation + trace replay for the serving engines.

``LoadGen`` draws a seeded arrival trace over a client population —
Poisson arrivals per scheduler tick, power-law client popularity (a few
hot clients, a long cold tail, the shape personalization caches live or
die by), uniform prompt/output lengths — entirely from one
``np.random.default_rng(seed)`` stream, so a trace is a pure function of
its config: benchmarks and tests replay byte-identical request streams
without storing them.

``replay`` drives an engine tick-by-tick against a trace: requests are
submitted when the scheduler clock reaches their arrival tick, idle gaps
fast-forward the clock (no busy-waiting), and an optional snapshot
hot-swap fires at a configured tick — mid-stream, exactly as a training
round completing would.  Per-tick wall time and pool utilization are
recorded; ``latency_stats`` reduces any sample list to p50/p99/mean."""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Optional

import numpy as np

from repro.serving.engine import Request, ServeEngine


@dataclasses.dataclass
class LoadGen:
    """Seeded request-stream generator over ``population`` clients.

    ``rate`` is the mean arrivals per scheduler tick; ``skew`` ≥ 1 bends
    client popularity toward low ids (rank = ⌊M·u^skew⌋ — skew=1 is
    uniform, larger concentrates traffic on fewer clients)."""
    population: int = 32
    rate: float = 0.5
    prompt_len: tuple[int, int] = (4, 16)       # inclusive range
    max_new: tuple[int, int] = (4, 12)
    vocab: int = 256
    seed: int = 0
    skew: float = 1.0

    def generate(self, n_requests: int) -> list[tuple[int, Request]]:
        """``n_requests`` arrivals as (arrival_tick, Request), tick-sorted,
        uids dense in submission order."""
        rng = np.random.default_rng(self.seed)
        out: list[tuple[int, Request]] = []
        tick, uid = 0, 0
        while uid < n_requests:
            k = rng.poisson(self.rate)
            for _ in range(min(k, n_requests - uid)):
                cid = int(self.population * rng.random() ** self.skew)
                cid = min(cid, self.population - 1)
                n = int(rng.integers(self.prompt_len[0],
                                     self.prompt_len[1] + 1))
                m = int(rng.integers(self.max_new[0], self.max_new[1] + 1))
                prompt = rng.integers(1, self.vocab, size=n).astype(np.int32)
                out.append((tick, Request(uid=uid, prompt=prompt,
                                          max_new_tokens=m, client_id=cid)))
                uid += 1
            tick += 1
        return out


def replay(engine: ServeEngine, trace: list[tuple[int, Request]], *,
           swap_at: Optional[int] = None, snapshot: Optional[dict] = None,
           max_ticks: int = 100_000) -> dict[str, Any]:
    """Drive ``engine`` through ``trace`` until drained.  Returns per-tick
    wall seconds, post-step utilization, completions, and totals."""
    pending = deque(sorted(trace, key=lambda e: e[0]))
    tick_wall: list[float] = []
    util: list[float] = []
    n0_done, t0_tick = len(engine.done), engine.ticks
    n0_dropped = getattr(engine, "dropped", 0)
    swapped = swap_at is None
    wall0 = time.perf_counter()
    while pending or engine.queue \
            or any(a is not None for a in engine.active):
        if engine.ticks - t0_tick >= max_ticks:
            break
        if not swapped and engine.ticks >= swap_at:
            engine.swap(snapshot)           # between ticks, mid-stream
            swapped = True
        while pending and pending[0][0] <= engine.ticks:
            engine.submit(pending.popleft()[1])
        if not engine.queue \
                and all(a is None for a in engine.active) and pending:
            # idle gap: fast-forward the clock to the next arrival
            engine.ticks = max(engine.ticks + 1, pending[0][0])
            continue
        w0 = time.perf_counter()
        engine.step()
        tick_wall.append(time.perf_counter() - w0)
        util.append(engine.utilization)
    wall = time.perf_counter() - wall0
    if not swapped:                          # swap point past the drain
        engine.swap(snapshot)
    completions = engine.done[n0_done:]
    return {
        "completions": completions,
        "n_requests": len(completions),
        "ticks": engine.ticks - t0_tick,
        "wall_s": wall,
        "requests_per_s": len(completions) / wall if wall > 0 else 0.0,
        "tick_wall": tick_wall,
        "utilization": util,
        "mean_utilization": float(np.mean(util)) if util else 0.0,
        "dropped": getattr(engine, "dropped", 0) - n0_dropped,
    }


def latency_stats(samples: list[float],
                  dropped: int = 0) -> dict[str, float]:
    if not samples:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0,
                "dropped": float(dropped)}
    arr = np.asarray(samples, np.float64)
    return {"p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "mean": float(arr.mean()),
            "dropped": float(dropped)}
