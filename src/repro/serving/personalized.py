"""Per-client personalized serving over the shared flat master buffer.

FedaGrac training already maintains a per-client correction signal — the
`(M, P)` ν⁽ⁱ⁾ rows the calibration stage aggregates against the global
orientation ν.  This module puts that signal to work at inference: every
``Request.client_id`` resolves to a personalized parameter VIEW

    row(cid) = flat_master + delta(cid)

where the delta comes from a pluggable ``PERSONALIZERS`` registry
(mirroring the stage/compressor registry idiom, DESIGN.md §2):

    "none"     delta = 0 — pure shared base; the engine serves through the
               EXACT code path of the plain ServeEngine (golden-pinned).
    "nu"       delta = scale · (ν⁽ⁱ⁾[cid] − ν) — one calibrated correction
               step toward the client's own gradient direction.  Storage is
               the training-state (M, P) rows: right for training-sized
               populations, not for millions of clients.
    "lowrank"  delta = scale · coeff[cid] @ basis — an (M, r) coefficient
               table against a shared (r, P) orthonormal basis
               (``lowrank_factors`` builds both from the ν rows).  O(M·r)
               storage + O(r·P) resolve: the serving-scale representation.

Resolution happens ONCE per request, at admission: the summed `(P,)` row
and the snapshot version are pinned to the slot, so requests from
different clients (and different snapshot versions) batch into one decode
tick, and a checkpoint **hot-swap** between ticks can never perturb an
in-flight request — its pinned row and its KV cache both predate the
swap.  ``swap()`` installs a new versioned snapshot for NEW admissions
only; completions record the version they were served under.

Decode ticks pick the cheapest sound path per composition:

  * all live slots share one version, no deltas → ONE shared batched
    decode with that version's materialized param tree — the identical
    jaxpr the plain engine runs (this is what makes the "none" golden pin
    structural rather than numerical);
  * several versions live, still no deltas → one shared decode per live
    version over the full pool, then a per-slot axis-2 splice (batch rows
    are independent, pinned by tests/test_serving_engine.py);
  * any slot carries a delta → the vmapped row path: per-slot `(P,)`
    buffers viewed through the FlatSpec table inside a batch-1 decode,
    vmapped over the pool (cache batch axis = 2 throughout).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import serialize
from repro.configs.base import ModelConfig
from repro.core import flat as flat_lib
from repro.models import model as model_lib
from repro.serving.engine import Request, ServeEngine

Snapshot = Dict[str, Any]

# -- snapshots ----------------------------------------------------------------


def make_snapshot(version: int, flat_master, nu=None, nu_i=None,
                  coeff=None, basis=None) -> Snapshot:
    """A versioned publication of training state: the `(P,)` master plus
    whatever per-client signal the personalizer kind needs."""
    snap: Snapshot = {"version": np.int32(version),
                      "flat_master": jnp.asarray(flat_master)}
    for k, v in (("nu", nu), ("nu_i", nu_i),
                 ("coeff", coeff), ("basis", basis)):
        if v is not None:
            snap[k] = jnp.asarray(v)
    return snap


def save_snapshot(path: str, snap: Snapshot) -> None:
    serialize.save(path, snap)


def load_snapshot(path: str) -> Snapshot:
    raw = serialize.load_raw(path)
    raw["version"] = np.int32(raw["version"])
    return {k: (v if k == "version" else jnp.asarray(v))
            for k, v in raw.items()}


def lowrank_factors(nu_i, nu, r: int):
    """Factor the ν correction rows into `(M, r)` coefficients against a
    shared `(r, P)` orthonormal basis (QR of the row space), so serving
    stores O(M·r + r·P) instead of O(M·P).  Exact when rank(rows) ≤ r."""
    rows = jnp.asarray(nu_i) - jnp.asarray(nu)[None]      # (M, P)
    q = jnp.linalg.qr(rows.T)[0]                          # (P, min(M, P))
    r = min(r, q.shape[1])
    basis = q[:, :r].T                                    # (r, P) orthonormal
    coeff = rows @ basis.T                                # (M, r)
    return coeff, basis


# -- personalizer registry ----------------------------------------------------
# Each entry: (snapshot, scale) -> resolve(client_id) -> (P,) delta | None.
# None means "serve the shared base" — both the "none" kind and cold-start
# clients outside the stored population land there, which keeps the shared
# (bit-identical, cheaper) decode path reachable per-slot.


def _resolve_none(snap: Snapshot, scale: float) -> Callable:
    return lambda cid: None


def _resolve_nu(snap: Snapshot, scale: float) -> Callable:
    nu_i, nu = snap.get("nu_i"), snap.get("nu")
    if nu_i is None or nu is None:
        raise ValueError('personalizer "nu" needs snapshot keys '
                         '"nu_i" and "nu"')
    m = nu_i.shape[0]

    def resolve(cid: int):
        if not 0 <= cid < m:
            return None                          # cold start → shared base
        return scale * (nu_i[cid] - nu)
    return resolve


def _resolve_lowrank(snap: Snapshot, scale: float) -> Callable:
    coeff, basis = snap.get("coeff"), snap.get("basis")
    if coeff is None or basis is None:
        raise ValueError('personalizer "lowrank" needs snapshot keys '
                         '"coeff" and "basis" (see lowrank_factors)')
    m = coeff.shape[0]

    def resolve(cid: int):
        if not 0 <= cid < m:
            return None
        return scale * (coeff[cid] @ basis)      # (r,) @ (r, P)
    return resolve


PERSONALIZERS: Dict[str, Callable] = {
    "none": _resolve_none,
    "nu": _resolve_nu,
    "lowrank": _resolve_lowrank,
}


def make_personalizer(name: str, snap: Snapshot,
                      scale: float = 1.0) -> Callable:
    if name not in PERSONALIZERS:
        raise ValueError(f"unknown personalizer {name!r}; "
                         f"choose from {sorted(PERSONALIZERS)}")
    return PERSONALIZERS[name](snap, scale)


# -- functional decode core ---------------------------------------------------


def personalized_decode(spec: flat_lib.FlatSpec, cfg: ModelConfig,
                        rows, tokens, caches, offsets):
    """Batched decode where every slot runs its OWN `(P,)` parameter row
    through the FlatSpec view table: vmap of a batch-1 ``serve_decode``
    over (row, token, cache-row, offset).  Cache leaves carry their batch
    dim at axis 2 (`(n_groups, count, B, …)`, models/model.py init_caches),
    so the whole cache pytree maps with a uniform axis.  Shared core of
    the engine's row path and the launch/serve.py sharded lowering."""
    def one(row, tok, cache, off):
        params = flat_lib.view_tree(spec, row)
        c1 = jax.tree.map(lambda x: x[:, :, None], cache)
        logits, c1 = model_lib.serve_decode(
            params, {"tokens": tok[None]}, c1, off, cfg)
        return logits[0, 0], jax.tree.map(lambda x: x[:, :, 0], c1)

    return jax.vmap(one, in_axes=(0, 0, 2, 0), out_axes=(0, 2))(
        rows, tokens, caches, offsets)


# -- the engine ---------------------------------------------------------------


class PersonalizedServeEngine(ServeEngine):
    """ServeEngine where ``Request.client_id`` selects a parameter view and
    ``swap(snapshot)`` hot-swaps the base between ticks."""

    def __init__(self, cfg: ModelConfig, spec: flat_lib.FlatSpec,
                 snapshot: Snapshot, *, personalizer: str = "none",
                 scale: float = 1.0, **kw):
        self.spec = spec
        self.kind = personalizer
        self.scale = scale
        self._versions: Dict[int, dict] = {}
        self.version = self._register(snapshot)
        # per-slot pins, set at admission: snapshot version, and (row path
        # only) the summed (P,) parameter row
        super().__init__(cfg, self._versions[self.version]["params"], **kw)
        self._slot_ver: list[Optional[int]] = [None] * self.slots
        self._slot_row: list[Optional[jax.Array]] = [None] * self.slots
        self._flat_prefill = jax.jit(
            lambda row, toks, caches: model_lib.forward(
                flat_lib.view_tree(spec, row), {"tokens": toks}, cfg,
                caches=caches)[:2])
        self._row_decode = jax.jit(
            lambda rows, toks, caches, offs: personalized_decode(
                spec, cfg, rows, toks, caches, offs))

    # -- snapshot lifecycle ---------------------------------------------------

    def _register(self, snap: Snapshot) -> int:
        v = int(snap["version"])
        base = jnp.asarray(snap["flat_master"])
        # materialize the view ONCE per version: the shared decode path
        # then runs the plain engine's params-tree jaxpr on concrete
        # arrays — bit-identity with ServeEngine is structural
        params = jax.tree.map(jnp.asarray,
                              flat_lib.view_tree(self.spec, base))
        self._versions[v] = {
            "base": base,
            "params": params,
            "resolve": make_personalizer(self.kind, snap, self.scale),
        }
        return v

    def swap(self, snap: Snapshot) -> int:
        """Install a new snapshot for FUTURE admissions.  In-flight slots
        keep their pinned version/rows and their caches — a swap between
        ticks cannot change any already-admitted request's tokens."""
        self.version = self._register(snap)
        self.params = self._versions[self.version]["params"]
        self._gc_versions()
        return self.version

    def _gc_versions(self) -> None:
        live = {self.version} | {v for v in self._slot_ver if v is not None}
        for v in [v for v in self._versions if v not in live]:
            del self._versions[v]

    def resolve(self, client_id: int):
        """The current version's delta for ``client_id`` (None = base)."""
        return self._versions[self.version]["resolve"](client_id)

    # -- engine hooks ---------------------------------------------------------

    def step(self) -> None:
        super().step()
        for s in range(self.slots):
            if self.active[s] is None:
                self._slot_ver[s] = None
                self._slot_row[s] = None
        self._gc_versions()

    def _prefill_slot(self, s: int, req: Request, toks, caches):
        v = self.version
        ver = self._versions[v]
        delta = ver["resolve"](req.client_id)
        self._slot_ver[s] = v
        if delta is None:
            # shared base: the plain engine's prefill jaxpr, this
            # version's materialized tree
            self._slot_row[s] = None
            return self._prefill(ver["params"], toks, caches)
        # pin the SUMMED row now — later swaps can't touch it
        self._slot_row[s] = ver["base"] + jnp.asarray(delta)
        return self._flat_prefill(self._slot_row[s], toks, caches)

    def _decode_tick(self, toks: np.ndarray, live: list[int]):
        if any(self._slot_row[s] is not None for s in live):
            return self._decode_rows(toks)
        versions = sorted({self._slot_ver[s] for s in live})
        if len(versions) == 1:
            # plain engine fast path (and the "none" golden pin)
            self.params = self._versions[versions[0]]["params"]
            return super()._decode_tick(toks, live)
        return self._decode_grouped(toks, live, versions)

    def _decode_rows(self, toks: np.ndarray):
        """Row path: every slot decodes its own pinned `(P,)` buffer; slots
        without a delta (or idle) use their pinned — or current — base."""
        cur = self._versions[self.version]["base"]
        rows = jnp.stack([
            self._slot_row[s] if self._slot_row[s] is not None
            else self._versions[self._slot_ver[s]]["base"]
            if self._slot_ver[s] is not None else cur
            for s in range(self.slots)])
        logits, self.caches = self._row_decode(
            rows, jnp.asarray(toks), self.caches,
            jnp.asarray(self.pos, jnp.int32))
        return logits

    def _decode_grouped(self, toks: np.ndarray, live: list[int],
                        versions: list[int]):
        """Several snapshot versions share the pool (hot-swap with base-only
        slots in flight): run the shared batched decode once PER VERSION
        over the full pool, keep each slot's row from its own version's
        call.  Row independence makes the splice bit-exact."""
        tok_dev = jnp.asarray(toks)
        offs = jnp.asarray(self.pos, jnp.int32)
        outs = {v: self._decode(self._versions[v]["params"], tok_dev,
                                self.caches, offs) for v in versions}
        cache = outs[versions[0]][1]
        logits = np.asarray(outs[versions[0]][0][:, 0]).copy()
        for v in versions[1:]:
            lv, cv = outs[v]
            for s in live:
                if self._slot_ver[s] == v:
                    logits[s] = np.asarray(lv[s, 0])
                    cache = _take_slot(cache, cv, s)
        self.caches = cache
        return jnp.asarray(logits)

    def _slot_version(self, s: int) -> int:
        return self._slot_ver[s] or 0


def _take_slot(dst, src, s: int):
    """Copy batch row ``s`` (cache axis 2) from ``src`` into ``dst``."""
    def w(d, o):
        if d.ndim >= 3 and d.shape == o.shape:
            return d.at[:, :, s:s + 1].set(o[:, :, s:s + 1])
        return d
    return jax.tree.map(w, dst, src)
