from repro.serving.engine import Completion, Request, ServeEngine
from repro.serving.loadgen import LoadGen, latency_stats, replay
from repro.serving.personalized import (PERSONALIZERS,
                                        PersonalizedServeEngine,
                                        load_snapshot, lowrank_factors,
                                        make_personalizer, make_snapshot,
                                        personalized_decode, save_snapshot)

__all__ = ["Completion", "Request", "ServeEngine", "LoadGen", "replay",
           "latency_stats", "PERSONALIZERS", "PersonalizedServeEngine",
           "make_personalizer", "make_snapshot", "save_snapshot",
           "load_snapshot", "lowrank_factors", "personalized_decode"]
