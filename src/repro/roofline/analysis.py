"""Three-term roofline from compiled dry-run artifacts.

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis`` supplies FLOPs / bytes of the SPMD-partitioned module
(per-device program).  Collective bytes are NOT in cost_analysis — we parse
the optimized HLO (``compiled.as_text()``) and sum the shaped-buffer sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Hardware constants: TPU v5e.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# --- TPU v5e -----------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~uni-directional)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shaped buffer: f32[8,128]{1,0:...} — captures dtype + dims
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# an HLO op line: "%name = <shape-or-tuple> opcode(" / "name = ... opcode("
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-buffer bytes per collective kind over the module.

    ``-done`` ops repeat the ``-start`` shape; we count starts (or the plain
    op) only."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:          # async completion, shape already counted
            continue
        shape_part, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_part)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                      # per-device HLO flops (trip-weighted)
    bytes_accessed: float             # per-device HLO bytes (trip-weighted)
    coll_bytes: dict[str, int]        # per-device collective bytes by kind
    chips: int
    model_flops: float = 0.0          # 6·N·D useful flops (whole step, global)
    xla_flops: float = 0.0            # raw cost_analysis (loop bodies once)
    xla_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return sum(self.coll_bytes.values()) / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / (chips × HLO_FLOPs) — remat/redundancy waste."""
        if not self.model_flops or not self.flops:
            return None
        return self.model_flops / (self.chips * self.flops)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_accessed,
            "collective_bytes": self.coll_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "xla_flops": self.xla_flops,
            "xla_bytes": self.xla_bytes,
        }


def from_compiled(compiled, chips: int, model_flops: float = 0.0,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Trip-count-aware roofline from the optimized HLO (see hlo.py).

    ``compiled.cost_analysis()`` counts while bodies once, so scanned layers
    and local-step loops vanish from it — we keep its numbers only as
    ``xla_*`` reference fields."""
    from repro.roofline import hlo as hlo_mod
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_mod.analyze(text)
    rl = Roofline(flops=cost.flops, bytes_accessed=cost.bytes_accessed,
                  coll_bytes={k: int(v) for k, v in cost.coll_bytes.items()},
                  chips=chips, model_flops=model_flops)
    try:
        xla = compiled.cost_analysis()
        if isinstance(xla, (list, tuple)):
            xla = xla[0]
        rl.xla_flops = float(xla.get("flops", 0.0))
        rl.xla_bytes = float(xla.get("bytes accessed", 0.0))
    except Exception:
        pass
    return rl


def layout_comparison(tree: Roofline, flat: Roofline,
                      conversion_bytes: Optional[float] = None) -> dict:
    """The flat-vs-tree layout win at the HLO level (DESIGN.md §11) —
    deterministic, unlike wall-clock on a shared-core container: compare
    the flat round's memory/collective bytes (and op count as a proxy for
    dispatch/scheduling load) NEXT TO the tree round's.  Ratios < 1 mean
    the single-buffer round moves fewer bytes / issues fewer ops for the
    identical arithmetic.

    ``conversion_bytes`` is the loss-boundary line item (DESIGN.md §13):
    the extra HLO bytes the flat-native grad path moves over the plain
    tree ``value_and_grad`` at the same round shape — the view-table
    slices into the buffer plus the cotangent accumulation out of it.
    Negative means the flat boundary moves FEWER bytes than the tree
    boundary (e.g. when XLA fuses the slices into the consumers)."""
    coll_t = sum(tree.coll_bytes.values())
    coll_f = sum(flat.coll_bytes.values())
    out = {
        "tree_bytes": tree.bytes_accessed,
        "flat_bytes": flat.bytes_accessed,
        "bytes_ratio": (flat.bytes_accessed / tree.bytes_accessed
                        if tree.bytes_accessed else None),
        "tree_collective_bytes": coll_t,
        "flat_collective_bytes": coll_f,
        "collective_ratio": coll_f / coll_t if coll_t else None,
        "tree_t_memory_s": tree.t_memory,
        "flat_t_memory_s": flat.t_memory,
        "tree_t_collective_s": tree.t_collective,
        "flat_t_collective_s": flat.t_collective,
    }
    if conversion_bytes is not None:
        out["conversion_bytes"] = conversion_bytes
        out["conversion_fraction_of_flat"] = (
            conversion_bytes / flat.bytes_accessed
            if flat.bytes_accessed else None)
    return out


def bytes_on_the_wire(n_params: int, *, uses_nu: bool = True,
                      compressor: str = "none",
                      broadcast_compressor: str = "none",
                      topk_frac: float = 0.05,
                      participants: int = 1, rounds: int = 1) -> dict:
    """Cross-device wire-traffic model for a federated run (DESIGN.md §14):
    per-client payloads under the configured compressors (``payload_bytes``
    formulas — scales/indices included), totals over ``participants``
    reports × ``rounds``, and the uplink reduction factor vs fp32.  This is
    the analytic twin of the measured ``History.bytes_up``/``bytes_down``
    series; benchmarks/compression_bench.py pins the two against each
    other."""
    from repro.core.compress import CompressionConfig, wire_cost
    comp = (None if compressor == "none" and broadcast_compressor == "none"
            else CompressionConfig(uplink=compressor,
                                   downlink=broadcast_compressor,
                                   topk_frac=topk_frac))
    per = wire_cost(n_params, uses_nu, comp)
    scale = float(participants) * float(rounds)
    return {
        **per,
        "uplink_total": scale * per["uplink_per_client"],
        "downlink_total": scale * per["downlink_per_client"],
        "uplink_reduction": (per["uplink_fp32_per_client"]
                             / per["uplink_per_client"]),
        "downlink_reduction": (per["downlink_fp32_per_client"]
                               / per["downlink_per_client"]),
    }


def hlo_op_count(hlo_text: str) -> int:
    """Instruction count of the optimized module — the dispatch/scheduling
    load proxy used by the layout comparison."""
    return sum(1 for line in hlo_text.splitlines() if " = " in line)


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6·N·D) helpers
# ---------------------------------------------------------------------------

def train_model_flops(cfg, tokens: int) -> float:
    """6·N_active·D for one FedaGrac round (all clients, all local steps)."""
    return 6.0 * cfg.active_param_count() * tokens


def prefill_model_flops(cfg, tokens: int) -> float:
    return 2.0 * cfg.active_param_count() * tokens


def decode_model_flops(cfg, batch: int) -> float:
    return 2.0 * cfg.active_param_count() * batch


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
