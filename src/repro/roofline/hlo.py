"""Trip-count-aware cost model over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every while body ONCE — a scanned
48-layer model looks 48× too cheap, and collectives inside the local-step
loop vanish.  The optimized HLO carries ``known_trip_count`` on every
bounded while, so we reconstruct true per-device execution counts:

1. split the module into computations (bracket-aware: headers carry
   tuple-typed params, tuple types carry ``/*index=N*/`` comments);
2. walk the call graph from ENTRY, multiplying through
   ``body=…  backend_config={"known_trip_count":{"n":k}}``;
3. per executed instruction, charge
     FLOPs   — dots: 2·|out|·K (K from operand shapes + contracting dims),
               convs: 2·|out|·∏window, elementwise/transcendental: |out|;
     bytes   — at "body-like" computation level only (ENTRY, while
               bodies/conds, conditional branches): operand + output buffer
               sizes per instruction ≈ HBM traffic at fusion boundaries;
     collective bytes — by kind, output-buffer-size proxy.

Shapes are per-shard (the module is the per-device program), so every total
is per-device.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "token": 0,
    "opaque": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_NAME_EQ_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"(?:true_computation|false_computation)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations={([^}]*)}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_WINDOW_RE = re.compile(r"window={[^}]*size=([0-9x]+)")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# bytes-counted but zero-FLOP data movement / reindexing ops
_MOVEMENT = {
    "copy", "transpose", "broadcast", "concatenate", "slice",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter", "pad",
    "reverse", "convert", "reduce-precision", "sort", "rng-bit-generator",
    "iota", "copy-start", "copy-done",
}
_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "opt-barrier", "domain", "call",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "logistic", "power",
                   "rsqrt", "sqrt", "cosine", "sine",
                   "exponential-minus-one", "log-plus-one", "atan2"}


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        total += _numel(dims) * b
    return total


def shape_numel(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype in _DTYPE_BYTES:
            total += _numel(dims)
    return total


def _first_shape_dims(text: str) -> list[int] | None:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def _matching_paren(s: str, start: int) -> int:
    """Index of the ')' matching the '(' at ``start`` (-1 if unbalanced)."""
    depth = 0
    for i in range(start, len(s)):
        ch = s[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return i
    return -1


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list[Instr]
    shapes: dict[str, str]          # instr/param name -> type text


def _parse_header(line: str) -> tuple[str, bool, dict[str, str]] | None:
    """'%name (p: type, …) -> type {' → (name, is_entry, param shapes)."""
    stripped = line.strip()
    if not stripped.endswith("{") or "->" not in line:
        return None
    is_entry = stripped.startswith("ENTRY")
    if is_entry:
        stripped = stripped[len("ENTRY"):].strip()
    m = re.match(r"%?([\w.\-]+)\s*\(", stripped)
    if not m:
        return None
    name = m.group(1)
    p_open = stripped.index("(", m.start())
    p_close = _matching_paren(stripped, p_open)
    if p_close < 0:
        return None
    params_text = stripped[p_open + 1:p_close]
    shapes: dict[str, str] = {}
    depth = 0
    cur = ""
    parts = []
    for ch in params_text:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        parts.append(cur)
    for part in parts:
        if ":" not in part:
            continue
        pname, ptype = part.split(":", 1)
        shapes[pname.strip().lstrip("%")] = ptype.strip()
    return name, is_entry, shapes


def _parse_instr(line: str) -> Instr | None:
    m = _NAME_EQ_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):                      # tuple-typed result
        close = _matching_paren(rest, 0)
        if close < 0:
            return None
        shape = rest[:close + 1]
        tail = rest[close + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        tail = rest[sp:]
    mo = _OPCODE_RE.match(tail)
    if not mo:
        return None
    return Instr(name, shape, mo.group(1), line)


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            hdr = _parse_header(line)
            if hdr:
                name, is_entry, shapes = hdr
                cur = Computation(name, is_entry, [], shapes)
                if is_entry:
                    entry = name
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        instr = _parse_instr(line)
        if instr:
            cur.instrs.append(instr)
            cur.shapes[instr.name] = instr.shape
    if cur is not None:
        comps[cur.name] = cur
    if not entry and comps:
        referenced = set()
        for c in comps.values():
            for i in c.instrs:
                for pat in (_BODY_RE, _COND_RE, _CALLS_RE, _TOAPPLY_RE):
                    mm = pat.search(i.line)
                    if mm:
                        referenced.add(mm.group(1))
        entry = next((n for n in comps if n not in referenced),
                     next(iter(comps)))
    return comps, entry


def execution_counts(comps: dict[str, Computation], entry: str
                     ) -> tuple[dict[str, float], set[str]]:
    """Returns (name → execution count, set of body-like computations).

    Body-like = ENTRY / while bodies / conditional branches: their
    instructions sit at a fusion boundary, so their buffers model HBM
    traffic.  Everything reached via calls=/to_apply= is inlined."""
    counts: dict[str, float] = defaultdict(float)
    body_like = {entry}
    stack: list[tuple[str, float]] = [(entry, 1.0)]
    guard = 0
    while stack:
        guard += 1
        if guard > 500_000:
            break
        name, mult = stack.pop()
        comp = comps.get(name)
        if comp is None:
            continue
        counts[name] += mult
        for i in comp.instrs:
            if i.opcode == "while":
                trips = 1.0
                mt = _TRIP_RE.search(i.line)
                if mt:
                    trips = float(mt.group(1))
                mb = _BODY_RE.search(i.line)
                mc = _COND_RE.search(i.line)
                if mb:
                    body_like.add(mb.group(1))
                    stack.append((mb.group(1), mult * trips))
                if mc:
                    body_like.add(mc.group(1))
                    stack.append((mc.group(1), mult * (trips + 1)))
            elif i.opcode == "conditional":
                names = [mm.group(1) for mm in _BRANCH_RE.finditer(i.line)]
                mbr = _BRANCHES_RE.search(i.line)
                if mbr:
                    names += [n.strip().lstrip("%")
                              for n in mbr.group(1).split(",")]
                for n in names:
                    body_like.add(n)
                    stack.append((n, mult))
            elif i.opcode in ("fusion", "call"):
                mcal = _CALLS_RE.search(i.line) or _TOAPPLY_RE.search(i.line)
                if mcal:
                    stack.append((mcal.group(1), mult))
            # reduce/scatter/sort to_apply bodies are scalar lambdas — skip
    return dict(counts), body_like


def _dot_flops(instr: Instr, shapes: dict[str, str]) -> float:
    out = shape_numel(instr.shape)
    args = instr.line.split("(", 1)[1]
    ops = _OPERAND_RE.findall(args.split(")", 1)[0])
    mdims = re.search(r"lhs_contracting_dims={([0-9,]*)}", instr.line)
    k = 1
    if ops and mdims:
        lhs_shape = shapes.get(ops[0])
        if lhs_shape:
            dims = _first_shape_dims(lhs_shape)
            if dims:
                for d in mdims.group(1).split(","):
                    if d and int(d) < len(dims):
                        k *= dims[int(d)]
    return 2.0 * out * max(k, 1)


def _conv_flops(instr: Instr) -> float:
    out = shape_numel(instr.shape)
    mw = _WINDOW_RE.search(instr.line)
    kelems = 1
    if mw:
        for part in mw.group(1).split("x"):
            kelems *= int(part)
    return 2.0 * out * kelems


_SLICE_LIKE = {"slice", "dynamic-slice", "gather"}


def _instr_operands(instr: Instr) -> list[str]:
    args = instr.line.split("(", 1)[1]
    stop = args.find("), ")
    arg_text = args[:stop] if stop > 0 else args
    return _OPERAND_RE.findall(arg_text)


def _fusion_param_charges(comp: Computation) -> dict[int, float]:
    """Per-parameter-index byte charge for one fusion body.

    A parameter consumed ONLY by slice-like ops is charged at the sliced
    output size (the scan-over-stacked-layers pattern reads one layer's
    slice of the stacked weights per iteration, not the whole stack)."""
    param_names: dict[str, int] = {}
    for i in comp.instrs:
        if i.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", i.line)
            if m:
                param_names[i.name] = int(m.group(1))
    charges: dict[int, float] = {}
    for pname, idx in param_names.items():
        consumers = [i for i in comp.instrs
                     if i.opcode != "parameter"
                     and re.search(r"%" + re.escape(pname) + r"\b", i.line)]
        full = shape_bytes(comp.shapes.get(pname, ""))
        if consumers and all(c.opcode in _SLICE_LIKE for c in consumers):
            charges[idx] = sum(shape_bytes(c.shape) for c in consumers)
        else:
            charges[idx] = full
    return charges


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    transcendentals: float = 0.0
    coll_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    coll_count: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})


def analyze(text: str) -> HLOCost:
    comps, entry = parse_module(text)
    counts, body_like = execution_counts(comps, entry)
    cost = HLOCost()
    fusion_charges: dict[str, dict[int, float]] = {}
    for cname, mult in counts.items():
        comp = comps.get(cname)
        if comp is None or mult == 0:
            continue
        at_boundary = cname in body_like
        for i in comp.instrs:
            op = i.opcode
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVE_KINDS:
                b = shape_bytes(i.shape)
                if op.endswith("-start"):      # tuple repeats in/out buffers
                    b //= 2
                cost.coll_bytes[base] += mult * b
                cost.coll_count[base] += mult
                cost.bytes_accessed += mult * b
                continue
            if op.endswith("-done"):
                continue
            # ---- FLOPs --------------------------------------------------
            if op == "dot":
                cost.flops += mult * _dot_flops(i, comp.shapes)
            elif op == "convolution":
                cost.flops += mult * _conv_flops(i)
            elif op not in _MOVEMENT and op not in _SKIP_BYTES \
                    and op != "fusion":
                out = shape_numel(i.shape)
                cost.flops += mult * out
                if op in _TRANSCENDENTAL:
                    cost.transcendentals += mult * out
            # ---- bytes (fusion-boundary traffic) ------------------------
            if not at_boundary or op in _SKIP_BYTES:
                continue
            out_bytes = shape_bytes(i.shape)
            if op in _SLICE_LIKE:
                cost.bytes_accessed += mult * 2 * out_bytes
                continue
            if op in ("dynamic-update-slice", "scatter"):
                ops_ = _instr_operands(i)
                upd = (shape_bytes(comp.shapes.get(ops_[1], ""))
                       if len(ops_) > 1 else out_bytes)
                cost.bytes_accessed += mult * 2 * max(upd, 1)
                continue
            if op == "fusion":
                callee = _CALLS_RE.search(i.line)
                charges = None
                if callee and callee.group(1) in comps:
                    cal = callee.group(1)
                    if cal not in fusion_charges:
                        fusion_charges[cal] = _fusion_param_charges(
                            comps[cal])
                    charges = fusion_charges[cal]
                operand_bytes = 0.0
                for pos, oname in enumerate(_instr_operands(i)):
                    sh = comp.shapes.get(oname)
                    full = shape_bytes(sh) if sh else 0
                    if charges is not None and pos in charges:
                        operand_bytes += min(charges[pos], full) \
                            if full else charges[pos]
                    else:
                        operand_bytes += full
                cost.bytes_accessed += mult * (operand_bytes + out_bytes)
                continue
            operand_bytes = 0
            for oname in _instr_operands(i):
                sh = comp.shapes.get(oname)
                if sh:
                    operand_bytes += shape_bytes(sh)
            cost.bytes_accessed += mult * (operand_bytes + out_bytes)
    return cost
