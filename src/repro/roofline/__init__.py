from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                     collective_bytes, decode_model_flops,
                                     from_compiled, memory_stats,
                                     prefill_model_flops, train_model_flops)

__all__ = ["HBM_BW", "ICI_BW", "PEAK_FLOPS", "Roofline", "collective_bytes",
           "decode_model_flops", "from_compiled", "memory_stats",
           "prefill_model_flops", "train_model_flops"]
