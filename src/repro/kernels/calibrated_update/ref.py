"""Pure-jnp oracle for the fused calibrated local update (Alg. 1, line 9):

    x ← x − η (g + λ c)        c = ν − ν⁽ⁱ⁾

and its FedProx variant  x ← x − η (g + λ c + μ (x − x₀)).
"""
from __future__ import annotations

import jax.numpy as jnp


def calibrated_update(x: jnp.ndarray, g: jnp.ndarray, c: jnp.ndarray,
                      eta: float, lam: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    return (xf - eta * (gf + lam * cf)).astype(x.dtype)


def calibrated_update_prox(x: jnp.ndarray, g: jnp.ndarray, c: jnp.ndarray,
                           x0: jnp.ndarray, eta: float, lam: float,
                           mu: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    x0f = x0.astype(jnp.float32)
    return (xf - eta * (gf + lam * cf + mu * (xf - x0f))).astype(x.dtype)
