"""Public op: fused calibrated update over arbitrary pytrees.

Leaves are flattened, concatenated and lane-padded to (rows, 128) so ONE
kernel launch covers the whole parameter vector (instead of one tiny
launch per leaf — important for models with hundreds of small tensors).
On non-TPU backends (this container) the kernel runs in interpret mode;
``use_pallas=False`` falls back to the jnp oracle for A/B benchmarks.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.calibrated_update import ref
from repro.kernels.calibrated_update.kernel import (LANES,
                                                    calibrated_update_2d,
                                                    calibrated_update_prox_2d)

PyTree = Any


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flatten_to_2d(tree: PyTree) -> tuple[jax.Array, list, Any, int]:
    """Concat all leaves (as f32) into (rows, LANES); returns
    (mat, shapes/dtypes, treedef, true_size)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    metas = [(lv.shape, lv.dtype, lv.size) for lv in leaves]
    flat = jnp.concatenate([lv.astype(jnp.float32).reshape(-1)
                            for lv in leaves])
    n = flat.shape[0]
    rows = -(-n // LANES)
    pad = rows * LANES - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, LANES), metas, treedef, n


def unflatten_from_2d(mat: jax.Array, metas, treedef, n: int) -> PyTree:
    flat = mat.reshape(-1)[:n]
    leaves = []
    off = 0
    for shape, dtype, size in metas:
        leaves.append(flat[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, leaves)


def calibrated_update_tree(x: PyTree, g: PyTree, c: PyTree, eta, lam, *,
                           use_pallas: bool = True,
                           interpret: bool | None = None) -> PyTree:
    """x ← x − η (g + λ c) fused over the whole pytree."""
    if not use_pallas:
        return jax.tree.map(
            lambda xx, gg, cc: ref.calibrated_update(xx, gg, cc, eta, lam),
            x, g, c)
    if interpret is None:
        interpret = not _is_tpu()
    xm, metas, treedef, n = flatten_to_2d(x)
    gm, _, _, _ = flatten_to_2d(g)
    cm, _, _, _ = flatten_to_2d(c)
    om = calibrated_update_2d(xm, gm, cm, eta, lam, interpret=interpret)
    return unflatten_from_2d(om, metas, treedef, n)


def calibrated_update_prox_tree(x: PyTree, g: PyTree, c: PyTree, x0: PyTree,
                                eta, lam, mu, *, use_pallas: bool = True,
                                interpret: bool | None = None) -> PyTree:
    """FedProx variant fused over the whole pytree:
    x ← x − η (g + λ c + μ (x − x₀))."""
    if not use_pallas:
        return jax.tree.map(
            lambda xx, gg, cc, aa: ref.calibrated_update_prox(
                xx, gg, cc, aa, eta, lam, mu), x, g, c, x0)
    if interpret is None:
        interpret = not _is_tpu()
    xm, metas, treedef, n = flatten_to_2d(x)
    gm, _, _, _ = flatten_to_2d(g)
    cm, _, _, _ = flatten_to_2d(c)
    am, _, _, _ = flatten_to_2d(x0)
    om = calibrated_update_prox_2d(xm, gm, cm, am, eta, lam, mu,
                                   interpret=interpret)
    return unflatten_from_2d(om, metas, treedef, n)
