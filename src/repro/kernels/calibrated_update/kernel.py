"""Pallas TPU kernel: fused calibrated local update.

The hot loop of FedaGrac's local step is parameter-vector AXPY arithmetic:
``x ← x − η (g + λ c)``.  Unfused, XLA issues three HBM-bound elementwise
ops (add, mul, sub) ⇒ up to 3 reads + intermediate writes of a full
parameter-sized tensor per local step.  The fused kernel streams x, g, c
through VMEM once: 3 reads + 1 write, the bandwidth floor.

TPU adaptation: the parameter pytree is flattened and lane-padded to
(rows, 128); each grid step processes a (BLOCK_ROWS, 128) VMEM tile — the
last-dim multiple-of-128 requirement of the VPU.  η and λ are scalar
operands in SMEM so schedules (λ increasing over rounds) don't recompile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
BLOCK_ROWS = 512            # (512, 128) fp32 tile = 256 KiB/operand in VMEM


def _kernel(scal_ref, x_ref, g_ref, c_ref, o_ref):
    eta = scal_ref[0]
    lam = scal_ref[1]
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = (x - eta * (g + lam * c)).astype(o_ref.dtype)


def _kernel_prox(scal_ref, x_ref, g_ref, c_ref, x0_ref, o_ref):
    eta = scal_ref[0]
    lam = scal_ref[1]
    mu = scal_ref[2]
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    x0 = x0_ref[...].astype(jnp.float32)
    o_ref[...] = (x - eta * (g + lam * c + mu * (x - x0))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def calibrated_update_2d(x: jax.Array, g: jax.Array, c: jax.Array,
                         eta: jax.Array, lam: jax.Array, *,
                         block_rows: int = BLOCK_ROWS,
                         interpret: bool = False) -> jax.Array:
    """x, g, c: (rows, 128·k).  eta/lam: f32 scalars."""
    rows, cols = x.shape
    assert cols % LANES == 0, cols
    br = min(block_rows, rows)
    grid = (pl.cdiv(rows, br),)
    scal = jnp.stack([jnp.asarray(eta, jnp.float32),
                      jnp.asarray(lam, jnp.float32)])
    spec = pl.BlockSpec((br, cols), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(scal, x, g, c)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def calibrated_update_prox_2d(x, g, c, x0, eta, lam, mu, *,
                              block_rows: int = BLOCK_ROWS,
                              interpret: bool = False) -> jax.Array:
    rows, cols = x.shape
    assert cols % LANES == 0, cols
    br = min(block_rows, rows)
    grid = (pl.cdiv(rows, br),)
    scal = jnp.stack([jnp.asarray(eta, jnp.float32),
                      jnp.asarray(lam, jnp.float32),
                      jnp.asarray(mu, jnp.float32)])
    spec = pl.BlockSpec((br, cols), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel_prox,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(scal, x, g, c, x0)
