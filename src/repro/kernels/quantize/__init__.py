from repro.kernels.quantize.ops import (dequantize_2d, masked_abs_rowmax,
                                        quantize_2d, row_scales,
                                        topk_mask_2d, topk_thresholds)

__all__ = ["quantize_2d", "dequantize_2d", "topk_mask_2d",
           "masked_abs_rowmax", "row_scales", "topk_thresholds"]
