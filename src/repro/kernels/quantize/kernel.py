"""Pallas TPU kernels: wire compression on the flat (M, P) layout.

The compression stage (core/compress.py, DESIGN.md §14) turns every
transmitted quantity — client deltas, ν updates, the server broadcast —
into a quantized/sparsified wire payload.  On the lane-padded flat layout
that is pure streaming elementwise arithmetic over ``(rows, 128·k)``
matrices with one scalar (the scale / the top-k threshold) per row:

* ``quantize_2d``   — int codes  q = clip(round(x / s), −qmax, qmax)
  (qmax = 127 for int8, 7 for int4; the int4 codes ship in an int8
  container on device — the *wire* accounting charges 4 bits/element,
  see ``compress.payload_bytes``);
* ``dequantize_2d`` — x̂ = q · s, the server-side reconstruction;
* ``topk_mask_2d``  — x̂ = x · 1[|x| ≥ tᵣ], the row-threshold form of
  top-k sparsification (the k-th magnitude per row is computed outside
  the kernel — a ``lax.top_k`` reduction, not a streaming op).

Same conventions as calibrated_update/kernel.py: a (BLOCK_ROWS, cols)
VMEM tile per grid step, per-row scalars ride along as a (rows, 1) f32
operand blocked to (BLOCK_ROWS, 1), compile-time-constant qmax in SMEM so
int8/int4 share one kernel.  Scale selection (padding-masked amax) is the
caller's job: these kernels transform exactly what they are given, so the
padding tail stays zero iff the input tail is zero — which the compressor
stage guarantees by masking (core/compress.py pins it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
BLOCK_ROWS = 512            # (512, 128) fp32 tile = 256 KiB/operand in VMEM


def _quantize_kernel(scal_ref, x_ref, s_ref, o_ref):
    qmax = scal_ref[0]
    x = x_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)              # (br, 1) broadcasts
    o_ref[...] = jnp.clip(jnp.round(x / s), -qmax, qmax).astype(jnp.int8)


def _dequantize_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s).astype(o_ref.dtype)


def _topk_mask_kernel(x_ref, t_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    t = t_ref[...].astype(jnp.float32)              # (br, 1) broadcasts
    o_ref[...] = jnp.where(jnp.abs(x) >= t, x, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("qmax", "block_rows",
                                             "interpret"))
def quantize_2d(x: jax.Array, scale: jax.Array, *, qmax: int = 127,
                block_rows: int = BLOCK_ROWS,
                interpret: bool = False) -> jax.Array:
    """x: (rows, 128·k); scale: (rows, 1) f32 > 0.  Returns int8 codes in
    [−qmax, qmax] (int4 uses qmax = 7 in the same container)."""
    rows, cols = x.shape
    assert cols % LANES == 0, cols
    br = min(block_rows, rows)
    grid = (pl.cdiv(rows, br),)
    scal = jnp.asarray([float(qmax)], jnp.float32)
    spec = pl.BlockSpec((br, cols), lambda i: (i, 0))
    sspec = pl.BlockSpec((br, 1), lambda i: (i, 0))
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), spec, sspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int8),
        interpret=interpret,
    )(scal, x, scale)


@functools.partial(jax.jit, static_argnames=("out_dtype", "block_rows",
                                             "interpret"))
def dequantize_2d(q: jax.Array, scale: jax.Array, *,
                  out_dtype=jnp.float32, block_rows: int = BLOCK_ROWS,
                  interpret: bool = False) -> jax.Array:
    """q: (rows, 128·k) int8 codes; scale: (rows, 1) f32.  x̂ = q·s."""
    rows, cols = q.shape
    assert cols % LANES == 0, cols
    br = min(block_rows, rows)
    grid = (pl.cdiv(rows, br),)
    spec = pl.BlockSpec((br, cols), lambda i: (i, 0))
    sspec = pl.BlockSpec((br, 1), lambda i: (i, 0))
    return pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[spec, sspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.dtype(out_dtype)),
        interpret=interpret,
    )(q, scale)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def topk_mask_2d(x: jax.Array, thresh: jax.Array, *,
                 block_rows: int = BLOCK_ROWS,
                 interpret: bool = False) -> jax.Array:
    """x: (rows, 128·k); thresh: (rows, 1) f32 ≥ 0 — the k-th |x| per row.
    Zeroes every element strictly below its row threshold (ties survive,
    so ≥ k elements may pass; the wire model charges exactly k)."""
    rows, cols = x.shape
    assert cols % LANES == 0, cols
    br = min(block_rows, rows)
    grid = (pl.cdiv(rows, br),)
    spec = pl.BlockSpec((br, cols), lambda i: (i, 0))
    sspec = pl.BlockSpec((br, 1), lambda i: (i, 0))
    return pl.pallas_call(
        _topk_mask_kernel,
        grid=grid,
        in_specs=[spec, sspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, thresh)
