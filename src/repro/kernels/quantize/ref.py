"""jnp oracles for the quantize kernels — the CPU execution path.

Bitwise-identical arithmetic to kernel.py (same f32-internal ops in the
same order); tests/test_compression.py pins kernel (interpret mode) ==
oracle across shapes and dtypes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_2d(x: jax.Array, scale: jax.Array,
                qmax: int = 127) -> jax.Array:
    xf = x.astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / scale.astype(jnp.float32)),
                 -float(qmax), float(qmax))
    return q.astype(jnp.int8)


def dequantize_2d(q: jax.Array, scale: jax.Array,
                  out_dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)
            ).astype(out_dtype)


def topk_mask_2d(x: jax.Array, thresh: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    out = jnp.where(jnp.abs(xf) >= thresh.astype(jnp.float32), xf, 0.0)
    return out.astype(x.dtype)
