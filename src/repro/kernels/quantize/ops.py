"""Dispatch wrappers for the quantize kernels (kernel on TPU, oracle
elsewhere — the ``ops.calibrated_update_tree`` convention) plus the
scalar-selection helpers the kernels deliberately exclude:

* ``masked_abs_rowmax`` — per-row max |x| over the TRUE elements only:
  the lane-padding tail ``[n, p)`` is masked OUT of the reduction, so a
  (hypothetically) poisoned pad can never inflate a quantization scale.
  This is the structural fix the compression stage builds every scale on.
* ``row_scales`` — the int8/int4 scale s = max(amax/qmax, eps).
* ``topk_thresholds`` — the k-th |x| per row (pad masked to −1 so it can
  never enter the top-k), consumed by ``topk_mask_2d``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.quantize import kernel, ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas: Optional[bool],
             interpret: Optional[bool]) -> tuple[bool, bool]:
    use_pallas = _is_tpu() if use_pallas is None else use_pallas
    interpret = (not _is_tpu()) if interpret is None else interpret
    return use_pallas, interpret


def quantize_2d(x: jax.Array, scale: jax.Array, *, qmax: int = 127,
                use_pallas: Optional[bool] = None,
                interpret: Optional[bool] = None) -> jax.Array:
    use_pallas, interpret = _resolve(use_pallas, interpret)
    if use_pallas:
        return kernel.quantize_2d(x, scale, qmax=qmax, interpret=interpret)
    return ref.quantize_2d(x, scale, qmax=qmax)


def dequantize_2d(q: jax.Array, scale: jax.Array, *, out_dtype=jnp.float32,
                  use_pallas: Optional[bool] = None,
                  interpret: Optional[bool] = None) -> jax.Array:
    use_pallas, interpret = _resolve(use_pallas, interpret)
    if use_pallas:
        return kernel.dequantize_2d(q, scale, out_dtype=out_dtype,
                                    interpret=interpret)
    return ref.dequantize_2d(q, scale, out_dtype=out_dtype)


def topk_mask_2d(x: jax.Array, thresh: jax.Array, *,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    use_pallas, interpret = _resolve(use_pallas, interpret)
    if use_pallas:
        return kernel.topk_mask_2d(x, thresh, interpret=interpret)
    return ref.topk_mask_2d(x, thresh)


# -- scalar selection (outside the streaming kernels) ------------------------

def masked_abs_rowmax(x: jax.Array, n: int) -> jax.Array:
    """(rows, P) → (rows, 1) f32: max |x| over columns [0, n) ONLY — the
    lane-padding tail [n, P) is excluded from the reduction by
    construction, not by assuming it holds zeros."""
    p = x.shape[-1]
    mask = jnp.arange(p) < n                         # static n: folded
    a = jnp.where(mask, jnp.abs(x.astype(jnp.float32)), 0.0)
    return jnp.max(a, axis=-1, keepdims=True)


def row_scales(x: jax.Array, n: int, qmax: int,
               eps: float = 1e-12) -> jax.Array:
    """Per-row symmetric quantization scale s = max(amax/qmax, eps)."""
    return jnp.maximum(masked_abs_rowmax(x, n) / float(qmax), eps)


def topk_thresholds(x: jax.Array, n: int, k: int) -> jax.Array:
    """(rows, P) → (rows, 1) f32: the k-th largest |x| per row over the
    true columns (pad magnitudes forced to −1, below any real |x|, so
    padding can never occupy a top-k slot).  Requires k ≤ n."""
    p = x.shape[-1]
    mask = jnp.arange(p) < n
    mag = jnp.where(mask, jnp.abs(x.astype(jnp.float32)), -1.0)
    top = jax.lax.top_k(mag, k)[0]
    return top[..., k - 1:k]
