"""Pure-jnp oracle for the chunked SSD (Mamba2) scan — re-exports the model
layer's implementation, which tests/test_ssm_equivalence.py proves exactly
equal to the naive per-step recurrence."""
from repro.models.mamba2 import ssd_chunked  # noqa: F401
