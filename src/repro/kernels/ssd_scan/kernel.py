"""Pallas TPU kernel: chunked SSD (Mamba2) scan forward.

TPU adaptation of the SSD insight (Dao & Gu 2024): the inter-chunk
recurrence carries an (head_dim × d_state) state matrix in VMEM scratch
across a *sequential* chunk grid axis; intra-chunk work is three MXU
matmuls on (L × L)/(L × N)/(L × P) tiles.  Where the GPU kernel spreads
chunks over thread blocks and synchronizes states through global memory,
the TPU version makes the chunk axis the innermost sequential grid
dimension — states never leave VMEM.

Grid: (B, H, n_chunks) — last axis "arbitrary"; scratch S (P, N) f32.
Per (b, h, c) block:

    cum   = cumsum(dA)                              (L,)
    y_diag = ((C·Bᵀ) ∘ exp(segsum(dA)) ∘ tril) · x  (L, P)
    y_off  = exp(cum) ∘ (C · Sᵀ)                    (L, P)
    S     ← exp(cum_L) S + xᵀ · (exp(cum_L − cum) ∘ B)

Inputs are pre-arranged (B, H, C, L, ·) by ops.py (dt folded into x and
dA = dt·A_h, GQA-style group broadcast already applied).  Block shapes:
L multiple of 8; P/N are lane-padded to 128 by ops.py for MXU alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(xdt_ref, da_ref, b_ref, c_ref, y_ref, s_out_ref, s_ref, *,
                n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    xdt = xdt_ref[0, 0, 0].astype(jnp.float32)       # (L, P)
    da = da_ref[0, 0, 0, :, 0].astype(jnp.float32)   # (L,)
    Bm = b_ref[0, 0, 0].astype(jnp.float32)          # (L, N)
    Cm = c_ref[0, 0, 0].astype(jnp.float32)          # (L, N)
    L = xdt.shape[0]

    cum = jnp.cumsum(da)                             # (L,)
    seg = cum[:, None] - cum[None, :]                # (L, L): cum_z − cum_s
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    lmat = jnp.where(tri, jnp.exp(seg), 0.0)

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    y = jax.lax.dot_general(cb * lmat, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, P)

    s_prev = s_ref[...]                              # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, s_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (L,N)·(P,N)ᵀ → (L,P)

    decay_end = jnp.exp(cum[-1] - cum)               # (L,)
    s_ref[...] = (jnp.exp(cum[-1]) * s_prev
                  + jax.lax.dot_general(
                      xdt, decay_end[:, None] * Bm,
                      (((0,), (0,)), ((), ())),
                      preferred_element_type=jnp.float32))

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        s_out_ref[0, 0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan_bhclp(xdt: jax.Array, da: jax.Array, b: jax.Array,
                   c: jax.Array, *, interpret: bool = False):
    """xdt (B,H,C,L,P); da (B,H,C,L,1); b, c (B,H,C,L,N).
    Returns (y (B,H,C,L,P), state (B,H,P,N) f32)."""
    B, H, C, L, P = xdt.shape
    N = b.shape[-1]
    grid = (B, H, C)
    kernel = functools.partial(_ssd_kernel, n_chunks=C)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, 1), lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, N), lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, N), lambda i, j, k: (i, j, k, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda i, j, k: (i, j, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, H, C, L, P), xdt.dtype),
                   jax.ShapeDtypeStruct((B, H, P, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, da, b, c)
