"""Public op: Mamba2 SSD scan with the model-layer calling convention.

Matches ``repro.models.mamba2.ssd_chunked(x, dt, A, B, C, chunk)``:
x (b, l, h, p); dt (b, l, h) post-softplus; A (h,) negative;
B, C (b, l, g, n) with g groups broadcast over heads.  Returns
(y (b, l, h, p), final state (b, h, p, n)).

The wrapper folds dt into x, expands groups to heads, reshapes to the
kernel's (B, H, C, L, ·) layout, and lane-pads P/N to 128 for MXU
alignment (zero-padding is exact: padded state rows/cols stay zero).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_bhclp

LANES = 128


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, chunk: int, *, use_pallas: bool = True,
             interpret: bool | None = None):
    if not use_pallas:
        from repro.kernels.ssd_scan.ref import ssd_chunked
        return ssd_chunked(x, dt, A, B, C, chunk)
    if interpret is None:
        interpret = not _is_tpu()
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    L = min(chunk, l)
    assert l % L == 0, (l, L)
    nc = l // L
    rep = h // g

    xdt = (x * dt[..., None]).astype(jnp.float32)
    da = dt.astype(jnp.float32) * A[None, None, :]
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)

    def to_bhcl(t, feat):
        # (b, l, h, f) -> (b, h, nc, L, f)
        return t.transpose(0, 2, 1, 3).reshape(b, h, nc, L, feat)

    xdt_k = to_bhcl(xdt, p)
    da_k = da.transpose(0, 2, 1).reshape(b, h, nc, L, 1)
    B_k = to_bhcl(Bh, n)
    C_k = to_bhcl(Ch, n)

    pad_p = (-p) % LANES if not interpret else 0
    pad_n = (-n) % LANES if not interpret else 0
    if pad_p:
        xdt_k = jnp.pad(xdt_k, ((0, 0),) * 4 + ((0, pad_p),))
    if pad_n:
        B_k = jnp.pad(B_k, ((0, 0),) * 4 + ((0, pad_n),))
        C_k = jnp.pad(C_k, ((0, 0),) * 4 + ((0, pad_n),))

    y, state = ssd_scan_bhclp(xdt_k, da_k, B_k, C_k, interpret=interpret)
    y = y[..., :p].reshape(b, h, l, p).transpose(0, 2, 1, 3)
    state = state[:, :, :p, :n]
    return y.astype(jnp.float32), state
