"""Public ops: flash attention over the model-layer (B, S, H, D) layout.

* ``flash_attention``      — forward-only (serving paths / benchmarks).
* ``flash_attention_diff`` — custom_vjp op whose forward AND backward run
  the Pallas kernels (backward.py): softmax scores never touch HBM in
  either pass, so training-time attention HBM traffic is O(S·D) instead
  of O(S²).

Both handle layout transposes, head-dim lane padding to 128, and backend
dispatch (interpret mode off-TPU).  ``use_pallas=False`` falls back to the
jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.backward import flash_attention_bwd_bhsd
from repro.kernels.flash_attention.kernel import (flash_attention_bhsd,
                                                  flash_attention_fwd_bhsd)

LANES = 128


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_scale(q, k, v):
    """Lane-pad head dims to a common multiple of 128 (q/k share D_qk;
    v may differ — MLA); rescale q so the kernel's 1/√D' matches 1/√D_qk."""
    D = q.shape[-1]
    Dv = v.shape[-1]
    Dt = max(-(-D // LANES), -(-Dv // LANES)) * LANES
    if D == Dt and Dv == Dt:
        return q, k, v, 0
    scale_fix = (D ** -0.5) / (Dt ** -0.5)
    q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, Dt - D))) * scale_fix
    k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, Dt - D)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, Dt - Dv)))
    return q, k, v, Dt - D


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 256,
                    use_pallas: bool = True,
                    interpret: bool | None = None) -> jax.Array:
    """Forward-only.  q (B, Sq, H, D); k, v (B, Skv, Hkv, D)."""
    if not use_pallas:
        return ref.attention(q, k, v, causal=causal, window=window)
    if interpret is None:
        interpret = not _is_tpu()
    dv = v.shape[-1]                 # output head dim (MLA: D_v ≠ D_qk)
    q, k, v, pad = _pad_scale(q, k, v)
    out = flash_attention_bhsd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    out = out.transpose(0, 2, 1, 3)
    return out[..., :dv] if out.shape[-1] != dv else out


# ---------------------------------------------------------------------------
# differentiable op (training path)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_diff_bhsd(q, k, v, causal, window, block_q, block_k, interpret):
    o, _ = flash_attention_fwd_bhsd(q, k, v, causal=causal, window=window,
                                    block_q=block_q, block_k=block_k,
                                    interpret=interpret)
    return o


def _flash_diff_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    o, lse = flash_attention_fwd_bhsd(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=interpret)
    return o, (q, k, v, o, lse[..., 0])


def _flash_diff_bwd(causal, window, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd_bhsd(
        q, k, v, o, lse, do, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return dq, dk, dv


_flash_diff_bhsd.defvjp(_flash_diff_fwd, _flash_diff_bwd)


def flash_attention_diff(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         block_q: int = 256, block_k: int = 256,
                         interpret: bool | None = None) -> jax.Array:
    """Differentiable flash attention.  q (B, Sq, H, D); k, v
    (B, Skv, Hkv, D) -> (B, Sq, H, D)."""
    if interpret is None:
        interpret = not _is_tpu()
    dv = v.shape[-1]                 # output head dim (MLA: D_v ≠ D_qk)
    q, k, v, pad = _pad_scale(q, k, v)
    out = _flash_diff_bhsd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal, window, block_q, block_k,
        interpret)
    out = out.transpose(0, 2, 1, 3)
    return out[..., :dv] if out.shape[-1] != dv else out
