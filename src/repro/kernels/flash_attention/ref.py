"""Pure-jnp oracle for causal (optionally sliding-window) flash attention
with GQA head groups.  Materializes the full score matrix — O(S²) memory,
fine for test shapes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              scale: float | None = None) -> jax.Array:
    """q (B, Sq, H, D); k, v (B, Skv, Hkv, D); H multiple of Hkv."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    if scale is None:
        scale = D ** -0.5
    qr = q.reshape(B, Sq, Hkv, g, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr * scale, k.astype(jnp.float32))
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = kv_pos <= q_pos
    if window:
        mask = jnp.logical_and(mask, kv_pos > q_pos - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)
