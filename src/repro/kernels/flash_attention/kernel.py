"""Pallas TPU flash attention (forward) — blocked online softmax.

TPU adaptation of the GPU flash-attention insight: instead of shared-memory
tiles + warp shuffles, VMEM-resident (block_q × head_dim) accumulators carried
across a *sequential* kv grid axis; the MXU consumes (block_q × block_k)
score tiles.  Causal + sliding-window blocks outside the band are skipped
with ``pl.when`` (zero MXU work), giving the 2× causal and O(S·W) window
savings structurally.

Grid: (B, H, Sq/bq, Skv/bk) — last axis "arbitrary" (sequential), carrying
(m, l, acc) scratch.  GQA maps q head h → kv head h // (H/Hkv) in the
index_map, so no repeated-KV materialization.

Block shapes: bq, bk multiples of the (8,128) fp32 VMEM tile; head_dim is
lane-padded to 128 by the ops wrapper when needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -2.0 ** 30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                 acc_ref, *,
                 scale: float, block_q: int, block_k: int, n_kv: int,
                 causal: bool, window: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_off = qi * block_q
    k_off = ki * block_k

    # band check: does this kv block intersect [q_pos-window+1, q_pos]?
    in_band = True
    if causal:
        in_band = jnp.logical_and(in_band, k_off <= q_off + block_q - 1)
    if window:
        in_band = jnp.logical_and(
            in_band, k_off + block_k - 1 > q_off - window)

    @pl.when(in_band)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kv_pos = k_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kv_pos <= q_pos)
        if window:
            mask = jnp.logical_and(mask, kv_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (alpha * acc_ref[...]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_fwd_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                             causal: bool = True, window: int = 0,
                             block_q: int = 256, block_k: int = 256,
                             interpret: bool = False):
    """q (B, H, Sq, D); k, v (B, Hkv, Skv, D).
    Returns (o (B, H, Sq, D), lse (B, H, Sq, 1)) — lse feeds backward."""
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    n_kv = Skv // bk
    grid = (B, H, Sq // bq, n_kv)
    scale = D ** -0.5

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=bq, block_k=bk, n_kv=n_kv,
        causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g_=g: (b, h // g_, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g_=g: (b, h // g_, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
                   jax.ShapeDtypeStruct((B, H, Sq, 1), jnp.float32)],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max m
            pltpu.VMEM((bq, 1), jnp.float32),      # running denom l
            pltpu.VMEM((bq, D), jnp.float32),      # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flash_attention_bhsd(q, k, v, *, causal=True, window=0, block_q=256,
                         block_k=256, interpret=False) -> jax.Array:
    o, _ = flash_attention_fwd_bhsd(q, k, v, causal=causal, window=window,
                                    block_q=block_q, block_k=block_k,
                                    interpret=interpret)
    return o
