"""Pallas TPU flash-attention backward kernels.

Standard flash backward (Dao 2022), adapted to the TPU grid model:
residuals are (q, k, v, o, lse); ``delta = rowsum(do ∘ o)`` is precomputed
in jnp (cheap elementwise pass).  Two kernels:

* ``dq``  — grid (B, H, Sq/bq, Skv/bk), kv sequential, accumulating dq in
            VMEM scratch;
* ``dkv`` — grid (B, Hkv, Skv/bk, Sq/bq), q sequential, accumulating
            dk/dv in VMEM scratch summed over the GQA group.

Scores are recomputed from (q, k, lse) inside VMEM — they never touch HBM,
which is the whole point: training-time attention HBM traffic drops from
O(S²) to O(S·D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -2.0 ** 30


def _band(q_off, k_off, bq, bk, causal, window):
    in_band = True
    if causal:
        in_band = jnp.logical_and(in_band, k_off <= q_off + bq - 1)
    if window:
        in_band = jnp.logical_and(in_band, k_off + bk - 1 > q_off - window)
    return in_band


def _mask(s, q_off, k_off, causal, window):
    q_pos = q_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kv_pos = k_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = jnp.logical_and(mask, kv_pos <= q_pos)
    if window:
        mask = jnp.logical_and(mask, kv_pos > q_pos - window)
    return mask


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, block_q, block_k, n_kv, causal, window):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_off = qi * block_q
    k_off = ki * block_k

    @pl.when(_band(q_off, k_off, block_q, block_k, causal, window))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)                # (bq, 1)
        delta = delta_ref[0, 0].astype(jnp.float32)            # (bq, 1)
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _mask(s, q_off, k_off, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        acc_ref[...] += scale * jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _finish():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                scale, block_q, block_k, n_q, n_g, causal, window):
    ki = pl.program_id(2)
    step = pl.program_id(3)            # enumerates (g, qi) pairs
    qi = step % n_q

    @pl.when(step == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_off = qi * block_q
    k_off = ki * block_k

    @pl.when(_band(q_off, k_off, block_q, block_k, causal, window))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)
        delta = delta_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _mask(s, q_off, k_off, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dk_acc[...] += scale * jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(step == n_g * n_q - 1)
    def _finish():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_bwd_bhsd(q, k, v, o, lse, do, *, causal=True, window=0,
                             block_q=256, block_k=256, interpret=False):
    """q/do/o (B,H,Sq,D); k,v (B,Hkv,Skv,D); lse (B,H,Sq).
    Returns (dq, dk, dv) in the input layouts."""
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    n_q, n_kv = Sq // bq, Skv // bk
    scale = D ** -0.5

    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                                   # (B,H,Sq)
    lse4 = lse[..., None]                                      # (B,H,Sq,1)
    delta4 = delta[..., None]

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, block_q=bq, block_k=bk,
                          n_kv=n_kv, causal=causal, window=window),
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g_=g: (b, h // g_, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g_=g: (b, h // g_, j, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse4, delta4)

    # dk/dv: one kv-head per grid row; the sequential axis enumerates the
    # g query-heads of the GQA group × the q blocks
    def hq(b, hkv, j, step, g_=g, n_q_=n_q):
        return (b, hkv * g_ + step // n_q_, step % n_q_, 0)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, block_q=bq, block_k=bk,
                          n_q=n_q, n_g=g, causal=causal, window=window),
        grid=(B, Hkv, n_kv, g * n_q),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), hq),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, s: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, s: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bq, D), hq),
            pl.BlockSpec((1, 1, bq, 1), hq),
            pl.BlockSpec((1, 1, bq, 1), hq),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, s: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, s: (b, h, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, Hkv, Skv, D), k.dtype),
                   jax.ShapeDtypeStruct((B, Hkv, Skv, D), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse4, delta4)
    return dq, dk, dv
