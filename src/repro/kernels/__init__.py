"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU; TPU is the target):

* ``calibrated_update`` — fused FedaGrac local step x ← x − η(g + λc)
* ``flash_attention``   — blocked online-softmax attention, forward +
                          custom_vjp backward kernels (training path)
* ``ssd_scan``          — chunked Mamba2 SSD scan, state carried in VMEM
                          across the sequential chunk grid axis
"""
from repro.kernels.calibrated_update.ops import calibrated_update_tree
from repro.kernels.flash_attention.ops import (flash_attention,
                                               flash_attention_diff)
from repro.kernels.ssd_scan.ops import ssd_scan

__all__ = ["calibrated_update_tree", "flash_attention",
           "flash_attention_diff", "ssd_scan"]
