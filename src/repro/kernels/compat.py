"""Pallas API compatibility shims.

``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` →
``CompilerParams`` around jax 0.5; the kernels are written against the new
name and this shim keeps them running on the older toolchain baked into the
container.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
