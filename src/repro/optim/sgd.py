"""Functional SGD (+momentum, +weight decay) — the paper's local optimizer."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class SGDState(NamedTuple):
    momentum: PyTree


def sgd_init(params: PyTree, momentum: float = 0.0) -> SGDState:
    if momentum == 0.0:
        return SGDState(momentum=None)
    return SGDState(momentum=jax.tree.map(jnp.zeros_like, params))


def sgd_update(grads: PyTree, state: SGDState, params: PyTree, *,
               lr: float, momentum: float = 0.0,
               weight_decay: float = 0.0) -> tuple[PyTree, SGDState]:
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    if momentum and state.momentum is not None:
        new_m = jax.tree.map(lambda m, g: momentum * m + g,
                             state.momentum, grads)
        updates = jax.tree.map(lambda m: -lr * m, new_m)
        return updates, SGDState(momentum=new_m)
    updates = jax.tree.map(lambda g: -lr * g, grads)
    return updates, state


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
