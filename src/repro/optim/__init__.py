from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedules import (constant, cosine, lambda_increase,
                                   step_decay)
from repro.optim.sgd import SGDState, apply_updates, sgd_init, sgd_update

__all__ = ["AdamWState", "SGDState", "adamw_init", "adamw_update",
           "apply_updates", "constant", "cosine", "lambda_increase",
           "sgd_init", "sgd_update", "step_decay"]
