"""Learning-rate and calibration-rate schedules.

The paper's Figure 2b "Increase" schedule steps λ upward over rounds
(0.1 → 0.5 → 1.0); we expose it as ``lambda_increase``.  η schedules cover
the constant grids used in §6 plus warmup-cosine for the LM examples."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine(base: float, total_steps: int, warmup: int = 0,
           floor: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1),
                        0.0, 1.0)
        cos = floor + 0.5 * (base - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn


def step_decay(base: float, boundaries: tuple[int, ...],
               factors: tuple[float, ...]):
    def fn(step):
        v = jnp.asarray(base, jnp.float32)
        for b, f in zip(boundaries, factors):
            v = jnp.where(step >= b, base * f, v)
        return v
    return fn


def lambda_increase(boundaries: tuple[int, ...] = (50, 150),
                    values: tuple[float, ...] = (0.1, 0.5, 1.0)):
    """Paper Fig. 2b: λ = 0.1 for t<50, 0.5 for t<150, then 1.0."""
    assert len(values) == len(boundaries) + 1

    def fn(t):
        v = jnp.asarray(values[0], jnp.float32)
        for b, nxt in zip(boundaries, values[1:]):
            v = jnp.where(t >= b, nxt, v)
        return v
    return fn
