"""Functional AdamW — used by the centralized-training examples and the
server-side optimizer variant (FedOpt-style server Adam is a beyond-paper
extension recorded in EXPERIMENTS.md)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def adamw_init(params: PyTree) -> AdamWState:
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())


def adamw_update(grads: PyTree, state: AdamWState, params: PyTree, *,
                 lr: float, b1: float = 0.9, b2: float = 0.999,
                 eps: float = 1e-8,
                 weight_decay: float = 0.0) -> tuple[PyTree, AdamWState]:
    step = state.step + 1
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, gf)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, gf)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(m, v, p):
        mhat = m / bc1
        vhat = v / bc2
        u = -lr * (mhat / (jnp.sqrt(vhat) + eps)
                   + weight_decay * p.astype(jnp.float32))
        return u.astype(p.dtype)

    updates = jax.tree.map(upd, mu, nu, params)
    return updates, AdamWState(step=step, mu=mu, nu=nu)
